"""Production mesh construction (pure function — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (data, model); 2x16x16 = 512 chips multi-pod.

    The ``pod`` axis joins ICI-connected slices over DCN and is used only for
    data parallelism / hierarchical gradient reduction, so DCN latency hides
    behind per-layer compute."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary logical meshes for tests / elastic restarts."""
    return jax.make_mesh(tuple(shape), tuple(axes))
