"""Training launcher: real devices (or forced-host meshes for rehearsal).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --smoke \
      --steps 50 --mesh 2x4
"""
import argparse

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import models
from repro.configs import registry
from repro.data.pipeline import DataConfig, batch_for_model
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh
from repro.runtime.fault_tolerance import (
    HeartbeatTracker, LoopConfig, PreemptionHandler, run_training_loop,
)
from repro.train.optimizer import OptimizerConfig, init_state
from repro.train.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", default="", help="e.g. 2x4 (data x model); default single device")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = registry.get_smoke(args.arch) if args.smoke else registry.get_config(args.arch)
    params = models.init(jax.random.PRNGKey(0), cfg)
    opt = init_state(params)
    opt_cfg = OptimizerConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                              total_steps=args.steps)
    step = make_train_step(cfg, opt_cfg, microbatches=args.microbatches)

    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        mesh = make_mesh(shape, ("data", "model")[: len(shape)])
        pspecs = shd.param_specs(params, cfg, mode="train")
        ospecs = shd.opt_state_specs(params, cfg)
        nps = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                           is_leaf=lambda x: isinstance(x, P))
        nos = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs,
                           is_leaf=lambda x: isinstance(x, P))
        params = jax.tree.map(jax.device_put, params, nps)
        opt = jax.tree.map(jax.device_put, opt, nos)
        step = jax.jit(step, in_shardings=(nps, nos, NamedSharding(mesh, P("data", None))),
                       out_shardings=(nps, nos, None))
    else:
        step = jax.jit(step)

    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                      global_batch=args.global_batch,
                      num_hosts=jax.process_count(), host_id=jax.process_index())

    def batch_fn(i):
        return {k: jnp.asarray(v) for k, v in batch_for_model(data, cfg, i).items()}

    tracker = HeartbeatTracker()
    state, stopped = run_training_loop(
        step, (params, opt), batch_fn, args.ckpt,
        LoopConfig(total_steps=args.steps, checkpoint_every=max(args.steps // 5, 1)),
        tracker=tracker, preemption=PreemptionHandler(),
        on_metrics=lambda s, m: (s % 10 == 0) and print(
            f"step {s}: loss {float(m['loss']):.4f} lr {float(m['lr']):.2e}"),
    )
    print(f"done at step {stopped}; checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
