import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this AOT-compiles the production step function against
ShapeDtypeStruct inputs (no allocation), then records:

* ``memory_analysis()``  — per-device bytes (proves the cell fits),
* ``cost_analysis()``    — HLO FLOPs / bytes for the roofline,
* collective bytes       — parsed from the optimized (post-SPMD) HLO:
  all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
  result sizes, i.e. per-device collective traffic per step.

Results land in ``benchmarks/_cache/dryrun/<arch>__<shape>__<mesh>.json``;
``benchmarks/roofline.py`` and EXPERIMENTS.md read from that cache.

Usage:
  python -m repro.launch.dryrun --arch stablelm-12b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --mesh both
"""
import argparse
import json
import pathlib
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import SHAPES, SHAPES_BY_NAME, cell_applicable
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.serve.serve_step import make_serve_step
from repro.train.optimizer import init_state
from repro.train.train_step import make_prefill_step, make_train_step

DEFAULT_OUT = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "_cache" / "dryrun"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "u4": 1, "s4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_SHAPE_RE = re.compile(r"\b(pred|bf16|f16|f32|f64|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str):
    """Sum per-device result bytes of every collective op, by op kind."""
    out = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*(\([^)]*\)|\S+)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)", stripped)
        if not m:
            continue
        kind = m.group(2)
        result_part = stripped[: m.end(1)]
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(result_part):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] += nbytes
        count[kind] += 1
    return out, count


def _mem_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    keys = (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes", "peak_memory_in_bytes",
    )
    return {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}


def _cost_dict(compiled):
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items() if isinstance(v, (int, float))}


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def lower_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: pathlib.Path,
               resume: bool = True, act_constraints: bool = False, tag: str = ""):
    mesh_name = ("2x16x16" if multi_pod else "16x16") + tag
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
    hlo_path = out_dir / f"{arch}__{shape_name}__{mesh_name}.hlo.gz"
    if resume and out_path.exists() and hlo_path.exists():
        rec = json.loads(out_path.read_text())
        if rec.get("ok"):
            print(f"[skip] {out_path.name} (cached)")
            return rec

    cfg = registry.get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        raise SystemExit(f"inapplicable cell: {why}")

    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "chips": int(mesh.size), "ok": False,
        "act_constraints": act_constraints,
    }
    t0 = time.time()
    import contextlib
    ctx = mesh if act_constraints else contextlib.nullcontext()  # `with mesh:` enables P-based constraints
    if act_constraints:
        shd.set_activation_policy(dp=shd.data_axes(multi_pod), tp="model",
                                  tp_size=mesh.shape["model"])
    if os.environ.get("REPRO_KV_WRITE_MODE"):
        import repro.models.paged_global as _pg
        _pg.WRITE_MODE = os.environ["REPRO_KV_WRITE_MODE"]
        rec["kv_write_mode"] = _pg.WRITE_MODE
    try:
      with ctx:
          if shape.lowers_serve_step:
              n_part = 1
              for ax in (shd.serve_partition_axes(shape, multi_pod=multi_pod),):
                  axes = ax if isinstance(ax, tuple) else (ax,)
                  for a in axes:
                      n_part *= mesh.shape[a]
              specs = registry.input_specs(cfg, shape, num_partitions=n_part)
              aparams = registry.abstract_params(cfg)
              pspecs = shd.param_specs(aparams, cfg, mode="serve", multi_pod=multi_pod)
              ispecs = shd.serve_input_specs(cfg, shape, multi_pod=multi_pod)
              ospec_logits, ospec_state = shd.serve_output_specs(cfg, shape, multi_pod=multi_pod)
              step = make_serve_step(cfg, kernel_mode="reference")
              jitted = jax.jit(
                  step,
                  in_shardings=(_named(mesh, pspecs), _named(mesh, ispecs)),
                  out_shardings=(NamedSharding(mesh, ospec_logits), _named(mesh, ospec_state)),
                  donate_argnums=(1,),
              )
              lowered = jitted.lower(aparams, specs)
          elif shape.kind == "prefill":
              specs = registry.input_specs(cfg, shape)
              aparams = registry.abstract_params(cfg)
              pspecs = shd.param_specs(aparams, cfg, mode="train", multi_pod=multi_pod)
              bspecs = shd.batch_specs(cfg, shape, multi_pod=multi_pod)
              dp = shd.data_axes(multi_pod)
              step = make_prefill_step(cfg, kernel_mode="reference")
              jitted = jax.jit(
                  step,
                  in_shardings=(_named(mesh, pspecs), _named(mesh, bspecs)),
                  out_shardings=NamedSharding(mesh, P(dp, "model")),
              )
              lowered = jitted.lower(aparams, specs)
          else:  # train
              specs = registry.input_specs(cfg, shape)
              aparams = registry.abstract_params(cfg)
              aopt = jax.eval_shape(init_state, aparams)
              pspecs = shd.param_specs(aparams, cfg, mode="train", multi_pod=multi_pod)
              ospecs = shd.opt_state_specs(aparams, cfg, multi_pod=multi_pod)
              bspecs = shd.batch_specs(cfg, shape, multi_pod=multi_pod)
              step = make_train_step(cfg, kernel_mode="reference")
              mspec = {"loss": P(), "grad_norm": P(), "lr": P()}
              jitted = jax.jit(
                  step,
                  in_shardings=(_named(mesh, pspecs), _named(mesh, ospecs), _named(mesh, bspecs)),
                  out_shardings=(_named(mesh, pspecs), _named(mesh, ospecs), _named(mesh, mspec)),
                  donate_argnums=(0, 1),
              )
              lowered = jitted.lower(aparams, aopt, specs)
          rec["lower_s"] = round(time.time() - t0, 1)

          t1 = time.time()
          compiled = lowered.compile()
          rec["compile_s"] = round(time.time() - t1, 1)

          rec["memory"] = _mem_dict(compiled)
          rec["cost"] = _cost_dict(compiled)
          hlo_text = compiled.as_text()
          coll, coll_n = collective_bytes(hlo_text)
          rec["collective_bytes"] = coll
          rec["collective_count"] = coll_n
          # Archive the optimized HLO for offline analysis (loop-aware
          # collective accounting, hillclimb diffs) — benchmarks/roofline.py.
          import gzip
          out_dir.mkdir(parents=True, exist_ok=True)
          with gzip.open(out_dir / f"{arch}__{shape_name}__{mesh_name}.hlo.gz", "wt") as f:
              f.write(hlo_text)
          rec["input_bytes"] = int(sum(
              v.size * v.dtype.itemsize for v in jax.tree.leaves(specs)
          ))
          rec["param_count"] = int(sum(x.size for x in jax.tree.leaves(aparams)))
          rec["ok"] = True
          print(f"[ok] {arch} x {shape_name} x {mesh_name}: "
                f"lower={rec['lower_s']}s compile={rec['compile_s']}s "
                f"flops={rec['cost'].get('flops', 0):.3g} "
                f"coll={sum(coll.values())/2**20:.1f}MiB")
    except Exception as e:  # noqa: BLE001 — recorded, not swallowed
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {arch} x {shape_name} x {mesh_name}: {rec['error']}")
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    shd.clear_activation_policy()
    jax.clear_caches()  # keep the 80-cell sweep's RSS bounded
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="16x16", choices=["16x16", "2x16x16", "both"])
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--act-constraints", action="store_true",
                    help="perf iteration: explicit activation sharding")
    ap.add_argument("--tag", default="", help="suffix for output filenames")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    archs = registry.ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.mesh == "both" else [args.mesh == "2x16x16"]

    n_ok = n_fail = 0
    for arch in archs:
        cfg = registry.get_config(arch)
        for sname in shapes:
            ok, _ = cell_applicable(cfg, SHAPES_BY_NAME[sname])
            if not ok:
                continue
            for mp in meshes:
                rec = lower_cell(arch, sname, mp, out_dir, resume=not args.no_resume,
                                 act_constraints=args.act_constraints, tag=args.tag)
                n_ok += int(rec.get("ok", False))
                n_fail += int(not rec.get("ok", False))
    print(f"done: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
