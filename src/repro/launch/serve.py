"""Serving launcher: the SPARTA paged engine on a smoke config.

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-12b \
      --requests 8 --max-new 16
"""
import argparse
import time

import jax
import numpy as np

from repro import models
from repro.configs import registry
from repro.configs.base import ModelConfig
from repro.serve.engine import SpartaEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-12b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--partitions", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    base = registry.get_smoke(args.arch).__dict__.copy()
    base.update(dtype="float32", kv_page_size=8)
    cfg = ModelConfig(**base)
    if cfg.family not in ("dense", "moe", "vlm"):
        raise SystemExit(f"engine demo supports decoder-only archs, not {cfg.family}")
    params = models.init(jax.random.PRNGKey(0), cfg)
    eng = SpartaEngine(cfg, params, num_partitions=args.partitions,
                       slots_per_partition=128, max_batch=args.max_batch)
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        eng.submit(list(rng.integers(0, cfg.vocab, rng.integers(4, 16))),
                   max_new_tokens=args.max_new)
    t0 = time.time()
    eng.run_to_completion()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in eng.finished.values())
    print(f"{len(eng.finished)} requests, {toks} tokens, {dt:.1f}s "
          f"({toks/dt:.1f} tok/s single CPU)")
    eng.kv.check_invariants()


if __name__ == "__main__":
    main()
