"""train_step / prefill_step builders (family-agnostic)."""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro import models
from repro.train.optimizer import OptimizerConfig, apply_updates


def make_loss_fn(cfg: ModelConfig, *, kernel_mode: str = "reference", remat: bool = True):
    def loss_fn(params, batch):
        return models.loss_fn(params, batch, cfg, kernel_mode=kernel_mode, remat=remat)
    return loss_fn


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: OptimizerConfig = OptimizerConfig(),
    *,
    kernel_mode: str = "reference",
    remat: bool = True,
    microbatches: int = 1,
    compress_grads: Callable | None = None,
) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatches`` > 1 accumulates gradients over the leading batch split
    (sequential scan — overlaps with the reduce via XLA scheduling).
    ``compress_grads`` optionally transforms the gradient pytree before the
    optimizer (e.g. top-k + error feedback across pods)."""
    loss_fn = make_loss_fn(cfg, kernel_mode=kernel_mode, remat=remat)
    vg = jax.value_and_grad(loss_fn)

    def step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = vg(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_fn(carry, mb_i):
                loss_acc, g_acc = carry
                loss_i, g_i = vg(params, mb_i)
                return (loss_acc + loss_i, jax.tree.map(jnp.add, g_acc, g_i)), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_fn, (jnp.float32(0.0), zeros), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        if compress_grads is not None:
            grads = compress_grads(grads)
        params, opt_state, om = apply_updates(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return step


def make_prefill_step(cfg: ModelConfig, *, kernel_mode: str = "reference") -> Callable:
    """Inference prefill: logits for the whole prompt (the 32k-prefill shape).

    Dense/MoE/VLM/enc-dec run the training forward without loss/grad; the
    serving engine variant that also emits page-layout KV lives in
    ``repro.models.transformer.prefill_with_kv``."""
    def step(params, batch):
        logits, _ = models.forward(params, batch, cfg, kernel_mode=kernel_mode, remat=True)
        return logits[:, -1]  # next-token logits
    return step
