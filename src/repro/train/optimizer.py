"""AdamW with cosine schedule and global-norm clipping (no optax on box).

Optimizer state lives in the same sharding as the parameters (the FSDP
PartitionSpecs from ``repro.distributed.sharding``), i.e. ZeRO-style: every
device holds only its shard of (m, v).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_state(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply_updates(params, grads, state, cfg: OptimizerConfig):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = schedule(cfg, step.astype(jnp.float32))
    b1, b2 = cfg.betas
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * gf * gf
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm, "lr": lr}
