"""Atomic, shardable, reshardable checkpoints (no orbax on box).

Layout::

    <root>/step_000123.tmp-<nonce>/   (written)
        manifest.json                 {leaf path -> file, shape, dtype}
        <leaf>.npy ...
    <root>/step_000123/               (atomic rename = commit)

Guarantees:
* **Atomicity** — readers only ever see fully-written checkpoints (rename is
  the commit point; interrupted writes leave only ``.tmp-*`` junk that is
  swept on the next save).
* **Keep-k** — old steps pruned after a successful commit.
* **Elastic restore** — ``restore_resharded`` materialises the tree on ANY
  mesh with fresh PartitionSpecs, so a job can restart on a different device
  count (node failures) without conversion tools.
* **Async** — ``AsyncCheckpointer`` moves serialisation off the step loop.
"""
from __future__ import annotations

import contextlib
import hashlib
import io
import json
import os
import pathlib
import queue
import re
import shutil
import threading
import time
import uuid
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import numpy as np

try:  # POSIX advisory locks; absent on some platforms (file_lock degrades)
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX
    fcntl = None

_SEP = "::"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(k.key) if hasattr(k, "key") else str(k.idx) for k in path
        )
        flat[key] = leaf
    return flat


def _unflatten_into(template, flat: dict):
    def fill(path, leaf):
        key = _SEP.join(
            str(k.key) if hasattr(k, "key") else str(k.idx) for k in path
        )
        arr = flat[key]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = arr.astype(leaf.dtype)
        return arr

    return jax.tree_util.tree_map_with_path(fill, template)


def step_dir(root, step: int) -> pathlib.Path:
    return pathlib.Path(root) / f"step_{step:08d}"


def latest_step(root) -> Optional[int]:
    root = pathlib.Path(root)
    if not root.exists():
        return None
    steps = [
        int(m.group(1))
        for p in root.iterdir()
        if (m := re.fullmatch(r"step_(\d+)", p.name))
    ]
    return max(steps) if steps else None


def save(root, step: int, tree, *, keep: int = 3) -> pathlib.Path:
    """Write checkpoint atomically; prune to the newest ``keep`` steps."""
    root = pathlib.Path(root)
    root.mkdir(parents=True, exist_ok=True)
    # Sweep stale partial writes from crashed runs.
    for junk in root.glob("*.tmp-*"):
        shutil.rmtree(junk, ignore_errors=True)

    final = step_dir(root, step)
    tmp = root / f"{final.name}.tmp-{uuid.uuid4().hex[:8]}"
    tmp.mkdir()
    manifest = {}
    for key, leaf in _flatten(tree).items():
        arr = np.asarray(leaf)
        fname = f"{abs(hash(key)) & 0xFFFFFFFF:08x}_{len(manifest)}.npy"
        np.save(tmp / fname, arr)
        manifest[key] = {"file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    (tmp / "manifest.json").write_text(json.dumps({"step": step, "leaves": manifest}))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)  # commit

    steps = sorted(
        int(re.fullmatch(r"step_(\d+)", p.name).group(1))
        for p in root.iterdir()
        if re.fullmatch(r"step_(\d+)", p.name)
    )
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(step_dir(root, s), ignore_errors=True)
    return final


def restore(root, step: Optional[int] = None, template: Any = None):
    """Load a checkpoint as numpy arrays (or into ``template``'s structure)."""
    root = pathlib.Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = step_dir(root, step)
    manifest = json.loads((d / "manifest.json").read_text())["leaves"]
    flat = {k: np.load(d / meta["file"]) for k, meta in manifest.items()}
    if template is None:
        return flat, step
    return _unflatten_into(template, flat), step


def restore_resharded(root, template, mesh, specs, step: Optional[int] = None):
    """Elastic restore: place every leaf on ``mesh`` with ``specs`` —
    the mesh may differ arbitrarily from the one that saved."""
    from jax.sharding import NamedSharding

    tree, step = restore(root, step, template)
    def put(leaf, spec):
        return jax.device_put(leaf, NamedSharding(mesh, spec))
    return jax.tree.map(put, tree, specs), step


# ---------------------------------------------------------------------------
# Single-file checksummed blobs (sweep-orchestrator chunk checkpoints).
#
# Format: one ASCII header line `repro-ckpt-v1 sha256:<hex>\n` followed by an
# npz payload whose digest the header pins.  Arrays plus a JSON meta dict ride
# in one file so a chunk checkpoint commits (or doesn't) as a unit; the
# directory layout above stays reserved for model trees.
# ---------------------------------------------------------------------------

BLOB_MAGIC = "repro-ckpt-v1"
_META_KEY = "__meta__"


class CheckpointCorruptError(RuntimeError):
    """A checkpoint blob failed validation (truncated, bit-flipped, or not a
    checkpoint at all).  Deliberately NOT silently ignored by resume paths."""


def write_checkpoint_blob(path, arrays: Dict[str, np.ndarray], meta: dict) -> pathlib.Path:
    """Atomically write a checksummed single-file checkpoint.

    Durability contract (mirrors the BENCH_sweep.json history policy): the
    payload is serialised fully in memory, sha256-pinned in the header,
    written to a ``.tmp-<nonce>`` sibling, fsync'd, then ``os.replace``d into
    place (the commit point), and the parent directory is fsync'd so the
    rename itself survives power loss.  Readers therefore only ever see a
    complete blob or no blob.
    """
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    if _META_KEY in arrays:
        raise ValueError(f"array key {_META_KEY!r} is reserved for metadata")
    payload = {k: np.asarray(v) for k, v in arrays.items()}
    payload[_META_KEY] = np.frombuffer(
        json.dumps(meta, sort_keys=True).encode(), dtype=np.uint8
    ).copy()
    buf = io.BytesIO()
    np.savez(buf, **payload)
    body = buf.getvalue()
    header = f"{BLOB_MAGIC} sha256:{hashlib.sha256(body).hexdigest()}\n".encode()

    tmp = path.with_name(f"{path.name}.tmp-{uuid.uuid4().hex[:8]}")
    with open(tmp, "wb") as f:
        f.write(header)
        f.write(body)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)  # commit
    try:
        dfd = os.open(str(path.parent), os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
    except OSError:  # pragma: no cover - e.g. directories on exotic fs
        pass
    return path


def read_checkpoint_blob(path) -> Tuple[Dict[str, np.ndarray], dict]:
    """Load and validate a blob written by :func:`write_checkpoint_blob`.

    Raises :class:`CheckpointCorruptError` (with a clear, actionable message)
    if the header is missing/foreign or the payload digest does not match —
    a truncated or bit-flipped checkpoint is *refused*, never resumed.
    """
    path = pathlib.Path(path)
    data = path.read_bytes()
    nl = data.find(b"\n")
    refusal = (
        "refusing to resume from it — delete it deliberately (or start "
        "without --resume) to begin a fresh run"
    )
    if nl < 0:
        raise CheckpointCorruptError(
            f"checkpoint {path} has no header line (truncated?); {refusal}")
    try:
        magic, digest_field = data[:nl].decode("ascii").split(" ", 1)
    except (UnicodeDecodeError, ValueError):
        raise CheckpointCorruptError(
            f"checkpoint {path} header is unparseable; {refusal}") from None
    if magic != BLOB_MAGIC or not digest_field.startswith("sha256:"):
        raise CheckpointCorruptError(
            f"checkpoint {path} is not a {BLOB_MAGIC} blob "
            f"(header {data[:nl][:64]!r}); {refusal}")
    body = data[nl + 1:]
    actual = hashlib.sha256(body).hexdigest()
    expected = digest_field[len("sha256:"):]
    if actual != expected:
        raise CheckpointCorruptError(
            f"checkpoint {path} failed its content checksum "
            f"(expected sha256:{expected[:12]}…, got sha256:{actual[:12]}… — "
            f"truncated or bit-flipped); {refusal}")
    try:
        with np.load(io.BytesIO(body), allow_pickle=False) as npz:
            meta = json.loads(bytes(npz[_META_KEY]).decode())
            arrays = {k: npz[k] for k in npz.files if k != _META_KEY}
    except CheckpointCorruptError:
        raise
    except Exception as e:
        raise CheckpointCorruptError(
            f"checkpoint {path} payload is undecodable ({e}); {refusal}") from e
    return arrays, meta


# ---------------------------------------------------------------------------
# Advisory file locks + shard lease files (scheduler work-queue primitives).
#
# A lease is a tiny JSON file that marks a shard as claimed by one worker.
# Ownership is advisory but race-free: every acquire/refresh/release takes an
# flock on a sibling `.lck` file, so two workers racing for the same shard
# serialise and exactly one wins.  A lease with no heartbeat for longer than
# its TTL is *stale* and may be broken by a new claimant — that is how work
# owned by a SIGKILLed worker gets re-dispatched.
# ---------------------------------------------------------------------------

LEASE_FORMAT = "repro-lease-v1"


class LeaseHeld(RuntimeError):
    """The shard is already claimed under a fresh (non-stale) lease."""


@contextlib.contextmanager
def file_lock(path, *, timeout_s: float = 30.0, poll_s: float = 0.02) -> Iterator[None]:
    """Advisory exclusive lock on ``path`` (created if absent).

    Blocks up to ``timeout_s`` then raises ``TimeoutError``.  Uses
    ``fcntl.flock`` where available; degrades to a no-op on platforms
    without it (single-writer environments).
    """
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    if fcntl is None:  # pragma: no cover - non-POSIX
        yield
        return
    fd = os.open(str(path), os.O_RDWR | os.O_CREAT, 0o644)
    try:
        deadline = time.monotonic() + timeout_s
        while True:
            try:
                fcntl.flock(fd, fcntl.LOCK_EX | fcntl.LOCK_NB)
                break
            except OSError:
                if time.monotonic() >= deadline:
                    raise TimeoutError(f"could not lock {path} within {timeout_s}s")
                time.sleep(poll_s)
        yield
    finally:
        try:
            fcntl.flock(fd, fcntl.LOCK_UN)
        finally:
            os.close(fd)


def _lease_lock_path(path) -> pathlib.Path:
    path = pathlib.Path(path)
    return path.with_name(path.name + ".lck")


def read_lease(path) -> Optional[dict]:
    """Return the lease dict, or None if absent/unreadable (a torn lease is
    treated as stale-able junk, not an error)."""
    try:
        rec = json.loads(pathlib.Path(path).read_text())
    except (OSError, ValueError):
        return None
    return rec if isinstance(rec, dict) and rec.get("format") == LEASE_FORMAT else None


def lease_is_stale(lease: Optional[dict], *, now: Optional[float] = None) -> bool:
    """A lease is stale once its last heartbeat is older than its TTL.
    Unreadable/foreign leases are stale by definition."""
    if lease is None:
        return True
    now = time.time() if now is None else now
    try:
        return (now - float(lease["ts"])) > float(lease["ttl_s"])
    except (KeyError, TypeError, ValueError):
        return True


def _write_lease_locked(path, owner: str, *, ttl_s: float, **extra) -> dict:
    rec = {
        "format": LEASE_FORMAT,
        "owner": owner,
        "ts": time.time(),
        "ttl_s": float(ttl_s),
        **extra,
    }
    path = pathlib.Path(path)
    tmp = path.with_name(f"{path.name}.tmp-{uuid.uuid4().hex[:8]}")
    tmp.write_text(json.dumps(rec, sort_keys=True))
    os.replace(tmp, path)
    return rec


def acquire_lease(path, owner: str, *, ttl_s: float, **extra) -> dict:
    """Claim the shard lease at ``path`` for ``owner``.

    Succeeds if no lease exists, the existing lease is stale (broken and
    taken over — the dead worker's claim), or ``owner`` already holds it
    (re-entrant refresh).  Raises :class:`LeaseHeld` otherwise.
    """
    path = pathlib.Path(path)
    with file_lock(_lease_lock_path(path)):
        cur = read_lease(path)
        if cur is not None and cur.get("owner") != owner and not lease_is_stale(cur):
            raise LeaseHeld(
                f"shard lease {path.name} is held by {cur.get('owner')!r} "
                f"(heartbeat {time.time() - float(cur.get('ts', 0)):.1f}s ago, "
                f"ttl {cur.get('ttl_s')}s)")
        return _write_lease_locked(path, owner, ttl_s=ttl_s, **extra)


def refresh_lease(path, owner: str, *, ttl_s: float, **extra) -> bool:
    """Heartbeat: re-stamp ``ts`` if ``owner`` still holds the lease.
    Returns False (without writing) if the lease was lost — broken by
    another claimant after this owner stalled past the TTL."""
    path = pathlib.Path(path)
    with file_lock(_lease_lock_path(path)):
        cur = read_lease(path)
        if cur is None or cur.get("owner") != owner:
            return False
        _write_lease_locked(path, owner, ttl_s=ttl_s, **extra)
        return True


def release_lease(path, owner: str) -> bool:
    """Delete the lease if ``owner`` holds it (and sweep the lock sibling).
    Returns True if a lease was removed."""
    path = pathlib.Path(path)
    with file_lock(_lease_lock_path(path)):
        cur = read_lease(path)
        if cur is not None and cur.get("owner") == owner:
            with contextlib.suppress(OSError):
                path.unlink()
            return True
        return False


class AsyncCheckpointer:
    """Background-thread writer: ``submit`` returns immediately; ``wait``
    joins outstanding writes (call before exit / preemption)."""

    def __init__(self, root, *, keep: int = 3):
        self.root = root
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[BaseException] = None
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree = item
            try:
                save(self.root, step, tree, keep=self.keep)
            except BaseException as e:  # surfaced on wait()
                self._err = e
            finally:
                self._q.task_done()

    def submit(self, step: int, tree):
        # Pull to host first so the device buffers can be donated/reused.
        host_tree = jax.tree.map(np.asarray, tree)
        self._q.put((step, host_tree))

    def wait(self):
        self._q.join()
        if self._err is not None:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._t.join()
