"""RWKV6 "Finch" — attention-free LM with data-dependent per-channel decay.

Per §Arch-applicability (DESIGN.md): RWKV6 has no KV cache, so the SPARTA
paged-KV serving technique is inapplicable; decode carries O(1) recurrent
state.  The arch still runs every shape (including long_500k, which is the
whole point of an SSM) without the technique.

Block = time-mix (the rwkv6_scan kernel) + channel-mix, both with token
shift.  The decay LoRA follows the paper: w = exp(-exp(w_base + tanh(x A) B)).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.rwkv6_scan import rwkv6_decode_step, rwkv6_scan
from repro.models.layers import (
    Params, apply_norm, dense_init, dtype_of, embed_init, norm_params,
)

LORA_RANK = 64


def _heads(cfg: ModelConfig) -> Tuple[int, int]:
    n = cfg.ssm_headdim  # head size (64)
    assert cfg.d_model % n == 0
    return cfg.d_model // n, n


def layer_params(key, cfg: ModelConfig, dtype) -> Params:
    D, F = cfg.d_model, cfg.d_ff
    H, N = _heads(cfg)
    ks = jax.random.split(key, 12)
    return {
        "ln1": norm_params(ks[0], D, cfg.norm),
        "ln2": norm_params(ks[1], D, cfg.norm),
        "tm": {
            "mu": jnp.full((5, D), 0.5, jnp.float32),  # r, k, v, w, g shifts
            "wr": dense_init(ks[2], D, D, dtype),
            "wk": dense_init(ks[3], D, D, dtype),
            "wv": dense_init(ks[4], D, D, dtype),
            "wg": dense_init(ks[5], D, D, dtype),
            "w_base": jnp.full((D,), -1.0, jnp.float32),
            "w_lora_a": dense_init(ks[6], D, LORA_RANK, dtype),
            "w_lora_b": (dense_init(ks[7], LORA_RANK, D, jnp.float32) * 0.1),
            "u": jnp.zeros((H, N), jnp.float32),
            "head_norm": jnp.zeros((D,), jnp.float32),
            "wo": dense_init(ks[8], D, D, dtype),
        },
        "cm": {
            "mu": jnp.full((2, D), 0.5, jnp.float32),  # k, r shifts
            "wk": dense_init(ks[9], D, F, dtype),
            "wv": dense_init(ks[10], F, D, dtype),
            "wr": dense_init(ks[11], D, D, dtype),
        },
    }


def init(key, cfg: ModelConfig) -> Params:
    dtype = dtype_of(cfg.dtype)
    k_emb, k_layers, k_fin, k_head = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    return {
        "embed": embed_init(k_emb, cfg.vocab, cfg.d_model, dtype),
        "layers": jax.vmap(lambda k: layer_params(k, cfg, dtype))(layer_keys),
        "final_norm": norm_params(k_fin, cfg.d_model, cfg.norm),
        "lm_head": dense_init(k_head, cfg.d_model, cfg.vocab, dtype),
    }


def _shift(x: jnp.ndarray, last: jnp.ndarray | None = None) -> jnp.ndarray:
    """Token shift: previous token's activation (zeros / carry at t=0)."""
    if last is None:
        return jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
    return jnp.concatenate([last[:, None, :].astype(x.dtype), x[:, :-1]], axis=1)


def _decay(tm: Params, xw: jnp.ndarray) -> jnp.ndarray:
    lora = jnp.tanh(xw @ tm["w_lora_a"]).astype(jnp.float32) @ tm["w_lora_b"]
    return jnp.exp(-jnp.exp(tm["w_base"] + lora))  # (0, 1), per channel


def _time_mix(tm: Params, x: jnp.ndarray, cfg: ModelConfig, kernel_mode: str,
              shift_state=None, wkv_state=None):
    B, T, D = x.shape
    H, N = _heads(cfg)
    xs = _shift(x, shift_state)
    mu = tm["mu"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + mu[i] * (xs - x) for i in range(5))
    r = (xr @ tm["wr"]).reshape(B, T, H, N).transpose(0, 2, 1, 3)
    k = (xk @ tm["wk"]).reshape(B, T, H, N).transpose(0, 2, 1, 3)
    v = (xv @ tm["wv"]).reshape(B, T, H, N).transpose(0, 2, 1, 3)
    w = _decay(tm, xw).reshape(B, T, H, N).transpose(0, 2, 1, 3)
    g = jax.nn.silu(xg @ tm["wg"])
    if T == 1 and wkv_state is not None:
        o, new_state = rwkv6_decode_step(
            r[:, :, 0], k[:, :, 0], v[:, :, 0], w[:, :, 0], tm["u"], wkv_state
        )
        o = o[:, :, None, :]
    else:
        o, new_state = rwkv6_scan(r, k, v, w.astype(jnp.float32), tm["u"], kernel_mode=kernel_mode)
    o = o.transpose(0, 2, 1, 3).reshape(B, T, D)
    # Per-head normalisation (GroupNorm in the reference implementation).
    o = o.reshape(B, T, H, N)
    o = o * jax.lax.rsqrt(jnp.mean(o.astype(jnp.float32) ** 2, axis=-1, keepdims=True) + 1e-6)
    o = (o.reshape(B, T, D) * (1.0 + tm["head_norm"])).astype(x.dtype)
    out = ((o * g.astype(o.dtype)) @ tm["wo"]).astype(x.dtype)
    return out, x[:, -1, :].astype(jnp.float32), new_state


def _channel_mix(cm: Params, x: jnp.ndarray, shift_state=None):
    xs = _shift(x, shift_state)
    mu = cm["mu"].astype(x.dtype)
    xk = x + mu[0] * (xs - x)
    xr = x + mu[1] * (xs - x)
    k = jnp.square(jax.nn.relu(xk @ cm["wk"]))
    out = (jax.nn.sigmoid(xr @ cm["wr"]) * (k @ cm["wv"])).astype(x.dtype)
    return out, x[:, -1, :].astype(jnp.float32)


def forward(params: Params, tokens: jnp.ndarray, cfg: ModelConfig, *,
            kernel_mode: str = "auto", remat: bool = True):
    x = params["embed"][tokens]

    def block(x, lp):
        h, _, _ = _time_mix(lp["tm"], apply_norm(lp["ln1"], x, cfg.norm), cfg, kernel_mode)
        x = x + h
        h, _ = _channel_mix(lp["cm"], apply_norm(lp["ln2"], x, cfg.norm))
        return x + h, jnp.float32(0.0)

    blk = jax.checkpoint(block) if remat else block
    x, _ = jax.lax.scan(lambda c, lp: blk(c, lp), x, params["layers"])
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x @ params["lm_head"], jnp.float32(0.0)


def forward_hidden(params: Params, tokens: jnp.ndarray, cfg: ModelConfig, *,
                   kernel_mode: str = "auto", remat: bool = True):
    x = params["embed"][tokens]

    def block(x, lp):
        h, _, _ = _time_mix(lp["tm"], apply_norm(lp["ln1"], x, cfg.norm), cfg, kernel_mode)
        x = x + h
        h, _ = _channel_mix(lp["cm"], apply_norm(lp["ln2"], x, cfg.norm))
        return x + h, jnp.float32(0.0)

    blk = jax.checkpoint(block) if remat else block
    x, _ = jax.lax.scan(lambda c, lp: blk(c, lp), x, params["layers"])
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, params["lm_head"], jnp.float32(0.0)


def init_decode_state(cfg: ModelConfig, batch: int):
    H, N = _heads(cfg)
    L, D = cfg.num_layers, cfg.d_model
    return {
        "tm_shift": jnp.zeros((L, batch, D), jnp.float32),
        "cm_shift": jnp.zeros((L, batch, D), jnp.float32),
        "wkv": jnp.zeros((L, batch, H, N, N), jnp.float32),
    }


def decode_step(params: Params, tokens: jnp.ndarray, cfg: ModelConfig, state, *,
                kernel_mode: str = "auto"):
    """O(1) per-token decode — state size is independent of context length."""
    x = params["embed"][tokens][:, None, :]

    def body(x, scanned):
        lp, tm_s, cm_s, wkv_s = scanned
        h, tm_new, wkv_new = _time_mix(
            lp["tm"], apply_norm(lp["ln1"], x, cfg.norm), cfg, kernel_mode,
            shift_state=tm_s, wkv_state=wkv_s,
        )
        x = x + h
        h, cm_new = _channel_mix(lp["cm"], apply_norm(lp["ln2"], x, cfg.norm), shift_state=cm_s)
        return x + h, (tm_new, cm_new, wkv_new)

    x, (tm_s, cm_s, wkv_s) = jax.lax.scan(
        body, x, (params["layers"], state["tm_shift"], state["cm_shift"], state["wkv"])
    )
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = (x @ params["lm_head"])[:, 0]
    return logits, {"tm_shift": tm_s, "cm_shift": cm_s, "wkv": wkv_s}
