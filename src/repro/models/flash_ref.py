"""Memory-efficient (flash-style) attention in pure jnp.

The XLA-compiled counterpart of the Pallas flash kernel: a ``lax.scan`` over
KV blocks with running log-sum-exp statistics, so peak memory is
O(B x H x Tq x block_k) instead of O(Tq x Tk).  This is the ``reference``
execution path used inside training/prefill graphs on CPU and in the
dry-run; it matches ``kernels/flash_attention/ref.py`` exactly (tested), and
the Pallas kernel replaces it on real TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_jnp(
    q: jnp.ndarray,  # [B, Hq, Tq, D]
    k: jnp.ndarray,  # [B, Hkv, Tk, D]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    block_k: int = 512,
) -> jnp.ndarray:
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    G = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (D ** 0.5)
    block_k = min(block_k, Tk)

    nb = -(-Tk // block_k)
    pad = nb * block_k - Tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = k.reshape(B, Hkv, nb, block_k, D).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, Hkv, nb, block_k, D).transpose(2, 0, 1, 3, 4)

    qf = q.astype(jnp.float32).reshape(B, Hkv, G, Tq, D)
    q_pos = (jnp.arange(Tq) + (Tk - Tq))[:, None]          # decode alignment

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, start = xs
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kc.astype(jnp.float32)) * scale
        k_pos = start + jnp.arange(block_k)[None, :]
        mask = k_pos < Tk
        if causal:
            mask = mask & (k_pos <= q_pos)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vc.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, G, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Tq), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, G, Tq, D), jnp.float32)
    starts = jnp.arange(nb) * block_k
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kb, vb, starts))
    safe_l = jnp.where(l > 0, l, 1.0)
    o = (acc / safe_l[..., None]).reshape(B, Hq, Tq, D)
    return o.astype(q.dtype)
