"""Whisper-style encoder-decoder (audio backbone; conv frontend is a stub).

``input_specs()`` supplies precomputed frame embeddings [B, S_enc, D] (the
conv1d+GELU frontend stub per the assignment); the encoder adds sinusoidal
positions and runs bidirectional attention.  The decoder is causal with
cross-attention; decode uses SPARTA-paged self-attention KV plus replicated
(small) cross-attention KV computed once from the encoder output.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import transformer as tfm
from repro.models.layers import (
    Params, apply_norm, dense_init, dtype_of, embed_init, mlp_forward,
    mlp_params, norm_params,
)

MAX_DECODER_POS = 65_536  # learned positions (assignment decodes up to 32k)


def sinusoid_positions(length: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def _enc_layer_params(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "ln1": norm_params(ks[0], cfg.d_model, cfg.norm),
        "attn": attn.attention_params(ks[1], cfg, dtype),
        "ln2": norm_params(ks[2], cfg.d_model, cfg.norm),
        "mlp": mlp_params(ks[3], cfg.d_model, cfg.d_ff, cfg.activation, dtype),
    }


def _dec_layer_params(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 6)
    return {
        "ln1": norm_params(ks[0], cfg.d_model, cfg.norm),
        "self_attn": attn.attention_params(ks[1], cfg, dtype),
        "ln_x": norm_params(ks[2], cfg.d_model, cfg.norm),
        "cross_attn": attn.attention_params(ks[3], cfg, dtype),
        "ln2": norm_params(ks[4], cfg.d_model, cfg.norm),
        "mlp": mlp_params(ks[5], cfg.d_model, cfg.d_ff, cfg.activation, dtype),
    }


def init(key, cfg: ModelConfig) -> Params:
    dtype = dtype_of(cfg.dtype)
    keys = jax.random.split(key, 6)
    enc_keys = jax.random.split(keys[0], cfg.encoder_layers)
    dec_keys = jax.random.split(keys[1], cfg.num_layers)
    return {
        "embed": embed_init(keys[2], cfg.vocab, cfg.d_model, dtype),  # tied output
        "dec_pos": (jax.random.normal(keys[3], (MAX_DECODER_POS, cfg.d_model), jnp.float32) * 0.01).astype(dtype),
        "enc_layers": jax.vmap(lambda k: _enc_layer_params(k, cfg, dtype))(enc_keys),
        "enc_norm": norm_params(keys[4], cfg.d_model, cfg.norm),
        "dec_layers": jax.vmap(lambda k: _dec_layer_params(k, cfg, dtype))(dec_keys),
        "dec_norm": norm_params(keys[5], cfg.d_model, cfg.norm),
    }


def encode(params: Params, frames: jnp.ndarray, cfg: ModelConfig, *,
           kernel_mode: str = "auto", remat: bool = True) -> jnp.ndarray:
    """frames: stub frontend output [B, S, D]."""
    S = frames.shape[1]
    x = frames + sinusoid_positions(S, cfg.d_model).astype(frames.dtype)[None]

    def block(x, lp):
        h = apply_norm(lp["ln1"], x, cfg.norm)
        x = x + attn.attention_forward(lp["attn"], h, cfg, causal=False, kernel_mode=kernel_mode)
        h = apply_norm(lp["ln2"], x, cfg.norm)
        return x + mlp_forward(lp["mlp"], h, cfg.activation), None

    blk = jax.checkpoint(block) if remat else block
    x, _ = jax.lax.scan(lambda c, lp: blk(c, lp), x, params["enc_layers"])
    return apply_norm(params["enc_norm"], x, cfg.norm)


def decode_train(params: Params, enc_out: jnp.ndarray, tokens: jnp.ndarray,
                 cfg: ModelConfig, *, kernel_mode: str = "auto", remat: bool = True):
    B, T = tokens.shape
    x = params["embed"][tokens] + params["dec_pos"][:T][None]

    def block(x, lp):
        h = apply_norm(lp["ln1"], x, cfg.norm)
        x = x + attn.attention_forward(lp["self_attn"], h, cfg, causal=True, kernel_mode=kernel_mode)
        h = apply_norm(lp["ln_x"], x, cfg.norm)
        kv = attn.cross_kv(lp["cross_attn"], enc_out, cfg)
        x = x + attn.attention_forward(
            lp["cross_attn"], h, cfg, causal=False, kv_override=kv, kernel_mode=kernel_mode,
        )
        h = apply_norm(lp["ln2"], x, cfg.norm)
        return x + mlp_forward(lp["mlp"], h, cfg.activation), None

    blk = jax.checkpoint(block) if remat else block
    x, _ = jax.lax.scan(lambda c, lp: blk(c, lp), x, params["dec_layers"])
    x = apply_norm(params["dec_norm"], x, cfg.norm)
    return x @ params["embed"].T


def forward(params: Params, batch, cfg: ModelConfig, *, kernel_mode: str = "auto",
            remat: bool = True):
    """batch: {frames [B,S,D], tokens [B,T]} -> (logits, aux)."""
    enc = encode(params, batch["frames"], cfg, kernel_mode=kernel_mode, remat=remat)
    return decode_train(params, enc, batch["tokens"], cfg, kernel_mode=kernel_mode, remat=remat), jnp.float32(0.0)


def forward_hidden(params: Params, batch, cfg: ModelConfig, *,
                   kernel_mode: str = "auto", remat: bool = True):
    enc = encode(params, batch["frames"], cfg, kernel_mode=kernel_mode, remat=remat)
    tokens = batch["tokens"]
    B, T = tokens.shape
    x = params["embed"][tokens] + params["dec_pos"][:T][None]

    def block(x, lp):
        h = apply_norm(lp["ln1"], x, cfg.norm)
        x = x + attn.attention_forward(lp["self_attn"], h, cfg, causal=True, kernel_mode=kernel_mode)
        h = apply_norm(lp["ln_x"], x, cfg.norm)
        kv = attn.cross_kv(lp["cross_attn"], enc, cfg)
        x = x + attn.attention_forward(
            lp["cross_attn"], h, cfg, causal=False, kv_override=kv, kernel_mode=kernel_mode,
        )
        h = apply_norm(lp["ln2"], x, cfg.norm)
        return x + mlp_forward(lp["mlp"], h, cfg.activation), None

    blk = jax.checkpoint(block) if remat else block
    x, _ = jax.lax.scan(lambda c, lp: blk(c, lp), x, params["dec_layers"])
    x = apply_norm(params["dec_norm"], x, cfg.norm)
    return x, params["embed"].T, jnp.float32(0.0)


def precompute_cross_kv(params: Params, enc_out: jnp.ndarray, cfg: ModelConfig):
    """Per-layer cross-attention KV — computed once per request at prefill."""
    def one(lp):
        k, v = attn.cross_kv(lp["cross_attn"], enc_out, cfg)
        return jnp.stack([k, v])
    kv = jax.lax.map(one, params["dec_layers"])
    return kv[:, 0], kv[:, 1]  # [L, B, S, Hkv, hd] x2


def decode_step(
    params: Params,
    tokens: jnp.ndarray,      # [B]
    cfg: ModelConfig,
    k_pools: jnp.ndarray,     # [L, slots, page, Hkv, hd] paged self-attn KV
    v_pools: jnp.ndarray,
    cross_k: jnp.ndarray,     # [L, B, S_enc, Hkv, hd] replicated cross KV
    cross_v: jnp.ndarray,
    table: jnp.ndarray,
    ctx_len: jnp.ndarray,
    *,
    axis_name=None,
    kernel_mode: str = "auto",
):
    from repro.kernels.flash_attention import flash_attention

    B = tokens.shape[0]
    x = params["embed"][tokens][:, None, :] + params["dec_pos"][ctx_len - 1][:, None, :]

    def body(x, scanned):
        lp, kp, vp, ck, cv = scanned
        # Paged self-attention residual; cross-attention + MLP spliced after.
        x, kp, vp = tfm.decode_block(
            {"ln1": lp["ln1"], "attn": lp["self_attn"]},
            x, cfg, kp, vp, table, ctx_len,
            axis_name=axis_name, kernel_mode=kernel_mode, skip_mlp=True,
        )
        h = apply_norm(lp["ln_x"], x, cfg.norm)
        q = (h @ lp["cross_attn"]["wq"]).reshape(B, 1, cfg.num_heads, cfg.head_dim)
        o = flash_attention(
            q.transpose(0, 2, 1, 3), ck.transpose(0, 2, 1, 3), cv.transpose(0, 2, 1, 3),
            causal=False, kernel_mode=kernel_mode,
        ).transpose(0, 2, 1, 3).reshape(B, 1, cfg.q_dim)
        x = x + o @ lp["cross_attn"]["wo"]
        h = apply_norm(lp["ln2"], x, cfg.norm)
        x = x + mlp_forward(lp["mlp"], h, cfg.activation)
        return x, (kp, vp)

    x, (k_pools, v_pools) = jax.lax.scan(
        body, x, (params["dec_layers"], k_pools, v_pools, cross_k, cross_v)
    )
    x = apply_norm(params["dec_norm"], x, cfg.norm)
    logits = (x @ params["embed"].T)[:, 0]
    return logits, k_pools, v_pools
