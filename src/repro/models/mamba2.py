"""Mamba2 block (SSD) — used standalone and inside the Zamba2 hybrid."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.mamba2_scan import mamba2_decode_step, mamba2_scan
from repro.models.layers import Params, dense_init, norm_params, rmsnorm


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    """(d_inner, n_heads, head_dim, ssm_state)."""
    d_inner = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_headdim
    assert d_inner % P == 0
    return d_inner, d_inner // P, P, cfg.ssm_state


def block_params(key, cfg: ModelConfig, dtype) -> Params:
    D = cfg.d_model
    d_inner, H, P, N = dims(cfg)
    conv_dim = d_inner + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "norm": norm_params(ks[0], D, "rms"),
        "in_proj": dense_init(ks[1], D, 2 * d_inner + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv_width, conv_dim), jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 8.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "gate_norm": jnp.zeros((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[3], d_inner, D, dtype),
    }


def _split_proj(u: jnp.ndarray, cfg: ModelConfig):
    d_inner, H, P, N = dims(cfg)
    z, xBC, dt = jnp.split(u, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 conv_state: jnp.ndarray | None = None):
    """Depthwise causal conv over time.  xBC [B, T, C]; w [W, C].

    Returns (activated output, new conv state = last W-1 inputs)."""
    W = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xBC.shape[0], W - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = conv_state.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)             # [B, T+W-1, C]
    out = sum(xp[:, i : i + xBC.shape[1]] * w[i] for i in range(W))
    out = jax.nn.silu(out + b.astype(out.dtype))
    return out, xp[:, -(W - 1):].astype(jnp.float32)


def block_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                  kernel_mode: str = "auto", state=None):
    """x [B, T, D] -> (out [B, T, D], new state dict) — pre-norm residual block.

    ``state`` (conv + ssm) enables T=1 decode; None = training/prefill."""
    B, T, D = x.shape
    d_inner, H, P, N = dims(cfg)
    h = rmsnorm(x, p["norm"]["scale"])
    z, xBC, dt_raw = _split_proj(h @ p["in_proj"], cfg)
    conv_state = None if state is None else state["conv"]
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xs, Bm, C = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    xs = xs.reshape(B, T, H, P).transpose(0, 2, 1, 3)    # [B, H, T, P]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"]).transpose(0, 2, 1)  # [B, H, T]
    A = -jnp.exp(p["A_log"])

    if T == 1 and state is not None:
        y, new_ssm = mamba2_decode_step(
            xs[:, :, 0], dt[:, :, 0], A, Bm[:, 0].astype(jnp.float32),
            C[:, 0].astype(jnp.float32), p["D"], state["ssm"],
        )
        y = y[:, :, None, :]
    else:
        y, new_ssm = mamba2_scan(
            xs, dt, A, Bm.astype(jnp.float32), C.astype(jnp.float32), p["D"],
            kernel_mode=kernel_mode,
        )
    y = y.transpose(0, 2, 1, 3).reshape(B, T, d_inner)
    y = y * jax.nn.silu(z.astype(y.dtype))
    y = rmsnorm(y, p["gate_norm"])
    out = x + (y.astype(x.dtype) @ p["out_proj"])
    return out, {"conv": new_conv, "ssm": new_ssm}


def init_block_state(cfg: ModelConfig, batch: int):
    d_inner, H, P, N = dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, d_inner + 2 * N), jnp.float32),
        "ssm": jnp.zeros((batch, H, N, P), jnp.float32),
    }
