"""InternVL2-style VLM: stub ViT frontend + dense GQA LM backbone.

Per the assignment, the modality frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings [B, num_image_tokens, D] (the InternViT
+ MLP-projector output).  The LM backbone is the unified transformer; image
tokens are prepended to the text embeddings and the loss masks them out.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.models.layers import Params, cross_entropy


init = tfm.init  # backbone params only; the frontend is a stub


def forward(params: Params, batch, cfg: ModelConfig, *, kernel_mode: str = "auto",
            remat: bool = True):
    """batch: {patch_embeds [B, I, D], tokens [B, T_text]} -> (logits over the
    text positions [B, T_text, V], aux)."""
    patch = batch["patch_embeds"]
    tokens = batch["tokens"]
    x_text = tfm.embed_tokens(params, cfg, tokens)
    x = jnp.concatenate([patch.astype(x_text.dtype), x_text], axis=1)
    x, aux = tfm.backbone(params, x, cfg, kernel_mode=kernel_mode, remat=remat)
    logits = tfm.unembed(params, cfg, x[:, patch.shape[1]:])
    return logits, aux


def loss_fn(params: Params, batch, cfg: ModelConfig, **kw) -> jnp.ndarray:
    logits, aux = forward(params, batch, cfg, **kw)
    return cross_entropy(logits[:, :-1], batch["tokens"][:, 1:]) + aux


# Decode: identical to the dense transformer (the image prefix was written to
# the paged pools at prefill; ctx_len counts image + text tokens).
decode_step = tfm.decode_step


def forward_hidden(params: Params, batch, cfg: ModelConfig, *,
                   kernel_mode: str = "auto", remat: bool = True):
    patch = batch["patch_embeds"]
    x_text = tfm.embed_tokens(params, cfg, batch["tokens"])
    x = jnp.concatenate([patch.astype(x_text.dtype), x_text], axis=1)
    x, aux = tfm.backbone(params, x, cfg, kernel_mode=kernel_mode, remat=remat)
    from repro.models.layers import apply_norm
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x[:, patch.shape[1]:], tfm.head_matrix(params, cfg), aux
