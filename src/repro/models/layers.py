"""Common model building blocks (pure functions over param pytrees)."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


# -- initialisers -----------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jnp.ndarray:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# -- norms ------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm_params(key, d: int, kind: str) -> Params:
    if kind == "rms":
        return {"scale": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def apply_norm(p: Params, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "rms":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


# -- rotary embeddings ------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., T, H, D]; positions: broadcastable to [..., T].

    The two rotated halves are joined with ``stack(..., axis=-2).reshape``
    (row-major, so identical values to a last-axis concatenate) instead of
    ``jnp.concatenate``: XLA's SPMD partitioner mispartitions a last-axis
    concatenate whose operands carry a sharded head dim (as they do once
    wq/wk/wv are tensor-sharded and the reshape propagates into
    [B, T, H, hd]), silently corrupting sharded-vs-single-device runs — the
    sharded-train-step equivalence test pins this.  The stack/reshape form is
    bit-identical on a single device and partitions correctly.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                 # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs    # [..., T, D/2]
    cos = jnp.cos(angles)[..., None, :]                          # [..., T, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.stack([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-2)
    return out.reshape(x.shape).astype(x.dtype)


# -- MLP --------------------------------------------------------------------

def mlp_params(key, d: int, f: int, activation: str, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    if activation.endswith("_glu"):
        return {
            "w_gate": dense_init(ks[0], d, f, dtype),
            "w_up": dense_init(ks[1], d, f, dtype),
            "w_down": dense_init(ks[2], f, d, dtype),
        }
    return {"w_up": dense_init(ks[0], d, f, dtype), "w_down": dense_init(ks[1], f, d, dtype)}


def mlp_forward(p: Params, x: jnp.ndarray, activation: str) -> jnp.ndarray:
    if activation == "silu_glu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif activation == "gelu_glu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * (x @ p["w_up"])
    elif activation == "gelu":
        h = jax.nn.gelu(x @ p["w_up"], approximate=True)
    else:
        raise ValueError(activation)
    return h @ p["w_down"]


# -- losses -----------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray, mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean next-token cross entropy; logits [..., V] f32-upcast."""
    lf = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
