"""Mixture-of-Experts FFN: top-k token-choice routing with capacity bound.

Dispatch is the sort-based "dropping" formulation: assignments are sorted by
expert, ranked within expert (capacity C drops the overflow), gathered into
an [E, C, D] buffer, run through batched expert matmuls, and combined with
the router weights.  Expert weights shard over the mesh ``model`` axis (EP);
the token buffers shard over ``data`` — GSPMD inserts the all-to-alls.
A manual shard_map EP variant (local sort + explicit all_to_all) lives in
``repro.distributed.collectives`` and is the §Perf hillclimb for the MoE
cells.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense_init


def moe_params(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    moe = cfg.moe
    ks = jax.random.split(key, 4)
    E, D, F = moe.num_experts, cfg.d_model, moe.d_ff_expert
    # Per-expert GLU weights, stacked on the expert axis.
    def stack_init(k, d_in, d_out):
        keys = jax.random.split(k, E)
        return jnp.stack([dense_init(kk, d_in, d_out, dtype) for kk in keys])

    return {
        "router": dense_init(ks[0], D, E, jnp.float32),
        "w_gate": stack_init(ks[1], D, F),
        "w_up": stack_init(ks[2], D, F),
        "w_down": stack_init(ks[3], F, D),
    }


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    moe = cfg.moe
    c = int(tokens * moe.top_k * moe.capacity_factor / moe.num_experts)
    return max(8, c)


def moe_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, T, D] -> (out [B, T, D], aux load-balance loss scalar)."""
    moe = cfg.moe
    B, T, D = x.shape
    E, K = moe.num_experts, moe.top_k
    tokens = B * T
    C = _capacity(tokens, cfg)

    xf = x.reshape(tokens, D)
    gates = jax.nn.softmax((xf.astype(jnp.float32) @ p["router"]), axis=-1)  # [T, E]
    weights, ids = jax.lax.top_k(gates, K)                                   # [T, K]
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    # Aux load-balance loss (Switch-style): E * sum_e f_e * P_e.
    me = gates.mean(axis=0)                                                  # [E]
    ce = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0) / (tokens * K)
    aux = moe.router_aux_weight * E * jnp.sum(me * ce)

    # Sort assignments by expert; rank within expert; drop rank >= C.
    flat_ids = ids.reshape(-1)                                               # [T*K]
    sort = jnp.argsort(flat_ids)
    sorted_ids = flat_ids[sort]
    tk = tokens * K
    pos = jnp.arange(tk, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]])
    seg_start = jax.lax.cummax(jnp.where(is_start, pos, 0))
    rank = pos - seg_start                                                   # rank within expert
    keep = rank < C
    slot = sorted_ids * C + jnp.minimum(rank, C - 1)                         # [T*K]

    token_of = sort // K                                                     # source token per assignment
    # Dispatch: [E*C, D] buffer (dropped assignments never written).
    buf = jnp.zeros((E * C, D), x.dtype)
    buf = buf.at[jnp.where(keep, slot, E * C)].set(xf[token_of], mode="drop")
    h = buf.reshape(E, C, D)

    # Expert GLU FFN: batched over experts (EP shards this einsum).
    if cfg.activation == "gelu_glu":
        act = lambda z: jax.nn.gelu(z, approximate=True)
    else:
        act = jax.nn.silu
    hg = act(jnp.einsum("ecd,edf->ecf", h, p["w_gate"].astype(x.dtype)))
    hu = jnp.einsum("ecd,edf->ecf", h, p["w_up"].astype(x.dtype))
    ho = jnp.einsum("ecf,efd->ecd", hg * hu, p["w_down"].astype(x.dtype))
    ho = ho.reshape(E * C, D)

    # Combine: weighted scatter-add back to tokens.
    w_flat = weights.reshape(-1)[sort]                                       # [T*K] sorted order
    contrib = ho[jnp.minimum(slot, E * C - 1)] * jnp.where(keep, w_flat, 0.0)[:, None].astype(x.dtype)
    out = jnp.zeros((tokens, D), x.dtype).at[token_of].add(contrib)
    return out.reshape(B, T, D), aux
