"""Chunked (vocab-safe) cross-entropy.

Materialising [B, T, V] logits is impossible at production shapes (qwen3
train_4k would need 2.5 TB/device in f32).  The loss therefore scans over
sequence blocks: each block computes its [B, block, V] logits (sharded
B->data, V->model), reduces to per-token NLL, and discards them; the block
body is rematerialised in the backward pass.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def chunked_cross_entropy(
    hidden: jnp.ndarray,   # [B, T, D] final (normed) hidden states
    head: jnp.ndarray,     # [D, V]
    labels: jnp.ndarray,   # [B, T] targets aligned with hidden positions
    *,
    block: int = 512,
) -> jnp.ndarray:
    B, T, D = hidden.shape
    block = min(block, T)
    nb = -(-T // block)
    pad = nb * block - T
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hb = hidden.reshape(B, nb, block, D).transpose(1, 0, 2, 3)
    yb = labels.reshape(B, nb, block).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xs):
        h, y = xs
        logits = (h @ head).astype(jnp.float32)            # [B, blk, V]
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(y, 0)[..., None], axis=-1)[..., 0]
        valid = (y >= 0).astype(jnp.float32)
        nll = (logz - gold) * valid
        return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

    (total, count), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), (hb, yb))
    return total / jnp.maximum(count, 1.0)
