"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention block.

The assigned config (81 layers) is realised as ``hybrid_period``-sized groups
of Mamba2 blocks with the shared attention+MLP block applied after each group
(weights shared across all applications, as in Zamba2; we omit the
per-invocation LoRA deltas — noted in DESIGN.md).  With period 3 that is
81 Mamba2 layers and 27 shared-attention applications.

Serving: the Mamba2 state is O(1), while each shared-attention application
keeps a KV cache — THE SPARTA-paged, sequence-sharded cache.  This is the
hybrid arch that exercises long_500k *with* the paper's technique.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.paged_attention import merge_partials
from repro.models import attention as attn
from repro.models import mamba2
from repro.models import transformer as tfm
from repro.models.layers import (
    Params, apply_norm, dense_init, dtype_of, embed_init, mlp_forward,
    mlp_params, norm_params,
)


def group_dims(cfg: ModelConfig) -> Tuple[int, int]:
    period = max(cfg.hybrid_period, 1)
    assert cfg.num_layers % period == 0, (cfg.num_layers, period)
    return cfg.num_layers // period, period  # (groups, mamba per group)


def init(key, cfg: ModelConfig) -> Params:
    dtype = dtype_of(cfg.dtype)
    G, per = group_dims(cfg)
    k_emb, k_m, k_a, k_mlp, k_n1, k_n2, k_fin, k_head = jax.random.split(key, 8)
    mamba_keys = jax.random.split(k_m, G * per).reshape(G, per, -1)
    return {
        "embed": embed_init(k_emb, cfg.vocab, cfg.d_model, dtype),
        "mamba": jax.vmap(jax.vmap(lambda k: mamba2.block_params(k, cfg, dtype)))(mamba_keys),
        "shared_attn": {
            "ln1": norm_params(k_n1, cfg.d_model, cfg.norm),
            "attn": attn.attention_params(k_a, cfg, dtype),
            "ln2": norm_params(k_n2, cfg.d_model, cfg.norm),
            "mlp": mlp_params(k_mlp, cfg.d_model, cfg.d_ff, cfg.activation, dtype),
        },
        "final_norm": norm_params(k_fin, cfg.d_model, cfg.norm),
        "lm_head": dense_init(k_head, cfg.d_model, cfg.vocab, dtype),
    }


def _shared_attn_forward(sp: Params, x: jnp.ndarray, cfg: ModelConfig, kernel_mode: str):
    h = apply_norm(sp["ln1"], x, cfg.norm)
    x = x + attn.attention_forward(sp["attn"], h, cfg, causal=True, kernel_mode=kernel_mode)
    h = apply_norm(sp["ln2"], x, cfg.norm)
    return x + mlp_forward(sp["mlp"], h, cfg.activation)


def forward(params: Params, tokens: jnp.ndarray, cfg: ModelConfig, *,
            kernel_mode: str = "auto", remat: bool = True):
    x = params["embed"][tokens]
    G, per = group_dims(cfg)

    def group(x, gp):
        def m_block(x, mp):
            y, _ = mamba2.block_forward(mp, x, cfg, kernel_mode=kernel_mode)
            return y, None
        x, _ = jax.lax.scan(m_block, x, gp)
        x = _shared_attn_forward(params["shared_attn"], x, cfg, kernel_mode)
        return x, None

    grp = jax.checkpoint(group) if remat else group
    x, _ = jax.lax.scan(lambda c, gp: grp(c, gp), x, params["mamba"])
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x @ params["lm_head"], jnp.float32(0.0)


def forward_hidden(params: Params, tokens: jnp.ndarray, cfg: ModelConfig, *,
                   kernel_mode: str = "auto", remat: bool = True):
    x = params["embed"][tokens]
    G, per = group_dims(cfg)

    def group(x, gp):
        def m_block(x, mp):
            y, _ = mamba2.block_forward(mp, x, cfg, kernel_mode=kernel_mode)
            return y, None
        x, _ = jax.lax.scan(m_block, x, gp)
        x = _shared_attn_forward(params["shared_attn"], x, cfg, kernel_mode)
        return x, None

    grp = jax.checkpoint(group) if remat else group
    x, _ = jax.lax.scan(lambda c, gp: grp(c, gp), x, params["mamba"])
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, params["lm_head"], jnp.float32(0.0)


def init_decode_state(cfg: ModelConfig, batch: int):
    G, per = group_dims(cfg)
    one = mamba2.init_block_state(cfg, batch)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (G, per) + a.shape), one)


def decode_step(
    params: Params,
    tokens: jnp.ndarray,       # [B]
    cfg: ModelConfig,
    mamba_state,               # pytree with leading [G, per]
    k_pools: jnp.ndarray,      # [G, slots, page, Hkv, hd] — shared-attn caches
    v_pools: jnp.ndarray,
    table: jnp.ndarray,        # [B, pages_local]
    ctx_len: jnp.ndarray,      # [B]
    *,
    axis_name=None,
    kernel_mode: str = "auto",
):
    """One token: G x (per Mamba2 steps + one paged shared-attention)."""
    x = params["embed"][tokens][:, None, :]
    sp = params["shared_attn"]

    def group(x, scanned):
        gp, gstate, kp, vp = scanned

        def m_block(x, mpst):
            mp, st = mpst
            y, new_st = mamba2.block_forward(mp, x, cfg, kernel_mode=kernel_mode, state=st)
            return y, new_st
        x, new_gstate = jax.lax.scan(m_block, x, (gp, gstate))
        lp = {"ln1": sp["ln1"], "attn": sp["attn"], "ln2": sp["ln2"], "mlp": sp["mlp"]}
        x, kp, vp = tfm.decode_block(
            lp, x, cfg, kp, vp, table, ctx_len, axis_name=axis_name, kernel_mode=kernel_mode,
        )
        return x, (new_gstate, kp, vp)

    x, (mamba_state, k_pools, v_pools) = jax.lax.scan(
        group, x, (params["mamba"], mamba_state, k_pools, v_pools)
    )
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = (x @ params["lm_head"])[:, 0]
    return logits, mamba_state, k_pools, v_pools
