"""Unified decoder-only transformer (dense + MoE), scan-over-layers.

Covers stablelm-12b, qwen3-14b, starcoder2-7b, gemma-7b, qwen3-moe-30b-a3b,
dbrx-132b, and the LM backbone of internvl2-2b.  Layer weights are stacked on
a leading [L] axis and the body is a (rematerialised) ``lax.scan`` — constant
HLO size in depth, which keeps 40-layer x 512-device dry-run compiles cheap.

Three entry points:
* :func:`forward`          — training / prefill logits.
* :func:`prefill_with_kv`  — prefill that also emits page-layout KV.
* :func:`decode_block` / :func:`decode_step` — single-token decode against
  SPARTA-paged KV pools; ``axis_name`` enables the cross-partition merge
  (sequence-sharded SPARTA serving — see repro/serve/serve_step.py).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.paged_attention import merge_partials
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models.layers import (
    Params, apply_norm, dense_init, dtype_of, embed_init, mlp_forward,
    mlp_params, norm_params,
)


def layer_params(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "ln1": norm_params(ks[0], cfg.d_model, cfg.norm),
        "attn": attn.attention_params(ks[1], cfg, dtype),
        "ln2": norm_params(ks[2], cfg.d_model, cfg.norm),
    }
    if cfg.moe is not None:
        p["moe"] = moe_lib.moe_params(ks[3], cfg, dtype)
    else:
        p["mlp"] = mlp_params(ks[3], cfg.d_model, cfg.d_ff, cfg.activation, dtype)
    return p


def init(key, cfg: ModelConfig) -> Params:
    dtype = dtype_of(cfg.dtype)
    k_emb, k_layers, k_final, k_head = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    layers = jax.vmap(lambda k: layer_params(k, cfg, dtype))(layer_keys)
    params: Params = {
        "embed": embed_init(k_emb, cfg.vocab, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": norm_params(k_final, cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab, dtype)
    return params


def _block(cfg: ModelConfig, kernel_mode: str, x: jnp.ndarray, lp: Params):
    from repro.distributed.sharding import constrain_btd
    h = apply_norm(lp["ln1"], x, cfg.norm)
    # Constrain the RAW block outputs (pre-residual): forces the row-sharded
    # matmul psum to materialise in bf16 instead of being deferred into the
    # f32 LayerNorm fusion (perf iteration 2, EXPERIMENTS.md §Perf).
    o = constrain_btd(attn.attention_forward(lp["attn"], h, cfg, causal=True, kernel_mode=kernel_mode))
    x = constrain_btd(x + o)
    h = apply_norm(lp["ln2"], x, cfg.norm)
    if cfg.moe is not None:
        y, aux = moe_lib.moe_forward(lp["moe"], h, cfg)
    else:
        y, aux = mlp_forward(lp["mlp"], h, cfg.activation), jnp.float32(0.0)
    return constrain_btd(x + constrain_btd(y)), aux


def embed_tokens(params: Params, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def backbone(
    params: Params,
    x: jnp.ndarray,  # [B, T, D] (token or stub-frontend embeddings)
    cfg: ModelConfig,
    *,
    kernel_mode: str = "auto",
    remat: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scan the layer stack; returns (hidden [B,T,D], summed aux loss)."""
    block = functools.partial(_block, cfg, kernel_mode)
    if remat:
        block = jax.checkpoint(block)
    x, auxs = jax.lax.scan(lambda c, lp: block(c, lp), x, params["layers"])
    return x, auxs.sum()


def unembed(params: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    x = apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return x @ params["lm_head"]


def forward(
    params: Params,
    tokens: jnp.ndarray,  # [B, T] int32
    cfg: ModelConfig,
    *,
    kernel_mode: str = "auto",
    remat: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits [B, T, V], aux loss)."""
    x = embed_tokens(params, cfg, tokens)
    x, aux = backbone(params, x, cfg, kernel_mode=kernel_mode, remat=remat)
    return unembed(params, cfg, x), aux


def head_matrix(params: Params, cfg: ModelConfig) -> jnp.ndarray:
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def forward_hidden(
    params: Params,
    tokens: jnp.ndarray,
    cfg: ModelConfig,
    *,
    kernel_mode: str = "auto",
    remat: bool = True,
):
    """(final normed hidden [B,T,D], unembedding matrix [D,V], aux) — the
    vocab-safe path: the caller computes the loss with chunked CE instead of
    materialising [B, T, V] logits."""
    x = embed_tokens(params, cfg, tokens)
    x, aux = backbone(params, x, cfg, kernel_mode=kernel_mode, remat=remat)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, head_matrix(params, cfg), aux


# ---------------------------------------------------------------------------
# Prefill: forward + paged-layout KV emission.
# ---------------------------------------------------------------------------

def prefill_with_kv(
    params: Params,
    tokens: jnp.ndarray,  # [B, T]
    cfg: ModelConfig,
    *,
    kernel_mode: str = "auto",
):
    """Prefill producing last-position logits and per-layer KV in page layout
    [L, B, n_pages, page, Hkv, hd] — scattered into SPARTA pools by the
    serving engine according to the block tables."""
    B, T = tokens.shape
    page = cfg.kv_page_size
    n_pages = -(-T // page)
    pad = n_pages * page - T
    x = embed_tokens(params, cfg, tokens)
    positions = jnp.arange(T)[None, :]

    def block(x, lp):
        h = apply_norm(lp["ln1"], x, cfg.norm)
        q, k, v = attn._project_qkv(lp["attn"], h, cfg, positions)
        from repro.kernels.flash_attention import flash_attention
        o = flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            causal=True, kernel_mode=kernel_mode,
        ).transpose(0, 2, 1, 3).reshape(B, T, cfg.q_dim)
        x = x + o @ lp["attn"]["wo"]
        h = apply_norm(lp["ln2"], x, cfg.norm)
        if cfg.moe is not None:
            y, _ = moe_lib.moe_forward(lp["moe"], h, cfg)
        else:
            y = mlp_forward(lp["mlp"], h, cfg.activation)
        kv = jnp.stack([k, v])  # [2, B, T, Hkv, hd]
        if pad:
            kv = jnp.pad(kv, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        kv = kv.reshape(2, B, n_pages, page, cfg.num_kv_heads, cfg.head_dim)
        return x + y, kv

    x, kvs = jax.lax.scan(lambda c, lp: block(c, lp), x, params["layers"])
    logits = unembed(params, cfg, x[:, -1:, :])
    return logits, kvs[:, 0], kvs[:, 1]  # [L, B, n_pages, page, Hkv, hd] x2


# ---------------------------------------------------------------------------
# Paged decode.
# ---------------------------------------------------------------------------

def local_ctx_from_global(
    ctx: jnp.ndarray, partition: jnp.ndarray, num_partitions: int, page: int
) -> jnp.ndarray:
    """Valid token count within THIS partition's packed local pages.

    Logical page l lives on partition l % P at local index l // P; local
    pages are packed (all full except possibly the partition holding the
    globally-last partial page), so the paged-attention kernel's contiguous
    position masking applies verbatim with this local count.
    """
    n_pages = -(-ctx // page)  # ceil
    n_here = jnp.where(
        n_pages > partition, (n_pages - partition - 1) // num_partitions + 1, 0
    )
    last_owner = (n_pages - 1) % num_partitions
    tail = ctx - (n_pages - 1) * page
    return jnp.where(
        (n_here > 0) & (last_owner == partition),
        (n_here - 1) * page + tail,
        n_here * page,
    ).astype(jnp.int32)


def decode_block(
    lp: Params,
    x: jnp.ndarray,            # [B, 1, D]
    cfg: ModelConfig,
    k_pool: jnp.ndarray,       # [slots, page, Hkv, hd] this partition's pool
    v_pool: jnp.ndarray,
    table: jnp.ndarray,        # [B, pages_local]
    ctx_len: jnp.ndarray,      # [B] GLOBAL context length incl. new token
    *,
    axis_name: Optional[str] = None,
    kernel_mode: str = "auto",
    skip_mlp: bool = False,
):
    """One transformer layer of paged decode.  With ``axis_name``, pools are
    sequence-sharded over that mesh axis (SPARTA partitions) and partials
    merge with one all-gather of (acc, m, l).  ``skip_mlp`` returns after the
    attention residual (used by enc-dec decoders that splice cross-attention
    between self-attention and the MLP)."""
    page = cfg.kv_page_size
    if axis_name is None:
        me = jnp.int32(0)
        P = 1
    else:
        me = jax.lax.axis_index(axis_name)
        P = jax.lax.axis_size(axis_name)

    h = apply_norm(lp["ln1"], x, cfg.norm)
    q_all, k_all, v_all = attn._project_qkv(lp["attn"], h, cfg, (ctx_len - 1)[:, None])
    k_new, v_new = k_all[:, 0], v_all[:, 0]              # [B, Hkv, hd]

    # Attend over the pool as it stands BEFORE this token (hence ctx - 1).
    from repro.kernels.paged_attention import paged_attention_partial
    local_ctx = local_ctx_from_global(ctx_len - 1, me, P, page)
    acc, m, l = paged_attention_partial(
        q_all[:, 0], k_pool, v_pool, table, local_ctx, kernel_mode=kernel_mode,
    )

    # Write the new token's KV into the owning partition's pool.
    cur_page = (ctx_len - 1) // page                     # [B] global logical page
    owner = cur_page % P
    local_page = cur_page // P
    slot = jnp.take_along_axis(table, local_page[:, None], axis=1)[:, 0]
    off = (ctx_len - 1) % page
    mine = owner == me
    safe_slot = jnp.where(mine & (slot >= 0), slot, 0)
    k_cur = k_pool[safe_slot, off]                       # [B, Hkv, hd]
    v_cur = v_pool[safe_slot, off]
    k_wr = jnp.where(mine[:, None, None], k_new.astype(k_pool.dtype), k_cur)
    v_wr = jnp.where(mine[:, None, None], v_new.astype(v_pool.dtype), v_cur)
    k_pool = k_pool.at[safe_slot, off].set(k_wr)
    v_pool = v_pool.at[safe_slot, off].set(v_wr)

    # The freshly-written token must contribute to attention even though the
    # kernel read the pool before the write: fold it in as one extra partial
    # (the "hot tail" — the accelerator-side tiny TLB analogue: the newest
    # entry rides with the request, no partition lookup needed).
    hd, Hq, Hkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    G = Hq // Hkv
    q1 = q_all[:, 0].reshape(-1, Hkv, G, hd).astype(jnp.float32)
    kt = k_new.astype(jnp.float32)
    s_tail = jnp.einsum("bhgd,bhd->bhg", q1, kt) / (hd ** 0.5)
    tail_m = s_tail.reshape(-1, Hq)
    tail_l = jnp.ones_like(tail_m)
    tail_acc = jnp.repeat(v_new.astype(jnp.float32), G, axis=1) # [B, Hq, hd]
    # Only ONE partition (the owner… but every partition computed the same
    # tail from replicated activations) should count it: weight by 1/P is
    # wrong for max-merge, so mask to the owner partition.
    big_neg = jnp.float32(-1e30)
    tail_m = jnp.where(mine[:, None], tail_m, big_neg)
    tail_l = jnp.where(mine[:, None], tail_l, 0.0)
    tail_acc = jnp.where(mine[:, None, None], tail_acc, 0.0)

    accs = jnp.stack([acc, tail_acc])
    ms = jnp.stack([m, tail_m])
    ls = jnp.stack([l, tail_l])
    if axis_name is not None:
        accs = jax.lax.all_gather(accs, axis_name).reshape(-1, *acc.shape)
        ms = jax.lax.all_gather(ms, axis_name).reshape(-1, *m.shape)
        ls = jax.lax.all_gather(ls, axis_name).reshape(-1, *l.shape)
    merged = merge_partials(accs, ms, ls)                # [B, Hq, hd]
    x = x + attn.finish_decode_attention(lp["attn"], merged, cfg)

    if skip_mlp:
        return x, k_pool, v_pool
    h = apply_norm(lp["ln2"], x, cfg.norm)
    if cfg.moe is not None:
        y, _ = moe_lib.moe_forward(lp["moe"], h, cfg)
    else:
        y = mlp_forward(lp["mlp"], h, cfg.activation)
    return x + y, k_pool, v_pool


def decode_step(
    params: Params,
    tokens: jnp.ndarray,       # [B] int32 newest token ids
    cfg: ModelConfig,
    k_pools: jnp.ndarray,      # [L, slots, page, Hkv, hd]
    v_pools: jnp.ndarray,
    table: jnp.ndarray,        # [B, pages_local]
    ctx_len: jnp.ndarray,      # [B] global ctx incl. the new token
    *,
    axis_name: Optional[str] = None,
    kernel_mode: str = "auto",
):
    """Single-token decode over the full layer stack (scan); returns
    (logits [B, V], updated pools)."""
    x = embed_tokens(params, cfg, tokens[:, None])

    def body(x, scanned):
        lp, kp, vp = scanned
        x, kp, vp = decode_block(
            lp, x, cfg, kp, vp, table, ctx_len,
            axis_name=axis_name, kernel_mode=kernel_mode,
        )
        return x, (kp, vp)

    x, (k_pools, v_pools) = jax.lax.scan(body, x, (params["layers"], k_pools, v_pools))
    logits = unembed(params, cfg, x)[:, 0]
    return logits, k_pools, v_pools
