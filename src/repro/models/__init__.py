"""Model zoo: family dispatch for init / forward / loss / decode."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import cross_entropy


def get_family_module(cfg: ModelConfig):
    from repro.models import rwkv6, transformer, vlm, whisper, zamba2
    return {
        "dense": transformer,
        "moe": transformer,
        "ssm": rwkv6,
        "hybrid": zamba2,
        "encdec": whisper,
        "vlm": vlm,
    }[cfg.family]


def init(key, cfg: ModelConfig):
    return get_family_module(cfg).init(key, cfg)


def forward(params, batch, cfg: ModelConfig, **kw):
    """batch: dict with family-specific inputs; returns (logits, aux)."""
    mod = get_family_module(cfg)
    if cfg.family in ("dense", "moe", "ssm", "hybrid"):
        return mod.forward(params, batch["tokens"], cfg, **kw)
    return mod.forward(params, batch, cfg, **kw)


def forward_hidden(params, batch, cfg: ModelConfig, **kw):
    mod = get_family_module(cfg)
    if cfg.family in ("dense", "moe", "ssm", "hybrid"):
        return mod.forward_hidden(params, batch["tokens"], cfg, **kw)
    return mod.forward_hidden(params, batch, cfg, **kw)


def loss_fn(params, batch, cfg: ModelConfig, *, ce_block: int = 512, **kw) -> jnp.ndarray:
    """Next-token loss via vocab-safe chunked cross-entropy."""
    from repro.models.losses import chunked_cross_entropy
    hidden, head, aux = forward_hidden(params, batch, cfg, **kw)
    labels = batch["labels"] if "labels" in batch else batch["tokens"]
    return chunked_cross_entropy(hidden[:, :-1], head, labels[:, 1:], block=ce_block) + aux
