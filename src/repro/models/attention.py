"""Attention block: GQA + RoPE + optional qk-norm; train and decode paths."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention_partial
from repro.models.layers import Params, apply_rope, dense_init, rmsnorm


def attention_params(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.q_dim, dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.kv_dim, dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.kv_dim, dtype),
        "wo": dense_init(ks[3], cfg.q_dim, cfg.d_model, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.head_dim,), jnp.float32)
        p["k_norm"] = jnp.zeros((cfg.head_dim,), jnp.float32)
    return p


def _project_qkv(
    p: Params, x: jnp.ndarray, cfg: ModelConfig, positions: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: [B, T, D] -> q [B, T, Hq, hd], k/v [B, T, Hkv, hd] (RoPE applied)."""
    B, T, _ = x.shape
    q = (x @ p["wq"]).reshape(B, T, cfg.num_heads, cfg.head_dim)
    k = (x @ p["wk"]).reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    v = (x @ p["wv"]).reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    from repro.distributed.sharding import constrain_bthd
    q = constrain_bthd(q, cfg.num_heads)
    k = constrain_bthd(k, cfg.num_kv_heads)
    v = constrain_bthd(v, cfg.num_kv_heads)
    return q, k, v


def attention_forward(
    p: Params,
    x: jnp.ndarray,  # [B, T, D]
    cfg: ModelConfig,
    *,
    causal: bool = True,
    positions: Optional[jnp.ndarray] = None,
    kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # cross-attn
    kernel_mode: str = "auto",
) -> jnp.ndarray:
    """Full-sequence attention (training / prefill)."""
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.arange(T)[None, :]
    q, k, v = _project_qkv(p, x, cfg, positions)
    if kv_override is not None:
        k, v = kv_override
    o = flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=causal, kernel_mode=kernel_mode,
    )  # [B, Hq, T, hd]
    o = o.transpose(0, 2, 1, 3).reshape(B, T, cfg.q_dim)
    return o @ p["wo"]


def cross_kv(p: Params, enc: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Encoder K/V for cross-attention (no RoPE on whisper cross-attn)."""
    B, S, _ = enc.shape
    k = (enc @ p["wk"]).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = (enc @ p["wv"]).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    return k, v


def attention_decode_paged(
    p: Params,
    x: jnp.ndarray,                 # [B, 1, D] new token activations
    cfg: ModelConfig,
    k_pool: jnp.ndarray,            # [slots, page, Hkv, hd] (this partition's pool)
    v_pool: jnp.ndarray,
    block_table: jnp.ndarray,       # [B, pages_local] local slots
    ctx_len: jnp.ndarray,           # [B] total context (incl. new token)
    *,
    kernel_mode: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One decode step against a SPARTA-paged KV pool partition.

    Returns (attn residuals (acc, m, l) for cross-partition merge, plus the
    new (k, v) row to be written by the owning partition).
    """
    B = x.shape[0]
    positions = (ctx_len - 1)[:, None]
    q, k, v = _project_qkv(p, x, cfg, positions)
    acc, m, l = paged_attention_partial(
        q[:, 0], k_pool, v_pool, block_table, ctx_len, kernel_mode=kernel_mode,
    )
    return acc, m, l, k[:, 0], v[:, 0]


def finish_decode_attention(p: Params, merged: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """merged: [B, Hq, hd] -> output projection -> [B, 1, D]."""
    B = merged.shape[0]
    return (merged.reshape(B, 1, cfg.q_dim).astype(p["wo"].dtype)) @ p["wo"]
