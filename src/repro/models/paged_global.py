"""Global-view SPARTA paged attention: the partition axis is EXPLICIT.

The distributed serve path keeps KV pools as ``[B, P, pages_local, page,
Hkv, hd]`` where ``P`` is the number of SPARTA partitions, mapped 1:1 onto
mesh devices by sharding dim 1 (``PartitionSpec(..., 'model', ...)``).
Because every gather uses a *local* block table indexed within its own
partition (``take_along_axis`` on the pages_local dim), GSPMD never has to
move pages across partitions — the compiled program does per-device
translate+fetch and ONE cross-partition merge of flash softmax partials
(max/sum reductions over the P dim).  That is precisely the paper's
schedule: local page-table walk, local data fetch, overlap, single response.

The Pallas kernel in ``repro.kernels.paged_attention`` is the per-device TPU
hot path for this same math (used via shard_map in the serving engine); this
module is its GSPMD-friendly global formulation.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import Params, apply_norm, mlp_forward

NEG_INF = -1e30


def local_ctx_all_partitions(ctx: jnp.ndarray, P: int, page: int) -> jnp.ndarray:
    """[B] global ctx -> [B, P] per-partition packed valid-token counts."""
    from repro.models.transformer import local_ctx_from_global
    parts = jnp.arange(P, dtype=jnp.int32)
    return jax.vmap(
        lambda p: local_ctx_from_global(ctx, p, P, page), out_axes=1
    )(parts)


def paged_attention_global(
    q: jnp.ndarray,          # [B, Hq, hd] (new token)
    k_pool: jnp.ndarray,     # [B, P, pages_local, page, Hkv, hd]
    v_pool: jnp.ndarray,
    tables: jnp.ndarray,     # [B, P, pages_local] local slots (-1 = unmapped)
    ctx: jnp.ndarray,        # [B] context length EXCLUDING the new token
    *,
    extra_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # new token K/V [B, Hkv, hd]
) -> jnp.ndarray:
    """Returns merged attention output [B, Hq, hd] (f32)."""
    B, P, pl, page, Hkv, hd = k_pool.shape
    Hq = q.shape[1]
    G = Hq // Hkv
    scale = 1.0 / (hd ** 0.5)

    idx = jnp.maximum(tables, 0)[..., None, None, None]          # [B,P,pl,1,1,1]
    k = jnp.take_along_axis(k_pool, idx, axis=2)                 # local gather
    v = jnp.take_along_axis(v_pool, idx, axis=2)
    k = k.reshape(B, P, pl * page, Hkv, hd)
    v = v.reshape(B, P, pl * page, Hkv, hd)

    qf = q.astype(jnp.float32).reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bpshd->bphgs", qf, k.astype(jnp.float32)) * scale

    local_ctx = local_ctx_all_partitions(ctx, P, page)           # [B, P]
    pos = jnp.arange(pl * page, dtype=jnp.int32)
    valid = pos[None, None] < local_ctx[..., None]               # [B, P, S]
    valid &= jnp.repeat(tables >= 0, page, axis=-1)
    s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)

    m = s.max(axis=-1)                                           # [B, P, Hkv, G]
    p_ = jnp.exp(s - m[..., None])
    p_ = jnp.where(valid[:, :, None, None, :], p_, 0.0)
    l = p_.sum(axis=-1)
    acc = jnp.einsum("bphgs,bpshd->bphgd", p_, v.astype(jnp.float32))

    if extra_kv is not None:
        k1, v1 = extra_kv                                        # the hot tail
        s1 = jnp.einsum("bhgd,bhd->bhg", qf, k1.astype(jnp.float32)) * scale
        m = jnp.concatenate([m, s1[:, None]], axis=1)
        l = jnp.concatenate([l, jnp.ones_like(s1)[:, None]], axis=1)
        acc1 = jnp.broadcast_to(v1.astype(jnp.float32)[:, :, None, :], (B, Hkv, G, hd))
        acc = jnp.concatenate([acc, acc1[:, None]], axis=1)

    # SPARTA merge: one reduction over the partition axis.
    m_g = m.max(axis=1)                                          # [B, Hkv, G]
    alpha = jnp.exp(m - m_g[:, None])
    l_g = (l * alpha).sum(axis=1)
    acc_g = (acc * alpha[..., None]).sum(axis=1)
    safe_l = jnp.where(l_g > 0, l_g, 1.0)
    return (acc_g / safe_l[..., None]).reshape(B, Hq, hd)


# Write formulation for the new token's KV row: "where" (masked broadcast —
# reads+writes the whole pool; always partition-local under GSPMD) or
# "scatter" (one-row write; perf iteration, EXPERIMENTS.md §Perf cell B).
WRITE_MODE = "scatter"  # default since perf cell B (was "where"); both tested


def write_kv_global(
    pool: jnp.ndarray,       # [B, P, pages_local, page, Hkv, hd]
    tables: jnp.ndarray,     # [B, P, pages_local]
    new_kv: jnp.ndarray,     # [B, Hkv, hd]
    ctx: jnp.ndarray,        # [B] ctx INCLUDING the new token
    page: int,
) -> jnp.ndarray:
    """Write the new token into its owning partition's pool.

    The page's *slot* comes from the local table — demand-allocated anywhere
    in the partition (paper §5).
    """
    B, P, pl, pg, Hkv, hd = pool.shape
    gpage = (ctx - 1) // page                                    # [B] logical page
    owner = (gpage % P).astype(jnp.int32)
    lpage = (gpage // P).astype(jnp.int32)
    slot = jnp.take_along_axis(
        tables[jnp.arange(B), owner], lpage[:, None], axis=1
    )[:, 0]                                                      # [B]
    off = ((ctx - 1) % page).astype(jnp.int32)

    if WRITE_MODE == "scatter":
        b_idx = jnp.arange(B)
        safe_slot = jnp.maximum(slot, 0)
        return pool.at[b_idx, owner, safe_slot, off].set(
            new_kv.astype(pool.dtype), mode="drop",
        )
    pi = jax.lax.broadcasted_iota(jnp.int32, (B, P, pl, pg), 1)
    si = jax.lax.broadcasted_iota(jnp.int32, (B, P, pl, pg), 2)
    oi = jax.lax.broadcasted_iota(jnp.int32, (B, P, pl, pg), 3)
    mask = (
        (pi == owner[:, None, None, None])
        & (si == slot[:, None, None, None])
        & (oi == off[:, None, None, None])
    )[..., None, None]
    return jnp.where(mask, new_kv[:, None, None, None].astype(pool.dtype), pool)


def decode_block_global(
    lp: Params,
    x: jnp.ndarray,            # [B, 1, D]
    cfg: ModelConfig,
    k_pool: jnp.ndarray,       # [B, P, pages_local, page, Hkv, hd]
    v_pool: jnp.ndarray,
    tables: jnp.ndarray,
    ctx_len: jnp.ndarray,      # [B] incl. new token
    *,
    skip_mlp: bool = False,
):
    """One layer of global-view paged decode (dense/MoE/shared-attn)."""
    page = cfg.kv_page_size
    h = apply_norm(lp["ln1"], x, cfg.norm)
    q, k, v = attn._project_qkv(lp["attn"], h, cfg, (ctx_len - 1)[:, None])
    k_new, v_new = k[:, 0], v[:, 0]
    merged = paged_attention_global(
        q[:, 0], k_pool, v_pool, tables, ctx_len - 1, extra_kv=(k_new, v_new),
    )
    k_pool = write_kv_global(k_pool, tables, k_new, ctx_len, page)
    v_pool = write_kv_global(v_pool, tables, v_new, ctx_len, page)
    x = x + attn.finish_decode_attention(lp["attn"], merged, cfg)
    if skip_mlp:
        return x, k_pool, v_pool
    h = apply_norm(lp["ln2"], x, cfg.norm)
    if cfg.moe is not None:
        from repro.models import moe as moe_lib
        y, _ = moe_lib.moe_forward(lp["moe"], h, cfg)
    else:
        y = mlp_forward(lp["mlp"], h, cfg.activation)
    return x + y, k_pool, v_pool
