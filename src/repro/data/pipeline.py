"""Deterministic host-sharded synthetic data pipeline.

Every batch is a pure function of (step, host_id) — stateless Philox
streams — so there is NO data-loader state to checkpoint or lose: after a
node failure any surviving host can recompute any shard (DESIGN.md §7).
Token streams are Zipf-distributed with short-range repetition structure so
language-model losses actually descend (used by the e2e training examples).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    num_hosts: int = 1
    host_id: int = 0
    seed: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


def _rng_for(cfg: DataConfig, step: int) -> np.random.Generator:
    # Philox keyed by (seed, step, host): independent, reproducible streams.
    return np.random.Generator(np.random.Philox(key=cfg.seed, counter=[0, 0, step, cfg.host_id]))


def token_batch(cfg: DataConfig, step: int) -> np.ndarray:
    """[host_batch, seq_len] int32 — Zipf unigrams + local bigram copies."""
    rng = _rng_for(cfg, step)
    B, S, V = cfg.host_batch, cfg.seq_len, cfg.vocab
    toks = (rng.zipf(1.3, size=(B, S)) - 1).clip(max=V - 1).astype(np.int32)
    # Inject learnable structure: with p=0.5 a token repeats its predecessor
    # shifted by +1 (mod V) — a pattern an LM head can pick up quickly.
    rep = rng.random((B, S)) < 0.5
    shifted = np.roll(toks, 1, axis=1)
    toks = np.where(rep, (shifted + 1) % V, toks)
    return toks


def batch_for_model(cfg: DataConfig, model: ModelConfig, step: int) -> Dict[str, np.ndarray]:
    """Family-appropriate batch dict (matches ``registry.input_specs``)."""
    rng = _rng_for(cfg, step + 1_000_003)
    toks = token_batch(cfg, step)
    if model.family == "vlm":
        i = model.num_image_tokens
        patch = rng.standard_normal((cfg.host_batch, i, model.d_model)).astype(np.float32) * 0.02
        return {"patch_embeds": patch, "tokens": toks}
    if model.family == "encdec":
        frames = rng.standard_normal(
            (cfg.host_batch, cfg.seq_len, model.d_model)
        ).astype(np.float32) * 0.02
        return {"frames": frames, "tokens": toks}
    return {"tokens": toks}
