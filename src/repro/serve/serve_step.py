"""serve_step builders: one-token decode per architecture family.

Every builder returns ``step(params, inputs) -> (logits, new_state)`` where
``inputs`` matches ``repro.configs.registry.input_specs`` for the decode
shapes.  KV state uses the global-view SPARTA layout (partition axis
explicit, sharded onto the mesh ``model`` axis — or data x model for the
single-sequence long-context shape).
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import rwkv6 as rwkv6_m
from repro.models import mamba2
from repro.models import transformer as tfm
from repro.models.layers import apply_norm, mlp_forward
from repro.models.paged_global import decode_block_global


def _dense_serve(cfg: ModelConfig, kernel_mode: str):
    def step(params, inputs):
        tokens, ctx = inputs["tokens"], inputs["ctx_len"]
        x = tfm.embed_tokens(params, cfg, tokens[:, None])

        def body(x, scanned):
            lp, kp, vp = scanned
            x, kp, vp = decode_block_global(
                lp, x, cfg, kp, vp, inputs["tables"], ctx,
            )
            return x, (kp, vp)

        x, (k_pools, v_pools) = jax.lax.scan(
            body, x, (params["layers"], inputs["k_pools"], inputs["v_pools"])
        )
        logits = tfm.unembed(params, cfg, x)[:, 0]
        return logits, {"k_pools": k_pools, "v_pools": v_pools}
    return step


def _hybrid_serve(cfg: ModelConfig, kernel_mode: str):
    def step(params, inputs):
        tokens, ctx = inputs["tokens"], inputs["ctx_len"]
        x = params["embed"][tokens][:, None, :]
        sp = params["shared_attn"]

        def group(x, scanned):
            gp, conv_s, ssm_s, kp, vp = scanned

            def m_block(x, mpst):
                mp, cs, ss = mpst
                y, new = mamba2.block_forward(
                    mp, x, cfg, kernel_mode=kernel_mode, state={"conv": cs, "ssm": ss}
                )
                return y, (new["conv"], new["ssm"])
            x, (conv_s, ssm_s) = jax.lax.scan(m_block, x, (gp, conv_s, ssm_s))
            lp = {"ln1": sp["ln1"], "attn": sp["attn"], "ln2": sp["ln2"], "mlp": sp["mlp"]}
            x, kp, vp = decode_block_global(lp, x, cfg, kp, vp, inputs["tables"], ctx)
            return x, (conv_s, ssm_s, kp, vp)

        x, (conv_s, ssm_s, k_pools, v_pools) = jax.lax.scan(
            group, x,
            (params["mamba"], inputs["conv_state"], inputs["ssm_state"],
             inputs["k_pools"], inputs["v_pools"]),
        )
        x = apply_norm(params["final_norm"], x, cfg.norm)
        logits = (x @ params["lm_head"])[:, 0]
        return logits, {
            "conv_state": conv_s, "ssm_state": ssm_s,
            "k_pools": k_pools, "v_pools": v_pools,
        }
    return step


def _ssm_serve(cfg: ModelConfig, kernel_mode: str):
    def step(params, inputs):
        state = {k: inputs[k] for k in ("tm_shift", "cm_shift", "wkv")}
        logits, new_state = rwkv6_m.decode_step(
            params, inputs["tokens"], cfg, state, kernel_mode=kernel_mode
        )
        return logits, new_state
    return step


def _encdec_serve(cfg: ModelConfig, kernel_mode: str):
    from repro.kernels.flash_attention import flash_attention

    def step(params, inputs):
        tokens, ctx = inputs["tokens"], inputs["ctx_len"]
        B = tokens.shape[0]
        x = params["embed"][tokens][:, None, :] + params["dec_pos"][ctx - 1][:, None, :]

        def body(x, scanned):
            lp, kp, vp, ck, cv = scanned
            x, kp, vp = decode_block_global(
                {"ln1": lp["ln1"], "attn": lp["self_attn"]},
                x, cfg, kp, vp, inputs["tables"], ctx, skip_mlp=True,
            )
            h = apply_norm(lp["ln_x"], x, cfg.norm)
            q = (h @ lp["cross_attn"]["wq"]).reshape(B, 1, cfg.num_heads, cfg.head_dim)
            o = flash_attention(
                q.transpose(0, 2, 1, 3), ck.transpose(0, 2, 1, 3), cv.transpose(0, 2, 1, 3),
                causal=False, kernel_mode=kernel_mode,
            ).transpose(0, 2, 1, 3).reshape(B, 1, cfg.q_dim)
            x = x + o @ lp["cross_attn"]["wo"]
            h = apply_norm(lp["ln2"], x, cfg.norm)
            x = x + mlp_forward(lp["mlp"], h, cfg.activation)
            return x, (kp, vp)

        x, (k_pools, v_pools) = jax.lax.scan(
            body, x,
            (params["dec_layers"], inputs["k_pools"], inputs["v_pools"],
             inputs["cross_k"], inputs["cross_v"]),
        )
        x = apply_norm(params["dec_norm"], x, cfg.norm)
        logits = (x @ params["embed"].T)[:, 0]
        return logits, {"k_pools": k_pools, "v_pools": v_pools}
    return step


def make_serve_step(cfg: ModelConfig, *, kernel_mode: str = "reference") -> Callable:
    """Returns step(params, inputs)->(logits, new_state) for decode shapes."""
    return {
        "dense": _dense_serve,
        "moe": _dense_serve,
        "vlm": _dense_serve,
        "hybrid": _hybrid_serve,
        "ssm": _ssm_serve,
        "encdec": _encdec_serve,
    }[cfg.family](cfg, kernel_mode)
