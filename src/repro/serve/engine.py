"""Single-host serving engine: continuous batching over a SPARTA-paged pool.

The device pool is one array ``[L, P*S, page, Hkv, hd]`` whose slot space is
partition-major (slot = partition * S + local) — the logical "distributed
memory" of the paper collapsed onto one device for the runnable example; the
multi-device layout is exercised by the dry-run / sharded tests.

Features demonstrated end-to-end:
* demand allocation (pages appear as sequences grow),
* prefix sharing via ``fork`` + copy-on-write on the shared tail page,
* continuous batching (requests join/leave the batch between steps),
* prefill via ``prefill_with_kv`` scattered through the block tables.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.paged_kv import FREE, PagedKVConfig, SpartaKVManager
from repro.models import transformer as tfm


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    seq_id: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class SpartaEngine:
    def __init__(self, cfg: ModelConfig, params, *, num_partitions: int = 4,
                 slots_per_partition: int = 64, max_batch: int = 4,
                 kernel_mode: str = "reference"):
        self.cfg = cfg
        self.params = params
        self.kernel_mode = kernel_mode
        self.max_batch = max_batch
        self.kv = SpartaKVManager(PagedKVConfig(
            num_partitions=num_partitions,
            slots_per_partition=slots_per_partition,
            page_size=cfg.kv_page_size,
        ))
        L = cfg.num_layers
        total = num_partitions * slots_per_partition
        shape = (L, total, cfg.kv_page_size, cfg.num_kv_heads, cfg.head_dim)
        self.k_pool = jnp.zeros(shape, jnp.float32)
        self.v_pool = jnp.zeros(shape, jnp.float32)
        self.waiting: List[Request] = []
        self.active: List[Request] = []
        self.finished: Dict[int, Request] = {}
        self._next_rid = 0
        self._decode = jax.jit(
            lambda p, tok, kp, vp, tbl, ctx: tfm.decode_step(
                p, tok, cfg, kp, vp, tbl, ctx, kernel_mode=kernel_mode),
        )

    # -- request API ---------------------------------------------------------

    def submit(self, prompt: List[int], max_new_tokens: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.waiting.append(Request(rid, list(prompt), max_new_tokens))
        return rid

    def fork_request(self, rid: int, max_new_tokens: int = 16) -> int:
        """Prefix sharing: continue a finished/active request as a new branch
        (beam-search-style) — pages are shared, the tail page copies on
        write."""
        src = self.finished.get(rid) or next(r for r in self.active if r.rid == rid)
        child_sid = self.kv.fork(src.seq_id)
        rid2 = self._next_rid
        self._next_rid += 1
        req = Request(rid2, src.prompt + src.generated, max_new_tokens, seq_id=child_sid)
        self.active.append(req)
        return rid2

    # -- internals ------------------------------------------------------------

    def _global_slot(self, partition: int, local: int) -> int:
        return partition * self.kv.cfg.slots_per_partition + local

    def _prefill(self, req: Request) -> None:
        cfg, page = self.cfg, self.cfg.kv_page_size
        req.seq_id = self.kv.new_sequence()
        events = self.kv.append_tokens(req.seq_id, len(req.prompt))
        tokens = jnp.asarray(np.array(req.prompt, np.int32))[None]
        logits, kpages, vpages = tfm.prefill_with_kv(
            self.params, tokens, cfg, kernel_mode=self.kernel_mode)
        # Scatter the page-layout KV into the pool through the block table.
        for ev in events:
            g = self._global_slot(ev["partition"], ev["slot"])
            self.k_pool = self.k_pool.at[:, g].set(kpages[:, 0, ev["lp"]].astype(self.k_pool.dtype))
            self.v_pool = self.v_pool.at[:, g].set(vpages[:, 0, ev["lp"]].astype(self.v_pool.dtype))
        nxt = int(jnp.argmax(logits[0, -1]))
        req.generated.append(nxt)

    def _apply_events(self, events: List[dict]) -> None:
        """Apply CoW copies (old slot -> new slot, same partition)."""
        for ev in events:
            if ev["kind"] == "cow":
                g_new = self._global_slot(ev["partition"], ev["slot"])
                g_old = self._global_slot(ev["partition"], ev["old_slot"])
                self.k_pool = self.k_pool.at[:, g_new].set(self.k_pool[:, g_old])
                self.v_pool = self.v_pool.at[:, g_new].set(self.v_pool[:, g_old])

    def step(self) -> int:
        """One engine tick: admit, decode one token for every active request,
        retire finished ones.  Returns the number of active requests."""
        while self.waiting and len(self.active) < self.max_batch:
            req = self.waiting.pop(0)
            self._prefill(req)
            self.active.append(req)
        if not self.active:
            return 0

        # Grow each sequence by one token (allocates pages on demand + CoW).
        for req in self.active:
            self._apply_events(self.kv.append_tokens(req.seq_id, 1))

        seqs = [r.seq_id for r in self.active]
        max_pages = max(len(self.kv.seq_pages(s)) for s in seqs)
        table = self.kv.global_block_table(seqs, max_pages)
        ctx = self.kv.context_lengths(seqs)
        last = np.array([ (r.prompt + r.generated)[-1] for r in self.active], np.int32)

        logits, self.k_pool, self.v_pool = self._decode(
            self.params, jnp.asarray(last), self.k_pool, self.v_pool,
            jnp.asarray(table), jnp.asarray(ctx),
        )
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i, req in enumerate(self.active):
            req.generated.append(int(nxt[i]))
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
        for req in [r for r in self.active if r.done]:
            self.active.remove(req)
            self.finished[req.rid] = req
        return len(self.active)

    def run_to_completion(self, max_steps: int = 1000) -> None:
        for _ in range(max_steps):
            if not self.step() and not self.waiting:
                return
