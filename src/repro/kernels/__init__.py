# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# Kernel packages: flash_attention, paged_attention, rwkv6_scan,
# mamba2_scan, tlb_sim (sequential trace-sim scans), stackdist
# (segmented LRU-stack scan powering the sort-based sweep backend),
# timeline (cycle-approximate queueing scan for per-access latency),
# system_sim (batched 3-structure joint cache/TLB pipeline scan).
# Mode dispatch helpers live in common.py.
