"""Batched joint-system (cache + accel TLB + mem TLB) trace simulation as a
Pallas TPU kernel.

Same architecture as ``repro.kernels.tlb_sim.tlb_sim_batched_pallas``, with
THREE stacked LRU structures instead of one: every config's (tags, last-use)
state for the data cache, the accelerator-side TLB, and the partitioned
memory-side TLB array stays **resident in VMEM scratch** for the entire
trace (TPU grids execute sequentially, so scratch persists across grid
steps).  Each grid step streams one trace block HBM->VMEM once, carrying all
six per-config (set, tag) key views of that chunk, and writes back a single
packed hit word per access (bit 0 cache, bit 1 accel TLB, bit 2 mem TLB) —
7 streamed words per (config, access).

Per-config structure presence and the virtual-cache probe policy ride along
as an int32 ``[B, 3]`` flag row (``has_cache``, ``has_accel``,
``accel_probe_on_miss_only``) consumed as *data*, exactly like the batched
scan oracle (:func:`repro.kernels.system_sim.ref.system_sim_batched_ref`):
probes always execute, updates and hit bits are gated by the flags, so
heterogeneous design points (cacheless accelerators, physical vs virtual
caches) share one pallas_call.  Way padding beyond each config's own
associativity is poisoned with the shared ``_POISON_TAG`` / ``_POISON_LAST``
scheme, keeping the kernel bit-identical per config to the oracle.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Shared with the host-side batched oracle (via padded_tlb_state):
# kernel/oracle bit-identity depends on both using the same poison scheme.
from repro.core.tlbsim import _POISON_LAST, _POISON_TAG


def _system_batched_kernel(
    c_set_ref, c_tag_ref,   # int32 [B, BLK] cache (set, tag) views
    a_set_ref, a_tag_ref,   # int32 [B, BLK] accel-TLB views
    m_set_ref, m_tag_ref,   # int32 [B, BLK] mem-TLB views
    flags_ref,              # int32 [B, 3]  (has_cache, has_accel, miss_only)
    hit_ref,                # int32 [B, BLK] packed hit bits out
    c_tags, c_last,         # [B, CS, CW] persistent stacked VMEM state
    a_tags, a_last,         # [B, AS, AW]
    m_tags, m_last,         # [B, MS, MW]
    *,
    block: int,
    num_cfgs: int,
    valid: Tuple[Tuple[int, ...], Tuple[int, ...], Tuple[int, ...]],
):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        # Poison ways beyond each config's associativity in each structure:
        # their tag never matches and their last-use stamp is never the LRU
        # minimum.  valid is static, so the per-config masks are compile-time
        # constants, unrolled over the B axis (the tlb_sim kernel's scheme,
        # three times over).
        for tags_scr, last_scr, vws in (
            (c_tags, c_last, valid[0]),
            (a_tags, a_last, valid[1]),
            (m_tags, m_last, valid[2]),
        ):
            way_ix = jax.lax.broadcasted_iota(jnp.int32, tags_scr.shape[1:], 1)
            for b, vw in enumerate(vws):
                pad = way_ix >= vw
                tags_scr[b, :, :] = jnp.where(pad, _POISON_TAG, -1).astype(jnp.int32)
                last_scr[b, :, :] = jnp.where(pad, _POISON_LAST, 0).astype(jnp.int32)

    base = i * block

    def access(j, _):
        now = base + j + 1

        def per_cfg(b, _):
            has_c = flags_ref[b, 0] > 0
            has_a = flags_ref[b, 1] > 0
            miss_only = flags_ref[b, 2] > 0

            def probe(tags_scr, last_scr, s, t, do_update):
                row_t = tags_scr[b, s, :]
                row_l = last_scr[b, s, :]
                hit_vec = row_t == t
                hit = jnp.any(hit_vec)
                way = jnp.where(hit, jnp.argmax(hit_vec), jnp.argmin(row_l))
                tags_scr[b, s, way] = jnp.where(do_update, t, tags_scr[b, s, way])
                last_scr[b, s, way] = jnp.where(do_update, now, last_scr[b, s, way])
                return hit

            c_raw = probe(c_tags, c_last, c_set_ref[b, j], c_tag_ref[b, j], has_c)
            c_hit = has_c & c_raw
            # Physical cache: accel TLB probed every access.  Virtual cache:
            # only on cache misses (translation needed only to leave the
            # accelerator).
            do_a = jnp.where(miss_only, ~c_hit, jnp.bool_(True)) & has_a
            a_raw = probe(a_tags, a_last, a_set_ref[b, j], a_tag_ref[b, j], do_a)
            a_hit = jnp.where(
                has_a, jnp.where(do_a, a_raw, jnp.bool_(True)), jnp.bool_(False)
            )
            # Memory-side TLB sees only cache misses.
            m_raw = probe(m_tags, m_last, m_set_ref[b, j], m_tag_ref[b, j], ~c_hit)
            m_hit = jnp.where(~c_hit, m_raw, jnp.bool_(True))

            hit_ref[b, j] = (
                c_hit.astype(jnp.int32)
                | (a_hit.astype(jnp.int32) << 1)
                | (m_hit.astype(jnp.int32) << 2)
            )
            return 0

        jax.lax.fori_loop(0, num_cfgs, per_cfg, 0)
        return 0

    jax.lax.fori_loop(0, block, access, 0)


def _system_batched_carry_kernel(
    c_set_ref, c_tag_ref,   # int32 [B, BLK] cache (set, tag) views
    a_set_ref, a_tag_ref,   # int32 [B, BLK] accel-TLB views
    m_set_ref, m_tag_ref,   # int32 [B, BLK] mem-TLB views
    flags_ref,              # int32 [B, 3]
    c_tags_in, c_last_in,   # int32 [B, CS, CW] carried state in
    a_tags_in, a_last_in,   # int32 [B, AS, AW]
    m_tags_in, m_last_in,   # int32 [B, MS, MW]
    nb_ref,                 # int32 [1, 1] global access count before chunk
    hit_ref,                # int32 [B, BLK] packed hit bits out
    c_tags, c_last,         # int32 [B, CS, CW] carried state out = working
    a_tags, a_last,
    m_tags, m_last,
    *,
    block: int,
    num_cfgs: int,
):
    """Chunk-resumable variant of :func:`_system_batched_kernel`: the six
    state-out refs (constant-index BlockSpecs, VMEM-resident across the
    sequential grid) are the working state, loaded from the carried state-in
    at grid step 0 — the caller owns the poison init.  Timestamps continue
    the global access counter, so chunked execution is bit-identical to the
    monolithic kernel."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _load():
        c_tags[...] = c_tags_in[...]
        c_last[...] = c_last_in[...]
        a_tags[...] = a_tags_in[...]
        a_last[...] = a_last_in[...]
        m_tags[...] = m_tags_in[...]
        m_last[...] = m_last_in[...]

    base = nb_ref[0, 0] + i * block

    def access(j, _):
        now = base + j + 1

        def per_cfg(b, _):
            has_c = flags_ref[b, 0] > 0
            has_a = flags_ref[b, 1] > 0
            miss_only = flags_ref[b, 2] > 0

            def probe(tags_scr, last_scr, s, t, do_update):
                row_t = tags_scr[b, s, :]
                row_l = last_scr[b, s, :]
                hit_vec = row_t == t
                hit = jnp.any(hit_vec)
                way = jnp.where(hit, jnp.argmax(hit_vec), jnp.argmin(row_l))
                tags_scr[b, s, way] = jnp.where(do_update, t, tags_scr[b, s, way])
                last_scr[b, s, way] = jnp.where(do_update, now, last_scr[b, s, way])
                return hit

            c_raw = probe(c_tags, c_last, c_set_ref[b, j], c_tag_ref[b, j], has_c)
            c_hit = has_c & c_raw
            do_a = jnp.where(miss_only, ~c_hit, jnp.bool_(True)) & has_a
            a_raw = probe(a_tags, a_last, a_set_ref[b, j], a_tag_ref[b, j], do_a)
            a_hit = jnp.where(
                has_a, jnp.where(do_a, a_raw, jnp.bool_(True)), jnp.bool_(False)
            )
            m_raw = probe(m_tags, m_last, m_set_ref[b, j], m_tag_ref[b, j], ~c_hit)
            m_hit = jnp.where(~c_hit, m_raw, jnp.bool_(True))

            hit_ref[b, j] = (
                c_hit.astype(jnp.int32)
                | (a_hit.astype(jnp.int32) << 1)
                | (m_hit.astype(jnp.int32) << 2)
            )
            return 0

        jax.lax.fori_loop(0, num_cfgs, per_cfg, 0)
        return 0

    jax.lax.fori_loop(0, block, access, 0)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def system_sim_batched_pallas_carry(
    c_set: jnp.ndarray, c_tag: jnp.ndarray,   # int32 [B, L]
    a_set: jnp.ndarray, a_tag: jnp.ndarray,
    m_set: jnp.ndarray, m_tag: jnp.ndarray,
    flags: jnp.ndarray,                       # int32 [B, 3]
    state,                                    # 6-tuple int32 [B, S, W]
    now0: jnp.ndarray,                        # int32 scalar
    *,
    block: int = 512,
    interpret: bool = False,
):
    """Chunk-resumable batched joint-pipeline simulation; returns
    ``((cache_hit, accel_tlb_hit, mem_tlb_hit), state')``."""
    num_cfgs, n = c_set.shape
    block = min(block, n)
    assert n % block == 0, f"chunk length {n} must be a multiple of block {block}"
    grid = (n // block,)
    stream = pl.BlockSpec((num_cfgs, block), lambda i: (0, i))

    def whole(arr):
        return pl.BlockSpec(arr.shape, lambda i: (0,) * arr.ndim)

    outs = pl.pallas_call(
        functools.partial(
            _system_batched_carry_kernel, block=block, num_cfgs=num_cfgs,
        ),
        grid=grid,
        in_specs=[stream] * 6
        + [pl.BlockSpec((num_cfgs, 3), lambda i: (0, 0))]
        + [whole(s) for s in state]
        + [pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=[stream] + [whole(s) for s in state],
        out_shape=[jax.ShapeDtypeStruct((num_cfgs, n), jnp.int32)]
        + [jax.ShapeDtypeStruct(s.shape, jnp.int32) for s in state],
        interpret=interpret,
    )(c_set.astype(jnp.int32), c_tag.astype(jnp.int32),
      a_set.astype(jnp.int32), a_tag.astype(jnp.int32),
      m_set.astype(jnp.int32), m_tag.astype(jnp.int32),
      flags.astype(jnp.int32),
      *(s.astype(jnp.int32) for s in state),
      jnp.asarray(now0, jnp.int32).reshape(1, 1))
    hits = outs[0]
    return (
        (hits & 1).astype(bool),
        ((hits >> 1) & 1).astype(bool),
        ((hits >> 2) & 1).astype(bool),
    ), tuple(outs[1:])


@functools.partial(
    jax.jit, static_argnames=("geom", "valid", "block", "interpret"))
def system_sim_batched_pallas(
    c_set: jnp.ndarray, c_tag: jnp.ndarray,   # int32 [B, N]
    a_set: jnp.ndarray, a_tag: jnp.ndarray,   # int32 [B, N]
    m_set: jnp.ndarray, m_tag: jnp.ndarray,   # int32 [B, N]
    flags: jnp.ndarray,                       # int32 [B, 3]
    geom: Tuple[int, int, int, int, int, int],
    valid: Tuple[Tuple[int, ...], Tuple[int, ...], Tuple[int, ...]],
    *,
    block: int = 512,
    interpret: bool = False,
):
    """B-config batched joint-pipeline simulation; returns
    (cache_hit, accel_tlb_hit, mem_tlb_hit), each bool [B, N], bit-identical
    per config to the batched scan oracle on the same padded envelope."""
    num_cfgs, n = c_set.shape
    cs, cw, asets, aw, ms, mw = geom
    assert all(len(v) == num_cfgs for v in valid)
    block = min(block, n)
    assert n % block == 0, f"trace length {n} must be a multiple of block {block}"
    grid = (n // block,)
    stream = pl.BlockSpec((num_cfgs, block), lambda i: (0, i))
    hits = pl.pallas_call(
        functools.partial(
            _system_batched_kernel, block=block, num_cfgs=num_cfgs, valid=valid,
        ),
        grid=grid,
        in_specs=[stream] * 6 + [pl.BlockSpec((num_cfgs, 3), lambda i: (0, 0))],
        out_specs=stream,
        out_shape=jax.ShapeDtypeStruct((num_cfgs, n), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((num_cfgs, cs, cw), jnp.int32),
            pltpu.VMEM((num_cfgs, cs, cw), jnp.int32),
            pltpu.VMEM((num_cfgs, asets, aw), jnp.int32),
            pltpu.VMEM((num_cfgs, asets, aw), jnp.int32),
            pltpu.VMEM((num_cfgs, ms, mw), jnp.int32),
            pltpu.VMEM((num_cfgs, ms, mw), jnp.int32),
        ],
        interpret=interpret,
    )(c_set.astype(jnp.int32), c_tag.astype(jnp.int32),
      a_set.astype(jnp.int32), a_tag.astype(jnp.int32),
      m_set.astype(jnp.int32), m_tag.astype(jnp.int32),
      flags.astype(jnp.int32))
    return (
        (hits & 1).astype(bool),
        ((hits >> 1) & 1).astype(bool),
        ((hits >> 2) & 1).astype(bool),
    )
