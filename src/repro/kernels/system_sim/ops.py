"""Public batched joint-system op with kernel-mode dispatch.

Mode policy mirrors the timeline engine (PR 4): sweep-only backends are
rejected loudly — the joint pipeline's cache-hit-conditional TLB probes break
the LRU stack-inclusion property, so the exact stack-distance engine cannot
serve it, and silently falling back would misreport which backend produced a
figure.  ``"auto"`` resolves to the batched Pallas kernel on TPU backends and
the batched scan reference elsewhere.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.kernels.common import SWEEP_MODES, VALID_MODES, resolve_mode
from repro.kernels.system_sim.kernel import (
    system_sim_batched_pallas,
    system_sim_batched_pallas_carry,
)
from repro.kernels.system_sim.ref import (
    system_sim_batched_carry_ref,
    system_sim_batched_ref,
)

__all__ = ["system_sim_batched", "system_sim_batched_carry",
           "resolve_system_mode"]


def resolve_system_mode(kernel_mode: str) -> str:
    """Validate and resolve ``kernel_mode`` for the joint system sweep.

    ``"stackdist"`` (and any future sweep-only backend) raises: stack
    inclusion does not hold when TLB probes are conditional on cache hits, so
    there is no exact stack-distance execution of the joint pipeline — no
    silent coercion (the PR 4 policy that removed the timeline's).
    """
    if kernel_mode in SWEEP_MODES and kernel_mode not in VALID_MODES:
        raise ValueError(
            f"kernel_mode={kernel_mode!r} is a sweep_tlb/miss_ratio_curve-only "
            f"backend: the joint system sweep's cache-hit-conditional TLB "
            f"probes break the LRU stack-inclusion property, so the "
            f"stack-distance engine cannot serve it; expected one of "
            f"{VALID_MODES}")
    return resolve_mode(kernel_mode)


def system_sim_batched(
    c_set: jnp.ndarray, c_tag: jnp.ndarray,   # int32 [B, N]
    a_set: jnp.ndarray, a_tag: jnp.ndarray,   # int32 [B, N]
    m_set: jnp.ndarray, m_tag: jnp.ndarray,   # int32 [B, N]
    flags: jnp.ndarray,                       # int32 [B, 3]
    geom: Tuple[int, int, int, int, int, int],
    valid: Tuple[Tuple[int, ...], Tuple[int, ...], Tuple[int, ...]],
    *,
    block: int = 512,
    kernel_mode: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Batched-config joint cache + accel-TLB + mem-TLB simulation (the
    ``sweep_system`` hot loop): B configs' three LRU states advance together
    through ONE pass over the trace.  Returns (cache_hit, accel_tlb_hit,
    mem_tlb_hit) bool [B, N]; bit-identical per config to
    :func:`repro.core.tlbsim.simulate_system` on that config's own (unpadded)
    geometry."""
    mode = resolve_system_mode(kernel_mode)
    if mode == "reference":
        bools = tuple(flags[:, c].astype(bool) for c in range(3))
        return system_sim_batched_ref(
            (c_set, c_tag, a_set, a_tag, m_set, m_tag), bools, geom, valid)
    return system_sim_batched_pallas(
        c_set, c_tag, a_set, a_tag, m_set, m_tag, flags, geom, valid,
        block=block, interpret=(mode == "pallas_interpret"))


def system_sim_batched_carry(
    c_set: jnp.ndarray, c_tag: jnp.ndarray,   # int32 [B, L] one trace chunk
    a_set: jnp.ndarray, a_tag: jnp.ndarray,
    m_set: jnp.ndarray, m_tag: jnp.ndarray,
    flags: jnp.ndarray,                       # int32 [B, 3]
    state,                                    # 6-tuple int32 [B, S, W]
    now0: int,                                # accesses consumed before chunk
    *,
    block: int = 512,
    kernel_mode: str = "auto",
):
    """Chunk-resumable :func:`system_sim_batched`: run ONE trace chunk
    against caller-owned carried state (three
    :func:`repro.core.tlbsim.padded_tlb_state` pairs) and the global access
    counter.  Returns ``((c, a, m) hit bits bool [B, L], state')``; chunked
    execution is bit-identical to the monolithic op in any mode and across
    mode changes at chunk boundaries.

    State layout contract: each structure's carried state must include one
    spare *parked* set row at its last index that no real access ever
    indexes; Pallas chunks whose length is not a block multiple are padded
    with accesses into those rows (stamps live only there, padded hit bits
    dropped), so mid-stream padding is unobservable."""
    mode = resolve_system_mode(kernel_mode)
    state = tuple(state)
    if mode == "reference":
        bools = tuple(flags[:, c].astype(bool) for c in range(3))
        return system_sim_batched_carry_ref(
            (c_set, c_tag, a_set, a_tag, m_set, m_tag), bools,
            state, jnp.asarray(now0))
    n = int(c_set.shape[1])
    pad = (-n) % min(block, n) if n else 0
    streams = [c_set, c_tag, a_set, a_tag, m_set, m_tag]
    if pad:
        for k in range(3):
            parked = int(state[2 * k].shape[1]) - 1
            s, t = streams[2 * k], streams[2 * k + 1]
            streams[2 * k] = jnp.concatenate(
                [s, jnp.full((s.shape[0], pad), parked, s.dtype)], axis=1)
            streams[2 * k + 1] = jnp.concatenate(
                [t, jnp.zeros((t.shape[0], pad), t.dtype)], axis=1)
    hits, state = system_sim_batched_pallas_carry(
        *streams, flags, state, now0,
        block=block, interpret=(mode == "pallas_interpret"))
    return tuple(h[:, :n] for h in hits), state
