"""Public batched joint-system op with kernel-mode dispatch.

Mode policy mirrors the timeline engine (PR 4): sweep-only backends are
rejected loudly — the joint pipeline's cache-hit-conditional TLB probes break
the LRU stack-inclusion property, so the exact stack-distance engine cannot
serve it, and silently falling back would misreport which backend produced a
figure.  ``"auto"`` resolves to the batched Pallas kernel on TPU backends and
the batched scan reference elsewhere.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.kernels.common import SWEEP_MODES, VALID_MODES, resolve_mode
from repro.kernels.system_sim.kernel import system_sim_batched_pallas
from repro.kernels.system_sim.ref import system_sim_batched_ref

__all__ = ["system_sim_batched", "resolve_system_mode"]


def resolve_system_mode(kernel_mode: str) -> str:
    """Validate and resolve ``kernel_mode`` for the joint system sweep.

    ``"stackdist"`` (and any future sweep-only backend) raises: stack
    inclusion does not hold when TLB probes are conditional on cache hits, so
    there is no exact stack-distance execution of the joint pipeline — no
    silent coercion (the PR 4 policy that removed the timeline's).
    """
    if kernel_mode in SWEEP_MODES and kernel_mode not in VALID_MODES:
        raise ValueError(
            f"kernel_mode={kernel_mode!r} is a sweep_tlb/miss_ratio_curve-only "
            f"backend: the joint system sweep's cache-hit-conditional TLB "
            f"probes break the LRU stack-inclusion property, so the "
            f"stack-distance engine cannot serve it; expected one of "
            f"{VALID_MODES}")
    return resolve_mode(kernel_mode)


def system_sim_batched(
    c_set: jnp.ndarray, c_tag: jnp.ndarray,   # int32 [B, N]
    a_set: jnp.ndarray, a_tag: jnp.ndarray,   # int32 [B, N]
    m_set: jnp.ndarray, m_tag: jnp.ndarray,   # int32 [B, N]
    flags: jnp.ndarray,                       # int32 [B, 3]
    geom: Tuple[int, int, int, int, int, int],
    valid: Tuple[Tuple[int, ...], Tuple[int, ...], Tuple[int, ...]],
    *,
    block: int = 512,
    kernel_mode: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Batched-config joint cache + accel-TLB + mem-TLB simulation (the
    ``sweep_system`` hot loop): B configs' three LRU states advance together
    through ONE pass over the trace.  Returns (cache_hit, accel_tlb_hit,
    mem_tlb_hit) bool [B, N]; bit-identical per config to
    :func:`repro.core.tlbsim.simulate_system` on that config's own (unpadded)
    geometry."""
    mode = resolve_system_mode(kernel_mode)
    if mode == "reference":
        bools = tuple(flags[:, c].astype(bool) for c in range(3))
        return system_sim_batched_ref(
            (c_set, c_tag, a_set, a_tag, m_set, m_tag), bools, geom, valid)
    return system_sim_batched_pallas(
        c_set, c_tag, a_set, a_tag, m_set, m_tag, flags, geom, valid,
        block=block, interpret=(mode == "pallas_interpret"))
