"""Oracle for the batched joint-system kernel: the pure-JAX batched scan.

This is the scan that used to live as ``repro.core.sweep._scan_system_batched``
— moved here so the kernel package owns both sides of the bit-identity
contract (``repro.core.sweep`` re-exports it under the old name).  Per-config
semantics are identical to :func:`repro.core.tlbsim._scan_system`: structure
presence (``has_cache`` / ``has_accel``) and the virtual-cache probe policy
(``accel_probe_on_miss_only``) become per-config *data* instead of static
Python flags, so heterogeneous design points ride one scan.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.tlbsim import padded_tlb_state


@functools.partial(jax.jit, static_argnames=("geom", "valid"))
def system_sim_batched_ref(
    inputs,   # 6 x int32 [B, N]: cache/accel/mem (set, tag) streams
    flags,    # 3 x bool  [B]:    has_cache, has_accel, accel_on_miss_only
    geom: Tuple[int, int, int, int, int, int],
    valid: Tuple[Tuple[int, ...], Tuple[int, ...], Tuple[int, ...]],
):
    """Batched joint pipeline scan; returns (cache, accel, mem) hit bits,
    each bool [B, N]."""
    (c_set, c_tag, a_set, a_tag, m_set, m_tag) = inputs
    has_cache, has_accel, on_miss_only = flags
    cs, cw, asets, aw, ms, mw = geom
    B = c_set.shape[0]

    state0 = (
        *padded_tlb_state(B, cs, cw, valid[0]),
        *padded_tlb_state(B, asets, aw, valid[1]),
        *padded_tlb_state(B, ms, mw, valid[2]),
    )

    def probe(tags, last, s, t, now, do_update):
        row_t = tags[s]
        hit_vec = row_t == t
        hit = jnp.any(hit_vec)
        way = jnp.where(hit, jnp.argmax(hit_vec), jnp.argmin(last[s]))
        tags = tags.at[s, way].set(jnp.where(do_update, t, tags[s, way]))
        last = last.at[s, way].set(jnp.where(do_update, now, last[s, way]))
        return tags, last, hit

    def step_one(state_b, flags_b, inp_b, now):
        ct, cl, at, al, mt, ml = state_b
        has_c, has_a, miss_only = flags_b
        cs_i, ctag_i, as_i, atag_i, ms_i, mtag_i = inp_b
        ct, cl, c_raw = probe(ct, cl, cs_i, ctag_i, now, has_c)
        c_hit = jnp.where(has_c, c_raw, jnp.bool_(False))
        # Physical cache: accel TLB probed every access.  Virtual cache: only
        # on cache misses (translation needed only to leave the accelerator).
        do_a = jnp.where(miss_only, ~c_hit, jnp.bool_(True)) & has_a
        at, al, a_raw = probe(at, al, as_i, atag_i, now, do_a)
        a_hit = jnp.where(
            has_a, jnp.where(do_a, a_raw, jnp.bool_(True)), jnp.bool_(False)
        )
        # Memory-side TLB sees only cache misses (hits never leave the accel).
        mt, ml, m_raw = probe(mt, ml, ms_i, mtag_i, now, ~c_hit)
        m_hit = jnp.where(~c_hit, m_raw, jnp.bool_(True))
        return (ct, cl, at, al, mt, ml), (c_hit, a_hit, m_hit)

    vstep = jax.vmap(step_one, in_axes=(0, 0, 0, None))

    def step(state, inp):
        *streams, now = inp
        return vstep(state, flags, tuple(streams), now)

    n = c_set.shape[1]
    now = jnp.arange(1, n + 1, dtype=jnp.int32)
    xs = tuple(x.T for x in inputs) + (now,)
    (_, ys) = jax.lax.scan(step, state0, xs)
    return tuple(y.T for y in ys)


@jax.jit
def system_sim_batched_carry_ref(
    inputs,   # 6 x int32 [B, L]: one trace chunk's key streams
    flags,    # 3 x bool  [B]
    state,    # 6 x int32 [B, S, W]: carried (tags, last) x 3 structures
    now0,     # int32 scalar: accesses consumed before this chunk
):
    """Chunk-resumable :func:`system_sim_batched_ref`: explicit carried state.

    The caller owns the initial state (three :func:`padded_tlb_state` pairs)
    and the global access counter; feeding the trace in chunks is
    bit-identical to one monolithic pass.  Returns ``((c, a, m) hit bits,
    state')``.
    """
    (c_set, *_) = inputs

    def probe(tags, last, s, t, now, do_update):
        row_t = tags[s]
        hit_vec = row_t == t
        hit = jnp.any(hit_vec)
        way = jnp.where(hit, jnp.argmax(hit_vec), jnp.argmin(last[s]))
        tags = tags.at[s, way].set(jnp.where(do_update, t, tags[s, way]))
        last = last.at[s, way].set(jnp.where(do_update, now, last[s, way]))
        return tags, last, hit

    def step_one(state_b, flags_b, inp_b, now):
        ct, cl, at, al, mt, ml = state_b
        has_c, has_a, miss_only = flags_b
        cs_i, ctag_i, as_i, atag_i, ms_i, mtag_i = inp_b
        ct, cl, c_raw = probe(ct, cl, cs_i, ctag_i, now, has_c)
        c_hit = jnp.where(has_c, c_raw, jnp.bool_(False))
        do_a = jnp.where(miss_only, ~c_hit, jnp.bool_(True)) & has_a
        at, al, a_raw = probe(at, al, as_i, atag_i, now, do_a)
        a_hit = jnp.where(
            has_a, jnp.where(do_a, a_raw, jnp.bool_(True)), jnp.bool_(False)
        )
        mt, ml, m_raw = probe(mt, ml, ms_i, mtag_i, now, ~c_hit)
        m_hit = jnp.where(~c_hit, m_raw, jnp.bool_(True))
        return (ct, cl, at, al, mt, ml), (c_hit, a_hit, m_hit)

    vstep = jax.vmap(step_one, in_axes=(0, 0, 0, None))

    def step(carry, inp):
        *streams, now = inp
        return vstep(carry, flags, tuple(streams), now)

    n = c_set.shape[1]
    now = now0.astype(jnp.int32) + jnp.arange(1, n + 1, dtype=jnp.int32)
    xs = tuple(x.T for x in inputs) + (now,)
    (state, ys) = jax.lax.scan(step, tuple(state), xs)
    return tuple(y.T for y in ys), state
