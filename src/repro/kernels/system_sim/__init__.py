from repro.kernels.system_sim.ops import (
    resolve_system_mode,
    system_sim_batched,
    system_sim_batched_carry,
)
from repro.kernels.system_sim.ref import system_sim_batched_ref

__all__ = ["system_sim_batched", "system_sim_batched_carry",
           "system_sim_batched_ref", "resolve_system_mode"]
