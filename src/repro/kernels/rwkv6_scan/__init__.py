from repro.kernels.rwkv6_scan.ops import rwkv6_decode_step, rwkv6_scan  # noqa: F401
