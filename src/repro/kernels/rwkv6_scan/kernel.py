"""RWKV6 recurrence as a *chunked* Pallas TPU kernel.

A token-sequential scan wastes the MXU; the TPU-native formulation processes
chunks of C tokens with three matmuls (the flash-linear-attention trick,
adapted for RWKV6's per-channel data-dependent decay):

With per-channel cumulative decays d_t = prod_{s<=t} w_s inside a chunk and
chunk-entry state S0:

    o_t   = (r_t . d_{t-1}) @ S0                      (inter-chunk,  [C,N]@[N,N])
          + sum_{s<t} ((r_t . d_{t-1}) . (k_s / d_s)) v_s   (strictly-causal A@V)
          + (r_t . u . k_t) v_t                       (bonus diagonal)
    S_C   = diag(d_C) S0 + (K . (d_C / d_s))^T V      ([N,C]@[C,N])

The ``k_s / d_s`` rescaling bounds: with w >= w_min and chunk C, the dynamic
range is w_min^-C — C = 32..64 with f32 accumulation is safe for the decay
ranges RWKV6 produces (w = exp(-exp(x)) saturates well above 0.6 in trained
models; we log the assumption in DESIGN.md).

Grid: (B, H, T/C) — chunks walk sequentially with S in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv6_kernel(
    r_ref, k_ref, v_ref, w_ref, u_ref,
    o_ref, s_out_ref,
    s_scr,
    *,
    chunk: int,
    n: int,
    t_blocks: int,
):
    tb = pl.program_id(2)

    @pl.when(tb == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, 0].astype(jnp.float32)   # [C, N]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)      # [N]
    S0 = s_scr[...]                       # [N, N]

    # Per-channel cumulative decay inside the chunk (inclusive).
    logw = jnp.log(w)
    logd = jnp.cumsum(logw, axis=0)            # [C, N]
    d_incl = jnp.exp(logd)
    d_prev = jnp.exp(logd - logw)              # d_{t-1} (exclusive cumprod)
    d_last = d_incl[-1]                        # [N]

    q_eff = r * d_prev                          # (r_t . d_{t-1})
    k_eff = k * jnp.exp(-logd)                  # k_s / d_s

    # Inter-chunk: [C, N] @ [N, N].
    o_inter = jax.lax.dot_general(
        q_eff, S0, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    # Intra-chunk strictly-causal attention.
    a = jax.lax.dot_general(
        q_eff, k_eff, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                           # [C, C]
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    a = jnp.where(si < ti, a, 0.0)
    o_intra = jax.lax.dot_general(
        a, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    # Bonus diagonal term.
    bonus = ((r * u[None, :] * k).sum(axis=-1, keepdims=True)) * v

    o_ref[0, 0] = (o_inter + o_intra + bonus).astype(o_ref.dtype)

    # State update: S = diag(d_C) S0 + (K . d_C/d_s)^T V.
    k_dec = k_eff * d_last[None, :]
    S_new = d_last[:, None] * S0 + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    s_scr[...] = S_new

    @pl.when(tb == t_blocks - 1)
    def _finish():
        s_out_ref[0, 0] = S_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan_pallas(
    r: jnp.ndarray,  # [B, H, T, N]
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,  # [H, N]
    *,
    chunk: int = 32,
    interpret: bool = False,
):
    B, H, T, N = r.shape
    chunk = min(chunk, T)
    assert T % chunk == 0, f"T={T} must be a multiple of chunk={chunk}"
    t_blocks = T // chunk

    kernel = functools.partial(_rwkv6_kernel, chunk=chunk, n=N, t_blocks=t_blocks)
    o, s = pl.pallas_call(
        kernel,
        grid=(B, H, t_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, N), lambda b, h, t: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, N, N), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(r.shape, r.dtype),
            jax.ShapeDtypeStruct((B, H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return o, s
