"""Public RWKV6 scan op with kernel-mode dispatch."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.kernels.common import resolve_mode
from repro.kernels.rwkv6_scan.kernel import rwkv6_scan_pallas
from repro.kernels.rwkv6_scan.ref import rwkv6_decode_step, rwkv6_scan_ref

__all__ = ["rwkv6_scan", "rwkv6_decode_step"]


def rwkv6_scan(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    *,
    chunk: int = 32,
    kernel_mode: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    mode = resolve_mode(kernel_mode)
    if mode == "reference":
        return rwkv6_scan_ref(r, k, v, w, u)
    return rwkv6_scan_pallas(
        r, k, v, w, u, chunk=chunk, interpret=(mode == "pallas_interpret")
    )
