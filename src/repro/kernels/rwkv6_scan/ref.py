"""Exact sequential oracle for the RWKV6 (Finch) recurrence.

Per head (head size N), with receptance r, key k, value v, data-dependent
per-channel decay w in (0, 1) and a learned bonus u:

    a_t    = k_t (x) v_t                      (outer product, [N, N])
    o_t[j] = sum_i r_t[i] (S[i,j] + u[i] a_t[i,j])
    S      = diag(w_t) S + a_t
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(
    r: jnp.ndarray,  # [B, H, T, N]
    k: jnp.ndarray,  # [B, H, T, N]
    v: jnp.ndarray,  # [B, H, T, N]
    w: jnp.ndarray,  # [B, H, T, N] decay in (0, 1)
    u: jnp.ndarray,  # [H, N] bonus
    state: jnp.ndarray | None = None,  # [B, H, N, N]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (o [B,H,T,N], final_state [B,H,N,N])."""
    B, H, T, N = r.shape
    if state is None:
        state = jnp.zeros((B, H, N, N), jnp.float32)

    def head_scan(rh, kh, vh, wh, uh, s0):
        def step(S, inp):
            rt, kt, vt, wt = inp
            a = kt[:, None] * vt[None, :]
            o = ((S + uh[:, None] * a) * rt[:, None]).sum(axis=0)
            S = wt[:, None] * S + a
            return S, o

        S, o = jax.lax.scan(step, s0, (rh, kh, vh, wh))
        return o, S

    f = jax.vmap(  # over B
        jax.vmap(head_scan, in_axes=(0, 0, 0, 0, 0, 0)),  # over H
        in_axes=(0, 0, 0, 0, None, 0),
    )
    o, S = f(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        w.astype(jnp.float32), u.astype(jnp.float32), state.astype(jnp.float32),
    )
    return o.astype(r.dtype), S


def rwkv6_decode_step(
    r: jnp.ndarray,  # [B, H, N] single token
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,  # [H, N]
    state: jnp.ndarray,  # [B, H, N, N]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """O(1) single-token step (the serve path — no KV cache, paper's
    'SPARTA inapplicable to attention-free archs' case)."""
    a = k[..., :, None] * v[..., None, :]
    o = ((state + u[None, :, :, None] * a) * r[..., :, None]).sum(axis=-2)
    state = w[..., :, None] * state + a
    return o.astype(r.dtype), state
