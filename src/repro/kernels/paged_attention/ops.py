"""Public paged-attention op with kernel-mode dispatch.

``paged_attention``       — full decode attention over a paged KV pool.
``paged_attention_partial`` — per-partition residuals for the SPARTA
                              sequence-sharded serve path (merged with
                              :func:`merge_partials`).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import resolve_mode
from repro.kernels.paged_attention.kernel import paged_attention_pallas
from repro.kernels.paged_attention.ref import merge_partials, paged_attention_ref

__all__ = ["paged_attention", "paged_attention_partial", "merge_partials"]


def paged_attention_partial(
    q: jnp.ndarray,
    k_pool: jnp.ndarray,
    v_pool: jnp.ndarray,
    block_table: jnp.ndarray,
    ctx_len: jnp.ndarray,
    *,
    sm_scale: float | None = None,
    kernel_mode: str = "auto",
):
    """Residuals (acc, m, l) over the pages mapped by ``block_table``."""
    mode = resolve_mode(kernel_mode)
    if mode == "reference":
        return paged_attention_ref(
            q, k_pool, v_pool, block_table, ctx_len,
            sm_scale=sm_scale, return_residuals=True,
        )
    return paged_attention_pallas(
        q, k_pool, v_pool, block_table, ctx_len,
        sm_scale=sm_scale, interpret=(mode == "pallas_interpret"),
    )


def paged_attention(
    q: jnp.ndarray,
    k_pool: jnp.ndarray,
    v_pool: jnp.ndarray,
    block_table: jnp.ndarray,
    ctx_len: jnp.ndarray,
    *,
    sm_scale: float | None = None,
    kernel_mode: str = "auto",
) -> jnp.ndarray:
    mode = resolve_mode(kernel_mode)
    if mode == "reference":
        return paged_attention_ref(
            q, k_pool, v_pool, block_table, ctx_len, sm_scale=sm_scale,
        )
    acc, m, l = paged_attention_pallas(
        q, k_pool, v_pool, block_table, ctx_len,
        sm_scale=sm_scale, interpret=(mode == "pallas_interpret"),
    )
    safe_l = jnp.where(l > 0, l, 1.0)
    return (acc / safe_l[..., None]).astype(q.dtype)
