from repro.kernels.paged_attention.ops import (  # noqa: F401
    merge_partials,
    paged_attention,
    paged_attention_partial,
)
