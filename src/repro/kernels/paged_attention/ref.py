"""Pure-jnp oracle for SPARTA paged decode attention."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(
    q: jnp.ndarray,            # [B, Hq, D] — one new token per sequence
    k_pool: jnp.ndarray,       # [slots, page, Hkv, D] physical KV pool
    v_pool: jnp.ndarray,
    block_table: jnp.ndarray,  # [B, pages] int32 physical slot per logical page (-1 = unmapped)
    ctx_len: jnp.ndarray,      # [B] int32 tokens of valid context
    *,
    sm_scale: float | None = None,
    return_residuals: bool = False,
):
    """Gather-translate-attend oracle.

    With ``return_residuals`` the un-normalised accumulator and the softmax
    statistics (m, l) are returned for cross-partition merging — the
    flash-style merge used by the SPARTA sequence-sharded ``serve_step``.
    """
    B, Hq, D = q.shape
    slots, page, Hkv, _ = k_pool.shape
    pages = block_table.shape[1]
    G = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (D ** 0.5)

    safe_table = jnp.maximum(block_table, 0)
    k = k_pool[safe_table]                 # [B, pages, page, Hkv, D]
    v = v_pool[safe_table]
    k = k.reshape(B, pages * page, Hkv, D)
    v = v.reshape(B, pages * page, Hkv, D)

    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, k.astype(jnp.float32)) * scale

    pos = jnp.arange(pages * page)[None, :]                      # [1, S]
    valid = (pos < ctx_len[:, None]) & (
        jnp.repeat(block_table >= 0, page, axis=1)
    )                                                            # [B, S]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)

    m = s.max(axis=-1)                                           # [B, Hkv, G]
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = p.sum(axis=-1)
    acc = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))

    if return_residuals:
        return (
            acc.reshape(B, Hq, D),
            m.reshape(B, Hq),
            l.reshape(B, Hq),
        )
    safe_l = jnp.where(l > 0, l, 1.0)
    o = acc / safe_l[..., None]
    return o.reshape(B, Hq, D).astype(q.dtype)


def merge_partials(
    accs: jnp.ndarray,  # [P, B, Hq, D] unnormalised accumulators
    ms: jnp.ndarray,    # [P, B, Hq]
    ls: jnp.ndarray,    # [P, B, Hq]
) -> jnp.ndarray:
    """Merge per-partition flash partials into the final attention output."""
    m = ms.max(axis=0)                       # [B, Hq]
    alpha = jnp.exp(ms - m[None])            # [P, B, Hq]
    l = (ls * alpha).sum(axis=0)
    acc = (accs * alpha[..., None]).sum(axis=0)
    safe_l = jnp.where(l > 0, l, 1.0)
    return (acc / safe_l[..., None]).astype(accs.dtype)
