"""SPARTA paged decode attention as a Pallas TPU kernel.

This is the kernel-level embodiment of the paper's translate-while-fetching:
the block table (the per-partition page table, logical KV page -> physical
pool slot) is a **scalar-prefetch operand** whose values drive the KV
BlockSpec ``index_map``.  On TPU the scalar prefetch happens ahead of the
grid step, so the *translation* (table lookup) programs the DMA that fetches
the KV page — translation and data fetch literally overlap, and while page
``p`` is being processed the DMA for page ``p+1`` (already translated) is in
flight.  The centralised-IOMMU analogue (gather through a *global* table on
another device) would serialise those steps.

Grid: (batch, pages).  Page blocks walk sequentially per sequence with the
f32 flash statistics (m, l, acc) in VMEM scratch.  Invalid pages (past the
context length, or unmapped table entries) are skipped with ``pl.when`` —
no DMA descriptors are wasted on them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(
    table_ref, ctx_ref,             # scalar-prefetch (SMEM)
    q_ref, k_ref, v_ref,            # VMEM blocks
    o_acc_ref, o_m_ref, o_l_ref,    # outputs (residuals)
    m_scr, l_scr, acc_scr,
    *,
    sm_scale: float,
    page: int,
    pages: int,
    hq: int,
    hkv: int,
    d: int,
):
    b = pl.program_id(0)
    p = pl.program_id(1)
    g = hq // hkv

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ctx = ctx_ref[b]
    page_start = p * page
    valid_page = (page_start < ctx) & (table_ref[b, p] >= 0)

    @pl.when(valid_page)
    def _compute():
        q = q_ref[0].astype(jnp.float32).reshape(hkv, g, d)
        k = k_ref[0].astype(jnp.float32)                 # [page, Hkv, D]
        v = v_ref[0].astype(jnp.float32)
        kt = jnp.transpose(k, (1, 0, 2))                 # [Hkv, page, D]
        # s[h, g, t] over the page
        s = jax.lax.dot_general(
            q, kt, (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32
        ) * sm_scale                                     # [Hkv, G, page]

        t_ids = page_start + jax.lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
        mask = t_ids < ctx
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...].reshape(hkv, g)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        pr = jnp.exp(s - m_new[..., None])
        pr = jnp.where(mask, pr, 0.0)
        vt = jnp.where(mask.reshape(1, page, 1)[:, :, :], jnp.transpose(v, (1, 0, 2)), 0.0)
        l_new = l_scr[...].reshape(hkv, g) * alpha + pr.sum(axis=-1)
        acc = acc_scr[...].reshape(hkv, g, d) * alpha[..., None] + jax.lax.dot_general(
            pr, vt, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new.reshape(hq)
        l_scr[...] = l_new.reshape(hq)
        acc_scr[...] = acc.reshape(hq, d)

    @pl.when(p == pages - 1)
    def _finish():
        o_acc_ref[0] = acc_scr[...].astype(o_acc_ref.dtype)
        o_m_ref[0] = m_scr[...].astype(o_m_ref.dtype)
        o_l_ref[0] = l_scr[...].astype(o_l_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("sm_scale", "interpret"),
)
def paged_attention_pallas(
    q: jnp.ndarray,            # [B, Hq, D]
    k_pool: jnp.ndarray,       # [slots, page, Hkv, D]
    v_pool: jnp.ndarray,
    block_table: jnp.ndarray,  # [B, pages] int32
    ctx_len: jnp.ndarray,      # [B] int32
    *,
    sm_scale: float | None = None,
    interpret: bool = False,
):
    """Returns residuals (acc, m, l); normalise with ``ref.merge_partials``
    (single-partition callers divide locally in ops.py)."""
    B, Hq, D = q.shape
    slots, page, Hkv, _ = k_pool.shape
    pages = block_table.shape[1]
    scale = sm_scale if sm_scale is not None else 1.0 / (D ** 0.5)

    grid = (B, pages)
    kernel = functools.partial(
        _paged_kernel,
        sm_scale=scale, page=page, pages=pages, hq=Hq, hkv=Hkv, d=D,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Hq, D), lambda b, p, tbl, ctx: (b, 0, 0)),
            # THE SPARTA LOOKUP: the table value selects the pool slot the
            # DMA reads — translation programs the fetch.
            pl.BlockSpec(
                (1, page, Hkv, D),
                lambda b, p, tbl, ctx: (jnp.maximum(tbl[b, p], 0), 0, 0, 0),
            ),
            pl.BlockSpec(
                (1, page, Hkv, D),
                lambda b, p, tbl, ctx: (jnp.maximum(tbl[b, p], 0), 0, 0, 0),
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, Hq, D), lambda b, p, tbl, ctx: (b, 0, 0)),
            pl.BlockSpec((1, Hq), lambda b, p, tbl, ctx: (b, 0)),
            pl.BlockSpec((1, Hq), lambda b, p, tbl, ctx: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((Hq,), jnp.float32),
            pltpu.VMEM((Hq,), jnp.float32),
            pltpu.VMEM((Hq, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hq), jnp.float32),
            jax.ShapeDtypeStruct((B, Hq), jnp.float32),
        ],
        interpret=interpret,
    )(block_table, ctx_len, q, k_pool, v_pool)
