from repro.kernels.timeline.ops import (
    FP_COLS,
    IP_COLS,
    TimelineParams,
    pack_params,
    resolve_timeline_mode,
    timeline_sim,
    timeline_sim_batched,
)

__all__ = ["TimelineParams", "timeline_sim", "timeline_sim_batched",
           "pack_params", "resolve_timeline_mode", "FP_COLS", "IP_COLS"]
