from repro.kernels.timeline.ops import TimelineParams, timeline_sim

__all__ = ["TimelineParams", "timeline_sim"]
