from repro.kernels.timeline.ops import (
    FP_COLS,
    IP_COLS,
    TimelineParams,
    pack_params,
    resolve_timeline_mode,
    timeline_init_state_batched,
    timeline_sim,
    timeline_sim_batched,
    timeline_sim_batched_carry,
)

__all__ = ["TimelineParams", "timeline_sim", "timeline_sim_batched",
           "timeline_sim_batched_carry", "timeline_init_state_batched",
           "pack_params", "resolve_timeline_mode", "FP_COLS", "IP_COLS"]
