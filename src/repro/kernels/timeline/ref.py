"""Pure-jnp oracle for the cycle-approximate timeline engine.

One :func:`timeline_step` advances the full queueing state by one trace
access; :func:`timeline_scan_ref` wraps it in a ``lax.scan``.  The Pallas
kernel (:mod:`repro.kernels.timeline.kernel`) executes the *same* step
function against VMEM-resident state, so the two paths are bit-identical by
construction (asserted by ``tests/test_timeline.py``).

Latency composition per access (virtual-cache accelerator, Fig 3 timelines):

* cache hit — ``l_cache``; never leaves the accelerator, no queueing.
* cache miss — design-specific translation + data path with three queueing
  points threaded in:

  - **MSHR window** (per accelerator): a miss may only *issue* once one of
    the accelerator's ``mshrs`` outstanding-miss slots is free (FIFO slot
    reuse: the i-th miss waits on the (i - mshrs)-th miss's completion).
  - **Memory-side TLB ports** (per partition, SPARTA only): a translation
    waits for the earliest-free of the partition's ``tlb_ports`` ports and
    occupies it for ``tlb_occ`` cycles.
  - **DRAM banks** (machine-wide): every DRAM reference (page walk, PTE
    read, data fetch) waits for its bank and occupies it for ``dram_occ``
    cycles.

With every resource unbounded (count 0) all waits vanish and the per-access
latency is exactly the Fig 3 analytical composition, so the post-warmup mean
reproduces :mod:`repro.core.cpi` — the subsystem's oracle property.

Arithmetic is float32 but every default latency parameter is an integer
number of cycles, so all absolute times and latencies stay exactly
representable (integer cycle counts) far beyond any benchmark's horizon.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class TimelineParams(NamedTuple):
    """Static (compile-time) scan parameters.

    ``serial_walk`` selects the conventional design (private accel-side TLB,
    page walk serialized before the data fetch); ``mem_tlb`` selects SPARTA
    (translation at the partition's memory-side TLB, overlapped with the
    network traversal).  Neither flag => DIPTA/ideal (translation fully
    overlapped; the per-access ``pen`` input carries DIPTA's serialized
    way-misprediction penalty, 0 for ideal).

    A resource count of 0 means *unbounded* (no queueing on that resource).
    """

    serial_walk: bool = False
    mem_tlb: bool = False
    num_accels: int = 1
    mshrs: int = 0            # outstanding-miss slots per accelerator
    num_partitions: int = 1   # memory-side TLB partitions (SPARTA P)
    tlb_ports: int = 0        # service ports per partition TLB
    dram_banks: int = 0       # DRAM banks machine-wide
    l_cache: float = 2.0
    l_tlb: float = 2.0
    l_dram: float = 120.0
    t_net: float = 390.0
    tlb_occ: float = 2.0      # port busy time per probe
    dram_occ: float = 120.0   # bank busy time per access
    issue_interval: float = 1.0  # cycles between successive issues per accel


def timeline_init_state(p: TimelineParams):
    """All-zero queueing state (times in cycles; everything free at t=0)."""
    A = p.num_accels
    return (
        jnp.zeros((A,), jnp.float32),                       # next nominal issue
        jnp.zeros((A, max(p.mshrs, 1)), jnp.float32),       # MSHR slot free times
        jnp.zeros((A,), jnp.int32),                         # per-accel miss count
        jnp.zeros((max(p.num_partitions, 1), max(p.tlb_ports, 1)), jnp.float32),
        jnp.zeros((max(p.dram_banks, 1),), jnp.float32),    # bank free times
    )


def timeline_step(state, inp, p: TimelineParams):
    """Advance the queueing state by one access.

    ``inp`` is the per-access tuple ``(accel, partition, bank_data, bank_pte,
    cache_hit, tlb_hit, mem_tlb_hit, pen)`` (int32 scalars + float32 ``pen``).
    Returns ``(state', (latency, overhead, done))`` where ``latency`` is
    issue->completion cycles, ``overhead`` the translation-induced component
    (including translation queue waits), ``done`` the absolute completion
    time.  Latencies are composed from *segments* (waits + service times), not
    endpoint differences, so unqueued runs are exact in float32 regardless of
    how far absolute time has advanced.
    """
    acc_next, mshr_ring, mshr_cnt, port_free, bank_free = state
    a, part, bank_d, bank_p, c, th, mh, pen = inp
    zero = jnp.float32(0.0)
    c_hit = c != 0
    nominal = acc_next[a]

    # --- MSHR admission: a miss needs a free outstanding-miss slot. ---------
    if p.mshrs > 0:
        slot = mshr_cnt[a] % p.mshrs
        w_mshr = jnp.maximum(mshr_ring[a, slot] - nominal, zero)
        issue = nominal + jnp.where(c_hit, zero, w_mshr)
    else:
        slot = jnp.int32(0)
        issue = nominal

    t0 = issue + p.l_cache  # cache probe; a miss leaves the accelerator here

    # --- translation path (computed unconditionally, applied on miss) -------
    if p.serial_walk:
        # Conventional: private accel-side TLB probe, then a page walk (one
        # memory reference over the network) serialized before the data fetch.
        walk_arr = t0 + p.l_tlb + p.t_net
        if p.dram_banks > 0:
            w_walk = jnp.maximum(bank_free[bank_p] - walk_arr, zero)
            do_walk = (~c_hit) & (th == 0)
            bank_free = bank_free.at[bank_p].set(jnp.where(
                do_walk, walk_arr + w_walk + p.dram_occ, bank_free[bank_p]))
        else:
            w_walk = zero
        walk = 2.0 * p.t_net + w_walk + p.l_dram
        trans = p.l_tlb + jnp.where(th != 0, zero, walk)
        # data fetch departs only after the walk returns: l_cache + trans,
        # then a full network round trip around the data DRAM access.
        data_arr = t0 + trans + p.t_net
        pen_eff = zero
    elif p.mem_tlb:
        # SPARTA: request reaches the partition after one traversal; the
        # memory-side TLB probe queues on the partition's ports and a miss
        # reads the PTE from the *local* DRAM (no extra traversals).
        arr = t0 + p.t_net
        if p.tlb_ports > 0:
            row = port_free[part]
            pslot = jnp.argmin(row)
            w_port = jnp.maximum(row[pslot] - arr, zero)
            port_free = port_free.at[part, pslot].set(jnp.where(
                ~c_hit, arr + w_port + p.tlb_occ, row[pslot]))
        else:
            w_port = zero
        probe_done = arr + w_port + p.l_tlb
        if p.dram_banks > 0:
            w_pte = jnp.maximum(bank_free[bank_p] - probe_done, zero)
            do_pte = (~c_hit) & (mh == 0)
            bank_free = bank_free.at[bank_p].set(jnp.where(
                do_pte, probe_done + w_pte + p.dram_occ, bank_free[bank_p]))
        else:
            w_pte = zero
        trans = w_port + p.l_tlb + jnp.where(mh != 0, zero, w_pte + p.l_dram)
        data_arr = arr + trans  # translation completes at the partition
        pen_eff = zero
    else:
        # DIPTA/ideal: translation fully overlapped with the row fetch; pen
        # carries DIPTA's serialized way-misprediction penalty (0 for ideal).
        trans = pen
        data_arr = t0 + p.t_net
        pen_eff = pen

    # --- data DRAM access (all designs) -------------------------------------
    if p.dram_banks > 0:
        w_data = jnp.maximum(bank_free[bank_d] - data_arr, zero)
        bank_free = bank_free.at[bank_d].set(jnp.where(
            ~c_hit, data_arr + w_data + p.dram_occ + pen_eff, bank_free[bank_d]))
    else:
        w_data = zero

    if p.serial_walk:
        lat_miss = p.l_cache + trans + p.t_net + w_data + p.l_dram + p.t_net
    elif p.mem_tlb:
        lat_miss = p.l_cache + p.t_net + trans + w_data + p.l_dram + p.t_net
    else:
        lat_miss = p.l_cache + p.t_net + w_data + p.l_dram + pen_eff + p.t_net

    latency = jnp.where(c_hit, jnp.float32(p.l_cache), lat_miss)
    overhead = jnp.where(c_hit, zero, trans)
    done = issue + latency

    # --- state updates -------------------------------------------------------
    if p.mshrs > 0:
        mshr_ring = mshr_ring.at[a, slot].set(
            jnp.where(c_hit, mshr_ring[a, slot], done))
        mshr_cnt = mshr_cnt.at[a].add(jnp.where(c_hit, 0, 1))
    acc_next = acc_next.at[a].set(issue + p.issue_interval)
    return (acc_next, mshr_ring, mshr_cnt, port_free, bank_free), (
        latency, overhead, done)


# ---------------------------------------------------------------------------
# Batched multi-simulation path: per-sim parameters become *data*.
# ---------------------------------------------------------------------------
#
# ``sweep_timeline`` (repro.core.timeline) stacks B heterogeneous simulations
# (mixed designs, accelerator counts, resource bounds, trace lengths) on a
# leading sim axis and advances all of them per trace element.  The static
# Python branches of :func:`timeline_step` (``if p.serial_walk`` / ``if
# p.mshrs > 0``) cannot be vmapped across sims that disagree on them, so
# :func:`timeline_step_dyn` re-expresses the same step with the per-sim
# configuration as two packed *traced* rows:
#
# * ``fp`` float32 [8]  — ``FP_COLS``: the latency table (plus ``walk2``, the
#   host-precomputed ``float32(2.0 * t_net)`` so the conventional walk's
#   round-trip term is rounded exactly like the oracle's Python-float fold).
# * ``ip`` int32   [7]  — ``IP_COLS``: design flags + resource counts.
#
# State arrays are padded to the batch's common resource envelope.  Padding is
# *poisoned* exactly like the PR-1 TLB sweep so it can never be observed:
#
# * MSHR slots / DRAM banks beyond a sim's own count are never indexed (slot
#   ids come from ``cnt % mshrs`` and per-sim bank ids are ``< dram_banks``),
#   so they stay at their always-free initial 0.
# * TLB-port columns beyond a sim's own ``tlb_ports`` are initialised to
#   ``PORT_POISON`` (~f32 max): the earliest-free ``argmin`` can never select
#   them, so the chosen port index — and hence every wait — matches the
#   oracle's own-width ``argmin`` bit-exactly.
#
# Every jnp.where selects between expressions computed in the oracle's exact
# float32 operation order, so per-sim outputs are bit-identical to
# :func:`timeline_step` on that sim's own configuration
# (tests/test_timeline_sweep.py asserts this across heterogeneous batches).

FP_COLS = ("l_cache", "l_tlb", "l_dram", "t_net", "walk2", "tlb_occ",
           "dram_occ", "issue_interval")
IP_COLS = ("serial_walk", "mem_tlb", "num_accels", "mshrs", "num_partitions",
           "tlb_ports", "dram_banks")

PORT_POISON = 3.0e38  # ~f32 max: argmin never selects a padded port column


def pack_params(p: TimelineParams):
    """(fp float32 [8], ip int32 [7]) rows for one sim's configuration."""
    fp = np.array([p.l_cache, p.l_tlb, p.l_dram, p.t_net,
                   np.float32(2.0 * p.t_net), p.tlb_occ, p.dram_occ,
                   p.issue_interval], np.float32)
    ip = np.array([int(p.serial_walk), int(p.mem_tlb), p.num_accels, p.mshrs,
                   p.num_partitions, p.tlb_ports, p.dram_banks], np.int32)
    return fp, ip


def timeline_init_state_batched(B: int, envelope, tlb_ports: jnp.ndarray):
    """Stacked all-zero queueing state on the (A, M, P, T, D) resource
    envelope, with port columns beyond each sim's own ``tlb_ports`` poisoned
    as always-busy (see module notes above)."""
    A, M, P, T, D = envelope
    col = jax.lax.broadcasted_iota(jnp.int32, (B, P, T), 2)
    port0 = jnp.where(col < tlb_ports[:, None, None],
                      jnp.float32(0.0), jnp.float32(PORT_POISON))
    return (
        jnp.zeros((B, A), jnp.float32),
        jnp.zeros((B, A, M), jnp.float32),
        jnp.zeros((B, A), jnp.int32),
        port0,
        jnp.zeros((B, D), jnp.float32),
    )


def _masked_set(arr, mask, value):
    """Dense equivalent of ``arr.at[idx].set(value)`` (``mask`` one-hot at
    idx): identical result, but vmapping it over sims yields wide
    compare/selects instead of batched scatters — the latter are the
    dominant cost of the batched scan on CPU backends."""
    return jnp.where(mask, value, arr)


def timeline_step_dyn(state, inp, fp, ip):
    """One sim's :func:`timeline_step` with traced per-sim parameters and
    envelope-padded state.  Shared by the batched ``lax.scan`` reference
    (vmapped over sims) and the batched Pallas kernel (fori over sims), so
    those two paths are bit-identical by construction — and each sim is
    bit-identical to the static-param oracle on its own configuration."""
    acc_next, mshr_ring, mshr_cnt, port_free, bank_free = state
    a, part, bank_d, bank_p, c, th, mh, pen = inp
    l_cache, l_tlb, l_dram, t_net = fp[0], fp[1], fp[2], fp[3]
    walk2, tlb_occ, dram_occ, issue_iv = fp[4], fp[5], fp[6], fp[7]
    serial, memtlb = ip[0] != 0, ip[1] != 0
    mshrs, ports, banks = ip[3], ip[5], ip[6]
    zero = jnp.float32(0.0)
    c_hit = c != 0
    nominal = acc_next[a]

    accel_ix = jax.lax.iota(jnp.int32, acc_next.shape[0])
    bank_ix = jax.lax.iota(jnp.int32, bank_free.shape[0])

    # --- MSHR admission (slot ids never reach padded columns) ---------------
    slot = mshr_cnt[a] % jnp.maximum(mshrs, 1)
    w_mshr = jnp.maximum(mshr_ring[a, slot] - nominal, zero)
    use_mshr = (~c_hit) & (mshrs > 0)
    issue = nominal + jnp.where(use_mshr, w_mshr, zero)

    t0 = issue + l_cache

    # --- SPARTA port queue (poisoned columns lose every argmin) -------------
    arr = t0 + t_net
    row = port_free[part]
    pslot = jnp.argmin(row)
    w_port = jnp.where(ports > 0, jnp.maximum(row[pslot] - arr, zero), zero)
    do_port = memtlb & (~c_hit) & (ports > 0)
    port_mask = (
        (jax.lax.broadcasted_iota(jnp.int32, port_free.shape, 0) == part)
        & (jax.lax.broadcasted_iota(jnp.int32, port_free.shape, 1) == pslot))
    port_free = _masked_set(port_free, port_mask & do_port,
                            arr + w_port + tlb_occ)
    probe_done = arr + w_port + l_tlb

    # --- translation-path DRAM reference (conv walk / SPARTA PTE read) ------
    walk_arr = t0 + l_tlb + t_net
    trans_arr = jnp.where(serial, walk_arr, probe_done)
    w_tr = jnp.where(banks > 0,
                     jnp.maximum(bank_free[bank_p] - trans_arr, zero), zero)
    do_tr = (~c_hit) & (banks > 0) & jnp.where(
        serial, th == 0, memtlb & (mh == 0))
    bank_free = _masked_set(bank_free, (bank_ix == bank_p) & do_tr,
                            trans_arr + w_tr + dram_occ)

    walk = walk2 + w_tr + l_dram
    trans_conv = l_tlb + jnp.where(th != 0, zero, walk)
    trans_sparta = w_port + l_tlb + jnp.where(mh != 0, zero, w_tr + l_dram)
    trans = jnp.where(serial, trans_conv, jnp.where(memtlb, trans_sparta, pen))
    data_arr = jnp.where(serial, t0 + trans_conv + t_net,
                         jnp.where(memtlb, arr + trans_sparta, arr))
    pen_eff = jnp.where(serial | memtlb, zero, pen)

    # --- data DRAM access (all designs) -------------------------------------
    w_data = jnp.where(banks > 0,
                       jnp.maximum(bank_free[bank_d] - data_arr, zero), zero)
    bank_free = _masked_set(bank_free,
                            (bank_ix == bank_d) & (~c_hit) & (banks > 0),
                            data_arr + w_data + dram_occ + pen_eff)

    lat_conv = l_cache + trans_conv + t_net + w_data + l_dram + t_net
    lat_sparta = l_cache + t_net + trans_sparta + w_data + l_dram + t_net
    lat_over = l_cache + t_net + w_data + l_dram + pen_eff + t_net
    lat_miss = jnp.where(serial, lat_conv,
                         jnp.where(memtlb, lat_sparta, lat_over))
    latency = jnp.where(c_hit, l_cache, lat_miss)
    overhead = jnp.where(c_hit, zero, trans)
    done = issue + latency

    # --- state updates -------------------------------------------------------
    mshr_mask = (
        (jax.lax.broadcasted_iota(jnp.int32, mshr_ring.shape, 0) == a)
        & (jax.lax.broadcasted_iota(jnp.int32, mshr_ring.shape, 1) == slot))
    mshr_ring = _masked_set(mshr_ring, mshr_mask & use_mshr, done)
    mshr_cnt = mshr_cnt + jnp.where((accel_ix == a) & use_mshr, 1, 0)
    acc_next = _masked_set(acc_next, accel_ix == a, issue + issue_iv)
    return (acc_next, mshr_ring, mshr_cnt, port_free, bank_free), (
        latency, overhead, done)


@functools.partial(jax.jit, static_argnames=("envelope",))
def timeline_scan_batched_ref(
    accel: jnp.ndarray,      # int32 [B, N]
    part: jnp.ndarray,       # int32 [B, N]
    bank_data: jnp.ndarray,  # int32 [B, N]
    bank_pte: jnp.ndarray,   # int32 [B, N]
    cache_hit: jnp.ndarray,  # int32 [B, N]
    tlb_hit: jnp.ndarray,    # int32 [B, N]
    mem_hit: jnp.ndarray,    # int32 [B, N]
    pen: jnp.ndarray,        # f32   [B, N]
    fparams: jnp.ndarray,    # f32   [B, 8]  (FP_COLS)
    iparams: jnp.ndarray,    # int32 [B, 7]  (IP_COLS)
    envelope: Tuple[int, int, int, int, int],   # (A, M, P, T, D)
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """All B sims advanced per trace element in ONE ``lax.scan``; returns
    (latency, overhead, done), each f32 [B, N]."""
    B = accel.shape[0]
    state0 = timeline_init_state_batched(B, envelope, iparams[:, 5])
    vstep = jax.vmap(timeline_step_dyn, in_axes=(0, 0, 0, 0))

    def step(state, inp):
        return vstep(state, inp, fparams, iparams)

    xs = tuple(x.T for x in (accel, part, bank_data, bank_pte,
                             cache_hit, tlb_hit, mem_hit, pen))
    _, ys = jax.lax.scan(step, state0, xs)
    return tuple(y.T for y in ys)


@jax.jit
def timeline_scan_batched_carry_ref(
    accel: jnp.ndarray,      # int32 [B, L] one trace chunk
    part: jnp.ndarray,
    bank_data: jnp.ndarray,
    bank_pte: jnp.ndarray,
    cache_hit: jnp.ndarray,
    tlb_hit: jnp.ndarray,
    mem_hit: jnp.ndarray,
    pen: jnp.ndarray,        # f32 [B, L]
    fparams: jnp.ndarray,    # f32 [B, 8]
    iparams: jnp.ndarray,    # int32 [B, 7]
    state,                   # 5-tuple: carried queueing state (see
                             # timeline_init_state_batched for layout)
):
    """Chunk-resumable :func:`timeline_scan_batched_ref`: explicit carried
    state.  The queueing state holds *absolute* times, so unlike the LRU
    scans no global access counter is threaded — carrying the five state
    arrays across chunks is bit-identical to one monolithic pass.  Returns
    ``((latency, overhead, done), state')``.
    """
    vstep = jax.vmap(timeline_step_dyn, in_axes=(0, 0, 0, 0))

    def step(carry, inp):
        return vstep(carry, inp, fparams, iparams)

    xs = tuple(x.T for x in (accel, part, bank_data, bank_pte,
                             cache_hit, tlb_hit, mem_hit, pen))
    state, ys = jax.lax.scan(step, tuple(state), xs)
    return tuple(y.T for y in ys), state


@functools.partial(jax.jit, static_argnames=("params",))
def timeline_scan_ref(
    accel: jnp.ndarray,      # int32 [N] issuing accelerator id
    part: jnp.ndarray,       # int32 [N] memory-side TLB partition id
    bank_data: jnp.ndarray,  # int32 [N] DRAM bank of the data line
    bank_pte: jnp.ndarray,   # int32 [N] DRAM bank of the PTE
    cache_hit: jnp.ndarray,  # int32 [N] 1 = cache hit
    tlb_hit: jnp.ndarray,    # int32 [N] accel-TLB hit (conventional)
    mem_hit: jnp.ndarray,    # int32 [N] memory-side TLB hit (SPARTA)
    pen: jnp.ndarray,        # f32   [N] serialized penalty (DIPTA)
    params: TimelineParams,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Sequential timeline simulation; returns (latency, overhead, done)."""

    def step(state, inp):
        return timeline_step(state, inp, params)

    _, ys = jax.lax.scan(
        step, timeline_init_state(params),
        (accel, part, bank_data, bank_pte, cache_hit, tlb_hit, mem_hit, pen),
    )
    return ys
