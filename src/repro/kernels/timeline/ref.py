"""Pure-jnp oracle for the cycle-approximate timeline engine.

One :func:`timeline_step` advances the full queueing state by one trace
access; :func:`timeline_scan_ref` wraps it in a ``lax.scan``.  The Pallas
kernel (:mod:`repro.kernels.timeline.kernel`) executes the *same* step
function against VMEM-resident state, so the two paths are bit-identical by
construction (asserted by ``tests/test_timeline.py``).

Latency composition per access (virtual-cache accelerator, Fig 3 timelines):

* cache hit — ``l_cache``; never leaves the accelerator, no queueing.
* cache miss — design-specific translation + data path with three queueing
  points threaded in:

  - **MSHR window** (per accelerator): a miss may only *issue* once one of
    the accelerator's ``mshrs`` outstanding-miss slots is free (FIFO slot
    reuse: the i-th miss waits on the (i - mshrs)-th miss's completion).
  - **Memory-side TLB ports** (per partition, SPARTA only): a translation
    waits for the earliest-free of the partition's ``tlb_ports`` ports and
    occupies it for ``tlb_occ`` cycles.
  - **DRAM banks** (machine-wide): every DRAM reference (page walk, PTE
    read, data fetch) waits for its bank and occupies it for ``dram_occ``
    cycles.

With every resource unbounded (count 0) all waits vanish and the per-access
latency is exactly the Fig 3 analytical composition, so the post-warmup mean
reproduces :mod:`repro.core.cpi` — the subsystem's oracle property.

Arithmetic is float32 but every default latency parameter is an integer
number of cycles, so all absolute times and latencies stay exactly
representable (integer cycle counts) far beyond any benchmark's horizon.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class TimelineParams(NamedTuple):
    """Static (compile-time) scan parameters.

    ``serial_walk`` selects the conventional design (private accel-side TLB,
    page walk serialized before the data fetch); ``mem_tlb`` selects SPARTA
    (translation at the partition's memory-side TLB, overlapped with the
    network traversal).  Neither flag => DIPTA/ideal (translation fully
    overlapped; the per-access ``pen`` input carries DIPTA's serialized
    way-misprediction penalty, 0 for ideal).

    A resource count of 0 means *unbounded* (no queueing on that resource).
    """

    serial_walk: bool = False
    mem_tlb: bool = False
    num_accels: int = 1
    mshrs: int = 0            # outstanding-miss slots per accelerator
    num_partitions: int = 1   # memory-side TLB partitions (SPARTA P)
    tlb_ports: int = 0        # service ports per partition TLB
    dram_banks: int = 0       # DRAM banks machine-wide
    l_cache: float = 2.0
    l_tlb: float = 2.0
    l_dram: float = 120.0
    t_net: float = 390.0
    tlb_occ: float = 2.0      # port busy time per probe
    dram_occ: float = 120.0   # bank busy time per access
    issue_interval: float = 1.0  # cycles between successive issues per accel


def timeline_init_state(p: TimelineParams):
    """All-zero queueing state (times in cycles; everything free at t=0)."""
    A = p.num_accels
    return (
        jnp.zeros((A,), jnp.float32),                       # next nominal issue
        jnp.zeros((A, max(p.mshrs, 1)), jnp.float32),       # MSHR slot free times
        jnp.zeros((A,), jnp.int32),                         # per-accel miss count
        jnp.zeros((max(p.num_partitions, 1), max(p.tlb_ports, 1)), jnp.float32),
        jnp.zeros((max(p.dram_banks, 1),), jnp.float32),    # bank free times
    )


def timeline_step(state, inp, p: TimelineParams):
    """Advance the queueing state by one access.

    ``inp`` is the per-access tuple ``(accel, partition, bank_data, bank_pte,
    cache_hit, tlb_hit, mem_tlb_hit, pen)`` (int32 scalars + float32 ``pen``).
    Returns ``(state', (latency, overhead, done))`` where ``latency`` is
    issue->completion cycles, ``overhead`` the translation-induced component
    (including translation queue waits), ``done`` the absolute completion
    time.  Latencies are composed from *segments* (waits + service times), not
    endpoint differences, so unqueued runs are exact in float32 regardless of
    how far absolute time has advanced.
    """
    acc_next, mshr_ring, mshr_cnt, port_free, bank_free = state
    a, part, bank_d, bank_p, c, th, mh, pen = inp
    zero = jnp.float32(0.0)
    c_hit = c != 0
    nominal = acc_next[a]

    # --- MSHR admission: a miss needs a free outstanding-miss slot. ---------
    if p.mshrs > 0:
        slot = mshr_cnt[a] % p.mshrs
        w_mshr = jnp.maximum(mshr_ring[a, slot] - nominal, zero)
        issue = nominal + jnp.where(c_hit, zero, w_mshr)
    else:
        slot = jnp.int32(0)
        issue = nominal

    t0 = issue + p.l_cache  # cache probe; a miss leaves the accelerator here

    # --- translation path (computed unconditionally, applied on miss) -------
    if p.serial_walk:
        # Conventional: private accel-side TLB probe, then a page walk (one
        # memory reference over the network) serialized before the data fetch.
        walk_arr = t0 + p.l_tlb + p.t_net
        if p.dram_banks > 0:
            w_walk = jnp.maximum(bank_free[bank_p] - walk_arr, zero)
            do_walk = (~c_hit) & (th == 0)
            bank_free = bank_free.at[bank_p].set(jnp.where(
                do_walk, walk_arr + w_walk + p.dram_occ, bank_free[bank_p]))
        else:
            w_walk = zero
        walk = 2.0 * p.t_net + w_walk + p.l_dram
        trans = p.l_tlb + jnp.where(th != 0, zero, walk)
        # data fetch departs only after the walk returns: l_cache + trans,
        # then a full network round trip around the data DRAM access.
        data_arr = t0 + trans + p.t_net
        pen_eff = zero
    elif p.mem_tlb:
        # SPARTA: request reaches the partition after one traversal; the
        # memory-side TLB probe queues on the partition's ports and a miss
        # reads the PTE from the *local* DRAM (no extra traversals).
        arr = t0 + p.t_net
        if p.tlb_ports > 0:
            row = port_free[part]
            pslot = jnp.argmin(row)
            w_port = jnp.maximum(row[pslot] - arr, zero)
            port_free = port_free.at[part, pslot].set(jnp.where(
                ~c_hit, arr + w_port + p.tlb_occ, row[pslot]))
        else:
            w_port = zero
        probe_done = arr + w_port + p.l_tlb
        if p.dram_banks > 0:
            w_pte = jnp.maximum(bank_free[bank_p] - probe_done, zero)
            do_pte = (~c_hit) & (mh == 0)
            bank_free = bank_free.at[bank_p].set(jnp.where(
                do_pte, probe_done + w_pte + p.dram_occ, bank_free[bank_p]))
        else:
            w_pte = zero
        trans = w_port + p.l_tlb + jnp.where(mh != 0, zero, w_pte + p.l_dram)
        data_arr = arr + trans  # translation completes at the partition
        pen_eff = zero
    else:
        # DIPTA/ideal: translation fully overlapped with the row fetch; pen
        # carries DIPTA's serialized way-misprediction penalty (0 for ideal).
        trans = pen
        data_arr = t0 + p.t_net
        pen_eff = pen

    # --- data DRAM access (all designs) -------------------------------------
    if p.dram_banks > 0:
        w_data = jnp.maximum(bank_free[bank_d] - data_arr, zero)
        bank_free = bank_free.at[bank_d].set(jnp.where(
            ~c_hit, data_arr + w_data + p.dram_occ + pen_eff, bank_free[bank_d]))
    else:
        w_data = zero

    if p.serial_walk:
        lat_miss = p.l_cache + trans + p.t_net + w_data + p.l_dram + p.t_net
    elif p.mem_tlb:
        lat_miss = p.l_cache + p.t_net + trans + w_data + p.l_dram + p.t_net
    else:
        lat_miss = p.l_cache + p.t_net + w_data + p.l_dram + pen_eff + p.t_net

    latency = jnp.where(c_hit, jnp.float32(p.l_cache), lat_miss)
    overhead = jnp.where(c_hit, zero, trans)
    done = issue + latency

    # --- state updates -------------------------------------------------------
    if p.mshrs > 0:
        mshr_ring = mshr_ring.at[a, slot].set(
            jnp.where(c_hit, mshr_ring[a, slot], done))
        mshr_cnt = mshr_cnt.at[a].add(jnp.where(c_hit, 0, 1))
    acc_next = acc_next.at[a].set(issue + p.issue_interval)
    return (acc_next, mshr_ring, mshr_cnt, port_free, bank_free), (
        latency, overhead, done)


@functools.partial(jax.jit, static_argnames=("params",))
def timeline_scan_ref(
    accel: jnp.ndarray,      # int32 [N] issuing accelerator id
    part: jnp.ndarray,       # int32 [N] memory-side TLB partition id
    bank_data: jnp.ndarray,  # int32 [N] DRAM bank of the data line
    bank_pte: jnp.ndarray,   # int32 [N] DRAM bank of the PTE
    cache_hit: jnp.ndarray,  # int32 [N] 1 = cache hit
    tlb_hit: jnp.ndarray,    # int32 [N] accel-TLB hit (conventional)
    mem_hit: jnp.ndarray,    # int32 [N] memory-side TLB hit (SPARTA)
    pen: jnp.ndarray,        # f32   [N] serialized penalty (DIPTA)
    params: TimelineParams,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Sequential timeline simulation; returns (latency, overhead, done)."""

    def step(state, inp):
        return timeline_step(state, inp, params)

    _, ys = jax.lax.scan(
        step, timeline_init_state(params),
        (accel, part, bank_data, bank_pte, cache_hit, tlb_hit, mem_hit, pen),
    )
    return ys
