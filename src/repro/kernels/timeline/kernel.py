"""Cycle-approximate timeline simulation as a Pallas TPU kernel.

Same architecture as the ``tlb_sim`` trace kernel: the full queueing state
(per-accelerator issue/MSHR windows, per-partition TLB port free times, DRAM
bank free times — a few KB at any realistic configuration) stays **resident
in VMEM scratch** for the entire trace.  TPU grids execute sequentially, so
scratch persists across grid steps while each step streams one trace block
(the eight per-access input columns) HBM->VMEM and writes the block's
(latency, overhead, done) columns back.

The per-access update is :func:`repro.kernels.timeline.ref.timeline_step` —
*shared* with the ``lax.scan`` oracle, so the two paths are bit-identical by
construction.  Inside the kernel the state is read from scratch as whole
(small) arrays, advanced functionally, and stored back; the access loop is
inherently serial (queue state carries a dependency) but each step is a
handful of scalar gathers plus a ports-wide argmin.

``timeline_sim_batched_pallas`` adds the **sim batch dimension** for the
``sweep_timeline`` engine (:mod:`repro.core.timeline`): B sims' queueing
states are stacked as the leading VMEM scratch axis (padded to the batch's
common resource envelope, poisoned per ``ref.timeline_init_state_batched``),
each grid step fetches one trace block HBM->VMEM once for all sims, and the
per-sim configuration rides along as packed ``fparams``/``iparams`` rows
consumed by the shared :func:`~repro.kernels.timeline.ref.timeline_step_dyn`.
The sim axis is what gives this kernel something to amortize — a single
sequential sim is better served by the scan reference (see the ``"auto"``
dispatch note in ``ops.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.timeline.ref import (
    PORT_POISON,
    TimelineParams,
    timeline_step,
    timeline_step_dyn,
)


def _timeline_kernel(
    a_ref, p_ref, bd_ref, bp_ref,   # int32 [BLK] ids
    c_ref, th_ref, mh_ref,          # int32 [BLK] hit bits
    pen_ref,                        # f32   [BLK] serialized penalty
    lat_ref, ov_ref, done_ref,      # f32   [BLK] outputs
    acc_scr, mshr_scr, cnt_scr, port_scr, bank_scr,  # persistent VMEM state
    *,
    block: int,
    params: TimelineParams,
):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)
        mshr_scr[...] = jnp.zeros_like(mshr_scr)
        cnt_scr[...] = jnp.zeros_like(cnt_scr)
        port_scr[...] = jnp.zeros_like(port_scr)
        bank_scr[...] = jnp.zeros_like(bank_scr)

    def body(j, _):
        state = (acc_scr[...], mshr_scr[...], cnt_scr[...],
                 port_scr[...], bank_scr[...])
        inp = (a_ref[j], p_ref[j], bd_ref[j], bp_ref[j],
               c_ref[j], th_ref[j], mh_ref[j], pen_ref[j])
        (acc, mshr, cnt, port, bank), (lat, ov, done) = timeline_step(
            state, inp, params)
        acc_scr[...] = acc
        mshr_scr[...] = mshr
        cnt_scr[...] = cnt
        port_scr[...] = port
        bank_scr[...] = bank
        lat_ref[j] = lat
        ov_ref[j] = ov
        done_ref[j] = done
        return 0

    jax.lax.fori_loop(0, block, body, 0)


def _timeline_batched_kernel(
    a_ref, p_ref, bd_ref, bp_ref,   # int32 [B, BLK] ids
    c_ref, th_ref, mh_ref,          # int32 [B, BLK] hit bits
    pen_ref,                        # f32   [B, BLK] serialized penalty
    fp_ref,                         # f32   [B, 8]  per-sim latency table
    ip_ref,                         # int32 [B, 7]  per-sim flags/counts
    lat_ref, ov_ref, done_ref,      # f32   [B, BLK] outputs
    acc_scr, mshr_scr, cnt_scr, port_scr, bank_scr,  # stacked VMEM state
    *,
    block: int,
    num_sims: int,
):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)
        mshr_scr[...] = jnp.zeros_like(mshr_scr)
        cnt_scr[...] = jnp.zeros_like(cnt_scr)
        # Port columns beyond each sim's own tlb_ports are poisoned as
        # always-busy so the earliest-free argmin never selects them (the
        # exact init of ref.timeline_init_state_batched).
        col = jax.lax.broadcasted_iota(jnp.int32, port_scr.shape, 2)
        port_scr[...] = jnp.where(col < ip_ref[:, 5][:, None, None],
                                  jnp.float32(0.0), jnp.float32(PORT_POISON))
        bank_scr[...] = jnp.zeros_like(bank_scr)

    def body(j, _):
        def per_sim(b, _):
            state = (acc_scr[b], mshr_scr[b], cnt_scr[b],
                     port_scr[b], bank_scr[b])
            inp = (a_ref[b, j], p_ref[b, j], bd_ref[b, j], bp_ref[b, j],
                   c_ref[b, j], th_ref[b, j], mh_ref[b, j], pen_ref[b, j])
            (acc, mshr, cnt, port, bank), (lat, ov, done) = timeline_step_dyn(
                state, inp, fp_ref[b], ip_ref[b])
            acc_scr[b] = acc
            mshr_scr[b] = mshr
            cnt_scr[b] = cnt
            port_scr[b] = port
            bank_scr[b] = bank
            lat_ref[b, j] = lat
            ov_ref[b, j] = ov
            done_ref[b, j] = done
            return 0

        jax.lax.fori_loop(0, num_sims, per_sim, 0)
        return 0

    jax.lax.fori_loop(0, block, body, 0)


def _timeline_batched_carry_kernel(
    a_ref, p_ref, bd_ref, bp_ref,   # int32 [B, BLK] ids
    c_ref, th_ref, mh_ref,          # int32 [B, BLK] hit bits
    pen_ref,                        # f32   [B, BLK]
    fp_ref,                         # f32   [B, 8]
    ip_ref,                         # int32 [B, 7]
    acc_in, mshr_in, cnt_in, port_in, bank_in,       # carried state in
    lat_ref, ov_ref, done_ref,      # f32   [B, BLK] outputs
    acc_scr, mshr_scr, cnt_scr, port_scr, bank_scr,  # carried state out =
    *,                                               # working state
    block: int,
    num_sims: int,
):
    """Chunk-resumable variant of :func:`_timeline_batched_kernel`: the five
    state-out refs (constant-index BlockSpecs, VMEM-resident across the
    sequential grid) are the working state, loaded from the carried state-in
    at grid step 0 — the caller owns the zero/poison init.  Queueing state
    holds absolute times, so no access counter is threaded; chunked execution
    is bit-identical to the monolithic kernel."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _load():
        acc_scr[...] = acc_in[...]
        mshr_scr[...] = mshr_in[...]
        cnt_scr[...] = cnt_in[...]
        port_scr[...] = port_in[...]
        bank_scr[...] = bank_in[...]

    def body(j, _):
        def per_sim(b, _):
            state = (acc_scr[b], mshr_scr[b], cnt_scr[b],
                     port_scr[b], bank_scr[b])
            inp = (a_ref[b, j], p_ref[b, j], bd_ref[b, j], bp_ref[b, j],
                   c_ref[b, j], th_ref[b, j], mh_ref[b, j], pen_ref[b, j])
            (acc, mshr, cnt, port, bank), (lat, ov, done) = timeline_step_dyn(
                state, inp, fp_ref[b], ip_ref[b])
            acc_scr[b] = acc
            mshr_scr[b] = mshr
            cnt_scr[b] = cnt
            port_scr[b] = port
            bank_scr[b] = bank
            lat_ref[b, j] = lat
            ov_ref[b, j] = ov
            done_ref[b, j] = done
            return 0

        jax.lax.fori_loop(0, num_sims, per_sim, 0)
        return 0

    jax.lax.fori_loop(0, block, body, 0)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def timeline_sim_batched_pallas_carry(
    accel: jnp.ndarray,      # int32 [B, L] one trace chunk
    part: jnp.ndarray,
    bank_data: jnp.ndarray,
    bank_pte: jnp.ndarray,
    cache_hit: jnp.ndarray,
    tlb_hit: jnp.ndarray,
    mem_hit: jnp.ndarray,
    pen: jnp.ndarray,        # f32 [B, L]
    fparams: jnp.ndarray,    # f32 [B, 8]
    iparams: jnp.ndarray,    # int32 [B, 7]
    state,                   # 5-tuple carried queueing state
    *,
    block: int = 512,
    interpret: bool = False,
):
    """Chunk-resumable batched timeline simulation; returns
    ``((latency, overhead, done), state')``."""
    B, n = accel.shape
    block = min(block, n)
    assert n % block == 0, f"chunk length {n} must be a multiple of block {block}"
    grid = (n // block,)
    stream = pl.BlockSpec((B, block), lambda i: (0, i))
    whole = lambda c: pl.BlockSpec((B, c), lambda i: (0, 0))

    def whole_nd(arr):
        return pl.BlockSpec(arr.shape, lambda i: (0,) * arr.ndim)

    state_dtypes = (jnp.float32, jnp.float32, jnp.int32, jnp.float32,
                    jnp.float32)
    outs = pl.pallas_call(
        functools.partial(
            _timeline_batched_carry_kernel, block=block, num_sims=B),
        grid=grid,
        in_specs=[stream] * 8 + [whole(8), whole(7)]
        + [whole_nd(s) for s in state],
        out_specs=[stream] * 3 + [whole_nd(s) for s in state],
        out_shape=[jax.ShapeDtypeStruct((B, n), jnp.float32)] * 3
        + [jax.ShapeDtypeStruct(s.shape, d)
           for s, d in zip(state, state_dtypes)],
        interpret=interpret,
    )(accel.astype(jnp.int32), part.astype(jnp.int32),
      bank_data.astype(jnp.int32), bank_pte.astype(jnp.int32),
      cache_hit.astype(jnp.int32), tlb_hit.astype(jnp.int32),
      mem_hit.astype(jnp.int32), pen.astype(jnp.float32),
      fparams.astype(jnp.float32), iparams.astype(jnp.int32),
      *(s.astype(d) for s, d in zip(state, state_dtypes)))
    return tuple(outs[:3]), tuple(outs[3:])


@functools.partial(
    jax.jit, static_argnames=("envelope", "block", "interpret"))
def timeline_sim_batched_pallas(
    accel: jnp.ndarray,      # int32 [B, N]
    part: jnp.ndarray,
    bank_data: jnp.ndarray,
    bank_pte: jnp.ndarray,
    cache_hit: jnp.ndarray,
    tlb_hit: jnp.ndarray,
    mem_hit: jnp.ndarray,
    pen: jnp.ndarray,        # f32 [B, N]
    fparams: jnp.ndarray,    # f32 [B, 8]
    iparams: jnp.ndarray,    # int32 [B, 7]
    envelope,                # (A, M, P, T, D) resource envelope
    *,
    block: int = 512,
    interpret: bool = False,
):
    """B-sim batched timeline simulation: every sim's queueing state is
    stacked on the leading VMEM scratch axis and each grid step streams one
    trace block (all sims' per-access columns) HBM->VMEM once.  Returns
    (latency, overhead, done), each f32 [B, N]; per sim bit-identical to
    :func:`timeline_sim_pallas` / the scan reference on that sim's own
    configuration (they all run one shared step)."""
    B, n = accel.shape
    A, M, P, T, D = envelope
    block = min(block, n)
    assert n % block == 0, f"trace length {n} must be a multiple of block {block}"
    grid = (n // block,)
    stream = pl.BlockSpec((B, block), lambda i: (0, i))
    whole = lambda c: pl.BlockSpec((B, c), lambda i: (0, 0))
    outs = pl.pallas_call(
        functools.partial(_timeline_batched_kernel, block=block, num_sims=B),
        grid=grid,
        in_specs=[stream] * 8 + [whole(8), whole(7)],
        out_specs=[stream] * 3,
        out_shape=[jax.ShapeDtypeStruct((B, n), jnp.float32)] * 3,
        scratch_shapes=[
            pltpu.VMEM((B, A), jnp.float32),
            pltpu.VMEM((B, A, M), jnp.float32),
            pltpu.VMEM((B, A), jnp.int32),
            pltpu.VMEM((B, P, T), jnp.float32),
            pltpu.VMEM((B, D), jnp.float32),
        ],
        interpret=interpret,
    )(accel.astype(jnp.int32), part.astype(jnp.int32),
      bank_data.astype(jnp.int32), bank_pte.astype(jnp.int32),
      cache_hit.astype(jnp.int32), tlb_hit.astype(jnp.int32),
      mem_hit.astype(jnp.int32), pen.astype(jnp.float32),
      fparams.astype(jnp.float32), iparams.astype(jnp.int32))
    return tuple(outs)


@functools.partial(jax.jit, static_argnames=("params", "block", "interpret"))
def timeline_sim_pallas(
    accel: jnp.ndarray,
    part: jnp.ndarray,
    bank_data: jnp.ndarray,
    bank_pte: jnp.ndarray,
    cache_hit: jnp.ndarray,
    tlb_hit: jnp.ndarray,
    mem_hit: jnp.ndarray,
    pen: jnp.ndarray,
    params: TimelineParams,
    *,
    block: int = 512,
    interpret: bool = False,
):
    """Returns (latency, overhead, done), each f32 [N]."""
    n = accel.shape[0]
    block = min(block, n)
    assert n % block == 0, f"trace length {n} must be a multiple of block {block}"
    grid = (n // block,)
    stream = pl.BlockSpec((block,), lambda i: (i,))
    A = params.num_accels
    outs = pl.pallas_call(
        functools.partial(_timeline_kernel, block=block, params=params),
        grid=grid,
        in_specs=[stream] * 8,
        out_specs=[stream] * 3,
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 3,
        scratch_shapes=[
            pltpu.VMEM((A,), jnp.float32),
            pltpu.VMEM((A, max(params.mshrs, 1)), jnp.float32),
            pltpu.VMEM((A,), jnp.int32),
            pltpu.VMEM((max(params.num_partitions, 1), max(params.tlb_ports, 1)),
                       jnp.float32),
            pltpu.VMEM((max(params.dram_banks, 1),), jnp.float32),
        ],
        interpret=interpret,
    )(accel.astype(jnp.int32), part.astype(jnp.int32),
      bank_data.astype(jnp.int32), bank_pte.astype(jnp.int32),
      cache_hit.astype(jnp.int32), tlb_hit.astype(jnp.int32),
      mem_hit.astype(jnp.int32), pen.astype(jnp.float32))
    return tuple(outs)
