"""Public timeline-simulation op with kernel-mode dispatch."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.kernels.common import resolve_mode
from repro.kernels.timeline.kernel import timeline_sim_pallas
from repro.kernels.timeline.ref import TimelineParams, timeline_scan_ref

__all__ = ["TimelineParams", "timeline_sim"]


def timeline_sim(
    accel: jnp.ndarray,      # int32 [N]
    part: jnp.ndarray,       # int32 [N]
    bank_data: jnp.ndarray,  # int32 [N]
    bank_pte: jnp.ndarray,   # int32 [N]
    cache_hit: jnp.ndarray,  # int32 [N]
    tlb_hit: jnp.ndarray,    # int32 [N]
    mem_hit: jnp.ndarray,    # int32 [N]
    pen: jnp.ndarray,        # f32   [N]
    params: TimelineParams,
    *,
    block: int = 512,
    kernel_mode: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-access (latency, overhead, completion-time) for one trace.

    The Pallas path streams whole blocks; the trace is padded with trailing
    cache hits from accelerator 0 (they read state but complete locally and
    cannot perturb any earlier access), then the padding's outputs dropped.
    """
    mode = resolve_mode(kernel_mode)
    n = int(accel.shape[0])
    if mode == "reference" or n == 0:
        return timeline_scan_ref(
            accel, part, bank_data, bank_pte,
            cache_hit, tlb_hit, mem_hit, pen, params)
    pad = (-n) % min(block, n)
    if pad:
        def pad_i(x, v):
            return jnp.concatenate(
                [x, jnp.full((pad,), v, dtype=x.dtype)])
        accel, part = pad_i(accel, 0), pad_i(part, 0)
        bank_data, bank_pte = pad_i(bank_data, 0), pad_i(bank_pte, 0)
        cache_hit = pad_i(cache_hit, 1)  # padding = local cache hits
        tlb_hit, mem_hit = pad_i(tlb_hit, 1), pad_i(mem_hit, 1)
        pen = pad_i(pen, np.float32(0.0))
    lat, ov, done = timeline_sim_pallas(
        accel, part, bank_data, bank_pte,
        cache_hit, tlb_hit, mem_hit, pen, params,
        block=block, interpret=(mode == "pallas_interpret"))
    return lat[:n], ov[:n], done[:n]
