"""Public timeline-simulation ops with kernel-mode dispatch.

``"auto"`` resolution is *batch-aware*: a single sequential simulation gives
the Pallas kernel nothing to amortize (measured 0.87x of the ``lax.scan``
reference in BENCH_sweep.json), so the degenerate batch — ``timeline_sim``,
or ``timeline_sim_batched`` with one sim — always auto-selects the scan
reference; multi-sim batches auto-select the batched kernel on TPU backends.
Explicit ``"pallas"`` / ``"pallas_interpret"`` are honoured as given.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.kernels.common import SWEEP_MODES, VALID_MODES, resolve_mode
from repro.kernels.timeline.kernel import (
    timeline_sim_batched_pallas,
    timeline_sim_batched_pallas_carry,
    timeline_sim_pallas,
)
from repro.kernels.timeline.ref import (
    FP_COLS,
    IP_COLS,
    TimelineParams,
    pack_params,
    timeline_init_state_batched,
    timeline_scan_batched_carry_ref,
    timeline_scan_batched_ref,
    timeline_scan_ref,
)

__all__ = ["TimelineParams", "timeline_sim", "timeline_sim_batched",
           "timeline_sim_batched_carry", "timeline_init_state_batched",
           "pack_params", "resolve_timeline_mode", "FP_COLS", "IP_COLS"]


def resolve_timeline_mode(kernel_mode: str, *, batch: int = 1) -> str:
    """Validate and resolve ``kernel_mode`` for the timeline engine.

    Sweep-only backends are rejected loudly (no silent coercion): the
    timeline is not a pure-LRU sweep, so ``"stackdist"`` cannot apply.
    ``"auto"`` resolves through the dispatch layer's cold-start rule: the
    scan reference for a degenerate (single-sim) batch — the 0.87x
    single-sequential-sim Pallas path is never auto-selected cold — and the
    generic backend rule otherwise (calibrated decisions happen upstream in
    :mod:`repro.core.dispatch` before per-op calls see a mode).
    """
    if kernel_mode in SWEEP_MODES and kernel_mode not in VALID_MODES:
        raise ValueError(
            f"kernel_mode={kernel_mode!r} is a sweep_tlb/miss_ratio_curve-only "
            f"backend, not a timeline backend; the timeline engine accepts "
            f"one of {VALID_MODES}")
    if kernel_mode == "auto":
        from repro.core import dispatch

        return dispatch.cold_start_mode("sweep_timeline", batch=batch)
    return resolve_mode(kernel_mode)


def timeline_sim(
    accel: jnp.ndarray,      # int32 [N]
    part: jnp.ndarray,       # int32 [N]
    bank_data: jnp.ndarray,  # int32 [N]
    bank_pte: jnp.ndarray,   # int32 [N]
    cache_hit: jnp.ndarray,  # int32 [N]
    tlb_hit: jnp.ndarray,    # int32 [N]
    mem_hit: jnp.ndarray,    # int32 [N]
    pen: jnp.ndarray,        # f32   [N]
    params: TimelineParams,
    *,
    block: int = 512,
    kernel_mode: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-access (latency, overhead, completion-time) for one trace.

    The Pallas path streams whole blocks; the trace is padded with trailing
    cache hits from accelerator 0 (they read state but complete locally and
    cannot perturb any earlier access), then the padding's outputs dropped.
    """
    mode = resolve_timeline_mode(kernel_mode, batch=1)
    n = int(accel.shape[0])
    if mode == "reference" or n == 0:
        return timeline_scan_ref(
            accel, part, bank_data, bank_pte,
            cache_hit, tlb_hit, mem_hit, pen, params)
    pad = (-n) % min(block, n)
    if pad:
        def pad_i(x, v):
            return jnp.concatenate(
                [x, jnp.full((pad,), v, dtype=x.dtype)])
        accel, part = pad_i(accel, 0), pad_i(part, 0)
        bank_data, bank_pte = pad_i(bank_data, 0), pad_i(bank_pte, 0)
        cache_hit = pad_i(cache_hit, 1)  # padding = local cache hits
        tlb_hit, mem_hit = pad_i(tlb_hit, 1), pad_i(mem_hit, 1)
        pen = pad_i(pen, np.float32(0.0))
    lat, ov, done = timeline_sim_pallas(
        accel, part, bank_data, bank_pte,
        cache_hit, tlb_hit, mem_hit, pen, params,
        block=block, interpret=(mode == "pallas_interpret"))
    return lat[:n], ov[:n], done[:n]


def timeline_sim_batched(
    accel: jnp.ndarray,      # int32 [B, N]
    part: jnp.ndarray,       # int32 [B, N]
    bank_data: jnp.ndarray,  # int32 [B, N]
    bank_pte: jnp.ndarray,   # int32 [B, N]
    cache_hit: jnp.ndarray,  # int32 [B, N]
    tlb_hit: jnp.ndarray,    # int32 [B, N]
    mem_hit: jnp.ndarray,    # int32 [B, N]
    pen: jnp.ndarray,        # f32   [B, N]
    fparams: np.ndarray,     # f32   [B, 8]  (FP_COLS, see pack_params)
    iparams: np.ndarray,     # int32 [B, 7]  (IP_COLS)
    *,
    block: int = 512,
    kernel_mode: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """B-sim batched timeline simulation (the ``sweep_timeline`` hot loop):
    every sim's queueing state advances together through ONE pass over the
    stacked trace.  Returns (latency, overhead, done), each f32 [B, N];
    per sim bit-identical to :func:`timeline_sim` on that sim's own
    configuration.

    ``iparams`` must be a *concrete* array — the resource envelope (max
    num_accels / mshrs / partitions / tlb_ports / dram_banks across sims,
    each floored at 1) is derived from it as a static state shape.
    """
    ip = np.asarray(iparams)
    envelope = tuple(
        max(int(ip[:, c].max()), 1) for c in (2, 3, 4, 5, 6))
    mode = resolve_timeline_mode(kernel_mode, batch=int(accel.shape[0]))
    n = int(accel.shape[1])
    if mode == "reference" or n == 0:
        return timeline_scan_batched_ref(
            accel, part, bank_data, bank_pte,
            cache_hit, tlb_hit, mem_hit, pen,
            jnp.asarray(fparams), jnp.asarray(ip), envelope)
    pad = (-n) % min(block, n)
    if pad:
        def pad_i(x, v):
            return jnp.concatenate(
                [x, jnp.full((x.shape[0], pad), v, dtype=x.dtype)], axis=1)
        accel, part = pad_i(accel, 0), pad_i(part, 0)
        bank_data, bank_pte = pad_i(bank_data, 0), pad_i(bank_pte, 0)
        cache_hit = pad_i(cache_hit, 1)  # padding = local cache hits
        tlb_hit, mem_hit = pad_i(tlb_hit, 1), pad_i(mem_hit, 1)
        pen = pad_i(pen, np.float32(0.0))
    lat, ov, done = timeline_sim_batched_pallas(
        accel, part, bank_data, bank_pte,
        cache_hit, tlb_hit, mem_hit, pen,
        jnp.asarray(fparams), jnp.asarray(ip), envelope,
        block=block, interpret=(mode == "pallas_interpret"))
    return lat[:, :n], ov[:, :n], done[:, :n]


def timeline_sim_batched_carry(
    accel: jnp.ndarray,      # int32 [B, L] one trace chunk
    part: jnp.ndarray,
    bank_data: jnp.ndarray,
    bank_pte: jnp.ndarray,
    cache_hit: jnp.ndarray,
    tlb_hit: jnp.ndarray,
    mem_hit: jnp.ndarray,
    pen: jnp.ndarray,        # f32 [B, L]
    fparams: np.ndarray,     # f32 [B, 8]
    iparams: np.ndarray,     # int32 [B, 7]
    state,                   # 5-tuple carried queueing state
    *,
    block: int = 512,
    kernel_mode: str = "auto",
):
    """Chunk-resumable :func:`timeline_sim_batched`: run ONE trace chunk
    against caller-owned carried queueing state (initialise with
    :func:`timeline_init_state_batched` on the batch's resource envelope).
    Returns ``((latency, overhead, done) f32 [B, L], state')``; chunked
    execution is bit-identical to the monolithic op in any mode and across
    mode changes at chunk boundaries (state layout and step function are
    shared by all backends).  Unlike the monolithic op this does NOT pad the
    chunk — mid-stream padding would perturb accelerator 0's issue clock —
    so a Pallas-mode chunk length must be a block multiple (or a single
    short block, ``L <= block``); the stream layer enforces that.
    """
    ip = np.asarray(iparams)
    mode = resolve_timeline_mode(kernel_mode, batch=int(accel.shape[0]))
    if mode == "reference" or int(accel.shape[1]) == 0:
        return timeline_scan_batched_carry_ref(
            accel, part, bank_data, bank_pte,
            cache_hit, tlb_hit, mem_hit, pen,
            jnp.asarray(fparams), jnp.asarray(ip), tuple(state))
    return timeline_sim_batched_pallas_carry(
        accel, part, bank_data, bank_pte,
        cache_hit, tlb_hit, mem_hit, pen,
        jnp.asarray(fparams), jnp.asarray(ip), tuple(state),
        block=block, interpret=(mode == "pallas_interpret"))
