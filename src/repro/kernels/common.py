"""Shared kernel-dispatch helpers.

Every kernel package exposes ``ops.py`` with a public op that takes
``kernel_mode``:

* ``"reference"``        — pure-jnp oracle (``ref.py``).  Default on CPU and
                           inside dry-run graphs (the CPU backend cannot
                           compile Mosaic/TPU kernels).
* ``"pallas"``           — the TPU kernel (``kernel.py``), compiled by Mosaic.
* ``"pallas_interpret"`` — the same kernel body executed by the Pallas
                           interpreter on CPU; used by the test suite to
                           validate kernels against the oracle.
* ``"auto"``             — ``pallas`` on TPU backends, else ``reference``.
"""
from __future__ import annotations

import jax

VALID_MODES = ("auto", "reference", "pallas", "pallas_interpret")


def resolve_mode(kernel_mode: str) -> str:
    if kernel_mode not in VALID_MODES:
        raise ValueError(f"kernel_mode={kernel_mode!r}; expected one of {VALID_MODES}")
    if kernel_mode == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "reference"
    return kernel_mode


def next_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
