"""Shared kernel-dispatch helpers.

Every kernel package exposes ``ops.py`` with a public op that takes
``kernel_mode``:

* ``"reference"``        — pure-jnp oracle (``ref.py``).  Default on CPU and
                           inside dry-run graphs (the CPU backend cannot
                           compile Mosaic/TPU kernels).
* ``"pallas"``           — the TPU kernel (``kernel.py``), compiled by Mosaic.
* ``"pallas_interpret"`` — the same kernel body executed by the Pallas
                           interpreter on CPU; used by the test suite to
                           validate kernels against the oracle.
* ``"auto"``             — resolved by :mod:`repro.core.dispatch`, the one
                           calibrated backend-selection layer.  For a bare
                           per-op call that is the cold-start rule
                           (``pallas`` on TPU backends, else ``reference``);
                           the orchestrated engines make a full
                           :class:`~repro.core.dispatch.DispatchDecision`
                           with per-candidate predicted rates.

The trace-sweep engine (:mod:`repro.core.sweep`) accepts one extra mode on
top of the generic four: ``"stackdist"``, the exact sort-based
stack-distance backend (:mod:`repro.core.stackdist`).  Sweep entry points
validate against :data:`SWEEP_MODES`; whether ``"auto"`` picks it is the
dispatch layer's call (every-spec-eligible pure-LRU TLBs) — per-op kernels
keep the plain four-mode registry.
"""
from __future__ import annotations

from typing import Sequence

import jax

VALID_MODES = ("auto", "reference", "pallas", "pallas_interpret")
SWEEP_MODES = VALID_MODES + ("stackdist",)


def resolve_mode(
    kernel_mode: str,
    *,
    valid: Sequence[str] = VALID_MODES,
) -> str:
    """Validate ``kernel_mode`` against ``valid`` and resolve ``"auto"``.

    Explicit modes are always honoured as given; ``"auto"`` resolves to the
    dispatch layer's generic cold-start default (engine entry points make a
    richer, calibrated decision through :mod:`repro.core.dispatch` before
    their per-op calls ever see a mode).
    """
    if kernel_mode not in valid:
        raise ValueError(f"kernel_mode={kernel_mode!r}; expected one of {tuple(valid)}")
    if kernel_mode == "auto":
        from repro.core import dispatch

        return dispatch.default_mode()
    return kernel_mode


def next_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
