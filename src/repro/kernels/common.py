"""Shared kernel-dispatch helpers.

Every kernel package exposes ``ops.py`` with a public op that takes
``kernel_mode``:

* ``"reference"``        — pure-jnp oracle (``ref.py``).  Default on CPU and
                           inside dry-run graphs (the CPU backend cannot
                           compile Mosaic/TPU kernels).
* ``"pallas"``           — the TPU kernel (``kernel.py``), compiled by Mosaic.
* ``"pallas_interpret"`` — the same kernel body executed by the Pallas
                           interpreter on CPU; used by the test suite to
                           validate kernels against the oracle.
* ``"auto"``             — ``pallas`` on TPU backends, else ``reference``.

The trace-sweep engine (:mod:`repro.core.sweep`) accepts one extra mode on
top of the generic four: ``"stackdist"``, the exact sort-based
stack-distance backend (:mod:`repro.core.stackdist`).  Sweep entry points
validate against :data:`SWEEP_MODES` and pass ``prefer="stackdist"`` so that
``"auto"`` picks it whenever every spec is a pure-LRU TLB it can serve —
per-op kernels keep the plain four-mode registry.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

VALID_MODES = ("auto", "reference", "pallas", "pallas_interpret")
SWEEP_MODES = VALID_MODES + ("stackdist",)


def resolve_mode(
    kernel_mode: str,
    *,
    valid: Sequence[str] = VALID_MODES,
    prefer: Optional[str] = None,
) -> str:
    """Validate ``kernel_mode`` against ``valid`` and resolve ``"auto"``.

    ``prefer`` names the backend ``"auto"`` should pick when the caller knows
    a better-than-default one applies (e.g. the sweep engine preferring
    ``"stackdist"``); explicit modes are always honoured as given.
    """
    if kernel_mode not in valid:
        raise ValueError(f"kernel_mode={kernel_mode!r}; expected one of {tuple(valid)}")
    if kernel_mode == "auto":
        if prefer is not None:
            return prefer
        return "pallas" if jax.default_backend() == "tpu" else "reference"
    return kernel_mode


def next_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
