"""Flash attention as a Pallas TPU kernel.

Tiling: grid = (B, Hq, Tq/Bq, Tk/Bk) — the KV-block dimension is innermost,
so on TPU the grid walks KV blocks sequentially while the f32 running
(m, l, acc) state lives in VMEM scratch that persists across grid steps.
Block shapes keep the MXU busy: Bq x D and Bk x D tiles with D = head_dim
(>= 128-aligned for the MXU; smaller head dims still validate via the
interpreter and pad on real hardware).

GQA is handled in the BlockSpec index maps: the KV specs map query head
``h`` to KV head ``h // group`` — no KV replication in HBM.

Causal masking uses the decode-style alignment (query i sees keys
<= i + Tk - Tq) and fully-masked KV blocks are skipped with ``pl.when``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,            # VMEM blocks
    o_ref,                          # output block
    m_scr, l_scr, acc_scr,          # f32 scratch, persists across kv steps
    *,
    sm_scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    kv_blocks: int,
    tq: int,
    tk: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q + (tk - tq)  # decode-style causal alignment
    k_start = ki * block_k

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [Bq, D]
        k = k_ref[0, 0].astype(jnp.float32)          # [Bk, D]
        v = v_ref[0, 0].astype(jnp.float32)          # [Bk, D]
        # Zero the Tk padding of V: the masked probabilities are 0 but the
        # padded V rows may be NaN (interpret mode) — 0 * NaN = NaN.
        k_valid = (k_start + jax.lax.broadcasted_iota(jnp.int32, (block_k, 1), 0)) < tk
        v = jnp.where(k_valid, v, 0.0)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale                                  # [Bq, Bk]

        q_ids = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_ids = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = k_ids < tk  # guard Tk padding
        if causal:
            mask = mask & (k_ids <= q_ids)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                           # [Bq]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    if causal:
        # Skip KV blocks strictly above the diagonal of this Q block.
        pl.when(k_start <= q_start + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == kv_blocks - 1)
    def _finish():
        l = l_scr[...]
        safe_l = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe_l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sm_scale", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jnp.ndarray,  # [B, Hq, Tq, D]
    k: jnp.ndarray,  # [B, Hkv, Tk, D]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (D ** 0.5)

    block_q = min(block_q, Tq)
    block_k = min(block_k, Tk)
    q_blocks = pl.cdiv(Tq, block_q)
    kv_blocks = pl.cdiv(Tk, block_k)

    grid = (B, Hq, q_blocks, kv_blocks)
    kernel = functools.partial(
        _flash_kernel,
        sm_scale=scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        kv_blocks=kv_blocks,
        tq=Tq,
        tk=Tk,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
