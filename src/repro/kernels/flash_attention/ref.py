"""Pure-jnp oracle for flash attention (GQA-aware, causal optional)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,  # [B, Hq, Tq, D]
    k: jnp.ndarray,  # [B, Hkv, Tk, D]
    v: jnp.ndarray,  # [B, Hkv, Tk, D]
    *,
    causal: bool = True,
    sm_scale: float | None = None,
) -> jnp.ndarray:
    """Naive full-materialisation attention in f32; GQA via head grouping."""
    B, Hq, Tq, D = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (D ** 0.5)

    qf = q.astype(jnp.float32).reshape(B, Hkv, G, Tq, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * scale
    if causal:
        Tk = k.shape[2]
        # Decode-style alignment: query i attends to keys <= i + (Tk - Tq).
        qi = jnp.arange(Tq)[:, None] + (Tk - Tq)
        ki = jnp.arange(Tk)[None, :]
        s = jnp.where(ki <= qi, s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(B, Hq, Tq, D).astype(q.dtype)
