"""Public flash-attention op with kernel-mode dispatch."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import resolve_mode
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    kernel_mode: str = "auto",
) -> jnp.ndarray:
    mode = resolve_mode(kernel_mode)
    if mode == "reference":
        # Memory-efficient XLA path (scan over KV blocks) — semantically
        # identical to attention_ref, which remains the naive test oracle.
        from repro.models.flash_ref import flash_attention_jnp
        return flash_attention_jnp(q, k, v, causal=causal, sm_scale=sm_scale)
    return flash_attention_pallas(
        q, k, v,
        causal=causal, sm_scale=sm_scale,
        block_q=block_q, block_k=block_k,
        interpret=(mode == "pallas_interpret"),
    )
