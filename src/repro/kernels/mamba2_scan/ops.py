"""Public Mamba2 SSD scan op with kernel-mode dispatch."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.kernels.common import resolve_mode
from repro.kernels.mamba2_scan.kernel import mamba2_scan_pallas
from repro.kernels.mamba2_scan.ref import mamba2_decode_step, mamba2_scan_ref

__all__ = ["mamba2_scan", "mamba2_decode_step"]


def mamba2_scan(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    Bm: jnp.ndarray,
    C: jnp.ndarray,
    D: jnp.ndarray,
    *,
    chunk: int = 64,
    kernel_mode: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    mode = resolve_mode(kernel_mode)
    if mode == "reference":
        return mamba2_scan_ref(x, dt, A, Bm, C, D)
    return mamba2_scan_pallas(
        x, dt, A, Bm, C, D, chunk=chunk, interpret=(mode == "pallas_interpret")
    )
