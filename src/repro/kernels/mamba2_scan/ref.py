"""Exact sequential oracle for the Mamba2 (SSD) recurrence.

Per head h (state size N, head dim P), with scalar decay a_t = exp(A_h dt_t):

    S_t = a_t S_{t-1} + dt_t B_t (x) x_t        (S in R^{N x P})
    y_t = C_t^T S_t + D_h x_t

B_t, C_t are shared across heads (n_groups = 1, the Mamba2 default).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def mamba2_scan_ref(
    x: jnp.ndarray,    # [B, H, T, P]
    dt: jnp.ndarray,   # [B, H, T]  (post-softplus, > 0)
    A: jnp.ndarray,    # [H]        (negative)
    Bm: jnp.ndarray,   # [B, T, N]
    C: jnp.ndarray,    # [B, T, N]
    D: jnp.ndarray,    # [H]
    state: jnp.ndarray | None = None,  # [B, H, N, P]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B_, H, T, P = x.shape
    N = Bm.shape[-1]
    if state is None:
        state = jnp.zeros((B_, H, N, P), jnp.float32)

    def head_scan(xh, dth, Ah, Bh, Ch, Dh, s0):
        def step(S, inp):
            xt, dtt, bt, ct = inp
            a = jnp.exp(Ah * dtt)
            S = a * S + dtt * bt[:, None] * xt[None, :]
            y = (ct[:, None] * S).sum(axis=0) + Dh * xt
            return S, y

        S, y = jax.lax.scan(step, s0, (xh, dth, Bh, Ch))
        return y, S

    f = jax.vmap(  # over B
        jax.vmap(head_scan, in_axes=(0, 0, 0, None, None, 0, 0)),  # over H
        in_axes=(0, 0, None, 0, 0, None, 0),
    )
    y, S = f(
        x.astype(jnp.float32), dt.astype(jnp.float32), A.astype(jnp.float32),
        Bm.astype(jnp.float32), C.astype(jnp.float32), D.astype(jnp.float32),
        state.astype(jnp.float32),
    )
    return y.astype(x.dtype), S


def mamba2_decode_step(
    x: jnp.ndarray,    # [B, H, P]
    dt: jnp.ndarray,   # [B, H]
    A: jnp.ndarray,    # [H]
    Bm: jnp.ndarray,   # [B, N]
    C: jnp.ndarray,    # [B, N]
    D: jnp.ndarray,    # [H]
    state: jnp.ndarray,  # [B, H, N, P]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """O(1) single-token step for decode (long_500k runs on this path)."""
    a = jnp.exp(A[None, :] * dt)                       # [B, H]
    S = a[..., None, None] * state + (
        dt[..., None, None] * Bm[:, None, :, None] * x[:, :, None, :]
    )
    y = (C[:, None, :, None] * S).sum(axis=2) + D[None, :, None] * x
    return y.astype(x.dtype), S
