"""Mamba2 SSD as a chunked Pallas TPU kernel.

Same chunked-matmul structure as the RWKV6 kernel, but the decay is a
per-head *scalar* per step, which makes the rescaling exactly the SSD
"1-semiseparable" decomposition (Dao & Gu, 2024) — three MXU matmuls per
chunk plus a rank-1 state update:

    c_t = prod_{s<=t} a_s                 (inclusive cumulative decay)
    y_t = (c_t C_t) @ S0                  [C,N] @ [N,P]
        + sum_{s<=t} (c_t/c_s)(C_t . B_s) dt_s x_s    (causal-inclusive A@X)
        + D x_t
    S_C = c_C S0 + (B . dt . c_C/c_s)^T X             [N,C] @ [C,P]

Grid: (B, H, T/C), chunks sequential, S in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba2_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref,
    y_ref, s_out_ref,
    s_scr,
    *,
    chunk: int,
    t_blocks: int,
):
    tb = pl.program_id(2)

    @pl.when(tb == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0, 0].astype(jnp.float32)     # [C, P]
    dt = dt_ref[0, 0].astype(jnp.float32)   # [C]
    A = a_ref[0]                             # scalar (per head)
    Bm = b_ref[0].astype(jnp.float32)       # [C, N]
    Cm = c_ref[0].astype(jnp.float32)       # [C, N]
    D = d_ref[0]
    S0 = s_scr[...]                          # [N, P]

    logc = jnp.cumsum(A * dt)                # [C] inclusive log-decay
    c_incl = jnp.exp(logc)
    c_last = c_incl[-1]

    q_eff = Cm * c_incl[:, None]             # (c_t C_t)
    k_eff = Bm * (dt * jnp.exp(-logc))[:, None]  # B_s dt_s / c_s

    y_inter = jax.lax.dot_general(
        q_eff, S0, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                        # [C, P]
    att = jax.lax.dot_general(
        q_eff, k_eff, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                        # [C, C]
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(si <= ti, att, 0.0)      # INCLUSIVE: y_t sees its own token
    y_intra = jax.lax.dot_general(
        att, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    y_ref[0, 0] = (y_inter + y_intra + D * x).astype(y_ref.dtype)

    k_dec = k_eff * c_last                    # B_s dt_s c_C / c_s
    S_new = c_last * S0 + jax.lax.dot_general(
        k_dec, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    s_scr[...] = S_new

    @pl.when(tb == t_blocks - 1)
    def _finish():
        s_out_ref[0, 0] = S_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2_scan_pallas(
    x: jnp.ndarray,    # [B, H, T, P]
    dt: jnp.ndarray,   # [B, H, T]
    A: jnp.ndarray,    # [H]
    Bm: jnp.ndarray,   # [B, T, N]
    C: jnp.ndarray,    # [B, T, N]
    D: jnp.ndarray,    # [H]
    *,
    chunk: int = 64,
    interpret: bool = False,
):
    B_, H, T, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, T)
    assert T % chunk == 0, f"T={T} vs chunk={chunk}"
    t_blocks = T // chunk

    kernel = functools.partial(_mamba2_kernel, chunk=chunk, t_blocks=t_blocks)
    y, s = pl.pallas_call(
        kernel,
        grid=(B_, H, t_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, t: (b, h, t)),
            pl.BlockSpec((1,), lambda b, h, t: (h,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, chunk, N), lambda b, h, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, t: (b, t, 0)),
            pl.BlockSpec((1,), lambda b, h, t: (h,), memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((B_, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bm, C, D.astype(jnp.float32))
    return y, s
