from repro.kernels.mamba2_scan.ops import mamba2_decode_step, mamba2_scan  # noqa: F401
