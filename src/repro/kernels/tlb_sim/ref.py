"""Oracle for the TLB-simulation kernel = the scan in repro.core.tlbsim."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.tlbsim import _scan_tlb


def tlb_sim_ref(set_idx: jnp.ndarray, tag: jnp.ndarray, total_sets: int, ways: int) -> jnp.ndarray:
    """Per-access hit bits (bool) for a set-associative LRU structure."""
    return _scan_tlb(set_idx, tag, total_sets, ways)
