"""Oracles for the TLB-simulation kernels = the scans in repro.core.tlbsim."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.core.tlbsim import _scan_tlb, _scan_tlb_batched, _scan_tlb_batched_carry


def tlb_sim_ref(set_idx: jnp.ndarray, tag: jnp.ndarray, total_sets: int, ways: int) -> jnp.ndarray:
    """Per-access hit bits (bool) for a set-associative LRU structure."""
    return _scan_tlb(set_idx, tag, total_sets, ways)


def tlb_sim_batched_ref(
    set_idx: jnp.ndarray,
    tag: jnp.ndarray,
    total_sets: int,
    ways: int,
    valid_ways: Tuple[int, ...],
) -> jnp.ndarray:
    """Hit bits (bool [B, N]) for B configs advancing through one trace pass."""
    return _scan_tlb_batched(set_idx, tag, total_sets, ways, valid_ways)


def tlb_sim_batched_carry_ref(
    set_idx: jnp.ndarray,
    tag: jnp.ndarray,
    tags: jnp.ndarray,
    last: jnp.ndarray,
    now0,
):
    """Chunk-resumable batched scan: (hits [B, L], tags', last')."""
    return _scan_tlb_batched_carry(set_idx, tag, tags, last, jnp.asarray(now0))
