"""Trace-driven TLB simulation as a Pallas TPU kernel.

TPU adaptation of the paper's evaluation hot loop (millions of trace
accesses x hundreds of configs).  The full TLB state (tags + last-use, a few
hundred KB for even the largest configs) stays **resident in VMEM scratch**
for the entire trace: TPU grids execute sequentially, so scratch persists
across grid steps while each step streams one trace block HBM->VMEM.  The
simulated per-partition TLB array (SPARTA's "divide") is the leading state
dimension: probing partition p touches only rows [p*sets, (p+1)*sets).

The access loop is inherently serial (LRU state carries a dependency), but
each probe is a W-wide vector compare/select — the VPU lanes handle the
ways.  The host-side oracle is ``repro.core.tlbsim._scan_tlb``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _tlb_kernel(
    set_ref, tag_ref,     # int32 [BLK] trace block
    hit_ref,              # int32 [BLK] output
    tags_scr, last_scr,   # [TS, W] persistent VMEM state
    *,
    block: int,
):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        tags_scr[...] = jnp.full_like(tags_scr, -1)
        last_scr[...] = jnp.zeros_like(last_scr)

    base = i * block

    def body(j, _):
        s = set_ref[j]
        t = tag_ref[j]
        row_t = tags_scr[s, :]
        row_l = last_scr[s, :]
        hit_vec = row_t == t
        hit = jnp.any(hit_vec)
        way = jnp.where(hit, jnp.argmax(hit_vec), jnp.argmin(row_l))
        tags_scr[s, way] = t
        last_scr[s, way] = base + j + 1
        hit_ref[j] = hit.astype(jnp.int32)
        return 0

    jax.lax.fori_loop(0, block, body, 0)


@functools.partial(jax.jit, static_argnames=("total_sets", "ways", "block", "interpret"))
def tlb_sim_pallas(
    set_idx: jnp.ndarray,
    tag: jnp.ndarray,
    total_sets: int,
    ways: int,
    *,
    block: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    n = set_idx.shape[0]
    block = min(block, n)
    assert n % block == 0, f"trace length {n} must be a multiple of block {block}"
    grid = (n // block,)
    hits = pl.pallas_call(
        functools.partial(_tlb_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((total_sets, ways), jnp.int32),
            pltpu.VMEM((total_sets, ways), jnp.int32),
        ],
        interpret=interpret,
    )(set_idx.astype(jnp.int32), tag.astype(jnp.int32))
    return hits.astype(bool)
