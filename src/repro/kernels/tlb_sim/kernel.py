"""Trace-driven TLB simulation as a Pallas TPU kernel.

TPU adaptation of the paper's evaluation hot loop (millions of trace
accesses x hundreds of configs).  The full TLB state (tags + last-use, a few
hundred KB for even the largest configs) stays **resident in VMEM scratch**
for the entire trace: TPU grids execute sequentially, so scratch persists
across grid steps while each step streams one trace block HBM->VMEM.  The
simulated per-partition TLB array (SPARTA's "divide") is the leading state
dimension: probing partition p touches only rows [p*sets, (p+1)*sets).

``tlb_sim_batched_pallas`` adds a **config batch dimension** for the sweep
engine (:mod:`repro.core.sweep`): B configs' states are stacked as the
leading VMEM scratch axis and each grid step fetches one trace block
HBM->VMEM once, carrying every config's (set, tag) view of that chunk, so
all configs advance through the trace together in a single pallas_call.
Geometry padding is poisoned exactly like the host-side batched scan
(`padded_tlb_state`), keeping the kernel bit-identical per config.

The access loop is inherently serial (LRU state carries a dependency), but
each probe is a W-wide vector compare/select — the VPU lanes handle the
ways.  The host-side oracles are ``repro.core.tlbsim._scan_tlb`` and
``repro.core.tlbsim._scan_tlb_batched``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Shared with the host-side batched oracle: kernel/oracle bit-identity
# depends on both using the same poison scheme.
from repro.core.tlbsim import _POISON_LAST, _POISON_TAG


def _tlb_kernel(
    set_ref, tag_ref,     # int32 [BLK] trace block
    hit_ref,              # int32 [BLK] output
    tags_scr, last_scr,   # [TS, W] persistent VMEM state
    *,
    block: int,
):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        tags_scr[...] = jnp.full_like(tags_scr, -1)
        last_scr[...] = jnp.zeros_like(last_scr)

    base = i * block

    def body(j, _):
        s = set_ref[j]
        t = tag_ref[j]
        row_t = tags_scr[s, :]
        row_l = last_scr[s, :]
        hit_vec = row_t == t
        hit = jnp.any(hit_vec)
        way = jnp.where(hit, jnp.argmax(hit_vec), jnp.argmin(row_l))
        tags_scr[s, way] = t
        last_scr[s, way] = base + j + 1
        hit_ref[j] = hit.astype(jnp.int32)
        return 0

    jax.lax.fori_loop(0, block, body, 0)


@functools.partial(jax.jit, static_argnames=("total_sets", "ways", "block", "interpret"))
def tlb_sim_pallas(
    set_idx: jnp.ndarray,
    tag: jnp.ndarray,
    total_sets: int,
    ways: int,
    *,
    block: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    n = set_idx.shape[0]
    block = min(block, n)
    assert n % block == 0, f"trace length {n} must be a multiple of block {block}"
    grid = (n // block,)
    hits = pl.pallas_call(
        functools.partial(_tlb_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((total_sets, ways), jnp.int32),
            pltpu.VMEM((total_sets, ways), jnp.int32),
        ],
        interpret=interpret,
    )(set_idx.astype(jnp.int32), tag.astype(jnp.int32))
    return hits.astype(bool)


def _tlb_batched_kernel(
    set_ref, tag_ref,     # int32 [B, BLK] trace block (all configs' key views)
    hit_ref,              # int32 [B, BLK] output
    tags_scr, last_scr,   # [B, TS, W] persistent stacked VMEM state
    *,
    block: int,
    num_cfgs: int,
    valid_ways: Tuple[int, ...],
):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        # Poison ways beyond each config's associativity: their tag never
        # matches and their last-use stamp is never the LRU minimum.
        # valid_ways is static, so the per-config masks are compile-time
        # constants (no captured arrays), unrolled over the B axis.
        way_ix = jax.lax.broadcasted_iota(jnp.int32, tags_scr.shape[1:], 1)
        for b, vw in enumerate(valid_ways):
            pad = way_ix >= vw
            tags_scr[b, :, :] = jnp.where(pad, _POISON_TAG, -1).astype(jnp.int32)
            last_scr[b, :, :] = jnp.where(pad, _POISON_LAST, 0).astype(jnp.int32)

    base = i * block

    def access(j, _):
        now = base + j + 1

        def per_cfg(b, _):
            s = set_ref[b, j]
            t = tag_ref[b, j]
            row_t = tags_scr[b, s, :]
            row_l = last_scr[b, s, :]
            hit_vec = row_t == t
            hit = jnp.any(hit_vec)
            way = jnp.where(hit, jnp.argmax(hit_vec), jnp.argmin(row_l))
            tags_scr[b, s, way] = t
            last_scr[b, s, way] = now
            hit_ref[b, j] = hit.astype(jnp.int32)
            return 0

        jax.lax.fori_loop(0, num_cfgs, per_cfg, 0)
        return 0

    jax.lax.fori_loop(0, block, access, 0)


def _tlb_batched_carry_kernel(
    set_ref, tag_ref,       # int32 [B, BLK] trace block
    tags_in, last_in,       # int32 [B, TS, W] carried state in (whole array)
    nb_ref,                 # int32 [1, 1] global access count before chunk
    hit_ref,                # int32 [B, BLK] output
    tags_out, last_out,     # int32 [B, TS, W] carried state out (whole array)
    *,
    block: int,
    num_cfgs: int,
):
    """Chunk-resumable variant of :func:`_tlb_batched_kernel`.

    The state-out refs use a constant-index BlockSpec, so they stay
    VMEM-resident across the (sequential) grid — they ARE the working state:
    initialised from the carried state-in at grid step 0 (the caller owns the
    poison init), mutated in place, and flushed back to HBM once at the end.
    Timestamps continue the global access counter (``nb_ref``), so chunked
    execution is bit-identical to the monolithic kernel.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _load():
        tags_out[...] = tags_in[...]
        last_out[...] = last_in[...]

    base = nb_ref[0, 0] + i * block

    def access(j, _):
        now = base + j + 1

        def per_cfg(b, _):
            s = set_ref[b, j]
            t = tag_ref[b, j]
            row_t = tags_out[b, s, :]
            row_l = last_out[b, s, :]
            hit_vec = row_t == t
            hit = jnp.any(hit_vec)
            way = jnp.where(hit, jnp.argmax(hit_vec), jnp.argmin(row_l))
            tags_out[b, s, way] = t
            last_out[b, s, way] = now
            hit_ref[b, j] = hit.astype(jnp.int32)
            return 0

        jax.lax.fori_loop(0, num_cfgs, per_cfg, 0)
        return 0

    jax.lax.fori_loop(0, block, access, 0)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def tlb_sim_batched_pallas_carry(
    set_idx: jnp.ndarray,   # int32 [B, L]
    tag: jnp.ndarray,       # int32 [B, L]
    tags: jnp.ndarray,      # int32 [B, TS, W] carried state
    last: jnp.ndarray,      # int32 [B, TS, W]
    now0: jnp.ndarray,      # int32 scalar
    *,
    block: int = 512,
    interpret: bool = False,
):
    """Chunk-resumable batched LRU simulation; returns (hits, tags', last')."""
    num_cfgs, n = set_idx.shape
    total_sets, ways = tags.shape[1], tags.shape[2]
    block = min(block, n)
    assert n % block == 0, f"chunk length {n} must be a multiple of block {block}"
    grid = (n // block,)
    stream = pl.BlockSpec((num_cfgs, block), lambda i: (0, i))
    whole = pl.BlockSpec((num_cfgs, total_sets, ways), lambda i: (0, 0, 0))
    hits, tags, last = pl.pallas_call(
        functools.partial(
            _tlb_batched_carry_kernel, block=block, num_cfgs=num_cfgs,
        ),
        grid=grid,
        in_specs=[stream, stream, whole, whole,
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=[stream, whole, whole],
        out_shape=[
            jax.ShapeDtypeStruct((num_cfgs, n), jnp.int32),
            jax.ShapeDtypeStruct((num_cfgs, total_sets, ways), jnp.int32),
            jax.ShapeDtypeStruct((num_cfgs, total_sets, ways), jnp.int32),
        ],
        interpret=interpret,
    )(set_idx.astype(jnp.int32), tag.astype(jnp.int32),
      tags.astype(jnp.int32), last.astype(jnp.int32),
      jnp.asarray(now0, jnp.int32).reshape(1, 1))
    return hits.astype(bool), tags, last


@functools.partial(
    jax.jit,
    static_argnames=("total_sets", "ways", "valid_ways", "block", "interpret"),
)
def tlb_sim_batched_pallas(
    set_idx: jnp.ndarray,   # int32 [B, N]
    tag: jnp.ndarray,       # int32 [B, N]
    total_sets: int,
    ways: int,
    valid_ways: Tuple[int, ...],
    *,
    block: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """B-config batched LRU simulation; returns hit bits bool [B, N]."""
    num_cfgs, n = set_idx.shape
    assert len(valid_ways) == num_cfgs
    block = min(block, n)
    assert n % block == 0, f"trace length {n} must be a multiple of block {block}"
    grid = (n // block,)
    hits = pl.pallas_call(
        functools.partial(
            _tlb_batched_kernel,
            block=block, num_cfgs=num_cfgs, valid_ways=valid_ways,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((num_cfgs, block), lambda i: (0, i)),
            pl.BlockSpec((num_cfgs, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((num_cfgs, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((num_cfgs, n), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((num_cfgs, total_sets, ways), jnp.int32),
            pltpu.VMEM((num_cfgs, total_sets, ways), jnp.int32),
        ],
        interpret=interpret,
    )(set_idx.astype(jnp.int32), tag.astype(jnp.int32))
    return hits.astype(bool)
