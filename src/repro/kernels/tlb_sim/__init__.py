from repro.kernels.tlb_sim.ops import (  # noqa: F401
    tlb_sim,
    tlb_sim_batched,
    tlb_sim_batched_carry,
)
