from repro.kernels.tlb_sim.ops import tlb_sim  # noqa: F401
