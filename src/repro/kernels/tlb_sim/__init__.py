from repro.kernels.tlb_sim.ops import tlb_sim, tlb_sim_batched  # noqa: F401
