"""Public TLB-simulation op with kernel-mode dispatch."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import resolve_mode
from repro.kernels.tlb_sim.kernel import tlb_sim_pallas
from repro.kernels.tlb_sim.ref import tlb_sim_ref

__all__ = ["tlb_sim"]


def tlb_sim(
    set_idx: jnp.ndarray,
    tag: jnp.ndarray,
    total_sets: int,
    ways: int,
    *,
    block: int = 512,
    kernel_mode: str = "auto",
) -> jnp.ndarray:
    mode = resolve_mode(kernel_mode)
    if mode == "reference":
        return tlb_sim_ref(set_idx, tag, total_sets, ways)
    return tlb_sim_pallas(
        set_idx, tag, total_sets, ways,
        block=block, interpret=(mode == "pallas_interpret"),
    )
