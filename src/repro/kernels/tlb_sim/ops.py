"""Public TLB-simulation ops with kernel-mode dispatch."""
from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp

from repro.kernels.common import resolve_mode
from repro.kernels.tlb_sim.kernel import tlb_sim_batched_pallas, tlb_sim_pallas
from repro.kernels.tlb_sim.ref import tlb_sim_batched_ref, tlb_sim_ref

__all__ = ["tlb_sim", "tlb_sim_batched"]


def tlb_sim(
    set_idx: jnp.ndarray,
    tag: jnp.ndarray,
    total_sets: int,
    ways: int,
    *,
    block: int = 512,
    kernel_mode: str = "auto",
) -> jnp.ndarray:
    mode = resolve_mode(kernel_mode)
    if mode == "reference":
        return tlb_sim_ref(set_idx, tag, total_sets, ways)
    return tlb_sim_pallas(
        set_idx, tag, total_sets, ways,
        block=block, interpret=(mode == "pallas_interpret"),
    )


def tlb_sim_batched(
    set_idx: jnp.ndarray,   # int32 [B, N]
    tag: jnp.ndarray,       # int32 [B, N]
    total_sets: int,        # padded envelope over configs
    ways: int,              # padded envelope over configs
    valid_ways: Optional[Sequence[int]] = None,
    *,
    block: int = 512,
    kernel_mode: str = "auto",
) -> jnp.ndarray:
    """Batched-config TLB simulation (the sweep-engine hot loop): B configs'
    LRU states advance together through ONE pass over the trace.  Returns
    hit bits bool [B, N]; bit-identical per config to :func:`tlb_sim` on
    that config's own (unpadded) geometry."""
    vw = tuple(valid_ways) if valid_ways is not None else (ways,) * set_idx.shape[0]
    mode = resolve_mode(kernel_mode)
    if mode == "reference":
        return tlb_sim_batched_ref(set_idx, tag, total_sets, ways, vw)
    return tlb_sim_batched_pallas(
        set_idx, tag, total_sets, ways, vw,
        block=block, interpret=(mode == "pallas_interpret"),
    )
