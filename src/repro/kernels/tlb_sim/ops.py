"""Public TLB-simulation ops with kernel-mode dispatch."""
from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp

from repro.kernels.common import resolve_mode
from repro.kernels.tlb_sim.kernel import (
    tlb_sim_batched_pallas,
    tlb_sim_batched_pallas_carry,
    tlb_sim_pallas,
)
from repro.kernels.tlb_sim.ref import (
    tlb_sim_batched_carry_ref,
    tlb_sim_batched_ref,
    tlb_sim_ref,
)

__all__ = ["tlb_sim", "tlb_sim_batched", "tlb_sim_batched_carry"]


def tlb_sim(
    set_idx: jnp.ndarray,
    tag: jnp.ndarray,
    total_sets: int,
    ways: int,
    *,
    block: int = 512,
    kernel_mode: str = "auto",
) -> jnp.ndarray:
    mode = resolve_mode(kernel_mode)
    if mode == "reference":
        return tlb_sim_ref(set_idx, tag, total_sets, ways)
    return tlb_sim_pallas(
        set_idx, tag, total_sets, ways,
        block=block, interpret=(mode == "pallas_interpret"),
    )


def tlb_sim_batched(
    set_idx: jnp.ndarray,   # int32 [B, N]
    tag: jnp.ndarray,       # int32 [B, N]
    total_sets: int,        # padded envelope over configs
    ways: int,              # padded envelope over configs
    valid_ways: Optional[Sequence[int]] = None,
    *,
    block: int = 512,
    kernel_mode: str = "auto",
) -> jnp.ndarray:
    """Batched-config TLB simulation (the sweep-engine hot loop): B configs'
    LRU states advance together through ONE pass over the trace.  Returns
    hit bits bool [B, N]; bit-identical per config to :func:`tlb_sim` on
    that config's own (unpadded) geometry."""
    vw = tuple(valid_ways) if valid_ways is not None else (ways,) * set_idx.shape[0]
    mode = resolve_mode(kernel_mode)
    if mode == "reference":
        return tlb_sim_batched_ref(set_idx, tag, total_sets, ways, vw)
    return tlb_sim_batched_pallas(
        set_idx, tag, total_sets, ways, vw,
        block=block, interpret=(mode == "pallas_interpret"),
    )


def tlb_sim_batched_carry(
    set_idx: jnp.ndarray,   # int32 [B, L] one trace chunk
    tag: jnp.ndarray,       # int32 [B, L]
    tags: jnp.ndarray,      # int32 [B, TS, W] carried state (caller-owned)
    last: jnp.ndarray,      # int32 [B, TS, W]
    now0: int,              # accesses consumed before this chunk
    *,
    block: int = 512,
    kernel_mode: str = "auto",
):
    """Chunk-resumable :func:`tlb_sim_batched`: run ONE trace chunk against
    caller-owned carried LRU state (initialise with
    :func:`repro.core.tlbsim.padded_tlb_state`) and the global access counter
    ``now0``.  Returns ``(hits bool [B, L], tags', last')``; feeding chunks
    sequentially is bit-identical to the monolithic op — in any mode, and
    across mode *changes* at chunk boundaries (the degradation ladder), since
    all backends share one state layout and timestamp rule.

    State layout contract: the carried state must include one spare *parked*
    set row at index ``TS - 1`` that no real access ever indexes.  Pallas
    chunks whose length is not a block multiple are padded with accesses into
    that row — their stamps live only there, so mid-stream padding is
    unobservable (the padded hit bits are dropped)."""
    mode = resolve_mode(kernel_mode)
    if mode == "reference":
        return tlb_sim_batched_carry_ref(set_idx, tag, tags, last, now0)
    n = int(set_idx.shape[1])
    pad = (-n) % min(block, n) if n else 0
    if pad:
        parked = int(tags.shape[1]) - 1
        set_idx = jnp.concatenate(
            [set_idx, jnp.full((set_idx.shape[0], pad), parked, set_idx.dtype)],
            axis=1)
        tag = jnp.concatenate(
            [tag, jnp.zeros((tag.shape[0], pad), tag.dtype)], axis=1)
    hits, tags, last = tlb_sim_batched_pallas_carry(
        set_idx, tag, tags, last, now0,
        block=block, interpret=(mode == "pallas_interpret"),
    )
    return hits[:, :n], tags, last
