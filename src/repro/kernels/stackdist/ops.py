"""Public segmented LRU-stack scan op with kernel-mode dispatch."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.kernels.common import resolve_mode
from repro.kernels.stackdist.kernel import stack_scan_pallas
from repro.kernels.stackdist.ref import stack_scan_ref

__all__ = ["stack_scan"]


def stack_scan(
    tags: jnp.ndarray,        # int32 [L, C] lane-blocked, set-sorted tag stream
    seg_flags: jnp.ndarray,   # bool  [L, C] True at set-segment starts
    init_stack: jnp.ndarray,  # int32 [L, W] carry-in stacks (-1 = empty)
    *,
    kernel_mode: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Advance L capped LRU stacks through C accesses each.

    Returns ``(depths, final)``: ``depths[l, c]`` is the 0-based position of
    ``tags[l, c]`` in lane ``l``'s pre-access stack (-1 = absent), ``final``
    the post-walk stacks.  An access with depth ``d`` hits every LRU structure
    of associativity ``w > d`` mapped to the same set — the stack-inclusion
    property that lets one scan serve a whole sweep axis of geometries.
    """
    mode = resolve_mode(kernel_mode)
    if mode == "reference":
        return stack_scan_ref(tags, seg_flags, init_stack)
    return stack_scan_pallas(
        tags, seg_flags, init_stack, interpret=(mode == "pallas_interpret")
    )
