"""Pure-jnp oracle for the segmented LRU-stack scan kernel.

The stack-distance engine (:mod:`repro.core.stackdist`) reshapes a set-sorted
access stream into ``L`` independent lanes of ``C`` accesses and walks all
lanes in lock-step: one :func:`lru_stack_step` per in-lane position, ``C``
sequential steps total instead of one per trace element.  Each lane carries a
capped LRU stack — the ``W`` most-recently-used distinct tags of the current
set segment, MRU first, ``-1`` = empty — and every access reports its 0-based
depth in the pre-access stack (``-1`` = absent: cold, or distance >= W).

``seg_flag`` marks set-segment starts; the stack resets there, which is what
makes one lane able to host many (short) per-set segments back to back.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def lru_stack_step(
    stack: jnp.ndarray,      # int32 [..., W] MRU-first, -1 = empty
    tag: jnp.ndarray,        # int32 [...]
    seg_start: jnp.ndarray,  # bool  [...]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Advance capped LRU stacks by one access per lane.

    Returns ``(new_stack, depth)``.  The update is exact for any ways <= W:
    the capped stack always equals the first W entries of the uncapped LRU
    stack (recency only ever deepens, so truncated entries never resurface).
    """
    W = stack.shape[-1]
    stack = jnp.where(seg_start[..., None], -1, stack)
    eq = stack == tag[..., None]
    found = jnp.any(eq, axis=-1)
    depth = jnp.where(found, jnp.argmax(eq, axis=-1).astype(jnp.int32), -1)
    # Move the tag to the front: rotate slots [0, idx] right by one, where idx
    # is the tag's slot on a hit and the last slot (LRU eviction) on a miss.
    idx = jnp.where(found, depth, W - 1)
    shifted = jnp.concatenate([tag[..., None], stack[..., :-1]], axis=-1)
    way_ix = jax.lax.broadcasted_iota(jnp.int32, stack.shape, stack.ndim - 1)
    new = jnp.where(way_ix <= idx[..., None], shifted, stack)
    return new, depth


@jax.jit
def stack_scan_ref(
    tags: jnp.ndarray,        # int32 [L, C]
    seg_flags: jnp.ndarray,   # bool  [L, C]
    init_stack: jnp.ndarray,  # int32 [L, W]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Walk C accesses per lane.  Returns (depths int32 [L, C], final [L, W])."""

    def step(stack, inp):
        t, f = inp
        new, depth = lru_stack_step(stack, t, f)
        return new, depth

    final, depths = jax.lax.scan(step, init_stack, (tags.T, seg_flags.T))
    return depths.T, final
