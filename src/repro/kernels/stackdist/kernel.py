"""Segmented LRU-stack scan as a Pallas TPU kernel.

TPU port of the stack-distance engine's hot loop (:mod:`repro.core.stackdist`):
``L`` lanes each advance a capped LRU stack — the W most-recently-used
distinct tags of the current set segment — through ``C`` in-lane accesses.
The stacked per-lane state ([L, W], a few hundred KB) lives in **VMEM
scratch** for the whole walk: TPU grids execute sequentially, so scratch
persists across grid steps while each step streams one access *column*
([L, 1]) HBM->VMEM.  The per-step update is a W-wide vector compare/rotate
per lane — VPU-friendly, no gathers, no sorts.

This is the same role the batched ``tlb_sim`` kernel plays for the scan
backend, but the sequential grid is only ``C`` long (the lane dimension
carries the parallelism), not one step per trace element.

Host-side oracle: :func:`repro.kernels.stackdist.ref.stack_scan_ref`.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# The per-access update is shared with the reference backend (pure jnp, so it
# lowers in both): one definition keeps the two paths bit-identical forever.
from repro.kernels.stackdist.ref import lru_stack_step


def _stack_scan_kernel(
    init_ref,                 # int32 [L, W] initial (carry-in) stacks
    tag_ref, flag_ref,        # int32 [L, 1] current access column
    depth_ref,                # int32 [L, 1] output column
    final_ref,                # int32 [L, W] final stacks (last write wins)
    stack_scr,                # int32 [L, W] persistent VMEM state
):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        stack_scr[...] = init_ref[...]

    new, depth = lru_stack_step(stack_scr[...], tag_ref[:, 0], flag_ref[:, 0] != 0)
    stack_scr[...] = new
    depth_ref[:, 0] = depth
    final_ref[...] = new


@functools.partial(jax.jit, static_argnames=("interpret",))
def stack_scan_pallas(
    tags: jnp.ndarray,        # int32 [L, C]
    seg_flags: jnp.ndarray,   # bool  [L, C]
    init_stack: jnp.ndarray,  # int32 [L, W]
    *,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (depths int32 [L, C], final stacks int32 [L, W])."""
    L, C = tags.shape
    W = init_stack.shape[-1]
    depths, final = pl.pallas_call(
        _stack_scan_kernel,
        grid=(C,),
        in_specs=[
            pl.BlockSpec((L, W), lambda i: (0, 0)),
            pl.BlockSpec((L, 1), lambda i: (0, i)),
            pl.BlockSpec((L, 1), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((L, 1), lambda i: (0, i)),
            pl.BlockSpec((L, W), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, C), jnp.int32),
            jax.ShapeDtypeStruct((L, W), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((L, W), jnp.int32)],
        interpret=interpret,
    )(init_stack.astype(jnp.int32), tags.astype(jnp.int32),
      seg_flags.astype(jnp.int32))
    return depths, final
