from repro.kernels.stackdist.ops import stack_scan

__all__ = ["stack_scan"]
