"""dbrx-132b: 40L fine-grained MoE 16 experts top-4 — [hf:databricks/dbrx-base]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=10752, vocab=100352,
    activation="silu_glu", norm="ln", rope_theta=500_000.0,
    moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10752),
)

def smoke() -> ModelConfig:
    return ModelConfig(
        name="dbrx-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, norm="ln", dtype="float32",
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
    )
