"""Architecture registry + per-(arch, shape) input specs.

``input_specs`` returns ``jax.ShapeDtypeStruct`` stand-ins for every input of
the lowered step function (no device allocation — the dry-run pattern):

* train/prefill shapes -> inputs of ``train_step`` / ``prefill_step``;
* decode/long_decode  -> inputs of ``serve_step``: one new token per
  sequence plus the SPARTA-paged KV pools.

KV pool layout (global view): ``[L, B, P, pages_local, page, Hkv, hd]`` —
``P`` is the number of SPARTA partitions (the mesh ``model`` axis, or
data x model for the single-sequence long-context shape), ``pages_local`` the
per-partition page region of one sequence.  Block tables are
``[B, P, pages_local]`` int32 *local* slot ids (the co-located per-partition
page tables).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (
    SHAPES, SHAPES_BY_NAME, ModelConfig, ShapeConfig, cell_applicable,
)

ARCH_IDS: Tuple[str, ...] = (
    "stablelm-12b",
    "qwen3-14b",
    "starcoder2-7b",
    "gemma-7b",
    "rwkv6-1.6b",
    "internvl2-2b",
    "qwen3-moe-30b-a3b",
    "dbrx-132b",
    "zamba2-7b",
    "whisper-medium",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; options: {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch_id])


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke()


def all_cells():
    """Yield every applicable (arch_id, ShapeConfig) cell (40 total minus
    documented long_500k skips)."""
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES:
            ok, _ = cell_applicable(cfg, s)
            if ok:
                yield a, s


# ---------------------------------------------------------------------------
# Input specs.
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def pool_geometry(cfg: ModelConfig, shape: ShapeConfig, num_partitions: int):
    page = cfg.kv_page_size
    pages_per_seq = -(-shape.seq_len // page)
    pages_local = -(-pages_per_seq // num_partitions)
    return page, pages_per_seq, pages_local


def input_specs(
    cfg: ModelConfig,
    shape: ShapeConfig,
    *,
    num_partitions: int = 16,
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for the step function of this (arch, shape) cell."""
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} x {shape.name}: {why}")
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    if not shape.lowers_serve_step:
        if cfg.family == "vlm":
            i = cfg.num_image_tokens
            return {
                "patch_embeds": _sds((B, i, cfg.d_model), dt),
                "tokens": _sds((B, S - i), jnp.int32),
            }
        if cfg.family == "encdec":
            return {
                "frames": _sds((B, S // 2, cfg.d_model), dt),
                "tokens": _sds((B, S // 2), jnp.int32),
            }
        return {"tokens": _sds((B, S), jnp.int32)}

    # ---- serve_step inputs -------------------------------------------------
    P = num_partitions
    page, pages_per_seq, pages_local = pool_geometry(cfg, shape, P)
    specs: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": _sds((B,), jnp.int32),
        "ctx_len": _sds((B,), jnp.int32),
    }
    if cfg.family == "ssm":  # rwkv6: O(1) recurrent state, no paged KV
        H = cfg.d_model // cfg.ssm_headdim
        N = cfg.ssm_headdim
        L, D = cfg.num_layers, cfg.d_model
        specs.update({
            "tm_shift": _sds((L, B, D), jnp.float32),
            "cm_shift": _sds((L, B, D), jnp.float32),
            "wkv": _sds((L, B, H, N, N), jnp.float32),
        })
        return specs

    Hkv, hd = cfg.num_kv_heads, cfg.head_dim
    if cfg.family == "hybrid":
        from repro.models.zamba2 import group_dims
        from repro.models.mamba2 import dims as m2dims
        G, per = group_dims(cfg)
        d_inner, H, Pdim, N = m2dims(cfg)
        pools = (G, B, P, pages_local, page, Hkv, hd)
        specs.update({
            "k_pools": _sds(pools, dt),
            "v_pools": _sds(pools, dt),
            "tables": _sds((B, P, pages_local), jnp.int32),
            "conv_state": _sds((G, per, B, cfg.ssm_conv_width - 1, d_inner + 2 * N), jnp.float32),
            "ssm_state": _sds((G, per, B, H, N, Pdim), jnp.float32),
        })
        return specs

    L = cfg.num_layers
    pools = (L, B, P, pages_local, page, Hkv, hd)
    specs.update({
        "k_pools": _sds(pools, dt),
        "v_pools": _sds(pools, dt),
        "tables": _sds((B, P, pages_local), jnp.int32),
    })
    if cfg.family == "encdec":
        s_enc = 1500  # whisper's fixed 30 s encoder grid
        specs["cross_k"] = _sds((L, B, s_enc, Hkv, hd), dt)
        specs["cross_v"] = _sds((L, B, s_enc, Hkv, hd), dt)
    return specs


def abstract_params(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs via eval_shape — no allocation."""
    from repro import models
    return jax.eval_shape(lambda k: models.init(k, cfg), jax.random.PRNGKey(0))
