"""starcoder2-7b: dense 32L GQA(36q/4kv), plain-GELU MLP — [arXiv:2402.19173; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    num_layers=32, d_model=4608, num_heads=36, num_kv_heads=4, head_dim=128,
    d_ff=18432, vocab=49152,
    activation="gelu", norm="ln", rope_theta=100_000.0,
)

def smoke() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
        d_ff=256, vocab=256, activation="gelu", norm="ln", dtype="float32",
    )
