"""qwen3-14b: dense 40L GQA(40q/8kv) + qk-norm — [hf:Qwen/Qwen3-8B; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=17408, vocab=151936,
    activation="silu_glu", norm="rms", qk_norm=True, rope_theta=1_000_000.0,
)

def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, qk_norm=True, dtype="float32",
    )
