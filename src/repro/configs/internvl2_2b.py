"""internvl2-2b: InternViT stub + InternLM2 backbone — [arXiv:2404.16821; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=92608,  # published 92553, padded to x64 for sharding
    activation="silu_glu", norm="rms", rope_theta=1_000_000.0,
    num_image_tokens=256, tie_embeddings=True,
)

def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, num_image_tokens=8, tie_embeddings=True, dtype="float32",
    )
