"""whisper-medium: 24L enc + 24L dec, conv frontend stubbed — [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=4096, vocab=51904,  # published 51865, padded to x64 for sharding
    activation="gelu", norm="ln", rope_theta=0.0,
    encoder_layers=24, tie_embeddings=True,
)

def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="encdec",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, activation="gelu", norm="ln", rope_theta=0.0,
        encoder_layers=2, tie_embeddings=True, dtype="float32",
    )
