"""rwkv6-1.6b "Finch": attention-free, data-dependent decay — [arXiv:2404.05892]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, num_heads=0, num_kv_heads=0, head_dim=0,
    d_ff=7168, vocab=65536,
    norm="ln", ssm_headdim=64,
)

def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke", family="ssm",
        num_layers=2, d_model=64, num_heads=0, num_kv_heads=0, head_dim=0,
        d_ff=128, vocab=256, norm="ln", ssm_headdim=16, dtype="float32",
    )
