"""Config schema: model architectures, input shapes, and the registry.

Every assigned architecture provides a module ``repro.configs.<arch_id>``
exporting ``CONFIG`` (the exact published dims) and ``smoke()`` (a reduced
same-family config for CPU tests).  ``repro.configs.registry`` maps ids to
configs and knows which (arch x shape) cells are applicable.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    activation: str = "silu_glu"  # silu_glu | gelu_glu | gelu
    norm: str = "rms"             # rms | ln
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    # SSM / hybrid (rwkv6 uses head size = ssm_state; mamba2 uses all three)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    hybrid_period: int = 0        # shared attention block every k SSM layers
    # Encoder-decoder
    encoder_layers: int = 0
    # VLM (stub frontend supplies this many precomputed patch embeddings)
    num_image_tokens: int = 0
    embed_scale: bool = False     # gemma-style sqrt(d_model) embedding scale
    dtype: str = "bfloat16"
    # Serving
    kv_page_size: int = 256       # tokens per SPARTA KV page

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic context handling => run long_500k (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.num_layers
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":  # rwkv6-style
            tm = D * (self.q_dim * 3) + D * D + D * D  # r/k/v(+g) + w-lora approx + out
            cm = 2 * D * F
            return emb + L * (tm + cm)
        att = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
        glu = 3 if self.activation.endswith("_glu") else 2
        if self.moe is not None:
            ffn = self.moe.num_experts * glu * D * self.moe.d_ff_expert + D * self.moe.num_experts
        else:
            ffn = glu * D * F
        if self.family == "hybrid":
            d_inner = self.ssm_expand * D
            m2 = D * (2 * d_inner + 2 * self.ssm_state) + d_inner * D
            n_shared = max(1, L // max(self.hybrid_period, 1))
            return emb + L * m2 + (att + glu * D * F)  # shared attn counted once
        body = L * (att + ffn)
        if self.encoder_layers:
            body += self.encoder_layers * (att + ffn) + L * att  # + cross-attn
        return emb + body


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long_decode

    @property
    def lowers_serve_step(self) -> bool:
        return self.kind in ("decode", "long_decode")


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "long_decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def cell_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Is (arch x shape) a defined cell?  Returns (ok, reason-if-not).

    long_500k needs sub-quadratic attention: run for SSM/hybrid, skip for
    pure full-attention archs (DESIGN.md §Arch-applicability).
    """
    if shape.kind == "long_decode" and not model.supports_long_context:
        return False, "pure full-attention arch: 500k decode skipped per assignment"
    return True, ""
