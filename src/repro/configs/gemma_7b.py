"""gemma-7b: dense 28L MHA(16q/16kv) head_dim=256, GeGLU — [arXiv:2403.08295; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16, head_dim=256,
    d_ff=24576, vocab=256000,
    activation="gelu_glu", norm="rms", rope_theta=10_000.0,
    tie_embeddings=True, embed_scale=True,
)

def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, activation="gelu_glu",
        tie_embeddings=True, embed_scale=True, dtype="float32",
    )
