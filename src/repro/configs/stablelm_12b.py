"""stablelm-12b: dense 40L GQA(32q/8kv) — [hf:stabilityai/stablelm-2-1_6b; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b", family="dense",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8, head_dim=160,
    d_ff=13824, vocab=100352,
    activation="silu_glu", norm="ln", rope_theta=10_000.0,
)

def smoke() -> ModelConfig:
    return ModelConfig(
        name="stablelm-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, activation="silu_glu", norm="ln", dtype="float32",
    )
