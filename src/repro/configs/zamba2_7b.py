"""zamba2-7b: Mamba2 backbone + shared attention — [arXiv:2411.15242].

81 Mamba2 layers in 27 groups of 3; the single shared attention+MLP block
(32 MHA heads, d_ff 14336) is applied after every group (27 applications,
one weight set).  Per-invocation LoRA deltas of the published model are
omitted (DESIGN.md assumptions log).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32, head_dim=112,
    d_ff=14336, vocab=32000,
    activation="gelu_glu", norm="rms", rope_theta=10_000.0,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, hybrid_period=3,
)

def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, ssm_state=16, ssm_headdim=16, ssm_expand=2,
        hybrid_period=2, dtype="float32",
    )
