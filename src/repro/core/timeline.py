"""Cycle-approximate event-timeline engine (per-access latency + queueing).

:mod:`repro.core.cpi` turns measured hit *rates* into average per-access
latency — it cannot express queueing contention on shared memory-side TLBs
or latency *distributions*, exactly the effects SPARTA's partitioning is
designed to remove.  This module composes a **per-access completion time**
from the per-access hit/miss event bits already produced by
:func:`repro.core.tlbsim.simulate_system` / :func:`repro.core.sweep.sweep_system`,
threading three bounded resources through the Fig 3 timelines:

* an MSHR-style window of outstanding misses per accelerator,
* per-partition memory-side TLB service ports with FIFO queueing (SPARTA),
* banked DRAM service slots (page walks, PTE reads and data fetches all
  occupy a bank).

Outputs are per-access latency/overhead arrays reduced to total cycles,
throughput and p50/p95/p99 tails for the four designs
(``conventional`` / ``sparta`` / ``dipta`` / ``ideal``).

**Oracle property**: with every resource unbounded
(:meth:`TimelineConfig.unbounded`) all queue waits vanish and the
post-warmup *mean* latency / translation overhead reproduce
:mod:`repro.core.cpi`'s analytical averages exactly (``tests/test_timeline.py``
asserts <= 1e-6 relative error for all designs and workloads).

The sequential hot loop lives in :mod:`repro.kernels.timeline` (jnp
``lax.scan`` oracle + Pallas TPU kernel with the state resident in VMEM
scratch, dispatched by ``kernel_mode`` like every other kernel package).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.cpi import DIPTA_WAY_PREDICTION_ACCURACY
from repro.core.sparta import SystemLatencies
from repro.core.tlbsim import LINE_SHIFT, SystemEvents
from repro.kernels.timeline import (
    TimelineParams,
    pack_params,
    resolve_timeline_mode,
    timeline_init_state_batched,
    timeline_sim,
    timeline_sim_batched,
    timeline_sim_batched_carry,
)

__all__ = ["TimelineConfig", "TimelineResult", "TimelineSpec",
           "TimelineSweepStream", "simulate_timeline", "sweep_timeline",
           "round_robin_accel_ids", "DESIGNS"]

DESIGNS = ("conventional", "sparta", "dipta", "ideal")


@dataclasses.dataclass(frozen=True)
class TimelineConfig:
    """Queueing-resource configuration (defaults logged in EXPERIMENTS.md).

    A count of 0 means the resource is *unbounded* — no queueing on it.
    ``mshrs`` bounds outstanding misses per accelerator, ``tlb_ports`` is the
    number of service ports of each partition's memory-side TLB, and
    ``dram_banks`` the machine-wide number of DRAM banks.  ``tlb_service`` /
    ``dram_service`` are the port/bank *occupancy* times per request and
    default to the corresponding probe/access latencies (``l_tlb`` /
    ``l_dram``); ``issue_interval`` is the cycles between successive issue
    attempts of one accelerator (offered-load knob).
    """

    mshrs: int = 8
    tlb_ports: int = 1
    dram_banks: int = 16
    tlb_service: Optional[float] = None
    dram_service: Optional[float] = None
    issue_interval: float = 1.0

    @classmethod
    def unbounded(cls, **kw) -> "TimelineConfig":
        """No queueing anywhere — the cpi-consistency configuration."""
        return cls(mshrs=0, tlb_ports=0, dram_banks=0, **kw)


@dataclasses.dataclass(frozen=True)
class TimelineResult:
    """Per-access timing arrays + reductions (post-warmup like SystemEvents)."""

    latency: np.ndarray    # f32 [N] issue -> completion cycles
    overhead: np.ndarray   # f32 [N] translation-induced component (incl. waits)
    done: np.ndarray       # f32 [N] absolute completion times
    cache_hit: np.ndarray  # bool [N]
    n_warm: int

    def _warm(self, x: np.ndarray) -> np.ndarray:
        return x[x.shape[0] - self.n_warm:]

    @property
    def mean_latency(self) -> float:
        w = self._warm(self.latency)
        return float(w.mean(dtype=np.float64)) if w.size else 0.0

    @property
    def mean_overhead(self) -> float:
        w = self._warm(self.overhead)
        return float(w.mean(dtype=np.float64)) if w.size else 0.0

    def latency_percentile(self, q: float) -> float:
        w = self._warm(self.latency)
        return float(np.percentile(w, q)) if w.size else 0.0

    def overhead_percentile(self, q: float, *, misses_only: bool = True) -> float:
        """Tail of the translation-induced latency.  ``misses_only`` restricts
        to cache-missing accesses (the translated stream): with high cache
        hit rates an all-access p99 would be identically zero for every
        design and say nothing about translation."""
        w = self._warm(self.overhead)
        if misses_only:
            w = w[~self._warm(self.cache_hit)]
        return float(np.percentile(w, q)) if w.size else 0.0

    @property
    def total_cycles(self) -> float:
        """Makespan: first issue happens at t=0."""
        return float(self.done.max()) if self.done.size else 0.0

    @property
    def throughput(self) -> float:
        """Accesses completed per cycle over the whole stream."""
        return self.done.shape[0] / max(self.total_cycles, 1e-9)

    def summary(self) -> Dict[str, float]:
        return {
            "mean_latency": self.mean_latency,
            "mean_overhead": self.mean_overhead,
            "p50_latency": self.latency_percentile(50),
            "p95_latency": self.latency_percentile(95),
            "p99_latency": self.latency_percentile(99),
            "p99_overhead": self.overhead_percentile(99),
            "total_cycles": self.total_cycles,
            "throughput": self.throughput,
        }


def round_robin_accel_ids(n: int, num_accels: int, granularity: int = 1) -> np.ndarray:
    """Issuing-accelerator ids for a :func:`repro.core.traces.interleave`'d
    trace (round-robin at ``granularity`` accesses per turn)."""
    return ((np.arange(n) // granularity) % num_accels).astype(np.int32)


def _pte_banks(vpns: np.ndarray, banks: int) -> np.ndarray:
    """DRAM bank of each page's PTE: a cheap stateless scatter of the VPN so
    walk/PTE traffic spreads over banks independently of the data lines."""
    v = vpns.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
    return ((v >> np.uint64(17)) % np.uint64(banks)).astype(np.int32)


def _timeline_inputs(
    lines: np.ndarray,
    events: SystemEvents,
    design: str,
    lat: SystemLatencies,
    cfg: TimelineConfig,
    num_partitions: int,
    page_shift: int,
    num_accelerators: int,
    accel_ids: Optional[np.ndarray],
    workload: str,
    way_accuracy: Optional[float],
) -> Tuple[Tuple[np.ndarray, ...], TimelineParams]:
    """The single address/event-to-input rule every timeline backend shares
    (bit-identity of the batched engine depends on it): per-access id/hit/pen
    columns plus the static :class:`TimelineParams` of one simulation."""
    if design not in DESIGNS:
        raise ValueError(f"unknown design {design!r}; options: {DESIGNS}")
    n = int(lines.shape[0])
    if accel_ids is None:
        accel_ids = round_robin_accel_ids(n, num_accelerators)
    vpns = lines >> (page_shift - LINE_SHIFT)

    P = num_partitions if design == "sparta" else 1
    part = (vpns % P).astype(np.int32)
    banks = max(cfg.dram_banks, 1)
    bank_d = (lines % banks).astype(np.int32)
    bank_p = _pte_banks(vpns, banks)

    c = events.cache_hit.astype(np.int32)
    th = events.accel_tlb_hit.astype(np.int32)
    mh = events.mem_tlb_hit.astype(np.int32)

    pen = np.zeros(n, np.float32)
    if design == "dipta":
        acc = way_accuracy if way_accuracy is not None else \
            DIPTA_WAY_PREDICTION_ACCURACY.get(workload, 0.75)
        pen[:] = (1.0 - acc) * 2.0 * lat.l_dram

    params = TimelineParams(
        serial_walk=(design == "conventional"),
        mem_tlb=(design == "sparta"),
        num_accels=int(num_accelerators),
        mshrs=int(cfg.mshrs),
        num_partitions=int(P),
        tlb_ports=int(cfg.tlb_ports),
        dram_banks=int(cfg.dram_banks),
        l_cache=float(lat.l_cache),
        l_tlb=float(lat.l_tlb),
        l_dram=float(lat.l_dram),
        t_net=float(lat.t_net),
        tlb_occ=float(cfg.tlb_service if cfg.tlb_service is not None else lat.l_tlb),
        dram_occ=float(cfg.dram_service if cfg.dram_service is not None else lat.l_dram),
        issue_interval=float(cfg.issue_interval),
    )
    return (accel_ids.astype(np.int32), part, bank_d, bank_p, c, th, mh, pen), params


def simulate_timeline(
    lines: np.ndarray,
    events: SystemEvents,
    design: str,
    lat: SystemLatencies,
    *,
    cfg: TimelineConfig = TimelineConfig(),
    num_partitions: int = 1,
    page_shift: int = 12,
    num_accelerators: int = 1,
    accel_ids: Optional[np.ndarray] = None,
    workload: str = "",
    way_accuracy: Optional[float] = None,
    kernel_mode: str = "auto",
    block: int = 512,
) -> TimelineResult:
    """Per-access completion times for one (design, trace, events) triple.

    ``events`` must come from the simulation of the *same* trace (``lines``)
    with the matching geometry/partitioning (``simulate_system`` or a
    ``sweep_system`` row).  ``num_accelerators`` > 1 models N accelerators
    sharing the memory-side structures: the trace is their interleaved
    stream (``traces.thread_traces`` + ``interleave``) and ``accel_ids``
    names the issuer of each access (round-robin by default).

    This is the reference path; for a sweep of many (design x workload x
    accel-count) cells use :func:`sweep_timeline`, which streams all cells
    in one pass and is bit-identical per cell.
    """
    inputs, params = _timeline_inputs(
        lines, events, design, lat, cfg, num_partitions, page_shift,
        num_accelerators, accel_ids, workload, way_accuracy)
    latency, overhead, done = timeline_sim(
        *(jnp.asarray(x) for x in inputs),
        params, block=block, kernel_mode=kernel_mode)
    return TimelineResult(
        latency=np.asarray(latency),
        overhead=np.asarray(overhead),
        done=np.asarray(done),
        cache_hit=events.cache_hit.astype(bool),
        n_warm=events.n_warm,
    )


# ---------------------------------------------------------------------------
# Batched multi-simulation sweep: all (design x workload x accel-count) cells
# advance per trace element in ONE pass.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class TimelineSpec:
    """One cell of a timeline sweep: (trace, events, design, queue config,
    accelerator count) plus the per-design knobs of
    :func:`simulate_timeline`.

    ``events`` must come from the simulation of the *same* ``lines`` trace
    with the matching geometry/partitioning (a ``sweep_system`` row — one
    batched system pass can feed many specs).  ``lat=None`` falls back to the
    ``lat`` argument of :func:`sweep_timeline`, so a shared latency table is
    stated once per sweep.
    """

    lines: np.ndarray
    events: SystemEvents
    design: str
    lat: Optional[SystemLatencies] = None
    cfg: TimelineConfig = TimelineConfig()
    num_partitions: int = 1
    page_shift: int = 12
    num_accelerators: int = 1
    accel_ids: Optional[np.ndarray] = None
    workload: str = ""
    way_accuracy: Optional[float] = None


# Same per-core scratch discipline as repro.core.sweep: cap the stacked VMEM
# footprint (queueing state + streamed trace blocks per sim) and chunk the
# sim axis when a sweep's padded envelope would not fit.  Chunks still stream
# the trace once each.
_VMEM_STATE_BUDGET_BYTES = 8 * 1024 * 1024


def _timeline_vmem_chunks(
    dims: Sequence[Tuple[int, int, int, int, int]], *, block: int = 512
) -> List[List[int]]:
    """Timeline instantiation of :func:`repro.core.sweep.envelope_chunks`:
    the stacked queueing state on a chunk's (A, M, P, T, D) envelope is
    A + A*M + A + P*T + D words per sim and each sim streams 11 x block
    words (8 input + 3 output per-access columns)."""
    from repro.core.sweep import envelope_chunks

    def state_elems(d):
        A, M, P, T, D = d
        return A + A * M + A + P * T + D

    return envelope_chunks(
        dims, state_elems,
        stream_words=11 * block, budget_bytes=_VMEM_STATE_BUDGET_BYTES)


def sweep_timeline(
    specs: Sequence[TimelineSpec],
    lat: Optional[SystemLatencies] = None,
    *,
    kernel_mode: str = "auto",
    block: int = 512,
) -> List[TimelineResult]:
    """Simulate every spec's timeline in a single pass over the trace axis.

    Specs are padded to a common resource envelope (accelerators, MSHRs,
    partitions, TLB ports, DRAM banks, trace length), their queueing states
    stacked on a leading sim axis, and all sims advanced per trace element
    through one vmapped ``lax.scan`` (or the batched Pallas kernel, chunked
    over the sim axis to the VMEM scratch budget).  Padding is poisoned so it
    is unobservable: trailing trace padding is zero-latency cache hits from
    accelerator 0 (reads state, completes locally, outputs dropped) and
    padded resource slots are never selected (see
    ``repro.kernels.timeline.ref``).  Per-spec results are **bit-identical**
    to :func:`simulate_timeline`, which stays the reference path
    (tests/test_timeline_sweep.py asserts equivalence).
    """
    if not specs:
        raise ValueError("sweep_timeline needs at least one spec")
    prepared = []
    for sp in specs:
        sp_lat = sp.lat if sp.lat is not None else lat
        if sp_lat is None:
            raise ValueError(
                "sweep_timeline: spec has lat=None and no sweep-level lat given")
        prepared.append(_timeline_inputs(
            sp.lines, sp.events, sp.design, sp_lat, sp.cfg, sp.num_partitions,
            sp.page_shift, sp.num_accelerators, sp.accel_ids, sp.workload,
            sp.way_accuracy))

    lens = [int(p[0][0].shape[0]) for p in prepared]
    n_max = max(lens)
    packed = [pack_params(params) for _, params in prepared]
    fparams = np.stack([fp for fp, _ in packed])
    iparams = np.stack([ip for _, ip in packed])

    # Trace-length padding: trailing zero-latency cache hits from accel 0
    # (exactly the Pallas block-padding discipline; outputs are dropped).
    cols = []
    for (inputs, _), n in zip(prepared, lens):
        row = [np.concatenate([x, np.full(n_max - n, v, dtype=x.dtype)])
               if n < n_max else x
               for x, v in zip(inputs, _PAD_VALS)]
        cols.append(row)
    stacked = [np.stack([row[k] for row in cols]) for k in range(8)]

    # Backend selection through the dispatch layer (cold-start for a bare
    # call; the orchestrator makes the calibrated decision and passes a
    # concrete mode).  resolve_timeline_mode still validates + rejects
    # sweep-only backends for explicit modes.
    from repro.core import dispatch

    mode = dispatch.decide_timeline(
        kernel_mode, batch=len(specs), n_accesses=n_max).mode
    if mode == "reference":
        chunks = [list(range(len(specs)))]
    else:
        dims = [tuple(max(int(x), 1) for x in ip[2:7]) for ip in iparams]
        chunks = _timeline_vmem_chunks(dims, block=min(block, max(n_max, 1)))

    lat_b = np.empty((len(specs), n_max), np.float32)
    ov_b = np.empty((len(specs), n_max), np.float32)
    done_b = np.empty((len(specs), n_max), np.float32)
    for chunk in chunks:
        out = timeline_sim_batched(
            *(jnp.asarray(s[chunk]) for s in stacked),
            fparams[chunk], iparams[chunk],
            block=block, kernel_mode=mode)
        lat_b[chunk], ov_b[chunk], done_b[chunk] = (np.asarray(o) for o in out)

    return [
        TimelineResult(
            latency=lat_b[i, :n], overhead=ov_b[i, :n], done=done_b[i, :n],
            cache_hit=sp.events.cache_hit.astype(bool),
            n_warm=sp.events.n_warm,
        )
        for i, (sp, n) in enumerate(zip(specs, lens))
    ]


# Trailing trace padding shared by sweep_timeline and TimelineSweepStream:
# zero-latency cache hits from accelerator 0 (read state, complete locally,
# outputs dropped).
_PAD_VALS = (0, 0, 0, 0, 1, 1, 1, np.float32(0.0))


class TimelineSweepStream:
    """Resumable chunked execution of :func:`sweep_timeline`.

    The stream prepares the stacked per-access columns of every spec once
    (identically to :func:`sweep_timeline`, including the trailing per-spec
    length padding) and owns the carried queueing state; each
    :meth:`run_chunk` call advances every sim through one slice
    ``[lo, hi)`` of the stacked trace axis.  Feeding the slices in order is
    **bit-identical** to one monolithic :func:`sweep_timeline` call in any
    backend and across backend changes at chunk boundaries: the sim grouping
    (:func:`_timeline_vmem_chunks`) is mode-independent and all backends
    share one state layout and step function.

    Unlike the LRU streams, a timeline chunk can NOT be padded mid-stream
    (padding perturbs accelerator 0's issue clock), so every chunk except
    the final one must be a multiple of ``block`` (or at most ``block``
    long); the final chunk is tail-padded exactly like the monolithic op.
    """

    engine = "sweep_timeline"

    def __init__(self, specs: Sequence[TimelineSpec],
                 lat: Optional[SystemLatencies] = None, *, block: int = 512):
        if not specs:
            raise ValueError("TimelineSweepStream needs at least one spec")
        self.specs = tuple(specs)
        self.block = int(block)
        prepared = []
        for sp in self.specs:
            sp_lat = sp.lat if sp.lat is not None else lat
            if sp_lat is None:
                raise ValueError(
                    "TimelineSweepStream: spec has lat=None and no "
                    "stream-level lat given")
            prepared.append(_timeline_inputs(
                sp.lines, sp.events, sp.design, sp_lat, sp.cfg,
                sp.num_partitions, sp.page_shift, sp.num_accelerators,
                sp.accel_ids, sp.workload, sp.way_accuracy))
        self.lens = [int(p[0][0].shape[0]) for p in prepared]
        self.n = max(self.lens)
        packed = [pack_params(params) for _, params in prepared]
        self.fparams = np.stack([fp for fp, _ in packed])
        self.iparams = np.stack([ip for _, ip in packed])
        cols = []
        for (inputs, _), n in zip(prepared, self.lens):
            cols.append([
                np.concatenate([x, np.full(self.n - n, v, dtype=x.dtype)])
                if n < self.n else x
                for x, v in zip(inputs, _PAD_VALS)])
        self._stacked = [np.stack([row[k] for row in cols]) for k in range(8)]

        dims = [tuple(max(int(x), 1) for x in ip[2:7]) for ip in self.iparams]
        self.groups = _timeline_vmem_chunks(
            dims, block=min(self.block, max(self.n, 1)))
        self._envelopes = []
        self._state = []
        for g in self.groups:
            env = tuple(int(self.iparams[g, c].max()) if int(
                self.iparams[g, c].max()) > 0 else 1 for c in (2, 3, 4, 5, 6))
            self._envelopes.append(env)
            self._state.append(timeline_init_state_batched(
                len(g), env, jnp.asarray(self.iparams[g, 5])))
        self.now = 0
        from repro.core.sweep import _note_envelope
        _note_envelope(self)

    @property
    def batch_size(self) -> int:
        return len(self.specs)

    def fingerprint(self) -> dict:
        return {
            "engine": self.engine,
            "block": self.block,
            "n": self.n,
            "lens": list(self.lens),
            "fparams": [[float(x) for x in row] for row in self.fparams],
            "iparams": [[int(x) for x in row] for row in self.iparams],
        }

    def run_chunk(self, lo: int, hi: int, *, kernel_mode: str = "auto"):
        """Advance every sim through the stacked-trace slice ``[lo, hi)``;
        returns (latency, overhead, done), each f32 [B, hi - lo].  Commit-on-
        success: a failed call leaves the stream unchanged."""
        if lo != self.now:
            raise ValueError(
                f"{self.engine} chunk starts at {lo}, stream is at {self.now}")
        if not lo < hi <= self.n:
            raise ValueError(
                f"{self.engine} chunk [{lo}, {hi}) outside stream [0, {self.n})")
        L = hi - lo
        if hi != self.n and L > self.block and L % self.block:
            raise ValueError(
                f"{self.engine} mid-stream chunk length {L} must be a "
                f"multiple of block {self.block} (or <= block): mid-stream "
                f"padding would perturb accelerator 0's issue clock")
        cols = [s[:, lo:hi] for s in self._stacked]
        pad = (-L) % min(self.block, L) if hi == self.n else 0
        if pad:
            # Final-chunk tail padding — the monolithic op's own discipline;
            # padded outputs dropped, and no further chunk reads the state.
            cols = [np.concatenate(
                [x, np.full((x.shape[0], pad), v, dtype=x.dtype)], axis=1)
                for x, v in zip(cols, _PAD_VALS)]
        outs = [np.empty((len(self.specs), L), np.float32) for _ in range(3)]
        new_state = []
        for gi, g in enumerate(self.groups):
            ys, st = timeline_sim_batched_carry(
                *(jnp.asarray(c[g]) for c in cols),
                self.fparams[g], self.iparams[g], self._state[gi],
                block=self.block, kernel_mode=kernel_mode)
            for o, y in zip(outs, ys):
                o[g] = np.asarray(y)[:, :L]   # forces compute (commit gate)
            new_state.append(st)
        self._state = new_state
        self.now = hi
        from repro.core.sweep import _count_sim_accesses
        _count_sim_accesses(self, L)
        return tuple(outs)

    def export_state(self) -> dict:
        out = {"now": np.array([self.now], np.int64)}
        names = ("acc_next", "mshr_ring", "mshr_cnt", "port_free", "bank_free")
        for gi, st in enumerate(self._state):
            for name, arr in zip(names, st):
                out[f"g{gi}_{name}"] = np.asarray(arr)
        return out

    def import_state(self, arrays: dict) -> None:
        names = ("acc_next", "mshr_ring", "mshr_cnt", "port_free", "bank_free")
        state = []
        for gi in range(len(self.groups)):
            st = []
            for j, name in enumerate(names):
                key = f"g{gi}_{name}"
                if key not in arrays:
                    raise ValueError(f"{self.engine} state missing array {key!r}")
                arr = np.asarray(arrays[key])
                ref = np.asarray(self._state[gi][j])
                if tuple(arr.shape) != tuple(ref.shape):
                    raise ValueError(
                        f"{self.engine} state array {key!r} has shape "
                        f"{tuple(arr.shape)}, expected {tuple(ref.shape)}")
                st.append(jnp.asarray(arr.astype(ref.dtype)))
            state.append(tuple(st))
        self._state = state
        self.now = int(np.asarray(arrays["now"]).reshape(-1)[0])

    def finalize(self, latency: np.ndarray, overhead: np.ndarray,
                 done: np.ndarray) -> List[TimelineResult]:
        """Assemble per-spec results from the accumulated [B, n] output
        buffers (each spec sliced back to its own unpadded length)."""
        return [
            TimelineResult(
                latency=latency[i, :n], overhead=overhead[i, :n],
                done=done[i, :n],
                cache_hit=sp.events.cache_hit.astype(bool),
                n_warm=sp.events.n_warm,
            )
            for i, (sp, n) in enumerate(zip(self.specs, self.lens))
        ]
