"""Cycle-approximate event-timeline engine (per-access latency + queueing).

:mod:`repro.core.cpi` turns measured hit *rates* into average per-access
latency — it cannot express queueing contention on shared memory-side TLBs
or latency *distributions*, exactly the effects SPARTA's partitioning is
designed to remove.  This module composes a **per-access completion time**
from the per-access hit/miss event bits already produced by
:func:`repro.core.tlbsim.simulate_system` / :func:`repro.core.sweep.sweep_system`,
threading three bounded resources through the Fig 3 timelines:

* an MSHR-style window of outstanding misses per accelerator,
* per-partition memory-side TLB service ports with FIFO queueing (SPARTA),
* banked DRAM service slots (page walks, PTE reads and data fetches all
  occupy a bank).

Outputs are per-access latency/overhead arrays reduced to total cycles,
throughput and p50/p95/p99 tails for the four designs
(``conventional`` / ``sparta`` / ``dipta`` / ``ideal``).

**Oracle property**: with every resource unbounded
(:meth:`TimelineConfig.unbounded`) all queue waits vanish and the
post-warmup *mean* latency / translation overhead reproduce
:mod:`repro.core.cpi`'s analytical averages exactly (``tests/test_timeline.py``
asserts <= 1e-6 relative error for all designs and workloads).

The sequential hot loop lives in :mod:`repro.kernels.timeline` (jnp
``lax.scan`` oracle + Pallas TPU kernel with the state resident in VMEM
scratch, dispatched by ``kernel_mode`` like every other kernel package).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.cpi import DIPTA_WAY_PREDICTION_ACCURACY
from repro.core.sparta import SystemLatencies
from repro.core.tlbsim import LINE_SHIFT, SystemEvents
from repro.kernels.timeline import TimelineParams, timeline_sim

__all__ = ["TimelineConfig", "TimelineResult", "simulate_timeline",
           "round_robin_accel_ids", "DESIGNS"]

DESIGNS = ("conventional", "sparta", "dipta", "ideal")


@dataclasses.dataclass(frozen=True)
class TimelineConfig:
    """Queueing-resource configuration (defaults logged in EXPERIMENTS.md).

    A count of 0 means the resource is *unbounded* — no queueing on it.
    ``mshrs`` bounds outstanding misses per accelerator, ``tlb_ports`` is the
    number of service ports of each partition's memory-side TLB, and
    ``dram_banks`` the machine-wide number of DRAM banks.  ``tlb_service`` /
    ``dram_service`` are the port/bank *occupancy* times per request and
    default to the corresponding probe/access latencies (``l_tlb`` /
    ``l_dram``); ``issue_interval`` is the cycles between successive issue
    attempts of one accelerator (offered-load knob).
    """

    mshrs: int = 8
    tlb_ports: int = 1
    dram_banks: int = 16
    tlb_service: Optional[float] = None
    dram_service: Optional[float] = None
    issue_interval: float = 1.0

    @classmethod
    def unbounded(cls, **kw) -> "TimelineConfig":
        """No queueing anywhere — the cpi-consistency configuration."""
        return cls(mshrs=0, tlb_ports=0, dram_banks=0, **kw)


@dataclasses.dataclass(frozen=True)
class TimelineResult:
    """Per-access timing arrays + reductions (post-warmup like SystemEvents)."""

    latency: np.ndarray    # f32 [N] issue -> completion cycles
    overhead: np.ndarray   # f32 [N] translation-induced component (incl. waits)
    done: np.ndarray       # f32 [N] absolute completion times
    cache_hit: np.ndarray  # bool [N]
    n_warm: int

    def _warm(self, x: np.ndarray) -> np.ndarray:
        return x[x.shape[0] - self.n_warm:]

    @property
    def mean_latency(self) -> float:
        w = self._warm(self.latency)
        return float(w.mean(dtype=np.float64)) if w.size else 0.0

    @property
    def mean_overhead(self) -> float:
        w = self._warm(self.overhead)
        return float(w.mean(dtype=np.float64)) if w.size else 0.0

    def latency_percentile(self, q: float) -> float:
        w = self._warm(self.latency)
        return float(np.percentile(w, q)) if w.size else 0.0

    def overhead_percentile(self, q: float, *, misses_only: bool = True) -> float:
        """Tail of the translation-induced latency.  ``misses_only`` restricts
        to cache-missing accesses (the translated stream): with high cache
        hit rates an all-access p99 would be identically zero for every
        design and say nothing about translation."""
        w = self._warm(self.overhead)
        if misses_only:
            w = w[~self._warm(self.cache_hit)]
        return float(np.percentile(w, q)) if w.size else 0.0

    @property
    def total_cycles(self) -> float:
        """Makespan: first issue happens at t=0."""
        return float(self.done.max()) if self.done.size else 0.0

    @property
    def throughput(self) -> float:
        """Accesses completed per cycle over the whole stream."""
        return self.done.shape[0] / max(self.total_cycles, 1e-9)

    def summary(self) -> Dict[str, float]:
        return {
            "mean_latency": self.mean_latency,
            "mean_overhead": self.mean_overhead,
            "p50_latency": self.latency_percentile(50),
            "p95_latency": self.latency_percentile(95),
            "p99_latency": self.latency_percentile(99),
            "p99_overhead": self.overhead_percentile(99),
            "total_cycles": self.total_cycles,
            "throughput": self.throughput,
        }


def round_robin_accel_ids(n: int, num_accels: int, granularity: int = 1) -> np.ndarray:
    """Issuing-accelerator ids for a :func:`repro.core.traces.interleave`'d
    trace (round-robin at ``granularity`` accesses per turn)."""
    return ((np.arange(n) // granularity) % num_accels).astype(np.int32)


def _pte_banks(vpns: np.ndarray, banks: int) -> np.ndarray:
    """DRAM bank of each page's PTE: a cheap stateless scatter of the VPN so
    walk/PTE traffic spreads over banks independently of the data lines."""
    v = vpns.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
    return ((v >> np.uint64(17)) % np.uint64(banks)).astype(np.int32)


def simulate_timeline(
    lines: np.ndarray,
    events: SystemEvents,
    design: str,
    lat: SystemLatencies,
    *,
    cfg: TimelineConfig = TimelineConfig(),
    num_partitions: int = 1,
    page_shift: int = 12,
    num_accelerators: int = 1,
    accel_ids: Optional[np.ndarray] = None,
    workload: str = "",
    way_accuracy: Optional[float] = None,
    kernel_mode: str = "auto",
    block: int = 512,
) -> TimelineResult:
    """Per-access completion times for one (design, trace, events) triple.

    ``events`` must come from the simulation of the *same* trace (``lines``)
    with the matching geometry/partitioning (``simulate_system`` or a
    ``sweep_system`` row).  ``num_accelerators`` > 1 models N accelerators
    sharing the memory-side structures: the trace is their interleaved
    stream (``traces.thread_traces`` + ``interleave``) and ``accel_ids``
    names the issuer of each access (round-robin by default).
    """
    if design not in DESIGNS:
        raise ValueError(f"unknown design {design!r}; options: {DESIGNS}")
    n = int(lines.shape[0])
    if accel_ids is None:
        accel_ids = round_robin_accel_ids(n, num_accelerators)
    vpns = lines >> (page_shift - LINE_SHIFT)

    P = num_partitions if design == "sparta" else 1
    part = (vpns % P).astype(np.int32)
    banks = max(cfg.dram_banks, 1)
    bank_d = (lines % banks).astype(np.int32)
    bank_p = _pte_banks(vpns, banks)

    c = events.cache_hit.astype(np.int32)
    th = events.accel_tlb_hit.astype(np.int32)
    mh = events.mem_tlb_hit.astype(np.int32)

    pen = np.zeros(n, np.float32)
    if design == "dipta":
        acc = way_accuracy if way_accuracy is not None else \
            DIPTA_WAY_PREDICTION_ACCURACY.get(workload, 0.75)
        pen[:] = (1.0 - acc) * 2.0 * lat.l_dram

    params = TimelineParams(
        serial_walk=(design == "conventional"),
        mem_tlb=(design == "sparta"),
        num_accels=int(num_accelerators),
        mshrs=int(cfg.mshrs),
        num_partitions=int(P),
        tlb_ports=int(cfg.tlb_ports),
        dram_banks=int(cfg.dram_banks),
        l_cache=float(lat.l_cache),
        l_tlb=float(lat.l_tlb),
        l_dram=float(lat.l_dram),
        t_net=float(lat.t_net),
        tlb_occ=float(cfg.tlb_service if cfg.tlb_service is not None else lat.l_tlb),
        dram_occ=float(cfg.dram_service if cfg.dram_service is not None else lat.l_dram),
        issue_interval=float(cfg.issue_interval),
    )
    latency, overhead, done = timeline_sim(
        *(jnp.asarray(x) for x in (accel_ids, part, bank_d, bank_p, c, th, mh, pen)),
        params, block=block, kernel_mode=kernel_mode)
    return TimelineResult(
        latency=np.asarray(latency),
        overhead=np.asarray(overhead),
        done=np.asarray(done),
        cache_hit=events.cache_hit.astype(bool),
        n_warm=events.n_warm,
    )
