"""Partition-local inverted page tables, demand paging, and the paper's OS
allocation algorithms (paper §5, Fig 6).

Three pieces:

1. :class:`InvertedPageTable` — the paper's per-partition hashed/inverted
   page table (modelled on IBM Power HTABs).  One table per partition, sized
   to the partition's frame count, co-located with the partition's data.
   Open-addressing hash on (asid, vpn) with a valid bit per entry — the
   structure the memory-side MMU walks *locally* on a TLB miss.

2. The OS allocation paths of §5:
   * :func:`alloc_page_vma` — Algorithm 1: the partition is derived from the
     faulting virtual address, the frame may be *any* free frame in that
     partition (demand paging; millions of placement options).
   * :func:`adjust_virtual_region` — the shared/remapped-pages path: slide a
     candidate virtual region so its partition sequence matches the partition
     sequence of the existing physical pages (the paper's [V5..V9]->[V7..V11]
     example).

3. :func:`page_fault_curve` — the Fig 6 experiment: LRU page-fault rate vs
   available memory for non-partitioned (1 node) vs partitioned (32 node)
   systems, computed exactly from LRU stack distances (Fenwick-tree algorithm
   run as a ``jax.lax.scan``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparta import mem_partition_index_hash


# ---------------------------------------------------------------------------
# 1. Partition-local inverted page table.
# ---------------------------------------------------------------------------

class InvertedPageTable:
    """Open-addressing inverted page table for ONE memory partition.

    Entries: (asid, vpn) -> local frame number.  Capacity is proportional to
    the partition's frames (load factor <= 0.75), i.e. table size scales with
    the partition — the property that makes SPARTA page walks local and O(1).
    """

    EMPTY = -1
    TOMB = -2

    def __init__(self, num_frames: int):
        self.capacity = max(8, int(num_frames / 0.75))
        self.keys_asid = np.full(self.capacity, self.EMPTY, dtype=np.int64)
        self.keys_vpn = np.full(self.capacity, self.EMPTY, dtype=np.int64)
        self.frames = np.full(self.capacity, self.EMPTY, dtype=np.int64)
        self.valid = np.zeros(self.capacity, dtype=bool)
        self.size = 0

    def _probe(self, asid: int, vpn: int) -> Tuple[int, Optional[int]]:
        """Returns (insert_slot, found_slot)."""
        h = hash((asid, vpn)) % self.capacity
        first_free = -1
        for i in range(self.capacity):
            j = (h + i) % self.capacity
            if self.keys_asid[j] == self.EMPTY:
                if first_free < 0:
                    first_free = j
                return first_free, None
            if self.keys_asid[j] == self.TOMB:
                if first_free < 0:
                    first_free = j
                continue
            if self.keys_asid[j] == asid and self.keys_vpn[j] == vpn:
                return j, j
        if first_free < 0:
            raise RuntimeError("inverted page table full")
        return first_free, None

    def insert(self, asid: int, vpn: int, frame: int) -> None:
        slot, found = self._probe(asid, vpn)
        if found is None:
            self.size += 1
        self.keys_asid[slot] = asid
        self.keys_vpn[slot] = vpn
        self.frames[slot] = frame
        self.valid[slot] = True

    def lookup(self, asid: int, vpn: int) -> Optional[int]:
        _, found = self._probe(asid, vpn)
        if found is None or not self.valid[found]:
            return None
        return int(self.frames[found])

    def invalidate(self, asid: int, vpn: int) -> bool:
        """Clear the valid bit (the CPU<->accelerator coherence hook, §5)."""
        _, found = self._probe(asid, vpn)
        if found is None:
            return False
        self.valid[found] = False
        self.keys_asid[found] = self.TOMB
        self.size -= 1
        return True


# ---------------------------------------------------------------------------
# 2. OS allocation paths (§5).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Partition:
    """One memory partition: free-frame list + its inverted page table."""

    index: int
    frames: List[int]
    page_table: InvertedPageTable

    def alloc_frame(self) -> Optional[int]:
        return self.frames.pop() if self.frames else None


def make_partitions(num_partitions: int, frames_per_partition: int) -> List[Partition]:
    return [
        Partition(
            index=p,
            frames=list(range(frames_per_partition - 1, -1, -1)),
            page_table=InvertedPageTable(frames_per_partition),
        )
        for p in range(num_partitions)
    ]


def alloc_page_vma(vaddr_vpn: int, asid: int, partitions: List[Partition]) -> Tuple[int, int]:
    """Algorithm 1: ALLOC_PAGES_VMA — partition from the hash, any free frame.

    Returns (partition_index, local_frame).  Raises on partition exhaustion
    (the caller models swapping / eviction).
    """
    p = int(mem_partition_index_hash(np.int64(vaddr_vpn), len(partitions)))
    frame = partitions[p].alloc_frame()
    if frame is None:
        raise MemoryError(f"partition {p} exhausted")
    partitions[p].page_table.insert(asid, vaddr_vpn, frame)
    return p, frame


def adjust_virtual_region(
    candidate_start_vpn: int,
    existing_partition_seq: Sequence[int],
    num_partitions: int,
    *,
    search_limit: int = 1 << 20,
) -> int:
    """§5 shared/remap path: slide the candidate virtual region forward until
    its partition sequence matches the existing physical pages' sequence.

    With the mod-P hash, consecutive virtual pages cycle through partitions,
    so it suffices to match the first page: the adjusted start is the
    smallest vpn >= candidate_start whose hash equals the first existing
    partition.  (The paper's example: candidate V5 with sequence (3,0,1,2,3)
    and P=4 adjusts to V7.)
    """
    if not existing_partition_seq:
        return candidate_start_vpn
    # Verify the existing sequence is realisable under the mod-P hash.
    base = existing_partition_seq[0]
    for i, p in enumerate(existing_partition_seq):
        if p != (base + i) % num_partitions:
            raise ValueError("existing physical pages do not form a contiguous partition cycle")
    delta = (base - candidate_start_vpn) % num_partitions
    if delta > search_limit:
        raise RuntimeError("no aligned region found")
    return candidate_start_vpn + delta


# ---------------------------------------------------------------------------
# 3. Demand paging: exact LRU fault curves from stack distances (Fig 6).
# ---------------------------------------------------------------------------

def _previous_occurrence(pages: np.ndarray) -> np.ndarray:
    """prev[i] = index of the previous access to pages[i], or -1."""
    order = np.argsort(pages, kind="stable")
    sorted_pages = pages[order]
    prev_sorted = np.full(pages.shape[0], -1, dtype=np.int64)
    same = sorted_pages[1:] == sorted_pages[:-1]
    prev_sorted[1:][same] = order[:-1][same]
    prev = np.empty_like(prev_sorted)
    prev[order] = prev_sorted
    return prev


@functools.partial(jax.jit, static_argnames=("n", "bits"))
def _fenwick_stack_distances(prev: jnp.ndarray, n: int, bits: int) -> jnp.ndarray:
    """LRU stack distances via a Fenwick tree maintained inside a scan.

    The tree stores a 1 at position j iff access j is currently the most
    recent access to its page; the stack distance of access i with previous
    occurrence p is then sum(tree[p+1 .. i-1]) + 1 (to include the page
    itself we report the count of *distinct other* pages + 1).
    First accesses (cold) get distance n+1 (always a fault).
    """
    tree0 = jnp.zeros(n + 1, dtype=jnp.int32)

    def prefix(tree, x):  # sum of tree[1..x]
        def body(b, carry):
            s, xx = carry
            take = xx > 0
            s = s + jnp.where(take, tree[jnp.maximum(xx, 0)], 0)
            xx = jnp.where(take, xx - (xx & -xx), xx)
            return (s, xx)
        s, _ = jax.lax.fori_loop(0, bits, body, (jnp.int32(0), x))
        return s

    def update(tree, x, v):
        def body(b, carry):
            t, xx = carry
            ok = (xx <= n) & (xx > 0)
            idx = jnp.clip(xx, 0, n)
            t = t.at[idx].add(jnp.where(ok, v, 0))
            xx = jnp.where(ok, xx + (xx & -xx), n + 1)
            return (t, xx)
        t, _ = jax.lax.fori_loop(0, bits, body, (tree, x))
        return t

    def step(tree, inp):
        i, p = inp
        cold = p < 0
        # distinct pages strictly between p and i (exclusive) among "most
        # recent" flags, +1 for the page itself.
        cnt = prefix(tree, i) - prefix(tree, jnp.maximum(p + 1, 0))
        dist = jnp.where(cold, jnp.int32(n + 1), cnt + 1)
        tree = update(tree, jnp.maximum(p + 1, 1), jnp.where(cold, 0, -1))
        tree = update(tree, i + 1, 1)
        return tree, dist

    idx = jnp.arange(n, dtype=jnp.int32)
    _, dists = jax.lax.scan(step, tree0, (idx, prev.astype(jnp.int32)))
    return dists


def stack_distances(pages: np.ndarray) -> np.ndarray:
    """Exact LRU stack distance per access (n+1 for cold misses)."""
    pages = np.asarray(pages, dtype=np.int64)
    n = pages.shape[0]
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    prev = _previous_occurrence(pages)
    bits = max(1, int(np.ceil(np.log2(n + 2))) + 1)
    return np.asarray(_fenwick_stack_distances(jnp.asarray(prev), n, bits), dtype=np.int64)


def stack_distances_batch(streams: List[np.ndarray]) -> List[np.ndarray]:
    """Batched stack distances: pads all streams to one length and vmaps the
    Fenwick scan, so the whole batch costs ONE compilation (the per-partition
    streams of Fig 6 have ragged lengths)."""
    if not streams:
        return []
    n = max(int(s.shape[0]) for s in streams)
    n = max(n, 1)
    prevs = []
    for s in streams:
        s = np.asarray(s, dtype=np.int64)
        pad = n - s.shape[0]
        if pad:
            # Repeat the last page; padded accesses are sliced off below.
            filler = np.full(pad, s[-1] if s.size else 0, dtype=np.int64)
            s = np.concatenate([s, filler])
        prevs.append(_previous_occurrence(s))
    bits = max(1, int(np.ceil(np.log2(n + 2))) + 1)
    fn = jax.vmap(lambda p: _fenwick_stack_distances(p, n, bits))
    out = np.asarray(fn(jnp.asarray(np.stack(prevs))), dtype=np.int64)
    return [out[i, : streams[i].shape[0]] for i in range(len(streams))]


def fault_rate(distances: np.ndarray, frames: int) -> float:
    """LRU inclusion property: access faults iff stack distance > frames."""
    if distances.size == 0:
        return 0.0
    return float((distances > frames).mean())


def page_fault_curve(
    vpns: np.ndarray,
    mem_frames: Sequence[int],
    *,
    num_partitions: int = 1,
    node_overhead_frames: int = 0,
    node_capacity_jitter: float = 0.0,
    seed: int = 0,
) -> np.ndarray:
    """Fault rate for each total-memory size, with optional partitioning.

    Partitioned mode splits both the trace (by the partition hash) and the
    frames (evenly, minus per-node overhead, with deterministic capacity
    jitter modelling the Linux-NUMA-node artifact the paper reports: the
    32-node setup needs ~1.5-2 GB extra memory for the same fault rate).
    """
    vpns = np.asarray(vpns, dtype=np.int64)
    if num_partitions == 1:
        d = stack_distances(vpns)
        return np.array([fault_rate(d, int(f)) for f in mem_frames])

    rng = np.random.default_rng(seed)
    jitter = 1.0 + node_capacity_jitter * rng.standard_normal(num_partitions)
    part = vpns % num_partitions
    dists = stack_distances_batch([vpns[part == p] for p in range(num_partitions)])
    out = []
    for f in mem_frames:
        usable = max(int(f) - node_overhead_frames * num_partitions, num_partitions)
        per = usable / num_partitions
        faults = 0
        total = 0
        for p in range(num_partitions):
            fp = max(1, int(per * jitter[p]))
            faults += int((dists[p] > fp).sum())
            total += dists[p].size
        out.append(faults / max(total, 1))
    return np.array(out)
