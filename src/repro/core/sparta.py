"""SPARTA core: partition hashing and translation-system configuration.

SPARTA (Split and PARtitioned Translation for Accelerators) divides address
translation between a (tiny or absent) accelerator-side TLB and per-partition
memory-side TLBs.  The single invariant the OS must maintain is::

    MEM_PARTITION_INDEX_HASH(vpn) == partition_of(pfn(vpn))

i.e. the virtual page number alone names the memory partition that holds the
page, while the page may live *anywhere inside* that partition.  Everything in
this package — the trace-driven TLB simulator, the CPI timeline model, the
demand-paging model, and the serving-side paged-KV manager — keys off the
functions and dataclasses in this module.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

PAGE_SHIFT_4K = 12
PAGE_SHIFT_2M = 21


def mem_partition_index_hash(vpn: jnp.ndarray, num_partitions: int) -> jnp.ndarray:
    """The paper's MEM_PARTITION_INDEX_HASH(): a subset of VA bits (mod P).

    The paper (§4.2) allows any simple hash; the Linux prototype uses
    ``VPN mod P``.  We keep that exact function so the OS-side examples in
    §5 of the paper (shared-mapping phase adjustment) reproduce verbatim.
    """
    return vpn % num_partitions


def partition_local_vpn(vpn: jnp.ndarray, num_partitions: int) -> jnp.ndarray:
    """The partition-local page identifier (the bits not consumed by the hash)."""
    return vpn // num_partitions


@dataclasses.dataclass(frozen=True)
class TLBConfig:
    """Geometry of one TLB (accelerator-side or one memory-side partition TLB)."""

    entries: int = 128
    ways: int = 4
    page_shift: int = PAGE_SHIFT_4K

    def __post_init__(self):
        if self.entries < 1 or self.ways < 1:
            raise ValueError(f"entries={self.entries}, ways={self.ways}: must be >= 1")
        # entries < ways is permitted: the structure degrades to fully-assoc of
        # size `entries` (see effective_ways).  Otherwise ways must tile entries.
        if self.entries > self.ways and self.entries % self.ways:
            raise ValueError(f"entries={self.entries} not divisible by ways={self.ways}")

    @property
    def sets(self) -> int:
        # Derive from the normalised associativity so entries < ways configs
        # report the (1-set, fully-assoc) geometry they actually simulate as.
        return max(1, self.entries // self.effective_ways)

    @property
    def effective_ways(self) -> int:
        # A config with fewer entries than ways degrades to fully-assoc of size
        # `entries`; normalise so sets >= 1 always holds.
        return min(self.ways, self.entries)


@dataclasses.dataclass(frozen=True)
class TranslationConfig:
    """A full translation system: SPARTA (P>1) or conventional (P==1).

    ``num_partitions == 1`` with ``shared=False`` models conventional
    per-accelerator TLBs; ``num_partitions >= 1`` with ``shared=True`` models
    SPARTA memory-side TLBs shared by all threads/accelerators.
    """

    num_partitions: int = 1
    tlb: TLBConfig = dataclasses.field(default_factory=TLBConfig)
    shared: bool = True  # memory-side TLBs are shared among all accelerators
    # Accelerator-side TLB (only meaningful with physical caches; None => none).
    accel_tlb: Optional[TLBConfig] = None

    @property
    def total_entries(self) -> int:
        n = self.num_partitions * self.tlb.entries
        if self.accel_tlb is not None:
            n += self.accel_tlb.entries
        return n


@dataclasses.dataclass(frozen=True)
class SystemLatencies:
    """Latency parameters (cycles @ accelerator clock) for the Fig 3 timelines.

    Defaults model the paper's 8-socket, 4-channels/socket, 128 GB machine at
    2 GHz: ~20 ns NoC traversal, ~110 ns average inter-socket traversal,
    ~60 ns DRAM access.  These are *assumptions* (the paper does not publish
    its table); see EXPERIMENTS.md for the calibration band check.
    """

    l_cache: float = 2.0        # accelerator cache hit
    l_tlb: float = 2.0          # TLB probe (accel- or memory-side)
    l_dram: float = 120.0       # one DRAM access (60 ns)
    l_noc: float = 40.0         # on-chip network one-way (20 ns)
    l_offchip: float = 400.0    # inter-socket traversal one-way (200 ns avg, multi-hop glueless 8-socket)
    n_sockets: int = 8

    @property
    def t_net(self) -> float:
        """Average one-way network latency from accelerator to a memory channel.

        Data is uniformly spread over sockets, so (1 - 1/n) of accesses pay the
        off-chip hop.  Larger machines => longer average traversals (paper §7.4).
        """
        remote_frac = 1.0 - 1.0 / self.n_sockets
        return self.l_noc + remote_frac * self.l_offchip


def conventional_timelines(lat: SystemLatencies):
    """(hit_total, miss_total, hit_overhead, miss_overhead) for conventional
    translation, accelerator without cache (Fig 3a/3b).

    Translation and data fetch are serialized; a page walk (perfect MMU
    caches => exactly one memory reference, the paper's conservative baseline)
    pays a full network round trip *before* the data fetch round trip.
    """
    data_path = 2 * lat.t_net + lat.l_dram
    hit_total = lat.l_tlb + data_path
    walk = 2 * lat.t_net + lat.l_dram
    miss_total = lat.l_tlb + walk + data_path
    return hit_total, miss_total, lat.l_tlb, lat.l_tlb + walk


def sparta_timelines(lat: SystemLatencies):
    """(hit_total, miss_total, hit_overhead, miss_overhead) for SPARTA
    (Fig 3c/3d).

    The network traversal to the partition is shared between translation and
    data paths; on a memory-side TLB miss the PTE is in the *same* partition,
    so the walk is one local DRAM access with no extra network traversals.
    """
    hit_total = 2 * lat.t_net + lat.l_tlb + lat.l_dram
    miss_total = 2 * lat.t_net + lat.l_tlb + lat.l_dram + lat.l_dram
    return hit_total, miss_total, lat.l_tlb, lat.l_tlb + lat.l_dram
