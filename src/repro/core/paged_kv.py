"""SPARTA-partitioned paged KV-cache management (DESIGN.md §2.2).

This is the paper's translation architecture transplanted to LLM serving:

* The KV cache is a *paged* memory: logical page ``l`` of a sequence is an
  index into a physical slot pool ("frames").  The logical->physical map is
  the page table; vLLM calls it the block table.
* SPARTA's invariant: ``partition(l) = l % P`` — a logical page number alone
  names the device (mesh ``model``-axis shard) that owns it.  The page may
  live in *any* free slot of that device's pool (demand allocation,
  millions of placement options — the paper's flexibility argument).
* Each partition keeps its OWN block table fragment, co-located with its
  pool — the per-partition TLB/page-table of the paper.  ``serve_step``
  ships only *local* tables to each device; no global table is gathered
  (that global replicated table is the "centralised IOMMU" baseline we
  compare against).
* Copy-on-write: ``fork`` shares pages by refcount (prefix sharing / beam
  search); writing a shared page copies it *within the same partition*
  (paper §5, CoW support).
* Demand paging: physical slots are allocated on first write.

The manager is host-side bookkeeping (numpy); it emits dense device arrays
(`local_block_tables`) consumed by the distributed attention in
``repro.serve.serve_step``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

FREE = -1


@dataclasses.dataclass(frozen=True)
class PagedKVConfig:
    num_partitions: int = 16       # P — size of the mesh `model` axis
    slots_per_partition: int = 256  # physical pages per device pool
    page_size: int = 256            # tokens per KV page

    @property
    def total_slots(self) -> int:
        return self.num_partitions * self.slots_per_partition


def partition_of(logical_page: int, num_partitions: int) -> int:
    """MEM_PARTITION_INDEX_HASH for KV pages."""
    return logical_page % num_partitions


@dataclasses.dataclass
class _Seq:
    length: int = 0                       # tokens written
    pages: List[int] = dataclasses.field(default_factory=list)  # slot per logical page (local index)


class SpartaKVManager:
    """Host-side allocator enforcing the SPARTA partition invariant."""

    def __init__(self, cfg: PagedKVConfig):
        self.cfg = cfg
        P, S = cfg.num_partitions, cfg.slots_per_partition
        # Per-partition free lists (LIFO) and slot refcounts.
        self._free: List[List[int]] = [list(range(S - 1, -1, -1)) for _ in range(P)]
        self._refcount = np.zeros((P, S), dtype=np.int32)
        self._seqs: Dict[int, _Seq] = {}
        self._next_seq_id = 0

    # -- basic queries ------------------------------------------------------

    def num_free(self, partition: int) -> int:
        return len(self._free[partition])

    def seq_length(self, seq_id: int) -> int:
        return self._seqs[seq_id].length

    def seq_pages(self, seq_id: int) -> List[int]:
        return list(self._seqs[seq_id].pages)

    def refcount(self, logical_page_index: int, slot: int) -> int:
        p = partition_of(logical_page_index, self.cfg.num_partitions)
        return int(self._refcount[p, slot])

    # -- allocation ---------------------------------------------------------

    def new_sequence(self) -> int:
        sid = self._next_seq_id
        self._next_seq_id += 1
        self._seqs[sid] = _Seq()
        return sid

    def _alloc_slot(self, partition: int) -> int:
        """Demand allocation: any free slot in the (hash-determined) partition."""
        if not self._free[partition]:
            raise MemoryError(f"KV partition {partition} exhausted")
        slot = self._free[partition].pop()
        assert self._refcount[partition, slot] == 0
        self._refcount[partition, slot] = 1
        return slot

    def _release_slot(self, partition: int, slot: int) -> None:
        self._refcount[partition, slot] -= 1
        assert self._refcount[partition, slot] >= 0
        if self._refcount[partition, slot] == 0:
            self._free[partition].append(slot)

    def append_tokens(self, seq_id: int, n_tokens: int) -> List[dict]:
        """Extend a sequence by ``n_tokens``; returns allocation events:
        {kind: "alloc"|"cow", lp, slot[, old_slot]}.  Triggers CoW if the
        current tail page is shared (a write to a read-only shared page,
        paper §5) — the engine copies the page data old->new slot."""
        seq = self._seqs[seq_id]
        P = self.cfg.num_partitions
        page_size = self.cfg.page_size
        written: List[dict] = []

        # Writing into the tail page of a forked sequence => copy-on-write.
        if seq.length % page_size != 0 and seq.pages:
            lp = len(seq.pages) - 1
            part = partition_of(lp, P)
            slot = seq.pages[lp]
            if self._refcount[part, slot] > 1:
                new_slot = self._alloc_slot(part)  # CoW copy stays in-partition
                self._release_slot(part, slot)
                seq.pages[lp] = new_slot
                written.append({"kind": "cow", "lp": lp, "slot": new_slot,
                                "old_slot": slot, "partition": part})

        new_len = seq.length + n_tokens
        needed_pages = -(-new_len // page_size)
        while len(seq.pages) < needed_pages:
            lp = len(seq.pages)
            part = partition_of(lp, P)
            slot = self._alloc_slot(part)
            seq.pages.append(slot)
            written.append({"kind": "alloc", "lp": lp, "slot": slot, "partition": part})
        seq.length = new_len
        return written

    # -- sharing / CoW ------------------------------------------------------

    def fork(self, parent_id: int) -> int:
        """Share all pages of ``parent`` with a new child (refcount bump).

        Every page keeps its partition (the hash depends only on the logical
        page number, which the child inherits) — the paper's shared-pages
        case needs no placement adjustment for KV because logical numbering
        is per-sequence and preserved by fork.
        """
        parent = self._seqs[parent_id]
        child_id = self.new_sequence()
        child = self._seqs[child_id]
        child.length = parent.length
        child.pages = list(parent.pages)
        for lp, slot in enumerate(parent.pages):
            self._refcount[partition_of(lp, self.cfg.num_partitions), slot] += 1
        return child_id

    def free_sequence(self, seq_id: int) -> None:
        seq = self._seqs.pop(seq_id)
        for lp, slot in enumerate(seq.pages):
            self._release_slot(partition_of(lp, self.cfg.num_partitions), slot)

    # -- device views -------------------------------------------------------

    def local_block_tables(
        self, seq_ids: List[int], max_pages: int
    ) -> np.ndarray:
        """Per-partition local block tables: int32 [P, B, ceil(max_pages/P)].

        Entry [p, b, j] is the local slot of logical page ``j*P + p`` of
        sequence b (FREE if past the end).  Each device receives ONLY its own
        [b, pages_local] fragment — the co-located page table.
        """
        P = self.cfg.num_partitions
        pages_local = -(-max_pages // P)
        out = np.full((P, len(seq_ids), pages_local), FREE, dtype=np.int32)
        for b, sid in enumerate(seq_ids):
            for lp, slot in enumerate(self._seqs[sid].pages):
                if lp >= max_pages:
                    break
                out[lp % P, b, lp // P] = slot
        return out

    def global_block_table(self, seq_ids: List[int], max_pages: int) -> np.ndarray:
        """The *baseline* (centralised-IOMMU analogue): one replicated table
        int32 [B, max_pages] of global slot ids = partition*S + local."""
        S = self.cfg.slots_per_partition
        out = np.full((len(seq_ids), max_pages), FREE, dtype=np.int32)
        for b, sid in enumerate(seq_ids):
            for lp, slot in enumerate(self._seqs[sid].pages):
                if lp >= max_pages:
                    break
                out[b, lp] = partition_of(lp, self.cfg.num_partitions) * S + slot
        return out

    def context_lengths(self, seq_ids: List[int]) -> np.ndarray:
        return np.array([self._seqs[s].length for s in seq_ids], dtype=np.int32)

    # -- invariants (exercised by hypothesis tests) --------------------------

    def check_invariants(self) -> None:
        P, S = self.cfg.num_partitions, self.cfg.slots_per_partition
        # 1. Free lists and refcounts are consistent; no double-free/alloc.
        for p in range(P):
            free = set(self._free[p])
            assert len(free) == len(self._free[p]), "duplicate slot in free list"
            for s in range(S):
                if s in free:
                    assert self._refcount[p, s] == 0
                else:
                    assert self._refcount[p, s] >= 1, f"leaked slot ({p},{s})"
        # 2. Partition invariant + refcount totals match live references.
        counts = np.zeros((P, S), dtype=np.int32)
        for seq in self._seqs.values():
            assert len(seq.pages) == -(-seq.length // self.cfg.page_size) or seq.length == 0
            for lp, slot in enumerate(seq.pages):
                part = partition_of(lp, P)
                assert 0 <= slot < S
                counts[part, slot] += 1
        assert (counts == self._refcount).all(), "refcount drift"
