"""Fault-tolerant shard scheduler: divide-and-conquer for the sweep axis.

SPARTA's thesis is divide and conquer with small independent per-partition
units; the sweep infrastructure works the same way here.  Any
:func:`run_sweep_tlb` / :func:`run_sweep_system` / :func:`run_sweep_timeline`
call is split into independent **shards** along the embarrassingly-parallel
config/sim axis, each shard executed as its own crash-safe orchestrator run
(:mod:`repro.core.orchestrator` — so every shard keeps PR 7's
retry -> halve -> downgrade ladder and per-chunk checkpoints), and the
partial results merged bit-identically to the unsharded orchestrator (the
engines are batch-mate invariant: a config's row does not depend on which
other configs share its batch — asserted by tests/test_scheduler.py).

Robustness machinery, in failure order:

* **Leases + heartbeats.**  A worker claims a shard by atomically writing a
  lease file (:func:`repro.checkpoint.checkpoint.acquire_lease`) and
  heartbeats it on a background thread.  A SIGKILLed worker stops
  heartbeating; once the lease is stale (TTL exceeded) the parent declares
  it expired and re-dispatches the shard to a live worker, which *takes
  over* the dead worker's per-chunk checkpoint and resumes mid-shard.

* **Straggler re-dispatch.**  With ``ScheduleConfig.deadline_s`` set, a
  shard still running past its deadline is speculatively duplicated onto an
  idle worker (checkpoint-less, so the two attempts never contend on one
  blob).  First completion wins; when the loser eventually reports, its
  result is verified bit-identical (``duplicate_verified``) — a mismatch is
  a hard error, never a silent coin-flip.

* **Poison-shard quarantine.**  A shard whose *attempts keep failing*
  (each attempt already descended the full per-chunk ladder) is quarantined
  after ``max_shard_attempts`` failures — the run **completes** with
  placeholder (all-zero) rows for the quarantined configs, a manifest in
  ``meta["scheduler"]["quarantined_shards"]`` (surfaced as
  ``_crash_safety["quarantined_shards"]`` in figure JSONs), and drivers
  exit with :data:`EX_DEGRADED` instead of dying.  A shard that keeps
  killing its workers (never even reports a failure) hits the dispatch cap
  and is quarantined the same way.

Every lease/expiry/re-dispatch/quarantine event flows through the
:mod:`repro.runtime.telemetry` run log (``kind="scheduler"`` attribute on
the event records, ``scheduler``/``shard`` spans) and is mirrored into
``meta["scheduler"]["events"]``.

Executors are pluggable: ``serial`` (inline, the default), ``thread``
(worker threads sharing the process's jax devices), ``process``
(``multiprocessing`` spawn — survives SIGKILL of individual workers; each
worker writes its own ``runlogs/*.jsonl``, merged by
``benchmarks/obs_report.py --merge``).  Results always travel back to the
parent in-message; per-chunk durability lives in the shard's own
orchestrator checkpoint blob.
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import os
import pathlib
import queue as queue_mod
import shutil
import socket
import tempfile
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint.checkpoint import (
    BLOB_MAGIC,
    LeaseHeld,
    acquire_lease,
    lease_is_stale,
    read_lease,
    refresh_lease,
    release_lease,
)
from repro.core import dispatch
from repro.core import orchestrator as orch
from repro.core.orchestrator import (
    LADDER,
    Preempted,
    SweepRunConfig,
    _maybe_handler,
    merge_throughput,
)
from repro.core.sweep import (
    BatchedSystemEvents,
    BatchedTLBResult,
    TLBSweepSpec,
)
from repro.core.timeline import TimelineResult, TimelineSpec
from repro.core.tlbsim import SystemSimConfig
from repro.runtime import telemetry
from repro.runtime.fault_tolerance import PreemptionHandler

_LOG = logging.getLogger("repro.core.scheduler")

__all__ = [
    "EX_DEGRADED",
    "ScheduleConfig",
    "SweepRunConfig",
    "Preempted",
    "run_sweep_tlb",
    "run_sweep_system",
    "run_sweep_timeline",
    "gc_checkpoints",
]

# Exit code for a run that *completed* but with quarantined shards (degraded
# data).  sysexits.h stops at 78; 75 (EX_TEMPFAIL) already means "preempted,
# rerun with --resume", so degraded gets the next free code.
EX_DEGRADED = 79

_EXECUTORS = ("auto", "serial", "thread", "process")


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    """How a sweep call is sharded and scheduled.

    ``shards=0`` auto-sizes to ``2 * workers`` (over-decomposition keeps
    workers busy when shard runtimes are uneven).  ``executor="auto"``
    resolves to ``serial`` for one worker and ``thread`` otherwise.
    ``hold_s`` and ``on_shard_start`` are fault-injection seams: the hold
    sleeps each shard's *first* attempt after its lease is acquired (the CI
    smoke's window for SIGKILLing a worker mid-shard), and the hook fires
    with ``(shard, attempt, worker)`` before the engine runs (must be
    picklable for the process executor).
    """

    shards: int = 0
    workers: int = 1
    executor: str = "auto"
    lease_ttl_s: float = 5.0
    heartbeat_s: float = 1.0
    deadline_s: Optional[float] = None
    max_shard_attempts: int = 3
    poll_s: float = 0.05
    hold_s: float = 0.0
    on_shard_start: Optional[Callable] = None
    mp_context: str = "spawn"   # fork would duplicate jax/XLA thread pools
    runlog_dir: Optional[str] = None

    def __post_init__(self):
        if self.executor not in _EXECUTORS:
            raise ValueError(
                f"executor={self.executor!r} not in {_EXECUTORS}")

    @property
    def enabled(self) -> bool:
        """False = pure passthrough to the unsharded orchestrator."""
        return (self.workers > 1 or self.shards not in (0, 1)
                or self.executor in ("thread", "process"))

    def resolve_executor(self) -> str:
        if self.executor != "auto":
            return self.executor
        return "serial" if self.workers <= 1 else "thread"

    def resolve_shards(self, n_items: int) -> int:
        n = self.shards if self.shards > 0 else max(1, 2 * self.workers)
        return max(1, min(n, n_items))


# ---------------------------------------------------------------------------
# Worker side: claim lease -> heartbeat -> run one shard engine -> report.
# Module-level so the spawn-based process executor can pickle it by name.
# ---------------------------------------------------------------------------


class _Heartbeat:
    """Background lease refresher; a dead worker's silence is the failure
    detector.  Stops refreshing (without killing the work) if the lease was
    lost to another claimant — the parent's first-completion-wins merge
    dedups the results."""

    def __init__(self, path, owner: str, *, ttl_s: float, interval_s: float):
        self.path, self.owner = path, owner
        self.ttl_s, self.interval_s = ttl_s, interval_s
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._loop, daemon=True,
                                   name=f"lease-heartbeat-{pathlib.Path(path).stem}")

    def start(self) -> "_Heartbeat":
        self._t.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            if not refresh_lease(self.path, self.owner, ttl_s=self.ttl_s):
                return

    def stop(self) -> None:
        self._stop.set()
        self._t.join(timeout=5.0)


def _run_engine_shard(task: dict) -> Tuple[Dict[str, np.ndarray], dict, dict]:
    """Run one shard through the crash-safe orchestrator.  Returns
    ``(arrays, extra, engine_meta)`` with arrays in the parent-mergeable
    layout.  ``resume=True`` whenever a checkpoint dir is set: that is how a
    re-dispatched shard takes over a dead worker's chunk checkpoint (and how
    a --resume rerun short-circuits completed shards)."""
    p = task["payload"]
    run_cfg = SweepRunConfig(
        checkpoint_dir=task.get("ckpt_dir"),
        resume=task.get("ckpt_dir") is not None,
        chunk_accesses=task["chunk_accesses"],
        max_retries=task["max_retries"],
        backoff_base_s=task["backoff_base_s"],
        backoff_cap_s=task["backoff_cap_s"],
        keep_checkpoint=True,
        # install=False: workers may be threads (signal.signal is
        # main-thread-only); the parent owns preemption and simply stops
        # dispatching.
        preemption=PreemptionHandler(install=False),
        fault_hook=task.get("fault_hook"),
        rng_seed=task.get("rng_seed"),
    )
    engine = task["engine"]
    if engine == "sweep_tlb":
        res, meta = orch.run_sweep_tlb(
            p["addrs"], p["specs"], warmup_frac=p["warmup_frac"],
            kernel_mode=p["mode"], block=p["block"], run=run_cfg,
            name=task["name"])
        return {"hits": np.asarray(res.hits)}, {}, meta
    if engine == "sweep_system":
        evs, meta = orch.run_sweep_system(
            p["lines"], p["cfgs"], warmup_frac=p["warmup_frac"],
            kernel_mode=p["mode"], block=p["block"], run=run_cfg,
            name=task["name"])
        return {"cache_hit": np.asarray(evs.cache_hit),
                "accel_tlb_hit": np.asarray(evs.accel_tlb_hit),
                "mem_tlb_hit": np.asarray(evs.mem_tlb_hit)}, {}, meta
    if engine == "sweep_timeline":
        res_list, meta = orch.run_sweep_timeline(
            p["specs"], p["lat"], kernel_mode=p["mode"], block=p["block"],
            run=run_cfg, name=task["name"])
        lens = [int(r.latency.shape[0]) for r in res_list]
        n = max(lens) if lens else 0
        arrays = {nm: np.zeros((len(res_list), n), np.float32)
                  for nm in ("latency", "overhead", "done")}
        for i, r in enumerate(res_list):
            arrays["latency"][i, :lens[i]] = r.latency
            arrays["overhead"][i, :lens[i]] = r.overhead
            arrays["done"][i, :lens[i]] = r.done
        return arrays, {"lens": lens}, meta
    raise ValueError(f"unknown shard engine {engine!r}")


def _execute_shard(worker_id: int, task: dict) -> dict:
    """One shard attempt, end to end: lease, heartbeat, injection seams,
    engine, release.  Always *returns* a message (never raises) for normal
    failures; only BaseExceptions (simulated kills) tear through."""
    out = {"shard": task["idx"], "attempt": task["attempt"],
           "worker": worker_id, "name": task["name"]}
    tracer = telemetry.get_tracer()
    owner = f"{socket.gethostname()}:{os.getpid()}:w{worker_id}"
    lease_path = task.get("lease_path")
    hb = None
    t0 = time.perf_counter()
    try:
        try:
            if lease_path:
                try:
                    acquire_lease(lease_path, owner, ttl_s=task["lease_ttl_s"],
                                  shard=task["idx"], attempt=task["attempt"],
                                  name=task["name"], pid=os.getpid())
                except LeaseHeld as exc:
                    return {**out, "kind": "lease_held", "error": str(exc)}
                tracer.event("lease_acquire", kind="scheduler",
                             engine=task["engine"], name=task["name"],
                             shard=task["idx"], attempt=task["attempt"],
                             owner=owner)
                hb = _Heartbeat(lease_path, owner, ttl_s=task["lease_ttl_s"],
                                interval_s=task["heartbeat_s"]).start()
            if task.get("hold_s"):
                time.sleep(task["hold_s"])
            hook = task.get("on_shard_start")
            if hook is not None:
                hook(task["idx"], task["attempt"], worker_id)
            with tracer.span("shard", engine=task["engine"], name=task["name"],
                             shard=task["idx"], attempt=task["attempt"],
                             worker=worker_id):
                arrays, extra, engine_meta = _run_engine_shard(task)
            return {**out, "kind": "done", "arrays": arrays,
                    "engine_meta": engine_meta,
                    "elapsed_s": round(time.perf_counter() - t0, 6), **extra}
        except Exception as exc:
            return {**out, "kind": "failed",
                    "error": f"{type(exc).__name__}: {exc}",
                    "elapsed_s": round(time.perf_counter() - t0, 6)}
    finally:
        if hb is not None:
            hb.stop()
        if lease_path:
            release_lease(lease_path, owner)


def _worker_loop(worker_id: int, inbox, outbox, init: dict) -> None:
    """Executor worker main: drain tasks until the ``None`` sentinel.  A
    process worker opens its own telemetry run log (the parent's file handle
    does not cross the process boundary); thread workers share the parent's
    tracer, which is thread-safe."""
    own_log = init.get("runlog_dir") is not None
    if own_log:
        run = init.get("run") or "scheduler"
        path = (pathlib.Path(init["runlog_dir"])
                / f"{run}-w{worker_id}-{os.getpid()}.jsonl")
        telemetry.start_run(path, run=f"{run}-w{worker_id}",
                            worker=worker_id, pid=os.getpid())
    try:
        while True:
            task = inbox.get()
            if task is None:
                return
            outbox.put(_execute_shard(worker_id, task))
    finally:
        if own_log:
            telemetry.end_run()


# ---------------------------------------------------------------------------
# Executors: a uniform slot model — `workers` slots, one in-flight task per
# slot, messages drain through poll(), dead slots are respawnable.
# ---------------------------------------------------------------------------


class _SerialExecutor:
    kind = "serial"
    workers = 1

    def __init__(self):
        self._msgs: List[dict] = []

    def submit(self, worker_id: int, task: dict) -> None:
        self._msgs.append(_execute_shard(worker_id, task))

    def poll(self, timeout: float) -> List[dict]:
        msgs, self._msgs = self._msgs, []
        return msgs

    def alive(self, worker_id: int) -> bool:
        return True

    def respawn(self, worker_id: int) -> None:  # pragma: no cover - unused
        pass

    def shutdown(self) -> None:
        pass


class _ThreadExecutor:
    kind = "thread"

    def __init__(self, workers: int):
        self.workers = workers
        self._outbox: "queue_mod.Queue" = queue_mod.Queue()
        self._inboxes: List["queue_mod.Queue"] = [queue_mod.Queue()
                                                  for _ in range(workers)]
        self._threads: List[threading.Thread] = [None] * workers
        for wid in range(workers):
            self.respawn(wid)

    def respawn(self, worker_id: int) -> None:
        t = threading.Thread(
            target=_worker_loop,
            args=(worker_id, self._inboxes[worker_id], self._outbox, {}),
            daemon=True, name=f"sweep-worker-{worker_id}")
        self._threads[worker_id] = t
        t.start()

    def submit(self, worker_id: int, task: dict) -> None:
        self._inboxes[worker_id].put(task)

    def poll(self, timeout: float) -> List[dict]:
        msgs = []
        try:
            msgs.append(self._outbox.get(timeout=timeout))
        except queue_mod.Empty:
            return msgs
        while True:
            try:
                msgs.append(self._outbox.get_nowait())
            except queue_mod.Empty:
                return msgs

    def alive(self, worker_id: int) -> bool:
        return self._threads[worker_id].is_alive()

    def shutdown(self) -> None:
        for inbox in self._inboxes:
            inbox.put(None)
        for t in self._threads:
            t.join(timeout=5.0)


class _ProcessExecutor:
    kind = "process"

    def __init__(self, workers: int, *, mp_context: str, init: dict):
        import multiprocessing

        self.workers = workers
        self._ctx = multiprocessing.get_context(mp_context)
        self._init = dict(init)
        self._outbox = self._ctx.Queue()
        self._inboxes = [self._ctx.Queue() for _ in range(workers)]
        self._procs: List = [None] * workers
        for wid in range(workers):
            self.respawn(wid)

    def respawn(self, worker_id: int) -> None:
        p = self._ctx.Process(
            target=_worker_loop,
            args=(worker_id, self._inboxes[worker_id], self._outbox,
                  self._init),
            daemon=True, name=f"sweep-worker-{worker_id}")
        self._procs[worker_id] = p
        p.start()

    def submit(self, worker_id: int, task: dict) -> None:
        self._inboxes[worker_id].put(task)

    def poll(self, timeout: float) -> List[dict]:
        msgs = []
        try:
            msgs.append(self._outbox.get(timeout=timeout))
        except queue_mod.Empty:
            return msgs
        while True:
            try:
                msgs.append(self._outbox.get_nowait())
            except queue_mod.Empty:
                return msgs

    def alive(self, worker_id: int) -> bool:
        return self._procs[worker_id].is_alive()

    def shutdown(self) -> None:
        for inbox, p in zip(self._inboxes, self._procs):
            if p.is_alive():
                with contextlib.suppress(Exception):
                    inbox.put_nowait(None)
        for p in self._procs:
            p.join(timeout=10.0)
            if p.is_alive():  # pragma: no cover - stuck worker
                p.terminate()
                p.join(timeout=5.0)


def _make_executor(kind: str, workers: int, sched: ScheduleConfig, init: dict):
    if kind == "serial":
        return _SerialExecutor()
    if kind == "thread":
        return _ThreadExecutor(workers)
    if kind == "process":
        return _ProcessExecutor(workers, mp_context=sched.mp_context, init=init)
    raise ValueError(f"unknown executor {kind!r}")


# ---------------------------------------------------------------------------
# Parent side: the shard state machine.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Shard:
    idx: int
    lo: int
    hi: int
    name: str
    state: str = "pending"          # pending | running | done | quarantined
    dispatches: int = 0
    failures: int = 0
    dup_queued: bool = False
    t_first: Optional[float] = None
    errors: List[str] = dataclasses.field(default_factory=list)
    running: Dict[int, dict] = dataclasses.field(default_factory=dict)
    arrays: Optional[Dict[str, np.ndarray]] = None
    engine_meta: Optional[dict] = None
    lens: Optional[List[int]] = None


def _shard_ranges(n_items: int, n_shards: int) -> List[Tuple[int, int]]:
    base, rem = divmod(n_items, n_shards)
    out, lo = [], 0
    for i in range(n_shards):
        hi = lo + base + (1 if i < rem else 0)
        out.append((lo, hi))
        lo = hi
    return out


def _arrays_equal(a: Dict[str, np.ndarray], b: Dict[str, np.ndarray]) -> bool:
    return (set(a) == set(b)
            and all(np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
                    for k in a))


def _merge_engine_meta(engine: str, mode: str, shards: Sequence[_Shard],
                       ckpt_root, sched_meta: dict) -> dict:
    metas = [sh.engine_meta for sh in shards if sh.engine_meta]
    final_mode = mode
    for m in metas:
        fm = m.get("final_mode", mode)
        if fm in LADDER and (final_mode not in LADDER
                             or LADDER.index(fm) > LADDER.index(final_mode)):
            final_mode = fm
    events = [dict(e, shard=sh.idx) for sh in shards
              for e in (sh.engine_meta or {}).get("events", [])]
    return {
        "engine": engine,
        "resumable": bool(metas) and all(m.get("resumable") for m in metas),
        "start_mode": mode,
        "final_mode": final_mode,
        "events": events,
        "chunks_committed": sum(m.get("chunks_committed", 0) for m in metas),
        "resumed_from": None,
        "completed_from_checkpoint": (
            bool(metas) and all(m.get("completed_from_checkpoint")
                                for m in metas)),
        "checkpoint": str(ckpt_root) if ckpt_root else None,
        "throughput": merge_throughput(metas),
        "scheduler": sched_meta,
    }


def _schedule(*, engine: str, payload: Callable[[int, int], dict],
              n_items: int, mode: str, run_cfg: SweepRunConfig,
              sched: ScheduleConfig, name: str) -> Tuple[List[_Shard], dict]:
    """The scheduler loop: dispatch shards to executor slots, watch leases,
    duplicate stragglers, quarantine poison, merge metadata."""
    tracer = telemetry.get_tracer()
    n_shards = sched.resolve_shards(n_items)
    kind = sched.resolve_executor()
    workers = 1 if kind == "serial" else max(1, sched.workers)
    ckpt_root = (pathlib.Path(run_cfg.checkpoint_dir)
                 if run_cfg.checkpoint_dir else None)
    tmp_lease_dir = ckpt_root is None
    lease_dir = (ckpt_root if ckpt_root is not None
                 else pathlib.Path(tempfile.mkdtemp(prefix="repro-sched-")))
    lease_dir.mkdir(parents=True, exist_ok=True)

    shards = [_Shard(idx=i, lo=lo, hi=hi,
                     name=f"{name}.s{i:02d}of{n_shards:02d}")
              for i, (lo, hi) in enumerate(_shard_ranges(n_items, n_shards))]
    if ckpt_root is not None and not run_cfg.resume:
        # Fresh run: stale shard blobs from a previous identical run must
        # not short-circuit this one (workers always run with resume=True so
        # re-dispatches can take over mid-shard state from *this* run).
        for sh in shards:
            with contextlib.suppress(OSError):
                os.remove(ckpt_root / f"{sh.name}.ckpt")

    run_cfg, handler = _maybe_handler(run_cfg)
    events: List[dict] = []

    def sev(event: str, level: int = logging.INFO, **kw) -> None:
        events.append({"event": event, "ts": time.time(),
                       "t_mono": time.perf_counter(), **kw})
        tracer.event(event, kind="scheduler", engine=engine, name=name, **kw)
        _LOG.log(level, "scheduler[%s] %s%s", name, event,
                 "".join(f" {k}={v}" for k, v in kw.items()))

    init = {"runlog_dir": sched.runlog_dir if kind == "process" else None,
            "run": tracer.run or name}
    executor = _make_executor(kind, workers, sched, init)
    busy: Dict[int, Tuple[int, int]] = {}
    pending = deque(range(n_shards))
    dead_waiting: List[Tuple[int, int, Optional[str]]] = []
    dispatch_cap = sched.max_shard_attempts + 3
    preempt_stop = False

    def make_task(sh: _Shard, attempt: int, duplicate: bool) -> dict:
        lease_name = (f"{sh.name}.dup{attempt}.lease" if duplicate
                      else f"{sh.name}.lease")
        return {
            "engine": engine, "name": sh.name, "idx": sh.idx,
            "attempt": attempt, "payload": payload(sh.lo, sh.hi),
            # Duplicates run checkpoint-less so two live attempts never race
            # on one shard's chunk blob.
            "ckpt_dir": (None if duplicate else
                         (str(ckpt_root) if ckpt_root else None)),
            "lease_path": str(lease_dir / lease_name),
            "lease_ttl_s": sched.lease_ttl_s,
            "heartbeat_s": sched.heartbeat_s,
            "hold_s": sched.hold_s if attempt == 0 else 0.0,
            "on_shard_start": sched.on_shard_start,
            "chunk_accesses": run_cfg.chunk_accesses,
            "max_retries": run_cfg.max_retries,
            "backoff_base_s": run_cfg.backoff_base_s,
            "backoff_cap_s": run_cfg.backoff_cap_s,
            "rng_seed": run_cfg.rng_seed,
            "fault_hook": run_cfg.fault_hook,
        }

    def maybe_requeue(sh: _Shard, reason: str) -> None:
        """Back to the queue — or quarantine if the shard is out of
        budget."""
        if sh.state in ("done", "quarantined") or sh.running:
            return
        if (sh.failures >= sched.max_shard_attempts
                or sh.dispatches >= dispatch_cap):
            sh.state = "quarantined"
            sev("quarantine", logging.ERROR, shard=sh.idx,
                failures=sh.failures, dispatches=sh.dispatches,
                error=(sh.errors[-1] if sh.errors else None))
            return
        if sh.idx not in pending:
            sh.state = "pending"
            pending.append(sh.idx)
            sev("redispatch", logging.WARNING, shard=sh.idx, reason=reason)

    try:
        with tracer.span("scheduler", engine=engine, name=name,
                         shards=n_shards, workers=workers, executor=kind):
            while True:
                pre = run_cfg.preemption
                if pre is not None and pre.requested and not preempt_stop:
                    preempt_stop = True
                    sev("preempt_stop", logging.WARNING,
                        done=sum(1 for s in shards if s.state == "done"))
                if not preempt_stop:
                    # Dispatch pending shards onto idle live slots.
                    for w in range(executor.workers):
                        if not pending:
                            break
                        if w in busy or not executor.alive(w):
                            continue
                        i = pending.popleft()
                        sh = shards[i]
                        if sh.state in ("done", "quarantined"):
                            continue
                        duplicate = sh.state == "running"
                        attempt = sh.dispatches
                        sh.dispatches += 1
                        task = make_task(sh, attempt, duplicate)
                        sh.running[attempt] = {
                            "worker": w, "t0": time.monotonic(),
                            "lease_path": task["lease_path"],
                            "duplicate": duplicate}
                        if sh.state == "pending":
                            sh.state = "running"
                            sh.t_first = time.monotonic()
                        busy[w] = (i, attempt)
                        sev("dispatch", shard=i, attempt=attempt, worker=w,
                            duplicate=duplicate)
                        executor.submit(w, task)
                    # Straggler duplication: only once everything else is
                    # dispatched and only one duplicate per shard.
                    if sched.deadline_s and not pending and len(busy) < executor.workers:
                        now_m = time.monotonic()
                        for sh in shards:
                            if (sh.state == "running" and not sh.dup_queued
                                    and len(sh.running) == 1
                                    and sh.t_first is not None
                                    and now_m - sh.t_first > sched.deadline_s):
                                sh.dup_queued = True
                                pending.append(sh.idx)
                                sev("redispatch", logging.WARNING,
                                    shard=sh.idx, reason="straggler",
                                    elapsed_s=round(now_m - sh.t_first, 3))

                for msg in executor.poll(sched.poll_s):
                    i, attempt = msg["shard"], msg["attempt"]
                    w = msg.get("worker")
                    if busy.get(w) == (i, attempt):
                        busy.pop(w)
                    sh = shards[i]
                    sh.running.pop(attempt, None)
                    if msg["kind"] == "done":
                        if sh.state == "done":
                            identical = _arrays_equal(sh.arrays, msg["arrays"])
                            sev("duplicate_verified", shard=i, attempt=attempt,
                                identical=identical)
                            if not identical:
                                raise RuntimeError(
                                    f"shard {sh.name} attempt {attempt} "
                                    f"produced a result differing from the "
                                    f"first completion — nondeterministic "
                                    f"engine or corrupted worker; refusing "
                                    f"to merge")
                        else:
                            sh.state = "done"
                            sh.arrays = msg["arrays"]
                            sh.engine_meta = msg["engine_meta"]
                            sh.lens = msg.get("lens")
                            sev("shard_done", shard=i, attempt=attempt,
                                worker=w, elapsed_s=msg.get("elapsed_s"))
                    elif msg["kind"] == "lease_held":
                        sev("lease_held", logging.WARNING, shard=i,
                            attempt=attempt, error=msg.get("error"))
                        maybe_requeue(sh, "lease_held")
                    else:
                        sh.failures += 1
                        sh.errors.append(msg.get("error", "unknown"))
                        sev("shard_failed", logging.WARNING, shard=i,
                            attempt=attempt, worker=w,
                            error=msg.get("error"))
                        maybe_requeue(sh, "failure")

                # Liveness: a busy slot whose worker died stops heartbeating;
                # once the lease is stale the shard is re-dispatched.
                for w in list(busy):
                    if not executor.alive(w):
                        i, attempt = busy.pop(w)
                        sh = shards[i]
                        info = sh.running.get(attempt)
                        sev("worker_dead", logging.WARNING, worker=w,
                            shard=i, attempt=attempt)
                        dead_waiting.append(
                            (i, attempt,
                             info["lease_path"] if info else None))
                        executor.respawn(w)
                        sev("worker_respawn", worker=w)
                still = []
                for (i, attempt, lease_path) in dead_waiting:
                    lease = read_lease(lease_path) if lease_path else None
                    if lease is not None and lease.get("shard") != i:
                        lease = None   # foreign/reused file, not this claim
                    if lease_path is not None and not lease_is_stale(lease):
                        still.append((i, attempt, lease_path))
                        continue
                    sh = shards[i]
                    sh.running.pop(attempt, None)
                    sev("lease_expire", logging.WARNING, shard=i,
                        attempt=attempt)
                    maybe_requeue(sh, "lease_expired")
                dead_waiting = still

                if all(sh.state in ("done", "quarantined") for sh in shards) \
                        and not any(sh.running for sh in shards) \
                        and not dead_waiting:
                    break
                if preempt_stop and not any(sh.running for sh in shards) \
                        and not dead_waiting:
                    done_items = sum(sh.hi - sh.lo for sh in shards
                                     if sh.state == "done")
                    raise Preempted(ckpt_root, done_items, n_items)
    finally:
        executor.shutdown()
        if handler is not None:
            handler.uninstall()
        # Leases are per-run claims, never results: sweep them regardless.
        for lp in list(lease_dir.glob(f"{name}.s*.lease")) + \
                list(lease_dir.glob(f"{name}.s*.lease.lck")):
            with contextlib.suppress(OSError):
                lp.unlink()
        if tmp_lease_dir:
            shutil.rmtree(lease_dir, ignore_errors=True)

    quarantined = [sh for sh in shards if sh.state == "quarantined"]
    if ckpt_root is not None and not run_cfg.keep_checkpoint \
            and not run_cfg.resume and not quarantined:
        # Mirror the orchestrator's fresh-run policy: a clean non-resume run
        # leaves no blobs behind.  Quarantined runs keep theirs so the
        # poisoned shard can be retried with --resume.
        for sh in shards:
            with contextlib.suppress(OSError):
                os.remove(ckpt_root / f"{sh.name}.ckpt")

    sched_meta = {
        "shards": n_shards,
        "workers": workers,
        "executor": kind,
        "deadline_s": sched.deadline_s,
        "events": events,
        "quarantined_shards": [
            {"shard": sh.idx, "name": sh.name, "items": [sh.lo, sh.hi],
             "failures": sh.failures, "dispatches": sh.dispatches,
             "errors": sh.errors[-3:]}
            for sh in quarantined],
        "shard_map": [
            {"shard": sh.idx, "name": sh.name, "items": [sh.lo, sh.hi],
             "state": sh.state, "dispatches": sh.dispatches,
             "failures": sh.failures,
             "resumed_from": (sh.engine_meta or {}).get("resumed_from"),
             "completed_from_checkpoint": bool(
                 (sh.engine_meta or {}).get("completed_from_checkpoint"))}
            for sh in shards],
    }
    if quarantined:
        _LOG.error(
            "scheduler[%s]: run completed DEGRADED — %d/%d shards "
            "quarantined (%s); their rows are zero placeholders",
            name, len(quarantined), n_shards,
            ", ".join(sh.name for sh in quarantined))
    meta = _merge_engine_meta(engine, mode, shards, ckpt_root, sched_meta)
    return shards, meta


# ---------------------------------------------------------------------------
# Public entry points: drop-in supersets of the orchestrator's.
# ---------------------------------------------------------------------------


def run_sweep_tlb(
    addrs: np.ndarray,
    specs: Sequence[TLBSweepSpec],
    *,
    warmup_frac: float = 0.25,
    kernel_mode: str = "auto",
    block: int = 512,
    run: SweepRunConfig = SweepRunConfig(),
    sched: Optional[ScheduleConfig] = None,
    name: str = "sweep_tlb",
) -> Tuple[BatchedTLBResult, dict]:
    """Sharded, fault-tolerant :func:`repro.core.orchestrator.run_sweep_tlb`.
    ``sched=None`` (or a disabled config) is a pure passthrough."""
    if sched is None or not sched.enabled or len(specs) <= 1:
        return orch.run_sweep_tlb(
            addrs, specs, warmup_frac=warmup_frac, kernel_mode=kernel_mode,
            block=block, run=run, name=name)
    addrs = np.asarray(addrs)
    specs = list(specs)
    # The dispatch decision is made ONCE over the full spec set (stackdist
    # eligibility and calibration lookups are properties of the whole sweep)
    # and passed concrete to every shard, so sharding can never flip the
    # backend choice.
    decision = dispatch.decide_tlb(
        kernel_mode, specs, n_accesses=int(addrs.shape[0]),
        store=dispatch.store_for(run.calibration_dir))
    dispatch.record_decision(decision, name=name)
    mode = decision.mode
    n = int(addrs.shape[0])
    shards, meta = _schedule(
        engine="sweep_tlb",
        payload=lambda lo, hi: {"addrs": addrs, "specs": specs[lo:hi],
                                "warmup_frac": warmup_frac, "block": block,
                                "mode": mode},
        n_items=len(specs), mode=mode, run_cfg=run, sched=sched, name=name)
    meta["dispatch"] = decision.to_json()
    rows = [np.zeros((sh.hi - sh.lo, n), bool) if sh.arrays is None
            else np.asarray(sh.arrays["hits"], bool)
            for sh in shards]
    hits = np.concatenate(rows, axis=0)
    return BatchedTLBResult(hits=hits, n_warm=n - int(n * warmup_frac)), meta


def run_sweep_system(
    lines: np.ndarray,
    cfgs: Sequence[SystemSimConfig],
    *,
    warmup_frac: float = 0.25,
    kernel_mode: str = "auto",
    block: int = 512,
    run: SweepRunConfig = SweepRunConfig(),
    sched: Optional[ScheduleConfig] = None,
    name: str = "sweep_system",
) -> Tuple[BatchedSystemEvents, dict]:
    """Sharded, fault-tolerant
    :func:`repro.core.orchestrator.run_sweep_system`."""
    if sched is None or not sched.enabled or len(cfgs) <= 1:
        return orch.run_sweep_system(
            lines, cfgs, warmup_frac=warmup_frac, kernel_mode=kernel_mode,
            block=block, run=run, name=name)
    lines = np.asarray(lines)
    cfgs = list(cfgs)
    # Decided once globally (see run_sweep_tlb): shards get a concrete mode.
    decision = dispatch.decide_system(
        kernel_mode, cfgs, n_accesses=int(lines.shape[0]),
        store=dispatch.store_for(run.calibration_dir))
    dispatch.record_decision(decision, name=name)
    mode = decision.mode
    n = int(lines.shape[0])
    shards, meta = _schedule(
        engine="sweep_system",
        payload=lambda lo, hi: {"lines": lines, "cfgs": cfgs[lo:hi],
                                "warmup_frac": warmup_frac, "block": block,
                                "mode": mode},
        n_items=len(cfgs), mode=mode, run_cfg=run, sched=sched, name=name)
    meta["dispatch"] = decision.to_json()
    cols = {}
    for nm in ("cache_hit", "accel_tlb_hit", "mem_tlb_hit"):
        cols[nm] = np.concatenate(
            [np.zeros((sh.hi - sh.lo, n), bool) if sh.arrays is None
             else np.asarray(sh.arrays[nm], bool) for sh in shards], axis=0)
    return BatchedSystemEvents(cols["cache_hit"], cols["accel_tlb_hit"],
                               cols["mem_tlb_hit"],
                               n_warm=n - int(n * warmup_frac)), meta


def run_sweep_timeline(
    specs: Sequence[TimelineSpec],
    lat=None,
    *,
    kernel_mode: str = "auto",
    block: int = 512,
    run: SweepRunConfig = SweepRunConfig(),
    sched: Optional[ScheduleConfig] = None,
    name: str = "sweep_timeline",
) -> Tuple[List[TimelineResult], dict]:
    """Sharded, fault-tolerant
    :func:`repro.core.orchestrator.run_sweep_timeline`."""
    if sched is None or not sched.enabled or len(specs) <= 1:
        return orch.run_sweep_timeline(
            specs, lat, kernel_mode=kernel_mode, block=block, run=run,
            name=name)
    specs = list(specs)
    # The batch-aware decision must see the GLOBAL batch size, not a
    # shard's — otherwise a single-spec shard would flip to the scan path
    # and the merged run would not be bit-identical to the unsharded one.
    decision = dispatch.decide_timeline(
        kernel_mode, batch=len(specs),
        n_accesses=max((int(np.asarray(sp.lines).shape[0]) for sp in specs),
                       default=0),
        store=dispatch.store_for(run.calibration_dir))
    dispatch.record_decision(decision, name=name)
    mode = decision.mode
    shards, meta = _schedule(
        engine="sweep_timeline",
        payload=lambda lo, hi: {"specs": specs[lo:hi], "lat": lat,
                                "block": block, "mode": mode},
        n_items=len(specs), mode=mode, run_cfg=run, sched=sched, name=name)
    meta["dispatch"] = decision.to_json()
    results: List[TimelineResult] = []
    for sh in shards:
        for j, g in enumerate(range(sh.lo, sh.hi)):
            sp = specs[g]
            cache_hit = np.asarray(sp.events.cache_hit).astype(bool)
            if sh.arrays is None:   # quarantined placeholder rows
                n_g = int(cache_hit.shape[0])
                results.append(TimelineResult(
                    latency=np.zeros(n_g, np.float32),
                    overhead=np.zeros(n_g, np.float32),
                    done=np.zeros(n_g, np.float32),
                    cache_hit=cache_hit, n_warm=sp.events.n_warm))
            else:
                n_g = int(sh.lens[j])
                results.append(TimelineResult(
                    latency=np.asarray(sh.arrays["latency"][j, :n_g]),
                    overhead=np.asarray(sh.arrays["overhead"][j, :n_g]),
                    done=np.asarray(sh.arrays["done"][j, :n_g]),
                    cache_hit=cache_hit, n_warm=sp.events.n_warm))
    return results, meta


# ---------------------------------------------------------------------------
# Garbage collection for the checkpoint/lease tree.
# ---------------------------------------------------------------------------


def gc_checkpoints(root, *, age_s: float = 7 * 86400.0,
                   now: Optional[float] = None,
                   dry_run: bool = False) -> dict:
    """Sweep stale shard blobs, expired leases and orphaned temp files under
    ``root`` (``benchmarks/_cache/ckpt``).

    Policy:

    * an *expired* lease (TTL exceeded) is deleted; a fresh lease marks its
      directory as **in-progress** and every blob there is kept regardless
      of age (never delete under a live run);
    * a ``.ckpt`` blob older than ``age_s`` is deleted only if its header
      identifies it as a repro checkpoint blob — unrecognized files are
      reported in ``skipped_foreign`` and never touched (the PR 6 policy:
      never delete data you did not write);
    * ``.tmp-*`` leftovers from crashed writers are deleted once old.

    Returns a summary dict; ``dry_run=True`` reports without deleting.
    """
    root = pathlib.Path(root)
    now = time.time() if now is None else now
    summary = {"deleted": [], "kept_in_progress": [], "kept_young": [],
               "skipped_foreign": [], "dry_run": dry_run}
    if not root.exists():
        return summary

    def delete(p: pathlib.Path) -> None:
        summary["deleted"].append(str(p))
        if not dry_run:
            with contextlib.suppress(OSError):
                p.unlink()

    fresh_dirs = set()
    lease_paths = [p for p in sorted(root.rglob("*.lease")) if p.is_file()]
    for lp in lease_paths:
        if not lease_is_stale(read_lease(lp), now=now):
            fresh_dirs.add(lp.parent)
    for lp in lease_paths:
        if lease_is_stale(read_lease(lp), now=now):
            delete(lp)
            lck = lp.with_name(lp.name + ".lck")
            if lck.exists():
                delete(lck)
        else:
            summary["kept_in_progress"].append(str(lp))

    for p in sorted(root.rglob("*")):
        if not p.is_file() or p.suffix == ".lease" \
                or p.name.endswith(".lease.lck"):
            continue
        try:
            age = now - p.stat().st_mtime
        except OSError:
            continue
        if ".tmp-" in p.name:
            if age > age_s:
                delete(p)
            else:
                summary["kept_young"].append(str(p))
            continue
        if p.suffix == ".ckpt":
            if p.parent in fresh_dirs:
                summary["kept_in_progress"].append(str(p))
                continue
            if age <= age_s:
                summary["kept_young"].append(str(p))
                continue
            try:
                head = p.open("rb").read(len(BLOB_MAGIC))
            except OSError:
                continue
            if head == BLOB_MAGIC.encode():
                delete(p)
            else:
                summary["skipped_foreign"].append(str(p))
            continue
        summary["skipped_foreign"].append(str(p))
    return summary
