"""Trace-driven set-associative LRU TLB / cache simulation (paper §6.2).

The paper probes set-associative TLB models with Pin traces.  We reproduce
the pipeline with a vectorised ``jax.lax.scan`` simulator:

* :func:`simulate_tlb` — one TLB (conventional) or an array of ``P``
  per-partition SPARTA TLBs, as a single scan whose state holds tags and
  last-use timestamps.  SPARTA partitioning maps virtual page ``v`` to
  partition ``v % P`` and probes only that partition's sets — the paper's
  ``MEM_PARTITION_INDEX_HASH``.
* :func:`simulate_system` — the *joint* accelerator pipeline: data cache +
  accelerator-side TLB + memory-side (per-partition) TLB in a single pass,
  emitting per-access hit bits for each structure.  This feeds the CPI
  timeline model (:mod:`repro.core.cpi`) for Figs 9/10.

The same machinery doubles as the accelerator *cache* simulator (a cache is
a set-associative LRU structure keyed by line address).

A Pallas TPU kernel with the identical semantics lives in
``repro.kernels.tlb_sim`` (state resident in VMEM scratch, trace streamed
HBM->VMEM); :func:`simulate_tlb` here is its pure-JAX oracle and the default
execution path on CPU.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparta import TLBConfig

LINE_SHIFT = 6


# ---------------------------------------------------------------------------
# Key preparation (numpy; cheap) — maps addresses to (set, tag) streams.
# ---------------------------------------------------------------------------

def _prepare_keys(
    vpns: np.ndarray, sets: int, num_partitions: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Compute per-access (global_set_index, tag) for a (possibly partitioned)
    set-associative structure.

    Partition ``p = vpn % P`` (the paper's hash), partition-local key
    ``k = vpn // P``; global set index is ``p * sets + (k % sets)``.
    """
    v = vpns.astype(np.int64)
    if num_partitions > 1:
        p = v % num_partitions
        k = v // num_partitions
    else:
        p = np.zeros_like(v)
        k = v
    set_idx = (p * sets + (k % sets)).astype(np.int32)
    # Store only the true tag (set bits excluded) so it fits int32 on CPU
    # without x64 mode; (set, tag) uniquely identifies the key.
    tag64 = k // sets
    if tag64.size and int(tag64.max()) >= 2**31:
        raise ValueError("tag overflow: key space too large for int32 tags")
    tag = tag64.astype(np.int32)
    return set_idx, tag


@functools.partial(jax.jit, static_argnames=("total_sets", "ways"))
def _scan_tlb(set_idx: jnp.ndarray, tag: jnp.ndarray, total_sets: int, ways: int):
    """Sequential LRU simulation.  Returns per-access hit bits."""
    tags0 = jnp.full((total_sets, ways), -1, dtype=jnp.int32)
    last0 = jnp.zeros((total_sets, ways), dtype=jnp.int32)

    def step(state, inp):
        tags, last = state
        s, t, now = inp
        row_t = tags[s]
        row_l = last[s]
        hit_vec = row_t == t
        hit = jnp.any(hit_vec)
        way = jnp.where(hit, jnp.argmax(hit_vec), jnp.argmin(row_l))
        tags = tags.at[s, way].set(t)
        last = last.at[s, way].set(now)
        return (tags, last), hit

    n = set_idx.shape[0]
    now = jnp.arange(1, n + 1, dtype=jnp.int32)
    (_, _), hits = jax.lax.scan(step, (tags0, last0), (set_idx, tag, now))
    return hits


_POISON_TAG = -2          # never matches a real tag (tags are >= 0, empty = -1)
_POISON_LAST = 2**31 - 1  # argmin never selects a poisoned way (real last <= N)
# (also used by the batched Pallas kernel, repro.kernels.tlb_sim.kernel)


def padded_tlb_state(
    num_cfgs: int, total_sets: int, ways: int, valid_ways: Tuple[int, ...]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Initial stacked (tags, last) for a batch of configs padded to a common
    ``(total_sets, ways)`` envelope.

    Ways beyond config ``b``'s ``valid_ways[b]`` are *poisoned*: their tag can
    never match (real tags are non-negative, empty ways hold -1) and their
    last-use stamp is so large that LRU replacement never selects them, so the
    padded simulation is bit-identical to each config's unpadded one.  Padded
    *sets* need no poisoning — a config's set indices never reach them.
    """
    vw = jnp.asarray(valid_ways, jnp.int32)[:, None, None]
    way_ix = jax.lax.broadcasted_iota(jnp.int32, (num_cfgs, total_sets, ways), 2)
    pad = way_ix >= vw
    tags0 = jnp.where(pad, _POISON_TAG, -1).astype(jnp.int32)
    last0 = jnp.where(pad, _POISON_LAST, 0).astype(jnp.int32)
    return tags0, last0


@functools.partial(jax.jit, static_argnames=("total_sets", "ways", "valid_ways"))
def _scan_tlb_batched(
    set_idx: jnp.ndarray,   # int32 [B, N]
    tag: jnp.ndarray,       # int32 [B, N]
    total_sets: int,        # padded envelope (max over configs)
    ways: int,              # padded envelope (max over configs)
    valid_ways: Tuple[int, ...],
):
    """Batched sequential LRU simulation: B configs advance in lock-step
    through ONE scan over the trace.  Returns hit bits [B, N].

    Per-config semantics are bit-identical to :func:`_scan_tlb` on that
    config's own geometry (see :func:`padded_tlb_state` for why padding is
    invisible)."""
    tags0, last0 = padded_tlb_state(set_idx.shape[0], total_sets, ways, valid_ways)

    def probe(tags_b, last_b, s, t, now):
        row_t = tags_b[s]
        row_l = last_b[s]
        hit_vec = row_t == t
        hit = jnp.any(hit_vec)
        way = jnp.where(hit, jnp.argmax(hit_vec), jnp.argmin(row_l))
        tags_b = tags_b.at[s, way].set(t)
        last_b = last_b.at[s, way].set(now)
        return tags_b, last_b, hit

    def step(state, inp):
        tags, last = state
        s, t, now = inp
        tags, last, hit = jax.vmap(probe, in_axes=(0, 0, 0, 0, None))(
            tags, last, s, t, now
        )
        return (tags, last), hit

    n = set_idx.shape[1]
    now = jnp.arange(1, n + 1, dtype=jnp.int32)
    (_, _), hits = jax.lax.scan(step, (tags0, last0), (set_idx.T, tag.T, now))
    return hits.T


@jax.jit
def _scan_tlb_batched_carry(
    set_idx: jnp.ndarray,   # int32 [B, L] one trace chunk
    tag: jnp.ndarray,       # int32 [B, L]
    tags0: jnp.ndarray,     # int32 [B, TS, W] carried state in
    last0: jnp.ndarray,     # int32 [B, TS, W]
    now0: jnp.ndarray,      # int32 scalar: accesses consumed before this chunk
):
    """Chunk-resumable :func:`_scan_tlb_batched`: explicit carried state.

    The caller owns the initial state (:func:`padded_tlb_state`) and the
    global access counter; feeding a trace in chunks through this scan is
    bit-identical to one monolithic ``_scan_tlb_batched`` pass because the
    carried (tags, last) and the ``now0``-offset timestamps are exactly the
    mid-scan state of the single pass.  Returns ``(hits [B, L], tags, last)``.
    """

    def probe(tags_b, last_b, s, t, now):
        row_t = tags_b[s]
        row_l = last_b[s]
        hit_vec = row_t == t
        hit = jnp.any(hit_vec)
        way = jnp.where(hit, jnp.argmax(hit_vec), jnp.argmin(row_l))
        tags_b = tags_b.at[s, way].set(t)
        last_b = last_b.at[s, way].set(now)
        return tags_b, last_b, hit

    def step(state, inp):
        tags, last = state
        s, t, now = inp
        tags, last, hit = jax.vmap(probe, in_axes=(0, 0, 0, 0, None))(
            tags, last, s, t, now
        )
        return (tags, last), hit

    n = set_idx.shape[1]
    now = now0.astype(jnp.int32) + jnp.arange(1, n + 1, dtype=jnp.int32)
    (tags, last), hits = jax.lax.scan(
        step, (tags0, last0), (set_idx.T, tag.T, now))
    return hits.T, tags, last


def simulate_tlb(
    vpns: np.ndarray,
    cfg: TLBConfig,
    *,
    num_partitions: int = 1,
    warmup_frac: float = 0.25,
) -> "TLBResult":
    """Simulate one conventional TLB (``num_partitions == 1``) or SPARTA's
    array of per-partition TLBs (``num_partitions == P``) on a VPN stream.

    Each partition TLB has ``cfg.entries`` entries (the paper compares equal
    *per-TLB* sizes; total entries = P * entries for SPARTA).
    """
    sets, ways = _geom(cfg)
    set_idx, tag = _prepare_keys(vpns, sets, num_partitions)
    hits = np.asarray(_scan_tlb(jnp.asarray(set_idx), jnp.asarray(tag), sets * num_partitions, ways))
    return TLBResult.from_hits(hits, warmup_frac)


class TLBResult(NamedTuple):
    hits: np.ndarray       # bool [N] (full stream, incl. warmup)
    n_warm: int            # accesses considered after warmup

    @classmethod
    def from_hits(cls, hits: np.ndarray, warmup_frac: float) -> "TLBResult":
        n0 = int(hits.shape[0] * warmup_frac)
        return cls(hits=hits, n_warm=hits.shape[0] - n0)

    @property
    def miss_ratio(self) -> float:
        h = self.hits[self.hits.shape[0] - self.n_warm:]
        return float(1.0 - h.mean()) if h.size else 1.0

    @property
    def hit_ratio(self) -> float:
        return 1.0 - self.miss_ratio


# ---------------------------------------------------------------------------
# Joint system simulation: cache + accel TLB + memory-side TLBs in one scan.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SystemSimConfig:
    """Joint pipeline configuration (Figs 9/10 setups).

    cache        — accelerator data cache geometry (keyed by line address);
                   ``None`` = cacheless accelerator.
    accel_tlb    — accelerator-side TLB; ``None`` = none (virtual cache /
                   pure SPARTA).  ``accel_probe_on_miss_only`` models virtual
                   caches (translation needed only for cache misses).
    mem_tlb      — memory-side TLB geometry (per partition).
    num_partitions — SPARTA P; 1 = conventional/centralised.
    page_shift   — 12 (4 KB) or 21 (2 MB) for both TLB levels.
    """

    cache: Optional[TLBConfig] = TLBConfig(entries=256, ways=4)  # 16KB / 64B
    accel_tlb: Optional[TLBConfig] = None
    mem_tlb: TLBConfig = TLBConfig(entries=128, ways=4)
    num_partitions: int = 1
    page_shift: int = 12
    accel_probe_on_miss_only: bool = True


class SystemEvents(NamedTuple):
    """Per-access hit bits (True = hit) for each structure, after warmup."""

    cache_hit: np.ndarray
    accel_tlb_hit: np.ndarray
    mem_tlb_hit: np.ndarray
    n_warm: int

    def _rate(self, x: np.ndarray) -> float:
        w = x[x.shape[0] - self.n_warm:]
        return float(w.mean()) if w.size else 0.0

    @property
    def cache_hit_ratio(self) -> float:
        return self._rate(self.cache_hit)

    @property
    def accel_tlb_hit_ratio(self) -> float:
        return self._rate(self.accel_tlb_hit)

    def mem_tlb_hit_ratio_given_cache_miss(self) -> float:
        n0 = self.cache_hit.shape[0] - self.n_warm
        cm = ~self.cache_hit[n0:]
        if cm.sum() == 0:
            return 1.0
        return float(self.mem_tlb_hit[n0:][cm].mean())

    def accel_tlb_hit_ratio_given_cache_hit(self) -> float:
        n0 = self.cache_hit.shape[0] - self.n_warm
        ch = self.cache_hit[n0:]
        if ch.sum() == 0:
            return 1.0
        return float(self.accel_tlb_hit[n0:][ch].mean())

    def accel_tlb_hit_ratio_given_cache_miss(self) -> float:
        """Accel-TLB hit rate on the cache-miss stream (virtual caches probe
        the TLB only on misses; bits for cache hits are forced True)."""
        n0 = self.cache_hit.shape[0] - self.n_warm
        cm = ~self.cache_hit[n0:]
        if cm.sum() == 0:
            return 1.0
        return float(self.accel_tlb_hit[n0:][cm].mean())


def _geom(cfg: Optional[TLBConfig]) -> Tuple[int, int]:
    """(sets, ways) of a structure; absent structures degrade to 1x1.

    The single source of geometry truth is :class:`TLBConfig` itself
    (``sets`` / ``effective_ways`` — including the entries < ways
    normalisation); every simulator and sweep derives through here."""
    if cfg is None:
        return 1, 1
    return cfg.sets, cfg.effective_ways


@functools.partial(
    jax.jit,
    static_argnames=("geom", "has_cache", "has_accel", "accel_on_miss_only"),
)
def _scan_system(
    inputs,
    geom: Tuple[int, int, int, int, int, int],
    has_cache: bool,
    has_accel: bool,
    accel_on_miss_only: bool,
):
    (c_set, c_tag, a_set, a_tag, m_set, m_tag) = inputs
    cs, cw, asets, aw, ms, mw = geom

    state0 = (
        jnp.full((cs, cw), -1, dtype=jnp.int32), jnp.zeros((cs, cw), jnp.int32),
        jnp.full((asets, aw), -1, dtype=jnp.int32), jnp.zeros((asets, aw), jnp.int32),
        jnp.full((ms, mw), -1, dtype=jnp.int32), jnp.zeros((ms, mw), jnp.int32),
    )

    def probe(tags, last, s, t, now, do_update):
        row_t = tags[s]
        hit_vec = row_t == t
        hit = jnp.any(hit_vec)
        way = jnp.where(hit, jnp.argmax(hit_vec), jnp.argmin(last[s]))
        upd = do_update
        tags = tags.at[s, way].set(jnp.where(upd, t, tags[s, way]))
        last = last.at[s, way].set(jnp.where(upd, now, last[s, way]))
        return tags, last, hit

    def step(state, inp):
        ct, cl, at, al, mt, ml = state
        cs_i, ctag_i, as_i, atag_i, ms_i, mtag_i, now = inp
        if has_cache:
            ct, cl, c_hit = probe(ct, cl, cs_i, ctag_i, now, jnp.bool_(True))
        else:
            c_hit = jnp.bool_(False)
        if has_accel:
            # Physical cache: TLB probed every access.  Virtual cache: TLB
            # consulted (and filled) only when the access misses the cache.
            do = jnp.where(jnp.bool_(accel_on_miss_only), ~c_hit, jnp.bool_(True))
            at, al, a_hit = probe(at, al, as_i, atag_i, now, do)
            a_hit = jnp.where(do, a_hit, jnp.bool_(True))  # not needed => free
        else:
            a_hit = jnp.bool_(False)
        # Memory-side TLB sees only cache misses (hits never leave the accel).
        mt, ml, m_hit = probe(mt, ml, ms_i, mtag_i, now, ~c_hit)
        m_hit = jnp.where(~c_hit, m_hit, jnp.bool_(True))
        return (ct, cl, at, al, mt, ml), (c_hit, a_hit, m_hit)

    n = c_set.shape[0]
    now = jnp.arange(1, n + 1, dtype=jnp.int32)
    (_, ys) = jax.lax.scan(step, state0, (c_set, c_tag, a_set, a_tag, m_set, m_tag, now))
    return ys


def simulate_system(
    lines: np.ndarray,
    cfg: SystemSimConfig,
    *,
    warmup_frac: float = 0.25,
) -> SystemEvents:
    """Run the joint cache + accel-TLB + memory-TLB pipeline on a line trace."""
    vpns = lines >> (cfg.page_shift - LINE_SHIFT)

    cs, cw = _geom(cfg.cache)
    if cfg.cache is not None:
        c_set, c_tag = _prepare_keys(lines, cs, 1)
    else:
        c_set = np.zeros(lines.shape[0], np.int32)
        c_tag = np.zeros(lines.shape[0], np.int32)

    asets, aw = _geom(cfg.accel_tlb)
    if cfg.accel_tlb is not None:
        a_set, a_tag = _prepare_keys(vpns, asets, 1)
    else:
        a_set = np.zeros(lines.shape[0], np.int32)
        a_tag = np.zeros(lines.shape[0], np.int32)

    ms, mw = _geom(cfg.mem_tlb)
    m_set, m_tag = _prepare_keys(vpns, ms, cfg.num_partitions)

    ys = _scan_system(
        tuple(jnp.asarray(x) for x in (c_set, c_tag, a_set, a_tag, m_set, m_tag)),
        (cs, cw, asets, aw, ms * cfg.num_partitions, mw),
        cfg.cache is not None,
        cfg.accel_tlb is not None,
        cfg.accel_probe_on_miss_only,
    )
    c_hit, a_hit, m_hit = (np.asarray(y) for y in ys)
    n0 = int(lines.shape[0] * warmup_frac)
    return SystemEvents(c_hit, a_hit, m_hit, n_warm=lines.shape[0] - n0)


# ---------------------------------------------------------------------------
# Convenience sweeps.
# ---------------------------------------------------------------------------

def miss_ratio(
    vpns: np.ndarray,
    entries: int,
    *,
    ways: int = 4,
    num_partitions: int = 1,
) -> float:
    # TLBConfig normalizes entries < ways itself (effective_ways).
    return simulate_tlb(vpns, TLBConfig(entries=entries, ways=ways), num_partitions=num_partitions).miss_ratio


def miss_ratio_curve(
    lines: np.ndarray,
    sizes,
    *,
    ways: int = 4,
    num_partitions: int = 1,
    page_shift: int = 12,
    kernel_mode: str = "auto",
) -> "np.ndarray":
    """Miss ratio at each TLB size, via the batched sweep engine: under the
    default ``kernel_mode="auto"`` the exact stack-distance backend
    (``kernel_mode="stackdist"``, :mod:`repro.core.stackdist`) computes every
    size's hit bits from data-parallel depth passes — no per-element scan;
    other modes stream the trace once for all sizes through the batched scan
    or Pallas kernel.  ``repro.core.sweep`` holds the engine;
    :func:`simulate_tlb` remains the single-config oracle path."""
    from repro.core import sweep  # local import: sweep builds on this module

    specs = [
        sweep.TLBSweepSpec(
            cfg=TLBConfig(entries=int(e), ways=ways),
            num_partitions=num_partitions,
            page_shift=page_shift,
        )
        for e in sizes
    ]
    return sweep.sweep_tlb(lines, specs, kernel_mode=kernel_mode).miss_ratios
