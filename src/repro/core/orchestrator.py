"""Crash-safe streaming sweep orchestrator: chunked engines + checkpoint/
resume + a graceful-degradation backend ladder.

Every figure driver funnels its batched engine calls through this module's
three entry points — :func:`run_sweep_tlb`, :func:`run_sweep_system`,
:func:`run_sweep_timeline` — which wrap the resumable stream classes
(:class:`repro.core.sweep.TLBSweepStream`,
:class:`repro.core.sweep.SystemSweepStream`,
:class:`repro.core.timeline.TimelineSweepStream`) in one shared chunk loop:

* **Bounded-memory streaming.**  The trace is consumed in
  ``chunk_accesses``-sized slices; per-config carried state (LRU tags +
  last-use stamps, MSHR/port/bank queues) lives in the stream object and the
  per-chunk working set is bounded regardless of trace length.  Chunked
  results are bit-identical to the monolithic engines (the stream classes'
  contract, asserted by tests/test_orchestrator.py).

* **Checkpoint/resume.**  With ``SweepRunConfig.checkpoint_dir`` set, every
  committed chunk atomically replaces a single checkpoint blob (write-tmp,
  fsync, rename + content checksum — :func:`repro.checkpoint.checkpoint.
  write_checkpoint_blob`) holding the carried state, the partial result
  buffers and a JSON meta record.  On restart with ``resume=True`` the blob
  is validated (checksum + engine/layout fingerprint) and the run re-enters
  at the first uncommitted chunk, bit-identically to an uninterrupted run.
  A corrupt, truncated or layout-mismatched checkpoint is **refused with a
  clear error** (the PR 6 ``_append_bench_entry`` policy: never silently
  regenerate over data you did not write).

* **Graceful degradation.**  Each chunk runs under a ladder: on a transient
  runtime fault (:func:`repro.runtime.fault_tolerance.is_transient` —
  RESOURCE_EXHAUSTED / XLA runtime faults, OOM, ...) the chunk is retried
  with bounded exponential backoff, then split in half (block-aligned), and
  finally the backend is downgraded ``pallas -> pallas_interpret ->
  reference`` (sticky for the rest of the run — and, via the checkpoint,
  across restarts).  Every retry/halve/downgrade is recorded in the run's
  ``meta["events"]`` so a run that silently fell back is visible in the
  recorded figure/benchmark metadata.  Non-transient errors raise
  immediately.

* **Preemption.**  A :class:`repro.runtime.fault_tolerance.PreemptionHandler`
  (installed automatically when checkpointing is on) turns SIGTERM/SIGINT
  into a clean checkpoint-and-exit at the next chunk boundary, raising
  :class:`Preempted` (drivers exit with code 75, the sysexits.h "temp
  failure; rerun with --resume" convention).

The TLB sweep's ``"stackdist"`` backend is a global sort over the whole
trace and cannot carry state across chunk boundaries; ``run_sweep_tlb``
runs it monolithically (``meta["resumable"] = False``) and only the
sequential backends stream.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import os
import pathlib
import random
import time
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint.checkpoint import (
    CheckpointCorruptError,
    read_checkpoint_blob,
    write_checkpoint_blob,
)
from repro.core import dispatch
from repro.core.sweep import (
    BatchedSystemEvents,
    BatchedTLBResult,
    SystemSweepStream,
    TLBSweepSpec,
    TLBSweepStream,
    sweep_tlb,
)
from repro.core.timeline import TimelineResult, TimelineSpec, TimelineSweepStream
from repro.core.tlbsim import SystemSimConfig
from repro.kernels.common import resolve_mode
from repro.runtime import telemetry
from repro.runtime.fault_tolerance import (
    PreemptionHandler,
    backoff_delays,
    is_transient,
)

_LOG = logging.getLogger("repro.core.orchestrator")

# Narration level per ladder event: anything that changes how the run
# executes (fell back, split, degraded, preempted) is a warning; resuming is
# the expected happy path of --resume.
_EVENT_LEVELS = {"retry": logging.WARNING, "halve": logging.WARNING,
                 "downgrade": logging.WARNING, "preempt": logging.WARNING,
                 "resume": logging.INFO}

__all__ = [
    "SweepRunConfig",
    "Preempted",
    "LADDER",
    "CKPT_FORMAT",
    "run_sweep_tlb",
    "run_sweep_system",
    "run_sweep_timeline",
    "merge_throughput",
]

# Degradation ladder, fastest first; a run enters at its resolved mode and
# only ever moves right.
LADDER = ("pallas", "pallas_interpret", "reference")

CKPT_FORMAT = "repro-sweep-ckpt-v1"


class Preempted(BaseException):
    """SIGTERM/SIGINT arrived; state was checkpointed at a chunk boundary.

    Deliberately a BaseException (like KeyboardInterrupt): the retry/ladder
    machinery catches transient ``Exception``s only, so a preemption can
    never be mistaken for a recoverable kernel fault.
    """

    def __init__(self, checkpoint: Optional[pathlib.Path], now: int, total: int):
        self.checkpoint = checkpoint
        self.now, self.total = now, total
        super().__init__(
            f"preempted at chunk boundary {now}/{total}; "
            + (f"state checkpointed to {checkpoint} — rerun with --resume"
               if checkpoint else "no checkpoint_dir, state discarded"))


@dataclasses.dataclass(frozen=True)
class SweepRunConfig:
    """How a streamed sweep executes (checkpointing, chunking, the ladder).

    ``chunk_accesses`` is the macro-chunk: the trace-slice granularity of
    checkpoint commits (rounded up to a whole number of kernel blocks).
    ``fault_hook(engine, lo, hi, mode, attempt)`` is a test seam invoked
    before every chunk attempt — the fault-injection harness raises
    simulated transient faults there; ``on_chunk_committed(chunk_idx)``
    fires after a chunk's checkpoint is durably on disk — the harness
    raises a simulated hard kill there.

    ``calibration_dir`` points ``kernel_mode="auto"`` at a measured-rate
    calibration table directory (:mod:`repro.core.dispatch`) and feeds
    achieved rates back into it after every run.  ``None`` (the default)
    keeps decisions on the deterministic cold-start heuristics —
    calibration is strictly opt-in so test/library behavior never depends
    on what a particular machine has measured.
    """

    checkpoint_dir: Optional[str] = None
    calibration_dir: Optional[str] = None
    resume: bool = False
    chunk_accesses: int = 65_536
    max_retries: int = 2
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 2.0
    keep_checkpoint: bool = False
    preemption: Optional[PreemptionHandler] = None
    fault_hook: Optional[Callable] = None
    on_chunk_committed: Optional[Callable] = None
    rng_seed: Optional[int] = 0   # backoff jitter; None -> wall-clock seeded


def _fingerprint_json(fp: dict) -> str:
    return json.dumps(fp, sort_keys=True)


class _ChunkRunner:
    """The shared chunk loop: ladder + checkpointing around one stream."""

    def __init__(self, stream, total: int, out_names: Sequence[str],
                 out_dtypes: Sequence, run_chunk: Callable,
                 start_mode: str, cfg: SweepRunConfig, *, name: str,
                 trace_sha: str,
                 decision: Optional[dispatch.DispatchDecision] = None):
        self.stream = stream
        self.decision = decision
        self.total = int(total)
        self.out_names = tuple(out_names)
        self.run_chunk = run_chunk     # (lo, hi, mode) -> tuple of [B, L]
        self.cfg = cfg
        self.name = name
        B = len(stream.specs) if hasattr(stream, "specs") else len(stream.cfgs)
        self.batch = B
        self.bufs = [np.zeros((B, self.total), dt) for dt in out_dtypes]
        # mode -> {chunks, accesses, sim_accesses, elapsed_s}: achieved
        # throughput per backend actually executed (meta()["throughput"],
        # thence the figure-JSON _telemetry stamp) — recorded even with the
        # tracer disabled, it is plain accumulation.
        self.throughput: dict = {}
        start_mode = resolve_mode(start_mode)  # never "auto" past this point
        self.ladder = LADDER[LADDER.index(start_mode):]
        self.rung = 0
        self.events: List[dict] = []
        self.chunks_committed = 0
        self.resumed_from: Optional[int] = None
        self._rng = random.Random(cfg.rng_seed)
        fp = dict(stream.fingerprint())
        fp["trace_sha256"] = trace_sha
        fp["total"] = self.total
        self._fp = _fingerprint_json(fp)
        self.path = (pathlib.Path(cfg.checkpoint_dir) / f"{name}.ckpt"
                     if cfg.checkpoint_dir else None)

    # -- checkpointing ------------------------------------------------------

    def _meta(self, completed: bool, *,
              chunks_committed: Optional[int] = None) -> dict:
        return {
            "format": CKPT_FORMAT,
            "engine": self.stream.engine,
            "name": self.name,
            "fingerprint": self._fp,
            "now": int(self.stream.now),
            "total": self.total,
            "completed": completed,
            "mode": self.ladder[self.rung],
            "events": self.events,
            "chunks_committed": (self.chunks_committed if chunks_committed
                                 is None else chunks_committed),
            "dispatch": (self.decision.to_json() if self.decision is not None
                         else None),
        }

    def _write_checkpoint(self, completed: bool, *,
                          chunks_committed: Optional[int] = None) -> None:
        if self.path is None:
            return
        self.path.parent.mkdir(parents=True, exist_ok=True)
        arrays = {f"s_{k}": v for k, v in self.stream.export_state().items()}
        now = int(self.stream.now)
        for nm, buf in zip(self.out_names, self.bufs):
            arrays[f"r_{nm}"] = buf[:, :now]
        write_checkpoint_blob(
            self.path, arrays,
            self._meta(completed, chunks_committed=chunks_committed))

    def try_resume(self) -> Optional[dict]:
        """Load the checkpoint if resuming.  Returns the blob meta when the
        checkpointed run had already completed (results restored), else
        None; raises :class:`CheckpointCorruptError` on a corrupt or
        mismatched blob."""
        if not (self.cfg.resume and self.path is not None and self.path.exists()):
            return None
        arrays, meta = read_checkpoint_blob(self.path)
        if meta.get("format") != CKPT_FORMAT or meta.get("engine") != self.stream.engine:
            raise CheckpointCorruptError(
                f"checkpoint {self.path} was written by "
                f"{meta.get('engine')!r}/{meta.get('format')!r}, not "
                f"{self.stream.engine!r}/{CKPT_FORMAT!r}; refusing to resume "
                f"from it — delete it deliberately (or start without "
                f"--resume) to begin a fresh run")
        if meta.get("fingerprint") != self._fp:
            raise CheckpointCorruptError(
                f"checkpoint {self.path} was taken on a different sweep "
                f"layout or trace (fingerprint mismatch); refusing to resume "
                f"from it — delete it deliberately (or start without "
                f"--resume) to begin a fresh run")
        self.stream.import_state(
            {k[2:]: v for k, v in arrays.items() if k.startswith("s_")})
        now = int(self.stream.now)
        for nm, buf in zip(self.out_names, self.bufs):
            buf[:, :now] = arrays[f"r_{nm}"]
        self.events = list(meta.get("events", []))
        # Resume-stickiness: the checkpointed run's DispatchDecision wins
        # over whatever this process just decided — a calibration table that
        # changed between runs must never flip the backend mid-stream (the
        # resumed tail has to be bit-identical to the uninterrupted run).
        dd = meta.get("dispatch")
        if dd:
            blob_dec = dispatch.DispatchDecision.from_json(dd)
            if blob_dec.mode in LADDER:
                self.ladder = LADDER[LADDER.index(blob_dec.mode):]
                self.rung = 0
            self.decision = dataclasses.replace(
                blob_dec, reason=blob_dec.reason + " (reused from checkpoint)",
                calibration=f"checkpoint:{blob_dec.calibration}")
        mode = meta.get("mode")
        if mode in self.ladder:   # sticky downgrade survives the restart
            self.rung = self.ladder.index(mode)
        self.chunks_committed = int(meta.get("chunks_committed", 0))
        self.resumed_from = now
        self._log("resume", now, self.total,
                  chunks_committed=self.chunks_committed,
                  completed=bool(meta.get("completed")))
        return meta if meta.get("completed") else None

    # -- the ladder ---------------------------------------------------------

    def _commit(self, lo: int, hi: int, outs) -> None:
        for buf, out in zip(self.bufs, outs):
            buf[:, lo:hi] = out
        # The blob (written with the incremented count) is the commit point:
        # the in-memory counter moves only once the write has succeeded, so
        # meta/events never claim one more durable chunk than disk holds.
        t0 = time.perf_counter()
        self._write_checkpoint(completed=False,
                               chunks_committed=self.chunks_committed + 1)
        self.chunks_committed += 1
        if self.path is not None:
            telemetry.get_tracer().event(
                "checkpoint_write", engine=self.stream.engine, name=self.name,
                chunk=self.chunks_committed,
                dur_s=round(time.perf_counter() - t0, 6))
        if self.cfg.on_chunk_committed is not None:
            self.cfg.on_chunk_committed(self.chunks_committed - 1)
        pre = self.cfg.preemption
        if pre is not None and pre.requested:
            self._log("preempt", int(self.stream.now), self.total,
                      chunks_committed=self.chunks_committed)
            raise Preempted(self.path, int(self.stream.now), self.total)

    def _log(self, event: str, lo: int, hi: int, **kw) -> None:
        """Record one ladder event into meta["events"], the telemetry run
        log, and the narration logger.  Every event carries a wall-clock
        (``ts``) and monotonic (``t_mono``) stamp so a degraded run can be
        reconstructed post-hoc."""
        rec = {"event": event, "lo": int(lo), "hi": int(hi),
               "mode": self.ladder[self.rung],
               "ts": time.time(), "t_mono": time.perf_counter(), **kw}
        self.events.append(rec)
        telemetry.get_tracer().event(
            event, engine=self.stream.engine, name=self.name,
            **{k: v for k, v in rec.items()
               if k not in ("event", "ts", "t_mono")})
        _LOG.log(_EVENT_LEVELS.get(event, logging.INFO),
                 "%s[%s] %s [%d, %d) mode=%s%s",
                 self.stream.engine, self.name, event, rec["lo"], rec["hi"],
                 rec["mode"],
                 "".join(f" {k}={v}" for k, v in kw.items()))

    def _note_chunk(self, lo: int, hi: int, mode: str, attempt: int,
                    dur_s: float) -> None:
        """Account a successful chunk attempt: per-mode throughput (always)
        plus a telemetry chunk span (when a run is active)."""
        n = int(hi - lo)
        agg = self.throughput.setdefault(
            mode, {"chunks": 0, "accesses": 0, "sim_accesses": 0,
                   "elapsed_s": 0.0})
        agg["chunks"] += 1
        agg["accesses"] += n
        agg["sim_accesses"] += n * self.batch
        agg["elapsed_s"] += dur_s
        telemetry.get_tracer().record_span(
            "chunk", dur_s, engine=self.stream.engine, name=self.name,
            lo=int(lo), hi=int(hi), mode=mode, attempt=attempt,
            accesses=n, configs=self.batch,
            accesses_per_s=round(n / dur_s, 1) if dur_s > 0 else None,
            sim_accesses_per_s=(round(n * self.batch / dur_s, 1)
                                if dur_s > 0 else None))

    def _exec(self, lo: int, hi: int) -> None:
        """Run span [lo, hi) through retries -> halving -> downgrade."""
        delays = backoff_delays(
            self.cfg.max_retries, base_s=self.cfg.backoff_base_s,
            cap_s=self.cfg.backoff_cap_s, rng=self._rng)
        last_exc: Optional[Exception] = None
        for attempt in range(self.cfg.max_retries + 1):
            mode = self.ladder[self.rung]
            # Only the chunk attempt itself may be retried.  _commit stays
            # OUTSIDE the try: once run_chunk has returned, the stream has
            # already advanced past `lo`, so re-entering this loop after a
            # checkpoint-write failure would re-apply the chunk to the
            # advanced state (double-applied hits, drifted `now`) and then
            # checkpoint the corrupted prefix as good.  A failed commit must
            # propagate, leaving the previous blob as the resume point.
            t0 = time.perf_counter()
            try:
                if self.cfg.fault_hook is not None:
                    self.cfg.fault_hook(self.stream.engine, lo, hi, mode, attempt)
                outs = self.run_chunk(lo, hi, mode)
            except Exception as exc:
                if not is_transient(exc):
                    raise
                last_exc = exc
                self._log("retry", lo, hi, attempt=attempt,
                          elapsed_s=round(time.perf_counter() - t0, 6),
                          error=f"{type(exc).__name__}: {exc}")
                if attempt < self.cfg.max_retries:
                    time.sleep(delays[attempt])
                continue
            self._note_chunk(lo, hi, mode, attempt, time.perf_counter() - t0)
            self._commit(lo, hi, outs)
            return
        # Retries exhausted.  Halve if the span spans more than one block,
        # else (or eventually) take the next rung down the ladder.
        block = self.stream.block
        if hi - lo > block:
            half = ((hi - lo) // 2 // block) * block
            mid = lo + max(half, block)
            self._log("halve", lo, hi, mid=int(mid))
            self._exec(lo, mid)
            self._exec(mid, hi)
            return
        if self.rung + 1 < len(self.ladder):
            self._log("downgrade", lo, hi,
                      to_mode=self.ladder[self.rung + 1],
                      error=f"{type(last_exc).__name__}: {last_exc}")
            self.rung += 1   # sticky for the rest of the run
            self._exec(lo, hi)
            return
        raise last_exc

    # -- the loop -----------------------------------------------------------

    def run(self) -> dict:
        block = self.stream.block
        chunk = max(int(self.cfg.chunk_accesses), 1)
        chunk += (-chunk) % block   # whole kernel blocks per macro-chunk
        while self.stream.now < self.total:
            lo = int(self.stream.now)
            self._exec(lo, min(lo + chunk, self.total))
        if self.path is not None and not self.cfg.keep_checkpoint \
                and not self.cfg.resume:
            # A fresh (non-resume) run that finished cleanly leaves no blob
            # behind unless asked to — the completed blob would be deleted
            # straight away, so don't serialize the full result prefix only
            # to unlink it; just drop the last chunk blob.
            try:
                os.remove(self.path)
            except OSError:
                pass
        else:
            # keep_checkpoint or --resume: the completed blob stays so an
            # identical rerun is a pure checkpoint read.
            self._write_checkpoint(completed=True)
        return self.meta()

    def meta(self, *, completed_from_checkpoint: bool = False) -> dict:
        return {
            "engine": self.stream.engine,
            "resumable": True,
            "start_mode": self.ladder[0],
            "final_mode": self.ladder[self.rung],
            "events": self.events,
            "chunks_committed": self.chunks_committed,
            "resumed_from": self.resumed_from,
            "completed_from_checkpoint": completed_from_checkpoint,
            "checkpoint": str(self.path) if self.path else None,
            "throughput": _throughput_meta(self.throughput),
            "dispatch": (self.decision.to_json() if self.decision is not None
                         else None),
        }


def _throughput_meta(agg_by_mode: dict) -> dict:
    """Finish the per-mode accumulators into achieved accesses/s (trace
    accesses and simulated config x access pairs per second of engine
    wall time)."""
    out = {}
    for mode, a in agg_by_mode.items():
        dt = a["elapsed_s"]
        out[mode] = {
            "chunks": a["chunks"], "accesses": a["accesses"],
            "sim_accesses": a["sim_accesses"],
            "elapsed_s": round(dt, 6),
            "accesses_per_s": round(a["accesses"] / dt, 1) if dt > 0 else None,
            "sim_accesses_per_s": (round(a["sim_accesses"] / dt, 1)
                                   if dt > 0 else None),
        }
    return out


def merge_throughput(metas: Sequence[dict]) -> dict:
    """Merge the ``meta["throughput"]`` stamps of several runs (the shard
    scheduler's per-shard orchestrator runs) into one per-mode aggregate
    with recomputed achieved rates."""
    agg: dict = {}
    for m in metas:
        for mode, d in (m.get("throughput") or {}).items():
            a = agg.setdefault(mode, {"chunks": 0, "accesses": 0,
                                      "sim_accesses": 0, "elapsed_s": 0.0})
            a["chunks"] += d["chunks"]
            a["accesses"] += d["accesses"]
            a["sim_accesses"] += d["sim_accesses"]
            a["elapsed_s"] += d["elapsed_s"]
    return _throughput_meta(agg)


def _sha256_arrays(*arrays: np.ndarray) -> str:
    import hashlib

    h = hashlib.sha256()
    for a in arrays:
        a = np.ascontiguousarray(a)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def _maybe_handler(cfg: SweepRunConfig) -> Tuple[SweepRunConfig, Optional[PreemptionHandler]]:
    """Install a PreemptionHandler for the duration of a checkpointing run
    when the caller did not supply one."""
    if cfg.checkpoint_dir is None or cfg.preemption is not None:
        return cfg, None
    handler = PreemptionHandler()
    return dataclasses.replace(cfg, preemption=handler), handler


def run_sweep_tlb(
    addrs: np.ndarray,
    specs: Sequence[TLBSweepSpec],
    *,
    warmup_frac: float = 0.25,
    kernel_mode: str = "auto",
    block: int = 512,
    run: SweepRunConfig = SweepRunConfig(),
    name: str = "sweep_tlb",
) -> Tuple[BatchedTLBResult, dict]:
    """Crash-safe :func:`repro.core.sweep.sweep_tlb`.

    Returns ``(BatchedTLBResult, meta)`` — the result is bit-identical to
    the monolithic engine.  ``"stackdist"`` (and ``"auto"`` resolving to it)
    runs monolithically: the sort-based engine needs the whole trace, so it
    is not resumable (``meta["resumable"] = False``).
    """
    addrs = np.asarray(addrs)
    store = dispatch.store_for(run.calibration_dir)
    decision = dispatch.decide_tlb(
        kernel_mode, specs, n_accesses=int(addrs.shape[0]), store=store)
    dispatch.record_decision(decision, name=name)
    mode = decision.mode
    if mode == "stackdist":
        # Monolithic, but still measured: the stackdist engine's achieved
        # accesses/s lands in meta["throughput"] (and a single whole-trace
        # "chunk" span in the run log) just like the streamed backends'.
        n = int(addrs.shape[0])
        t0 = time.perf_counter()
        res = sweep_tlb(addrs, specs, warmup_frac=warmup_frac,
                        kernel_mode=mode, block=block)
        dur = time.perf_counter() - t0
        telemetry.get_tracer().record_span(
            "chunk", dur, engine="sweep_tlb", name=name, lo=0, hi=n,
            mode=mode, attempt=0, accesses=n, configs=len(specs),
            accesses_per_s=round(n / dur, 1) if dur > 0 else None,
            sim_accesses_per_s=(round(n * len(specs) / dur, 1)
                                if dur > 0 else None))
        agg = {mode: {"chunks": 1, "accesses": n,
                      "sim_accesses": n * len(specs), "elapsed_s": dur}}
        throughput = _throughput_meta(agg)
        dispatch.observe(decision, throughput, store=store, name=name)
        return res, {"engine": "sweep_tlb", "resumable": False,
                     "start_mode": mode, "final_mode": mode, "events": [],
                     "chunks_committed": 0, "resumed_from": None,
                     "completed_from_checkpoint": False, "checkpoint": None,
                     "throughput": throughput,
                     "dispatch": decision.to_json()}

    run, handler = _maybe_handler(run)
    try:
        stream = TLBSweepStream(specs, block=block)
        n = int(addrs.shape[0])
        runner = _ChunkRunner(
            stream, n, ("hits",), (bool,),
            lambda lo, hi, m: (stream.run_chunk(addrs[lo:hi], kernel_mode=m),),
            mode, run, name=name, trace_sha=_sha256_arrays(addrs),
            decision=decision)
        done = runner.try_resume()
        meta = runner.meta(completed_from_checkpoint=True) if done else runner.run()
        dispatch.observe(runner.decision, meta.get("throughput") or {},
                         store=store, name=name)
        n0 = int(n * warmup_frac)
        return BatchedTLBResult(hits=runner.bufs[0], n_warm=n - n0), meta
    finally:
        if handler is not None:
            handler.uninstall()


def run_sweep_system(
    lines: np.ndarray,
    cfgs: Sequence[SystemSimConfig],
    *,
    warmup_frac: float = 0.25,
    kernel_mode: str = "auto",
    block: int = 512,
    run: SweepRunConfig = SweepRunConfig(),
    name: str = "sweep_system",
) -> Tuple[BatchedSystemEvents, dict]:
    """Crash-safe :func:`repro.core.sweep.sweep_system`; returns
    ``(BatchedSystemEvents, meta)``, bit-identical to the monolithic
    engine."""
    lines = np.asarray(lines)
    store = dispatch.store_for(run.calibration_dir)
    decision = dispatch.decide_system(
        kernel_mode, cfgs, n_accesses=int(lines.shape[0]), store=store)
    dispatch.record_decision(decision, name=name)
    run, handler = _maybe_handler(run)
    try:
        stream = SystemSweepStream(cfgs, block=block)
        n = int(lines.shape[0])
        runner = _ChunkRunner(
            stream, n, ("cache_hit", "accel_tlb_hit", "mem_tlb_hit"),
            (bool, bool, bool),
            lambda lo, hi, m: stream.run_chunk(lines[lo:hi], kernel_mode=m),
            decision.mode, run, name=name, trace_sha=_sha256_arrays(lines),
            decision=decision)
        done = runner.try_resume()
        meta = runner.meta(completed_from_checkpoint=True) if done else runner.run()
        dispatch.observe(runner.decision, meta.get("throughput") or {},
                         store=store, name=name)
        n0 = int(n * warmup_frac)
        return BatchedSystemEvents(*runner.bufs, n_warm=n - n0), meta
    finally:
        if handler is not None:
            handler.uninstall()


def run_sweep_timeline(
    specs: Sequence[TimelineSpec],
    lat=None,
    *,
    kernel_mode: str = "auto",
    block: int = 512,
    run: SweepRunConfig = SweepRunConfig(),
    name: str = "sweep_timeline",
) -> Tuple[List[TimelineResult], dict]:
    """Crash-safe :func:`repro.core.timeline.sweep_timeline`; returns
    ``(results, meta)``, bit-identical to the monolithic engine."""
    store = dispatch.store_for(run.calibration_dir)
    n_acc = max((int(np.asarray(sp.lines).shape[0]) for sp in specs),
                default=0) if specs else None
    decision = dispatch.decide_timeline(
        kernel_mode, batch=len(specs), n_accesses=n_acc, store=store)
    dispatch.record_decision(decision, name=name)
    run, handler = _maybe_handler(run)
    try:
        stream = TimelineSweepStream(specs, lat, block=block)
        runner = _ChunkRunner(
            stream, stream.n, ("latency", "overhead", "done"),
            (np.float32, np.float32, np.float32),
            lambda lo, hi, m: stream.run_chunk(lo, hi, kernel_mode=m),
            decision.mode, run, name=name,
            trace_sha=_sha256_arrays(*stream._stacked),
            decision=decision)
        done = runner.try_resume()
        meta = runner.meta(completed_from_checkpoint=True) if done else runner.run()
        dispatch.observe(runner.decision, meta.get("throughput") or {},
                         store=store, name=name)
        return stream.finalize(*runner.bufs), meta
    finally:
        if handler is not None:
            handler.uninstall()
