"""Unified cost-model backend dispatch: one calibrated selection layer.

Every engine used to resolve ``kernel_mode="auto"`` through its own
hard-coded rule — ``resolve_mode``'s "tpu -> pallas else reference",
``_stackdist_eligible``'s "ways <= AUTO_MAX_WAYS" in the TLB sweep, the
batch-aware special case in ``resolve_timeline_mode``.  Those rules were
derived on one CPU container and smeared across four modules; meanwhile the
orchestrator has been *measuring* what every backend actually achieves
(``meta["throughput"]``, chunk spans in the run logs, BENCH_sweep.json
rows).  This module closes the loop:

* :class:`DispatchDecision` — the one decision object: requested mode,
  chosen mode, per-candidate predicted rates, calibration provenance and a
  human-readable reason.  It is JSON-able end to end, so the orchestrator
  records it as a telemetry event, stamps it into checkpoint blob meta
  (resume reuses it — a calibration table that changed between runs can
  never flip the backend mid-stream) and the figure-JSON ``_telemetry``
  stamp carries it per engine call.

* **Analytic cost model.**  Per engine the work is ``sim_accesses =
  batch x trace length`` (config/sim count times streamed accesses; the
  envelope chunker's own work metric), and the predicted runtime of a
  backend is ``sim_accesses / rate`` where ``rate`` is a calibrated
  per-(device_kind, engine, mode, batch-bucket) constant in simulated
  accesses/second.  Buckets are ``"b1"`` (degenerate, batch <= 1) and
  ``"bN"``: the old timeline batch special case becomes a *measured* fact
  (a single sequential sim gives the kernel nothing to amortize) instead of
  an if-else.

* :class:`CalibrationStore` — per-device JSON tables under a caller-chosen
  directory (``benchmarks/_cache/calibration/`` for the bench drivers),
  written with the checkpoint-blob header discipline (one ASCII header line
  ``repro-dispatch-calib-v1 sha256:<hex>`` pinning the payload digest).  A
  corrupt or foreign file is **refused** with
  :class:`CalibrationCorruptError` — never silently regenerated (the
  BENCH_sweep.json / checkpoint-blob policy).  Rates merge by measured
  weight (simulated accesses), with the old weight capped so a stale table
  still adapts.

* **Cold start.**  With no calibration (or no measurement for the
  would-be default), ``decide_*`` falls back to exactly the legacy
  heuristics — :func:`cold_start_mode` is now their only home.  A
  calibrated choice is only taken when the cold-start default itself has a
  measured rate and at least one rival does too, so a half-measured table
  can never abandon the default for lack of data about it.

Feeds: the orchestrator calls :func:`observe` after every run (achieved
per-mode rates from ``meta["throughput"]``, plus ``dispatch_residual``
telemetry events comparing achieved against predicted);
``benchmarks/kernel_bench.py`` records every backend it times (the
mechanism by which a CPU host learns the batched scan beats
``pallas_interpret``); :func:`ingest_bench_history` /
:func:`ingest_runlogs` bootstrap a cold table from existing
BENCH_sweep.json rows and run-log chunk spans.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import logging
import os
import pathlib
import re
import time
import uuid
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.kernels.common import SWEEP_MODES, VALID_MODES
from repro.runtime import telemetry

_LOG = logging.getLogger("repro.core.dispatch")

__all__ = [
    "CALIB_FORMAT",
    "DispatchDecision",
    "CalibrationStore",
    "CalibrationCorruptError",
    "default_mode",
    "cold_start_mode",
    "stackdist_eligible",
    "decide_tlb",
    "decide_system",
    "decide_timeline",
    "observe",
    "record_decision",
    "store_for",
    "gc_calibration",
    "ingest_bench_history",
    "ingest_bench_entries",
    "ingest_runlogs",
]

# Header magic of a calibration table file (the checkpoint-blob discipline:
# `<magic> sha256:<hex>\n` + payload; bump on incompatible payload changes).
CALIB_FORMAT = "repro-dispatch-calib-v1"
SCHEMA_VERSION = 1

# The three orchestrated engines this layer dispatches for.
ENGINES = ("sweep_tlb", "sweep_system", "sweep_timeline")

# A calibrated rate is trusted for prediction only above this much measured
# work — a single tiny smoke chunk should not steer real sweeps.
MIN_CALIB_WEIGHT = 1_000.0

# When merging a new measurement into a stored rate, the stored weight is
# capped at this multiple of the new one so the table keeps adapting.
_MAX_OLD_WEIGHT_RATIO = 10.0


class CalibrationCorruptError(RuntimeError):
    """A calibration table failed validation (truncated, bit-flipped, or not
    a calibration file at all).  Deliberately raised, never silently
    regenerated — delete the file deliberately to start cold."""


def _default_backend() -> str:
    # Routed through repro.kernels.common's jax reference so tests that
    # monkeypatch `kernels.common.jax.default_backend` steer this layer too.
    from repro.kernels import common as _kc

    return _kc.jax.default_backend()


def default_mode() -> str:
    """The generic cold-start rule (per-op kernels, no engine context):
    the Mosaic kernel on TPU backends, the scan reference elsewhere."""
    return "pallas" if _default_backend() == "tpu" else "reference"


def stackdist_eligible(specs: Sequence) -> bool:
    """May ``"auto"`` consider the exact stack-distance backend for this TLB
    sweep?  Every ``TLBSweepSpec`` is a pure-LRU TLB today, so eligibility
    reduces to the associativity staying within the capped-stack state
    (:data:`repro.core.stackdist.AUTO_MAX_WAYS`).  This is a hard memory-
    shape constraint, not a perf heuristic — calibration never overrides
    it."""
    from repro.core import stackdist

    return max(sp.cfg.effective_ways for sp in specs) <= stackdist.AUTO_MAX_WAYS


def cold_start_mode(engine: str, *, batch: int = 1,
                    eligible_stackdist: bool = False) -> str:
    """The legacy ``"auto"`` heuristics, in their one remaining home.

    * ``sweep_tlb`` — the stack-distance engine when every spec is an
      eligible pure-LRU TLB, else the generic rule;
    * ``sweep_timeline`` — the scan reference for a degenerate (batch <= 1)
      run (one sequential sim gives the kernel nothing to amortize), else
      the generic rule;
    * ``sweep_system`` (and anything else) — the generic rule.
    """
    if engine == "sweep_tlb" and eligible_stackdist:
        return "stackdist"
    if engine == "sweep_timeline" and batch <= 1:
        return "reference"
    return default_mode()


def _bucket(batch: int) -> str:
    return "b1" if batch <= 1 else "bN"


# ---------------------------------------------------------------------------
# The decision object.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DispatchDecision:
    """One resolved backend choice: what was asked, what was chosen, what
    every candidate was predicted to achieve, and why.

    ``candidates`` maps each considered mode to ``{"rate":
    sim_accesses/s | None, "predicted_s": float | None}`` (rate from the
    calibration table; ``None`` = no trusted measurement).  ``calibration``
    is the provenance: ``"explicit"`` (mode was not ``"auto"``),
    ``"cold_start"`` or ``"measured:<table path>"``.
    """

    engine: str
    requested: str
    mode: str
    candidates: Dict[str, dict]
    calibration: str
    reason: str
    features: Dict[str, object]

    def to_json(self) -> dict:
        return {
            "engine": self.engine, "requested": self.requested,
            "mode": self.mode, "candidates": self.candidates,
            "calibration": self.calibration, "reason": self.reason,
            "features": self.features,
        }

    @classmethod
    def from_json(cls, d: dict) -> "DispatchDecision":
        return cls(
            engine=str(d.get("engine")), requested=str(d.get("requested")),
            mode=str(d.get("mode")), candidates=dict(d.get("candidates") or {}),
            calibration=str(d.get("calibration", "?")),
            reason=str(d.get("reason", "")),
            features=dict(d.get("features") or {}))


# ---------------------------------------------------------------------------
# Calibration store: per-device rate tables with blob-header integrity.
# ---------------------------------------------------------------------------


def _slug(s: str) -> str:
    return re.sub(r"[^a-z0-9]+", "-", str(s).lower()).strip("-") or "unknown"


def _write_table(path: pathlib.Path, payload: dict) -> None:
    body = json.dumps(payload, sort_keys=True, indent=1).encode()
    header = f"{CALIB_FORMAT} sha256:{hashlib.sha256(body).hexdigest()}\n".encode()
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_name(f"{path.name}.tmp-{uuid.uuid4().hex[:8]}")
    with open(tmp, "wb") as f:
        f.write(header)
        f.write(body)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _read_table(path: pathlib.Path) -> dict:
    data = path.read_bytes()
    nl = data.find(b"\n")
    refusal = ("refusing to use it — delete the file deliberately to start "
               "from a cold (heuristic) table")
    if nl < 0:
        raise CalibrationCorruptError(
            f"calibration table {path} has no header line (truncated?); {refusal}")
    try:
        magic, digest_field = data[:nl].decode("ascii").split(" ", 1)
    except (UnicodeDecodeError, ValueError):
        raise CalibrationCorruptError(
            f"calibration table {path} header is unparseable; {refusal}") from None
    if magic != CALIB_FORMAT or not digest_field.startswith("sha256:"):
        raise CalibrationCorruptError(
            f"calibration table {path} is not a {CALIB_FORMAT} file "
            f"(header {data[:nl][:64]!r}); {refusal}")
    body = data[nl + 1:]
    actual = hashlib.sha256(body).hexdigest()
    if actual != digest_field[len("sha256:"):]:
        raise CalibrationCorruptError(
            f"calibration table {path} failed its content checksum "
            f"(truncated or bit-flipped); {refusal}")
    try:
        payload = json.loads(body.decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise CalibrationCorruptError(
            f"calibration table {path} payload is undecodable ({e}); {refusal}"
        ) from e
    if not isinstance(payload, dict):
        raise CalibrationCorruptError(
            f"calibration table {path} payload is not an object; {refusal}")
    return payload


class CalibrationStore:
    """One device's measured-rate table: ``calib-<device slug>.json`` under
    a calibration directory, read lazily with an mtime cache and updated by
    locked read-modify-write (concurrent scheduler workers / bench runs
    serialize instead of losing appends)."""

    def __init__(self, path, *, device: Optional[dict] = None):
        self.path = pathlib.Path(path)
        self.device = dict(device or {})
        self._cache: Optional[Tuple[float, dict]] = None

    @classmethod
    def for_dir(cls, root, *, device: Optional[dict] = None) -> "CalibrationStore":
        """The per-device table under ``root`` for the current jax device
        (``benchtime.device_metadata()``) or an explicit ``device`` stamp."""
        if device is None:
            from repro.core.benchtime import device_metadata

            device = device_metadata()
        kind = device.get("device_kind", "unknown")
        return cls(pathlib.Path(root) / f"calib-{_slug(kind)}.json",
                   device=device)

    @property
    def device_kind(self) -> str:
        return str(self.device.get("device_kind", "unknown"))

    def load(self) -> dict:
        """The table payload (``{}``-shaped skeleton when the file does not
        exist).  Raises :class:`CalibrationCorruptError` on a corrupt or
        foreign file — never silently regenerates."""
        try:
            mtime = self.path.stat().st_mtime
        except OSError:
            return {"format": CALIB_FORMAT, "schema_version": SCHEMA_VERSION,
                    "device": self.device, "rates": {}}
        if self._cache is not None and self._cache[0] == mtime:
            return self._cache[1]
        payload = _read_table(self.path)
        self._cache = (mtime, payload)
        return payload

    def exists(self) -> bool:
        return self.path.exists()

    def describe(self) -> str:
        """Provenance string for decisions made against this table."""
        return f"measured:{self.path}" if self.exists() else "cold_start"

    def rate(self, engine: str, mode: str, batch: int) -> Optional[float]:
        """Trusted calibrated rate (sim accesses/s) or ``None``."""
        rec = (self.load().get("rates", {}).get(engine, {})
               .get(mode, {}).get(_bucket(batch)))
        if not rec:
            return None
        if float(rec.get("weight", 0.0)) < MIN_CALIB_WEIGHT:
            return None
        r = rec.get("rate")
        return float(r) if r and r > 0 else None

    def record(self, engine: str, mode: str, batch: int, rate: float,
               *, weight: float) -> None:
        self.record_many([(engine, mode, batch, rate, weight)])

    def record_many(
        self, rows: Iterable[Tuple[str, str, int, float, float]]
    ) -> None:
        """Merge measured ``(engine, mode, batch, rate, weight)`` rows into
        the table in one locked read-modify-write.  Weights are simulated
        accesses; the stored weight is capped at
        ``_MAX_OLD_WEIGHT_RATIO x`` the incoming one so the table adapts."""
        rows = [r for r in rows if r[3] and r[3] > 0 and r[4] > 0]
        if not rows:
            return
        from repro.checkpoint.checkpoint import file_lock

        lock = self.path.with_name(self.path.name + ".lock")
        with file_lock(lock):
            self._cache = None
            payload = self.load()
            payload.setdefault("format", CALIB_FORMAT)
            payload.setdefault("schema_version", SCHEMA_VERSION)
            payload.setdefault("device", self.device)
            rates = payload.setdefault("rates", {})
            for engine, mode, batch, rate, weight in rows:
                rec = (rates.setdefault(engine, {})
                       .setdefault(mode, {})
                       .setdefault(_bucket(batch), {}))
                w_old = min(float(rec.get("weight", 0.0)),
                            _MAX_OLD_WEIGHT_RATIO * float(weight))
                r_old = float(rec.get("rate", 0.0))
                w_new = float(weight)
                rec["rate"] = ((r_old * w_old + float(rate) * w_new)
                               / (w_old + w_new))
                rec["weight"] = float(rec.get("weight", 0.0)) + w_new
                rec["n"] = int(rec.get("n", 0)) + 1
                rec["updated_at"] = time.strftime("%Y-%m-%d %H:%M:%S")
            _write_table(self.path, payload)
        self._cache = None


def store_for(calibration_dir) -> Optional[CalibrationStore]:
    """A :class:`CalibrationStore` for ``calibration_dir``, or ``None`` when
    no directory is configured (cold-start decisions only)."""
    if not calibration_dir:
        return None
    return CalibrationStore.for_dir(calibration_dir)


# ---------------------------------------------------------------------------
# The decision core.
# ---------------------------------------------------------------------------


def _decide(engine: str, requested: str, concrete: Optional[str], *,
            candidates: Sequence[str], cold: str, batch: int,
            n_accesses: Optional[int], features: Dict[str, object],
            store: Optional[CalibrationStore]) -> DispatchDecision:
    feats = {"batch": int(batch), "n_accesses": n_accesses,
             "sim_accesses": (int(batch) * int(n_accesses)
                              if n_accesses else None),
             **features}
    if store is not None:
        feats.setdefault("device_kind", store.device_kind)
    sim = feats["sim_accesses"]

    cand: Dict[str, dict] = {}
    for m in candidates:
        r = store.rate(engine, m, batch) if store is not None else None
        cand[m] = {"rate": round(r, 1) if r else None,
                   "predicted_s": (round(sim / r, 6) if r and sim else None)}

    if concrete is not None:   # explicit mode: honoured as given
        cand.setdefault(concrete, {"rate": None, "predicted_s": None})
        return DispatchDecision(
            engine=engine, requested=requested, mode=concrete,
            candidates=cand, calibration="explicit",
            reason=f"kernel_mode={requested!r} given explicitly",
            features=feats)

    measured = {m: c["rate"] for m, c in cand.items() if c["rate"]}
    if cold in measured and len(measured) >= 2:
        chosen = max(measured, key=measured.get)
        ordered = ", ".join(
            f"{m}={measured[m]:.3g}/s" for m in
            sorted(measured, key=measured.get, reverse=True))
        return DispatchDecision(
            engine=engine, requested=requested, mode=chosen,
            candidates=cand, calibration=store.describe(),
            reason=(f"calibrated: fastest measured backend ({ordered}); "
                    f"cold-start default was {cold!r}"),
            features=feats)
    why = ("no calibration table" if store is None or not store.exists()
           else f"default {cold!r} not measured yet"
           if cold not in measured else "no measured rival to compare")
    return DispatchDecision(
        engine=engine, requested=requested, mode=cold, candidates=cand,
        calibration="cold_start" if store is None or not store.exists()
        else store.describe(),
        reason=f"cold-start heuristic ({why})", features=feats)


def decide_tlb(kernel_mode: str, specs: Sequence, *,
               n_accesses: Optional[int] = None,
               store: Optional[CalibrationStore] = None) -> DispatchDecision:
    """Backend decision for the TLB sweep (``SWEEP_MODES``, including the
    sweep-only exact stack-distance engine when every spec is eligible)."""
    if kernel_mode not in SWEEP_MODES:
        raise ValueError(
            f"kernel_mode={kernel_mode!r}; expected one of {tuple(SWEEP_MODES)}")
    eligible = stackdist_eligible(specs)
    candidates = ["reference"]
    if eligible:
        candidates.append("stackdist")
    if _default_backend() == "tpu":
        candidates.append("pallas")
    candidates.append("pallas_interpret")
    geoms = [sp.geometry for sp in specs]
    features = {
        "words_per_access": 3,
        "state_bytes": 4 * sum(2 * (g[0] + 1) * g[1] for g in geoms),
        "stackdist_eligible": eligible,
    }
    return _decide(
        "sweep_tlb", kernel_mode,
        None if kernel_mode == "auto" else kernel_mode,
        candidates=candidates,
        cold=cold_start_mode("sweep_tlb", batch=len(specs),
                             eligible_stackdist=eligible),
        batch=len(specs), n_accesses=n_accesses, features=features,
        store=store)


def decide_system(kernel_mode: str, cfgs: Sequence, *,
                  n_accesses: Optional[int] = None,
                  store: Optional[CalibrationStore] = None) -> DispatchDecision:
    """Backend decision for the joint system sweep.  Sweep-only modes raise
    (stack inclusion does not hold for cache-hit-conditional probes) via the
    engine's own validator."""
    from repro.kernels.system_sim import resolve_system_mode

    concrete = resolve_system_mode(kernel_mode)   # raises on invalid modes
    from repro.core.tlbsim import _geom

    state = 0
    for c in cfgs:
        cs, cw = _geom(c.cache)
        asets, aw = _geom(c.accel_tlb)
        ms, mw = _geom(c.mem_tlb)
        state += 2 * ((cs + 1) * cw + (asets + 1) * aw
                      + (ms * c.num_partitions + 1) * mw)
    candidates = ["reference"]
    if _default_backend() == "tpu":
        candidates.append("pallas")
    candidates.append("pallas_interpret")
    return _decide(
        "sweep_system", kernel_mode,
        None if kernel_mode == "auto" else concrete,
        candidates=candidates,
        cold=cold_start_mode("sweep_system", batch=len(cfgs)),
        batch=len(cfgs), n_accesses=n_accesses,
        features={"words_per_access": 7, "state_bytes": 4 * state},
        store=store)


def decide_timeline(kernel_mode: str, *, batch: int,
                    n_accesses: Optional[int] = None,
                    state_bytes: Optional[int] = None,
                    store: Optional[CalibrationStore] = None) -> DispatchDecision:
    """Backend decision for the batched timeline engine.  Sweep-only modes
    raise via the engine's own validator; the degenerate-batch scan
    preference is the cold-start rule (and otherwise emerges from the
    calibrated ``b1`` bucket)."""
    from repro.kernels.timeline import resolve_timeline_mode

    concrete = resolve_timeline_mode(kernel_mode, batch=batch)
    candidates = ["reference"]
    if _default_backend() == "tpu":
        candidates.append("pallas")
    candidates.append("pallas_interpret")
    features: Dict[str, object] = {"words_per_access": 11}
    if state_bytes is not None:
        features["state_bytes"] = int(state_bytes)
    return _decide(
        "sweep_timeline", kernel_mode,
        None if kernel_mode == "auto" else concrete,
        candidates=candidates,
        cold=cold_start_mode("sweep_timeline", batch=batch),
        batch=batch, n_accesses=n_accesses, features=features, store=store)


# ---------------------------------------------------------------------------
# Feedback: telemetry events + achieved-rate recording.
# ---------------------------------------------------------------------------


def record_decision(decision: DispatchDecision, *, name: str) -> None:
    """Emit the decision as a structured telemetry event (run-log record +
    event count) and a narration line."""
    telemetry.get_tracer().event(
        "dispatch", engine=decision.engine, name=name, mode=decision.mode,
        requested=decision.requested, calibration=decision.calibration,
        reason=decision.reason,
        candidates={m: c.get("rate") for m, c in decision.candidates.items()},
        predicted_s={m: c.get("predicted_s")
                     for m, c in decision.candidates.items()
                     if c.get("predicted_s") is not None})
    _LOG.info("%s[%s] dispatch %r -> %r (%s)", decision.engine, name,
              decision.requested, decision.mode, decision.reason)


def observe(decision: DispatchDecision, throughput: Dict[str, dict], *,
            store: Optional[CalibrationStore] = None,
            name: str = "?") -> None:
    """Close the loop after a run: record each executed backend's achieved
    rate into the calibration table and emit ``dispatch_residual`` events
    comparing achieved against predicted (the downgrade ladder's modes are
    measured too — a degraded run still calibrates what it ran)."""
    batch = int(decision.features.get("batch") or 1)
    rows = []
    tracer = telemetry.get_tracer()
    for mode, d in (throughput or {}).items():
        achieved = d.get("sim_accesses_per_s")
        if not achieved:
            continue
        predicted = (decision.candidates.get(mode) or {}).get("rate")
        tracer.event(
            "dispatch_residual", engine=decision.engine, name=name,
            mode=mode, chosen=(mode == decision.mode),
            predicted_rate=predicted, achieved_rate=achieved,
            ratio=(round(achieved / predicted, 3) if predicted else None))
        rows.append((decision.engine, mode, batch, float(achieved),
                     float(d.get("sim_accesses", 0))))
    if store is not None and rows:
        try:
            store.record_many(rows)
        except CalibrationCorruptError:
            raise
        except OSError as e:  # calibration is best-effort; the sweep is not
            _LOG.warning("calibration update failed (%s): %s", store.path, e)


# ---------------------------------------------------------------------------
# Bootstrap ingesters + garbage collection.
# ---------------------------------------------------------------------------


def ingest_bench_history(store: CalibrationStore, path) -> int:
    """Seed the table from recorded BENCH_sweep.json rows matching the
    store's device kind.  Returns the number of rates ingested."""
    path = pathlib.Path(path)
    if not path.exists():
        return 0
    hist = json.loads(path.read_text()).get("history", [])
    return ingest_bench_entries(store, hist)


def ingest_bench_entries(store: CalibrationStore, entries: Iterable[dict]) -> int:
    """Record the per-backend rates implied by BENCH_sweep.json-shaped
    entries (``kernel_bench`` feeds its freshly measured rows through here —
    the mechanism by which a CPU host learns the batched scan beats
    ``pallas_interpret``).  Entries whose ``device_kind`` differs from the
    store's are skipped.  Returns the number of rates ingested."""
    rows: List[Tuple[str, str, int, float, float]] = []
    for e in entries:
        if e.get("device_kind") != store.device_kind:
            continue
        bench = e.get("bench", "sweep")
        n_acc = float(e.get("n_accesses", 0) or 0)
        if bench == "sweep":
            batch = int(e.get("n_configs", 1) or 1)
            sim = n_acc * batch
            pairs = [("reference", e.get("t_reference_s")),
                     ("stackdist", e.get("t_stackdist_s")),
                     ("pallas", e.get("t_pallas_s"))]
            engine = "sweep_tlb"
        elif bench == "timeline":
            batch, sim, engine = 1, n_acc, "sweep_timeline"
            pairs = [("reference", e.get("t_reference_s")),
                     (e.get("mode", "pallas_interpret"), e.get("t_pallas_s"))]
        elif bench == "timeline_batched":
            batch = int(e.get("n_sims", 1) or 1)
            sim, engine = n_acc * batch, "sweep_timeline"
            pairs = [("reference", e.get("t_batched_s")),
                     (e.get("mode", "pallas_interpret"), e.get("t_pallas_s"))]
        elif bench == "system_batched":
            batch = int(e.get("n_configs", 1) or 1)
            sim, engine = n_acc * batch, "sweep_system"
            pairs = [("reference", e.get("t_batched_s")),
                     (e.get("mode", "pallas_interpret"), e.get("t_pallas_s"))]
        else:
            continue
        for mode, secs in pairs:
            if mode and secs and sim > 0:
                rows.append((engine, mode, batch, sim / float(secs), sim))
    store.record_many(rows)
    return len(rows)


def ingest_runlogs(store: CalibrationStore, paths: Iterable) -> int:
    """Seed the table from orchestrator ``chunk`` spans in telemetry run
    logs (only logs whose ``run_start`` device matches the store's device
    kind).  Returns the number of rates ingested."""
    rows: List[Tuple[str, str, int, float, float]] = []
    for p in paths:
        p = pathlib.Path(p)
        if not p.exists():
            continue
        device_ok = False
        for i, line in enumerate(p.read_text(encoding="utf-8").splitlines()):
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue  # torn tail from a crashed writer
            if rec.get("kind") == "run_start":
                dev = (rec.get("meta") or {}).get("device") or {}
                device_ok = dev.get("device_kind") == store.device_kind
            if not device_ok or rec.get("kind") != "span" \
                    or rec.get("name") != "chunk":
                continue
            a = rec.get("attrs") or {}
            rate = a.get("sim_accesses_per_s")
            engine, mode = a.get("engine"), a.get("mode")
            batch = int(a.get("configs", 1) or 1)
            sim = float(a.get("accesses", 0) or 0) * batch
            if engine in ENGINES and mode in SWEEP_MODES and mode != "auto" \
                    and rate and sim > 0:
                rows.append((engine, mode, batch, float(rate), sim))
    store.record_many(rows)
    return len(rows)


def gc_calibration(root, *, age_s: float = 7 * 86400.0,
                   now: Optional[float] = None,
                   dry_run: bool = False) -> dict:
    """Sweep stale calibration tables (and orphaned temp files) under
    ``root``.  A file is deleted only when it is older than ``age_s`` AND
    its header identifies it as a :data:`CALIB_FORMAT` table — unrecognized
    files are reported in ``skipped_foreign`` and never touched (the
    checkpoint-GC policy: never delete data you did not write)."""
    root = pathlib.Path(root)
    now = time.time() if now is None else now
    summary = {"deleted": [], "kept_young": [], "skipped_foreign": [],
               "dry_run": dry_run}
    if not root.exists():
        return summary

    def delete(p: pathlib.Path) -> None:
        summary["deleted"].append(str(p))
        if not dry_run:
            try:
                p.unlink()
            except OSError:
                pass

    for p in sorted(root.iterdir()):
        if not p.is_file():
            continue
        try:
            age = now - p.stat().st_mtime
        except OSError:
            continue
        if ".tmp-" in p.name or p.suffix == ".lock":
            if age > age_s:
                delete(p)
            else:
                summary["kept_young"].append(str(p))
            continue
        if age <= age_s:
            summary["kept_young"].append(str(p))
            continue
        try:
            head = p.open("rb").read(len(CALIB_FORMAT))
        except OSError:
            continue
        if head == CALIB_FORMAT.encode():
            delete(p)
        else:
            summary["skipped_foreign"].append(str(p))
    return summary
