"""Analytical CPI / timeline performance model (paper §6.3, Figs 3, 9, 10).

The paper models accelerators as simple in-order cores and composes per-access
latency from the Fig 3 timelines using measured hit rates.  This module takes
:class:`repro.core.tlbsim.SystemEvents` (cache / accelerator-TLB /
memory-side-TLB hit rates from the joint trace simulation) plus
:class:`repro.core.sparta.SystemLatencies` and produces:

* average cycles per memory access,
* *translation overhead* cycles per access (the quantity SPARTA reduces
  by 31.5x on average, up to 47x — claim C6),
* end-to-end speedup over the conventional 4 KB baseline (Fig 10),

for the four designs: ``conventional``, ``sparta``, ``dipta`` and ``ideal``.

Timeline composition (virtual-cache accelerator, the Fig 10 setup):

conventional  cache miss => probe accel TLB; on TLB miss walk the page table
              (1 memory reference — perfect MMU caches, the paper's
              conservative baseline) over the network *before* the data
              fetch round trip can begin.
sparta        cache miss => route by partition hash; translation runs at the
              partition overlapped with the row fetch.  Exposed overhead is
              only the memory-side TLB probe, plus one *local* DRAM access
              for the PTE on a memory-side TLB miss.
dipta         set-associative VM with way prediction: correct prediction
              fully overlaps; a misprediction pays an extra serialized DRAM
              access (paper §7.7).
ideal         zero translation overhead.

Every ``AccessTimes`` here is the exact mean of a per-access composition, so
the cycle-approximate timeline engine (:mod:`repro.core.timeline`) degrades
to this module when its queueing is disabled; use the timeline engine for
latency *distributions* and contention in time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.sparta import SystemLatencies
from repro.core.tlbsim import SystemEvents

# Way-prediction accuracy for DIPTA (paper §7.7: >90% for Hash Table, lower
# elsewhere; exact per-workload numbers are not published — assumption logged
# in EXPERIMENTS.md).
DIPTA_WAY_PREDICTION_ACCURACY: Dict[str, float] = {
    "hash_table": 0.92,   # paper: >90% for Hash Table
    "bst_internal": 0.55,  # pointer chases defeat address-locality way predictors
    "bst_external": 0.55,
    "skip_list": 0.45,     # worst spatial locality of the suite
    "rocksdb": 0.70,
    "multiprog": 0.50,     # paper: needs 16 ways to avoid faults
}


@dataclasses.dataclass(frozen=True)
class AccessTimes:
    """Average per-memory-access timing decomposition (cycles)."""

    total: float              # cache probe + fetch + translation overhead
    translation_overhead: float
    fetch: float              # translation-free component

    @property
    def overhead_fraction(self) -> float:
        return self.translation_overhead / max(self.total, 1e-12)


def _fetch_time(ev: SystemEvents, lat: SystemLatencies) -> float:
    """Translation-free access time: cache probe + miss => full data path."""
    h_c = ev.cache_hit_ratio
    data_path = 2.0 * lat.t_net + lat.l_dram
    return lat.l_cache + (1.0 - h_c) * data_path


def conventional_access(ev: SystemEvents, lat: SystemLatencies) -> AccessTimes:
    """Virtual cache + accelerator TLB + (perfect-MMU-cache) page walks."""
    h_c = ev.cache_hit_ratio
    # Accel TLB is probed only on cache misses in the virtual-cache baseline,
    # so the walk term must be conditioned on the cache-miss stream:
    # (1-h_c) * (1-h_t|miss) == P(cache miss AND TLB miss), which makes this
    # average exactly the mean of the per-access Fig 3 composition (the
    # timeline engine reproduces it access by access — tests/test_timeline.py).
    h_t = ev.accel_tlb_hit_ratio_given_cache_miss()
    walk = 2.0 * lat.t_net + lat.l_dram  # one memory reference, over the network
    overhead = (1.0 - h_c) * (lat.l_tlb + (1.0 - h_t) * walk)
    fetch = _fetch_time(ev, lat)
    return AccessTimes(total=fetch + overhead, translation_overhead=overhead, fetch=fetch)


def sparta_access(
    ev: SystemEvents,
    lat: SystemLatencies,
    *,
    physical_cache: bool = False,
) -> AccessTimes:
    """SPARTA: memory-side translation overlapped with the data fetch.

    Virtual cache (default): no accelerator-side translation hardware at all.
    Physical cache: a tiny accel-side TLB must cover cache *hits*; an accel
    TLB miss on a cache hit stalls for a memory-side PTE fetch (Fig 9).
    """
    h_c = ev.cache_hit_ratio
    h_m = ev.mem_tlb_hit_ratio_given_cache_miss()
    fetch = _fetch_time(ev, lat)
    # Exposed overhead on a cache miss: mem-TLB probe + local PTE read on miss.
    miss_side = (1.0 - h_c) * (lat.l_tlb + (1.0 - h_m) * lat.l_dram)
    if not physical_cache:
        return AccessTimes(total=fetch + miss_side, translation_overhead=miss_side, fetch=fetch)
    # Physical cache: every access probes the tiny accel TLB (l_tlb).  A cache
    # hit whose translation is absent must fetch the PTE from the memory side
    # (full network round trip + mem TLB probe / local walk).  Conditioning on
    # the cache-hit stream keeps h_c * (1-h_a|hit) == P(cache hit AND TLB miss).
    h_a = ev.accel_tlb_hit_ratio_given_cache_hit()
    pte_fetch = 2.0 * lat.t_net + lat.l_tlb + (1.0 - h_m) * lat.l_dram
    overhead = lat.l_tlb + h_c * (1.0 - h_a) * pte_fetch + miss_side
    return AccessTimes(total=fetch + overhead, translation_overhead=overhead, fetch=fetch)


def dipta_access(ev: SystemEvents, lat: SystemLatencies, way_accuracy: float) -> AccessTimes:
    """Idealised DRAM-based DIPTA (no DRAM capacity overhead, §7.7)."""
    h_c = ev.cache_hit_ratio
    # A way misprediction wastes the speculative way read and serialises a
    # second DRAM access (correct way after the page-table check): ~2x tRC.
    overhead = (1.0 - h_c) * (1.0 - way_accuracy) * 2.0 * lat.l_dram
    fetch = _fetch_time(ev, lat)
    return AccessTimes(total=fetch + overhead, translation_overhead=overhead, fetch=fetch)


def ideal_access(ev: SystemEvents, lat: SystemLatencies) -> AccessTimes:
    fetch = _fetch_time(ev, lat)
    return AccessTimes(total=fetch, translation_overhead=0.0, fetch=fetch)


@dataclasses.dataclass(frozen=True)
class PerfResult:
    """Per-(workload, design) performance summary."""

    cycles_per_instr: float
    access: AccessTimes

    def speedup_over(self, base: "PerfResult") -> float:
        return base.cycles_per_instr / self.cycles_per_instr


def cycles_per_instruction(
    access: AccessTimes,
    *,
    instr_per_access: float,
    base_cpi: float = 1.0,
) -> PerfResult:
    """In-order accelerator CPI: execution + amortised memory time."""
    f_mem = 1.0 / max(instr_per_access, 1e-9)
    return PerfResult(
        cycles_per_instr=base_cpi + f_mem * access.total,
        access=access,
    )


def evaluate_design(
    design: str,
    ev: SystemEvents,
    lat: SystemLatencies,
    *,
    instr_per_access: float,
    workload: str = "",
    physical_cache: bool = False,
) -> PerfResult:
    if design == "conventional":
        acc = conventional_access(ev, lat)
    elif design == "sparta":
        acc = sparta_access(ev, lat, physical_cache=physical_cache)
    elif design == "dipta":
        acc = dipta_access(ev, lat, DIPTA_WAY_PREDICTION_ACCURACY.get(workload, 0.75))
    elif design == "ideal":
        acc = ideal_access(ev, lat)
    else:
        raise ValueError(f"unknown design {design!r}")
    return cycles_per_instruction(acc, instr_per_access=instr_per_access)
