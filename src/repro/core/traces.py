"""Synthetic memory-trace generators for the paper's workloads (Table 2).

The paper evaluates SPARTA with trace-driven functional simulation of index
traversal workloads from ASCYLIB (128 GB footprints) plus RocksDB (16 GB).
We reproduce that methodology with *synthetic* trace generators that model
the documented locality character of each data structure:

* ``hash_table``   — bucket array + chained nodes; near-uniform, no reuse.
* ``bst_internal`` — root-to-leaf pointer chase over a level-ordered tree;
                     extreme reuse at the top levels, uniform at the bottom.
* ``bst_external`` — like the internal BST but keys/values live only in
                     (larger) leaves; internal nodes are slimmer.
* ``skip_list``    — tower traversal; nodes are *scattered* by allocation
                     order, so even the few high-tower nodes exhibit no
                     spatial locality (the paper notes skip lists have the
                     worst locality and a footprint slightly above 128 GB).
* ``rocksdb``      — Zipfian point lookups over SST blocks + memtable
                     (skip-list) probes + occasional sequential range scans.
* ``multiprog``    — 4 x 32 GB instances of the four index workloads in
                     disjoint address ranges, interleaved round-robin.

Traces are streams of **64-byte cache-line addresses** (int64).  One trace
feeds every simulator in :mod:`repro.core.tlbsim`: the accelerator cache is
probed with the line address, a 4 KB-page TLB with ``line >> 6`` and a 2 MB
TLB with ``line >> 15``.

Everything is vectorised numpy; generation of a few million accesses takes
well under a second per workload.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

LINE_SHIFT = 6  # 64-byte cache lines
LINES_PER_4K = 1 << (12 - LINE_SHIFT)
LINES_PER_2M = 1 << (21 - LINE_SHIFT)

GIB = 1 << 30

# Cache-line addresses above 2^52 lines (2^58 bytes) exceed any virtual
# address space the simulators model and almost certainly indicate a units
# bug (bytes where lines were meant, or float contamination).
MAX_LINE_ADDR = 1 << 52

WORKLOADS = (
    "hash_table",
    "bst_internal",
    "bst_external",
    "skip_list",
    "rocksdb",
    "multiprog",
)

# Instructions executed per memory access for the CPI model (§6.3): pointer
# chases execute a handful of compare/branch instructions between loads.
INSTR_PER_ACCESS: Dict[str, float] = {
    "hash_table": 6.0,
    "bst_internal": 5.0,
    "bst_external": 5.0,
    "skip_list": 4.0,
    "rocksdb": 8.0,
    "multiprog": 5.0,
}


def validate_lines(lines: np.ndarray, *, name: str = "trace") -> np.ndarray:
    """Strictly validate a stream of cache-line addresses.

    Rejects the inputs that would otherwise surface as garbage miss ratios
    deep inside a sweep: zero-length streams, NaN/non-integral floats,
    negative addresses, and addresses above ``MAX_LINE_ADDR`` (2^52 lines).
    Returns the stream as a 1-D int64 array.  Every error names the offending
    trace and the first bad index so the fix is at load time, not mid-sweep.
    """
    arr = np.asarray(lines)
    if arr.ndim != 1:
        raise ValueError(
            f"{name}: trace must be a 1-D stream of line addresses, "
            f"got shape {arr.shape}")
    if arr.shape[0] == 0:
        raise ValueError(
            f"{name}: zero-length trace — nothing to simulate; check "
            f"n_ops / max_accesses / interleave truncation upstream")
    if np.issubdtype(arr.dtype, np.floating):
        bad = ~np.isfinite(arr)
        if bad.any():
            i = int(np.argmax(bad))
            raise ValueError(
                f"{name}: non-finite address at index {i} ({arr[i]!r}); "
                f"traces must be integer cache-line addresses")
        if not np.array_equal(arr, np.floor(arr)):
            i = int(np.argmax(arr != np.floor(arr)))
            raise ValueError(
                f"{name}: non-integral address at index {i} ({arr[i]!r}); "
                f"traces must be integer cache-line addresses")
        arr = arr.astype(np.int64)
    elif not np.issubdtype(arr.dtype, np.integer):
        raise ValueError(
            f"{name}: trace dtype {arr.dtype} is not an address type; "
            f"expected integer cache-line addresses")
    if arr.min() < 0:
        i = int(np.argmax(arr < 0))
        raise ValueError(
            f"{name}: negative address at index {i} ({int(arr[i])}); "
            f"line addresses must be non-negative")
    if arr.max() > MAX_LINE_ADDR:
        i = int(np.argmax(arr > MAX_LINE_ADDR))
        raise ValueError(
            f"{name}: address at index {i} ({int(arr[i])}) exceeds 2^52 "
            f"lines — bytes passed where line addresses were expected?")
    return arr.astype(np.int64, copy=False)


@dataclasses.dataclass(frozen=True)
class Trace:
    """A stream of cache-line addresses plus workload metadata.

    Construction validates the stream (:func:`validate_lines`) so bad inputs
    fail here, at load time, with an actionable error."""

    name: str
    lines: np.ndarray  # int64 [N] cache-line addresses
    footprint_bytes: int

    def __post_init__(self):
        object.__setattr__(
            self, "lines", validate_lines(self.lines, name=self.name))

    @property
    def num_accesses(self) -> int:
        return int(self.lines.shape[0])

    def vpns(self, page_shift: int = 12) -> np.ndarray:
        """Virtual page numbers at the given page size."""
        return self.lines >> (page_shift - LINE_SHIFT)

    @property
    def instr_per_access(self) -> float:
        return INSTR_PER_ACCESS.get(self.name, 5.0)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """Cheap stateless scrambler used to scatter node ids over the heap."""
    x = (x + np.int64(-7046029254386353131)).astype(np.uint64)  # 0x9E3779B97F4A7C15
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    x = x ^ (x >> np.uint64(31))
    return x


def _scatter(ids: np.ndarray, space_lines: int, salt: int) -> np.ndarray:
    """Map structured ids to pseudo-random line addresses in [0, space)."""
    return (_splitmix64(ids.astype(np.int64) + np.int64(salt * 0x51_7C_C1)) % np.uint64(space_lines)).astype(np.int64)


# ---------------------------------------------------------------------------
# Individual workload generators.  Each returns int64 line addresses in
# [0, footprint_lines).
# ---------------------------------------------------------------------------

def _gen_hash_table(rng: np.random.Generator, n_ops: int, footprint_lines: int,
                    zipf_keys: float = 0.0, tslice=(0.0, 1.0)) -> np.ndarray:
    """Bucket array (25% of footprint) + chained nodes (75%).

    ``zipf_keys`` > 1 draws bucket indices from a Zipf popularity law
    (memcached-style hot keys) instead of uniform — used by the Fig 2
    footprint sweep where absolute hot-set size vs TLB reach matters."""
    bucket_lines = footprint_lines // 4
    heap_lines = footprint_lines - bucket_lines
    lo_b, hi_b = int(tslice[0] * bucket_lines), max(int(tslice[1] * bucket_lines), 1)
    if zipf_keys > 1.0:
        ranks = rng.zipf(zipf_keys, size=n_ops).astype(np.int64) - 1
        buckets = lo_b + _scatter(ranks.clip(max=bucket_lines - 1), hi_b - lo_b, salt=23)
        # Hot keys point at hot chain nodes too (correlated placement).
        hot_nodes = True
    else:
        buckets = rng.integers(lo_b, hi_b, size=n_ops, dtype=np.int64)
        hot_nodes = False
    # Chain length ~ geometric, mean ~1.5 node probes per lookup.
    chain = 1 + rng.geometric(0.67, size=n_ops).astype(np.int64).clip(max=4) - 1
    max_chain = int(chain.max(initial=1))
    lo_h = int(tslice[0] * heap_lines)
    hi_h = max(int(tslice[1] * heap_lines), lo_h + 1)
    if hot_nodes:
        # Chain nodes hash off the (zipf-popular) bucket: key popularity
        # carries over to node placement reuse.
        node_probe = lo_h + _scatter(
            (buckets[:, None] * 7 + np.arange(max_chain)[None, :]).ravel(),
            hi_h - lo_h, salt=29,
        ).reshape(n_ops, max_chain) + bucket_lines
    else:
        node_probe = rng.integers(lo_h, hi_h, size=(n_ops, max_chain), dtype=np.int64) + bucket_lines
    b2 = buckets[:, None]
    _op_reuse(rng, [b2, node_probe, chain[:, None]])
    buckets = b2[:, 0]
    chain = chain.copy()
    cols = np.arange(max_chain)[None, :]
    keep = cols < np.maximum(chain, 1)[:, None]
    # Interleave bucket probe then its chain probes, preserving per-op order.
    seq = np.concatenate([buckets[:, None], np.where(keep, node_probe, -1)], axis=1).ravel()
    return seq[seq >= 0]




def _op_reuse(rng: np.random.Generator, rows: "list[np.ndarray]", p: float = 0.3,
              window: int = 64) -> None:
    """Temporal key reuse: with probability ``p`` an op repeats a recent op
    (same path / same key), drawn uniformly from the last ``window`` ops.
    Real server traces re-touch recent keys (sessions, retries, read-modify-
    write); independent draws would understate single-thread TLB hit rates.
    Applied IN PLACE to parallel [n_ops, ...] matrices of one generator."""
    n = rows[0].shape[0]
    reuse = rng.random(n) < p
    back = rng.integers(1, window + 1, size=n)
    src = np.maximum(np.arange(n) - back, 0)
    # Resolve chains (a reuse op pointing at another reuse op) one level deep.
    idx = np.where(reuse, src, np.arange(n))
    for r in rows:
        r[reuse] = r[idx[reuse]]


def _tree_levels(total_nodes: int) -> int:
    return max(1, int(np.ceil(np.log2(total_nodes + 1))))


def _gen_bst(
    rng: np.random.Generator,
    n_ops: int,
    footprint_lines: int,
    *,
    external: bool,
    tslice=(0.0, 1.0),
    scatter_nodes: bool = False,
) -> np.ndarray:
    """Level-ordered binary tree pointer chase.

    Level ``l`` occupies a contiguous address range; a lookup touches one
    uniformly-random node per level.  Top levels therefore live in a handful
    of lines/pages reused by every lookup (great locality), while the deep
    levels are effectively uniform (miss-heavy) — exactly the behaviour the
    paper reports for in-memory search trees.
    """
    node_lines = 1  # 64B nodes
    if external:
        # External BST: slim internal nodes over ~1/4 of the footprint and
        # fat (4-line) leaves over the rest.
        internal_lines = footprint_lines // 4
        leaf_lines = footprint_lines - internal_lines
        n_internal = internal_lines // node_lines
        depth = _tree_levels(n_internal)
    else:
        n_internal = footprint_lines // node_lines
        depth = _tree_levels(n_internal)
        internal_lines = footprint_lines
        leaf_lines = 0

    level_sizes = np.minimum(np.int64(1) << np.arange(depth, dtype=np.int64), np.int64(n_internal))
    level_base = np.concatenate([[0], np.cumsum(level_sizes)[:-1]])
    # Clamp cumulative allocation to the internal region.
    level_base = np.minimum(level_base, internal_lines - 1)

    # One uniform node per level per lookup.  A thread slice restricts the
    # walk to its subtree once levels are wide enough (range-partitioned
    # worker threads share the top of the tree, diverge below).
    u = rng.random(size=(n_ops, depth))
    lo, hi = tslice
    wide = level_sizes >= 64
    base_f = np.where(wide, lo * level_sizes, 0.0)
    span_f = np.where(wide, (hi - lo) * level_sizes, level_sizes.astype(float))
    idx = (base_f[None, :] + u * span_f[None, :]).astype(np.int64)
    path = (level_base[None, :] + idx) * node_lines
    path = np.minimum(path, internal_lines - 1)
    if scatter_nodes:
        # Allocation-order placement: every node lands on its own scattered
        # line (no two tree nodes share a page) — the ASCYLIB reality the
        # paper's "minimal data locality" stresses.  Hot nodes stay hot
        # (same scattered address), but page-level reach collapses.
        path = _scatter(path.ravel(), internal_lines, salt=41).reshape(path.shape)
    _op_reuse(rng, [path])

    if external:
        leaf_lo = int(lo * max(leaf_lines - 4, 1))
        leaf_hi = max(int(hi * max(leaf_lines - 4, 1)), leaf_lo + 1)
        leaf = internal_lines + rng.integers(leaf_lo, leaf_hi, size=(n_ops, 1), dtype=np.int64)
        # Touch 2 lines of the 4-line leaf value.
        path = np.concatenate([path, leaf, leaf + 1], axis=1)
    return path.ravel()


def _gen_skip_list(rng: np.random.Generator, n_ops: int, footprint_lines: int,
                   tslice=(0.0, 1.0)) -> np.ndarray:
    """Skip-list tower traversal with allocation-order scattered nodes.

    There are N/2^l nodes of height >= l, but because nodes are allocated in
    insertion order their addresses are scattered: we map (level, node-id)
    through a stateless hash.  Footprint runs slightly above the nominal
    size (paper §7.3 notes Skip Lists exceed 128 GB).
    """
    space = int(footprint_lines * 1.02)
    n_nodes = footprint_lines  # one line per node
    max_level = _tree_levels(n_nodes)
    levels = np.arange(max_level - 1, -1, -1, dtype=np.int64)  # high -> low
    nodes_at = np.maximum(n_nodes >> (max_level - 1 - np.arange(max_level)), 1)[::-1].copy()
    # ~2 probes per level during search.
    probes_per_level = 2
    u = rng.random(size=(n_ops, max_level, probes_per_level))
    lo, hi = tslice
    counts = nodes_at[::-1].astype(float)
    wide = counts >= 64
    base_f = np.where(wide, lo * counts, 0.0)
    span_f = np.where(wide, (hi - lo) * counts, counts)
    ids = (base_f[None, :, None] + u * span_f[None, :, None]).astype(np.int64)
    _op_reuse(rng, [ids])
    lvl = levels[None, :, None]
    addr = _scatter((ids * np.int64(64) + lvl).ravel(), space, salt=11)
    return addr


def _gen_rocksdb(rng: np.random.Generator, n_ops: int, footprint_lines: int) -> np.ndarray:
    """Zipf point lookups over SST blocks + memtable probes + range scans."""
    # Regions: memtable skip-list (2%), block index (2%), SST data (96%).
    mem_lines = max(footprint_lines // 50, 1)
    idx_lines = max(footprint_lines // 50, 1)
    data_base = mem_lines + idx_lines
    data_lines = footprint_lines - data_base
    n_blocks = max(data_lines // LINES_PER_4K, 1)

    # Zipf block popularity (s ~= 0.99) via inverse-CDF on a truncated zipf.
    ranks = rng.zipf(1.2, size=n_ops).astype(np.int64)
    blocks = (ranks - 1).clip(max=n_blocks - 1)
    # Scatter popular ranks over the physical block space.
    blocks = _scatter(blocks, n_blocks, salt=3)

    # memtable probe: ~4 scattered lines in the memtable region
    mt = _scatter(rng.integers(0, 1 << 40, size=(n_ops, 4), dtype=np.int64).ravel(), mem_lines, salt=5)
    # index probe: 1 line
    ix = mem_lines + _scatter(blocks, idx_lines, salt=7)
    # data block: 2 sequential lines inside the 4 KB block
    off = rng.integers(0, LINES_PER_4K - 1, size=n_ops, dtype=np.int64)
    d0 = data_base + blocks * LINES_PER_4K + off
    seq = np.stack([mt.reshape(n_ops, 4)[:, 0], mt.reshape(n_ops, 4)[:, 1],
                    mt.reshape(n_ops, 4)[:, 2], mt.reshape(n_ops, 4)[:, 3],
                    ix, d0, d0 + 1], axis=1).ravel()

    # 5% of ops are 32-line sequential range scans, each burst inserted at a
    # random position in the point-lookup stream (range reads arrive
    # interleaved with gets in a real server, not as one tail batch).
    n_scan = n_ops // 20
    scan_start = data_base + rng.integers(0, max(data_lines - 32, 1), size=n_scan, dtype=np.int64)
    scans = scan_start[:, None] + np.arange(32)[None, :]
    return _interleave_bursts(seq, scans, rng)


def _interleave_bursts(stream: np.ndarray, bursts: np.ndarray,
                       rng: np.random.Generator) -> np.ndarray:
    """Insert each burst row (kept contiguous, in row order) at a uniformly
    random position of ``stream``, preserving the stream's own order."""
    n_b, blen = bursts.shape
    n = stream.shape[0]
    if n_b == 0:
        return stream
    ip = np.sort(rng.integers(0, n + 1, size=n_b))
    out = np.empty(n + n_b * blen, stream.dtype)
    # Stream element j shifts right by one burst length per burst inserted at
    # or before it; burst k starts at its insertion point plus the k bursts
    # already inserted to its left.
    shift = np.searchsorted(ip, np.arange(n), side="right")
    out[np.arange(n) + blen * shift] = stream
    burst_pos = (ip + blen * np.arange(n_b))[:, None] + np.arange(blen)
    out[burst_pos] = bursts
    return out


_INDEX_GENS = {
    "hash_table": _gen_hash_table,
    "bst_internal": lambda r, n, f: _gen_bst(r, n, f, external=False),
    "bst_external": lambda r, n, f: _gen_bst(r, n, f, external=True),
    "skip_list": _gen_skip_list,
    "rocksdb": _gen_rocksdb,
}


def generate(
    workload: str,
    *,
    n_ops: int = 50_000,
    seed: int = 0,
    footprint_bytes: int = 128 * GIB,
    max_accesses: int | None = None,
    zipf_keys: float = 0.0,
    thread_slice=(0.0, 1.0),
    scatter_nodes: bool = False,
) -> Trace:
    """Generate a trace for one workload.

    ``n_ops`` is the number of *operations* (lookups); each op expands to
    several memory accesses depending on the structure.
    """
    if workload == "multiprog":
        return _generate_multiprog(n_ops=n_ops, seed=seed, footprint_bytes=footprint_bytes)
    if workload not in _INDEX_GENS:
        raise ValueError(f"unknown workload {workload!r}; options: {WORKLOADS}")
    rng = np.random.default_rng(seed)
    footprint_lines = footprint_bytes >> LINE_SHIFT
    gens = {
        "hash_table": lambda: _gen_hash_table(rng, n_ops, footprint_lines, zipf_keys, thread_slice),
        "bst_internal": lambda: _gen_bst(rng, n_ops, footprint_lines, external=False,
                                         tslice=thread_slice, scatter_nodes=scatter_nodes),
        "bst_external": lambda: _gen_bst(rng, n_ops, footprint_lines, external=True,
                                         tslice=thread_slice, scatter_nodes=scatter_nodes),
        "skip_list": lambda: _gen_skip_list(rng, n_ops, footprint_lines, tslice=thread_slice),
        "rocksdb": lambda: _gen_rocksdb(rng, n_ops, footprint_lines),
    }
    lines = gens[workload]().astype(np.int64)
    if max_accesses is not None and lines.shape[0] > max_accesses:
        lines = lines[:max_accesses]
    return Trace(name=workload, lines=lines, footprint_bytes=footprint_bytes)


def _generate_multiprog(*, n_ops: int, seed: int, footprint_bytes: int) -> Trace:
    """4 x 32 GB single-app instances in disjoint ranges, interleaved."""
    per = footprint_bytes // 4
    parts = []
    for i, w in enumerate(("bst_external", "bst_internal", "hash_table", "skip_list")):
        t = generate(w, n_ops=n_ops // 4, seed=seed + 101 * i, footprint_bytes=per)
        parts.append(t.lines + np.int64(i * (per >> LINE_SHIFT)))
    lines = interleave(parts, granularity=8)
    return Trace(name="multiprog", lines=lines, footprint_bytes=footprint_bytes)


def interleave(streams: Sequence[np.ndarray], granularity: int = 1) -> np.ndarray:
    """Round-robin interleave several access streams at ``granularity``.

    Models concurrent threads issuing to a *shared* memory-side TLB (Fig 5 /
    Fig 8).  Streams are truncated to the shortest length (rounded down to a
    multiple of the granularity).
    """
    n = min(s.shape[0] for s in streams)
    n -= n % granularity
    if n == 0:
        raise ValueError("streams too short to interleave")
    stack = np.stack([s[:n].reshape(-1, granularity) for s in streams], axis=1)
    return stack.reshape(-1)


def thread_traces(
    workload: str,
    n_threads: int,
    *,
    n_ops: int = 20_000,
    seed: int = 0,
    footprint_bytes: int = 128 * GIB,
    region_skew: float = 0.5,
) -> List[np.ndarray]:
    """Per-thread traces over the *same shared dataset* (same footprint,
    different op streams) — the Fig 5 thread-contention setup.

    ``region_skew`` models range-partitioned worker threads (the standard
    server pattern): that fraction of each thread's accesses is remapped
    into its own 1/n_threads slice of the footprint, giving every thread a
    private hot set (the source of shared-TLB capacity contention the paper
    measures); the rest touch the shared structure globally."""
    out = []
    for t in range(n_threads):
        if n_threads > 1 and region_skew > 0:
            tslice = (t / n_threads, (t + 1) / n_threads)
        else:
            tslice = (0.0, 1.0)
        out.append(generate(workload, n_ops=n_ops, seed=seed + 997 * t,
                            footprint_bytes=footprint_bytes,
                            thread_slice=tslice, scatter_nodes=True).lines)
    return out
