"""Trustworthy wall-clock measurement for JAX benchmarks.

Every speedup number the repo records flows through :func:`measure`, which
fixes the three classic JAX timing mistakes the original hand-rolled timers
made:

1. **Async dispatch**: JAX returns futures — stopping the clock without
   ``block_until_ready`` on the *result of that rep* can end the measurement
   before the compute finishes.  :func:`measure` blocks inside the timed
   window of every rep (and :func:`block` also traverses plain dataclasses /
   containers, since the sweep engines return numpy-backed result objects
   that are not registered pytrees).
2. **Compile leakage**: the warm-up call must itself be blocked on, or the
   asynchronously-dispatched compile+run can overlap the first timed rep.
3. **Last-of-N**: wall-time noise is one-sided (preemption, GC, lazy page
   faults only ever make a run *slower*), so the honest point statistic is
   the **min** over reps, reported here with the spread so a noisy
   measurement is visible in the record.

:func:`device_metadata` is the companion schema stamp: every recorded
benchmark row carries the device kind / platform / device count / jax
version it was measured on, plus ``schema_version`` so downstream perf
gates (``benchmarks/perfcheck.py``) can tell trustworthy rows from legacy
ones.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Tuple

import jax

# Rows written with `measure()` + `device_metadata()` carry this version.
# Legacy BENCH_sweep.json rows (no schema_version) were recorded with
# non-blocking last-of-N timers and are excluded from perf gating.
SCHEMA_VERSION = 2


def block(x: Any) -> Any:
    """``jax.block_until_ready`` that also traverses plain dataclasses and
    containers (the sweep/timeline engines return frozen dataclasses of
    numpy arrays, which jax treats as opaque leaves)."""
    if x is None:
        return x
    if dataclasses.is_dataclass(x) and not isinstance(x, type):
        for f in dataclasses.fields(x):
            block(getattr(x, f.name))
        return x
    if isinstance(x, (list, tuple)):
        for v in x:
            block(v)
        return x
    if isinstance(x, dict):
        for v in x.values():
            block(v)
        return x
    # Pytrees of jax arrays block; numpy arrays / scalars are no-ops.
    jax.block_until_ready(x)
    return x


@dataclasses.dataclass(frozen=True)
class Measurement:
    """Blocked per-rep wall times (seconds, run order) + the last result."""

    times_s: Tuple[float, ...]
    result: Any = None

    @property
    def best_s(self) -> float:
        return min(self.times_s)

    @property
    def mean_s(self) -> float:
        return sum(self.times_s) / len(self.times_s)

    @property
    def spread_frac(self) -> float:
        """(max - min) / min — 0 for a perfectly stable measurement."""
        lo = self.best_s
        return (max(self.times_s) - lo) / lo if lo > 0 else 0.0

    @property
    def best_us(self) -> float:
        return self.best_s * 1e6


def measure(fn: Callable, *args, reps: int = 5, warmup: int = 1,
            label: str = None, **kwargs) -> Measurement:
    """Min-of-``reps`` wall-clock timing of ``fn(*args, **kwargs)``.

    Blocks until ready on every warm-up call (so compile/dispatch cannot
    leak into the first rep's window) and on every rep's own result *inside*
    its timed window.  Uses ``time.perf_counter`` (monotonic, high
    resolution).

    With ``label`` set, the measurement is also recorded as a ``"measure"``
    span in the active telemetry run (duration = the summed timed reps,
    warm-up excluded; best/mean/spread as span attributes) — no-op when no
    run is active.
    """
    if reps < 1:
        raise ValueError(f"reps must be >= 1, got {reps}")
    for _ in range(warmup):
        block(fn(*args, **kwargs))
    times, res = [], None
    for _ in range(reps):
        t0 = time.perf_counter()
        res = block(fn(*args, **kwargs))
        times.append(time.perf_counter() - t0)
    m = Measurement(times_s=tuple(times), result=res)
    if label is not None:
        from repro.runtime import telemetry

        telemetry.get_tracer().record_span(
            "measure", sum(times), label=label, reps=reps,
            best_s=round(m.best_s, 6), mean_s=round(m.mean_s, 6),
            spread_frac=round(m.spread_frac, 4))
    return m


def device_metadata() -> dict:
    """Schema stamp for a recorded benchmark row: what it was measured on."""
    dev = jax.devices()[0]
    return {
        "schema_version": SCHEMA_VERSION,
        "device_kind": dev.device_kind,
        "platform": dev.platform,
        "device_count": jax.device_count(),
        "jax_version": jax.__version__,
    }
