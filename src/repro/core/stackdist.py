"""Exact sort-based stack-distance engine for set-associative LRU sweeps.

The sequential LRU simulators in :mod:`repro.core.tlbsim` replay a trace one
access at a time — ``N`` scan steps no matter how wide the config batch,
which is the worst possible shape for both XLA and the Pallas TPU path.  For
pure-LRU structures the sequential state is unnecessary: an access hits a
``w``-way set **iff fewer than w distinct tags mapped to that set since the
same tag's previous occurrence** (the classic stack algorithm of
Mattson et al.; the same trace-driven methodology the paper uses in §6.2, and
the standard trick in translation studies that sweep huge design spaces —
Picorel et al. "Near-Memory Address Translation", Kanellopoulos et al.
"Utopia").  So exact per-access hit bits for *every* associativity fall out
of one data-parallel reuse-depth computation per set-mapping.

Pipeline (no O(N)-sequential scan anywhere):

1. **sort by set** (stable numpy argsort — radix, O(N)): the trace becomes
   contiguous per-set segments, trace order preserved inside each segment;
2. **lane-blocked segmented stack scan**: the set-sorted stream is reshaped
   into ``L = N/C`` lanes of ``C`` accesses and all lanes advance capped LRU
   stacks (the ``W`` most-recent distinct tags of the current segment) in
   lock-step — ``C`` sequential steps instead of ``N``.  Cross-lane carry is
   restored by composing per-lane *stack effects* (a short prefix pass over
   lane finals) and re-walking with the true carry-in.  The per-step update
   and the TPU kernel live in :mod:`repro.kernels.stackdist`;
3. **depth -> hits**: an access at stack depth ``d`` hits every ``ways > d``
   geometry sharing the set-mapping, so one pass per (sets, partitions,
   page_shift) bucket serves an entire sweep axis (the grouping layer in
   :mod:`repro.core.sweep` exploits this).

Exactness: a capped stack always equals the first ``W`` entries of the
uncapped LRU stack (recency only deepens, truncated entries never
resurface), and composing capped effects preserves that prefix — so hit bits
are **bit-identical** to :func:`repro.core.tlbsim.simulate_tlb` for every
``ways <= W`` (tests/test_stackdist.py asserts this across the property
grid).  The sequential scans remain the oracle path.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.stackdist import stack_scan

__all__ = [
    "STACKDIST_INF",
    "AUTO_MAX_WAYS",
    "MAX_CAP",
    "prev_occurrence",
    "stack_depths",
    "stack_depths_batched",
    "reuse_distances",
    "hits_from_depths",
]

# "Infinite" reuse distance: the tag was never seen before in its set.
STACKDIST_INF = np.int32(np.iinfo(np.int32).max)

# `auto` prefers the stackdist backend only when every spec's associativity is
# at most this: the scan state is [lanes, W], so huge fully-associative
# geometries would trade the N-step scan for a W-wide one.
AUTO_MAX_WAYS = 16

# Hard cap: beyond this the capped-stack state stops being "small" in the
# sense the engine is built around; use the sequential reference instead.
MAX_CAP = 256

_PAD_TAG = -2  # never matches a real tag (>= 0) nor an empty slot (-1)

# Chunk the (groups x padded-trace) workspace so a wide sweep (e.g. fig4's
# 60 specs) doesn't materialise gigabytes of lane-blocked arrays at once.
_CHUNK_ELEMS = 1 << 25


# ---------------------------------------------------------------------------
# Host-side layout preparation (numpy; cheap radix sorts, no scans).
# ---------------------------------------------------------------------------

def prev_occurrence(set_idx: np.ndarray, tag: np.ndarray) -> np.ndarray:
    """Index of the previous access to the same (set, tag), -1 if none.

    One stable lexsort by (set, tag): equal keys become adjacent in trace
    order, so each access's predecessor is its sorted neighbour.
    """
    n = set_idx.shape[0]
    prev = np.full(n, -1, np.int64)
    if n == 0:
        return prev
    order = np.lexsort((tag, set_idx))
    s, t = set_idx[order], tag[order]
    same = (s[1:] == s[:-1]) & (t[1:] == t[:-1])
    prev[order[1:][same]] = order[:-1][same]
    return prev


def _set_layout(set_idx: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(order, seg_flag): stable set-sort permutation (trace order preserved
    within each set) and segment-start flags in sorted order."""
    n = set_idx.shape[0]
    order = np.argsort(set_idx, kind="stable")  # radix for integer keys
    counts = np.bincount(set_idx)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    seg_flag = np.zeros(n, bool)
    seg_flag[starts[counts > 0]] = True
    return order, seg_flag


# ---------------------------------------------------------------------------
# Stack-effect composition across lanes.
# ---------------------------------------------------------------------------

def _merge_effects(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Stack after running sequence A then sequence B, given each sequence's
    final stack from empty: B's distinct tags (MRU side) followed by A's tags
    not in B, truncated to W.  Safe under capping: dropped entries could only
    ever get deeper."""
    W = a.shape[-1]
    in_b = (a[..., :, None] == b[..., None, :]).any(-1)
    a_kept = jnp.where(in_b | (a < 0), -1, a)
    c = jnp.concatenate([b, a_kept], axis=-1)                  # [..., 2W]
    valid = c >= 0
    pos = jnp.cumsum(valid, axis=-1) - 1
    onehot = (pos[..., None] == jnp.arange(W)) & valid[..., None]
    return jnp.max(jnp.where(onehot, c[..., None], -1), axis=-2)


@jax.jit
def _lane_prefix(finals: jnp.ndarray, has_start: jnp.ndarray) -> jnp.ndarray:
    """Exclusive prefix of lane effects along the lane-block axis.

    finals [G, NB, W] (per-lane final stacks from empty), has_start [G, NB]
    (lane contains a segment start => earlier lanes cannot influence it).
    Returns the carry-in stack for each lane.  NB sequential steps of [G, W]
    work — negligible next to the lane walks.
    """
    G, NB, W = finals.shape

    def step(carry, inp):
        s, f = inp
        new = jnp.where(f[:, None], s, _merge_effects(carry, s))
        return new, carry

    init = jnp.full((G, W), -1, jnp.int32)
    _, carries = jax.lax.scan(
        step, init, (finals.swapaxes(0, 1), has_start.swapaxes(0, 1))
    )
    return carries.swapaxes(0, 1)


# ---------------------------------------------------------------------------
# Core depth computation.
# ---------------------------------------------------------------------------

def _depths_layout(
    tags_l: np.ndarray,       # int32 [G, NP] set-sorted tags, padded
    seg_l: np.ndarray,        # bool  [G, NP] segment starts, padded
    cap: int,
    kernel_mode: str,
    block: int,
) -> np.ndarray:
    """Capped stack depths for G set-sorted (padded) streams, [G, NP]."""
    G, NP = tags_l.shape
    nb = NP // block
    tags_b = jnp.asarray(tags_l.reshape(G * nb, block))
    seg_b = jnp.asarray(seg_l.reshape(G * nb, block))
    empty = jnp.full((G * nb, cap), -1, jnp.int32)
    # Phase 1: per-lane effects from empty; phase 2: re-walk with true carry.
    _, finals = stack_scan(tags_b, seg_b, empty, kernel_mode=kernel_mode)
    carries = _lane_prefix(
        finals.reshape(G, nb, cap),
        jnp.asarray(seg_l.reshape(G, nb, block).any(axis=2)),
    ).reshape(G * nb, cap)
    depths, _ = stack_scan(tags_b, seg_b, carries, kernel_mode=kernel_mode)
    return np.asarray(depths).reshape(G, NP)


def stack_depths_batched(
    set_b: np.ndarray,        # int  [G, N] set-index streams (one per mapping)
    tag_b: np.ndarray,        # int  [G, N] tag streams
    *,
    cap: int,
    kernel_mode: str = "auto",
    block: int = 1024,
) -> np.ndarray:
    """Per-access LRU stack depth (trace order) for G set-mappings at once.

    Returns int32 [G, N]: 0-based depth of each access's tag in its set's
    pre-access LRU stack, or -1 when the tag is not among the ``cap`` most
    recent distinct tags (cold miss, or true distance >= cap).  An access
    hits a ``w``-way set iff ``0 <= depth < w`` for any ``w <= cap``.
    """
    if cap < 1:
        raise ValueError(f"cap={cap}: must be >= 1")
    if cap > MAX_CAP:
        raise ValueError(
            f"cap={cap} exceeds MAX_CAP={MAX_CAP}; the capped-stack engine is "
            "built for small associativities — use the sequential reference "
            "backend for huge fully-associative geometries"
        )
    G, n = set_b.shape
    if n == 0:
        return np.empty((G, 0), np.int32)
    # Tags are carried as int32 with -1 (empty slot) and -2 (padding) as
    # sentinels; anything outside [0, 2^31) would silently alias on the cast.
    if tag_b.min() < 0 or int(tag_b.max()) >= 2**31:
        raise ValueError("tags must be in [0, 2**31) to fit int32 stack slots")
    block = max(32, min(block, 1 << 14))
    n_pad = -(-n // block) * block

    tags_l = np.full((G, n_pad), _PAD_TAG, np.int32)
    seg_l = np.zeros((G, n_pad), bool)
    orders = []
    for g in range(G):
        order, seg = _set_layout(set_b[g])
        tags_l[g, :n] = tag_b[g][order]
        seg_l[g, :n] = seg
        if n_pad > n:
            seg_l[g, n] = True  # padding forms its own throwaway segment
        orders.append(order)

    out = np.empty((G, n), np.int32)
    g_chunk = max(1, min(G, _CHUNK_ELEMS // n_pad))
    for lo in range(0, G, g_chunk):
        hi = min(lo + g_chunk, G)
        tl, sl = tags_l[lo:hi], seg_l[lo:hi]
        if hi - lo < g_chunk and G > g_chunk:
            # Keep the compiled shape stable across chunks: pad the remainder
            # chunk by repeating its last stream (results discarded).
            reps = g_chunk - (hi - lo)
            tl = np.concatenate([tl, np.repeat(tl[-1:], reps, axis=0)])
            sl = np.concatenate([sl, np.repeat(sl[-1:], reps, axis=0)])
        d = _depths_layout(tl, sl, cap, kernel_mode, block)[: hi - lo]
        for g in range(lo, hi):
            out[g, orders[g]] = d[g - lo, :n]
    return out


def stack_depths(
    set_idx: np.ndarray,
    tag: np.ndarray,
    *,
    cap: int,
    kernel_mode: str = "auto",
    block: int = 1024,
) -> np.ndarray:
    """Single-stream :func:`stack_depths_batched`."""
    return stack_depths_batched(
        set_idx[None], tag[None], cap=cap, kernel_mode=kernel_mode, block=block
    )[0]


def hits_from_depths(depths: np.ndarray, ways: int) -> np.ndarray:
    """Hit bits for a ``ways``-way LRU structure (requires ways <= the cap
    the depths were computed with)."""
    return (depths >= 0) & (depths < ways)


def reuse_distances(
    set_idx: np.ndarray,
    tag: np.ndarray,
    *,
    cap: int = AUTO_MAX_WAYS,
    kernel_mode: str = "auto",
    block: int = 1024,
) -> np.ndarray:
    """Exact set-local LRU stack distances, clipped at ``cap``.

    Returns int32 [N]: the number of distinct other tags that mapped to the
    access's set since the same tag's previous occurrence — exact when
    ``< cap``, ``cap`` when the true (finite) distance is >= cap, and
    :data:`STACKDIST_INF` for cold accesses (no previous occurrence, i.e.
    infinite distance).  ``distance < STACKDIST_INF`` iff the access is a
    reuse; ``distance < w`` iff the access hits a w-way set (w <= cap).
    """
    depth = stack_depths(set_idx, tag, cap=cap, kernel_mode=kernel_mode, block=block)
    cold = prev_occurrence(set_idx, tag) < 0
    return np.where(
        depth >= 0, depth, np.where(cold, STACKDIST_INF, np.int32(cap))
    ).astype(np.int32)
