"""Batched multi-configuration sweep engine for the TLB/system simulator.

Every paper figure (Figs 4, 8, 9, 10) sweeps TLB geometries and partition
counts over the *same* trace.  The single-config simulators in
:mod:`repro.core.tlbsim` replay the trace once per configuration; this module
simulates **B configurations in a single pass**:

* geometries are padded to a common ``(max_total_sets, max_ways)`` envelope,
* per-config ``(tags, last)`` LRU state is stacked on a leading config axis
  (mirroring SPARTA's own per-partition-TLB-array state layout, paper §4.2),
* one ``lax.scan`` walks the trace while a vmapped probe updates all configs
  concurrently, so the trace is streamed exactly once per sweep instead of
  once per (trace x config) pair.

Way-padding is made invisible by *poisoning* (see
:func:`repro.core.tlbsim.padded_tlb_state`): the batched results are
**bit-identical** to the per-config oracles :func:`~repro.core.tlbsim.simulate_tlb`
and :func:`~repro.core.tlbsim.simulate_system`, which remain the reference
path (tests/test_sweep.py asserts equivalence).

``kernel_mode`` selects the execution backend for the TLB sweep:

* ``"stackdist"`` — the exact sort-based stack-distance engine
  (:mod:`repro.core.stackdist`): specs are bucketed by set-mapping
  (sets, partitions, page_shift) and ONE data-parallel depth pass per bucket
  yields hit bits for every associativity in it — no per-element sequential
  scan at all.  ``"auto"`` prefers this whenever every spec is a pure-LRU TLB
  with small associativity (:data:`repro.core.stackdist.AUTO_MAX_WAYS`),
  which is every sweep in the paper.
* ``"pallas"`` / ``"pallas_interpret"`` — the batched sequential Pallas TPU
  kernel (``repro.kernels.tlb_sim.tlb_sim_batched``, stacked VMEM scratch,
  trace blocks streamed HBM->VMEM once and shared by all configs).
* ``"reference"`` — the pure-JAX batched scan, the bit-exactness oracle.

The joint system sweep (:func:`sweep_system`) has the same two execution
backends, minus ``"stackdist"``: it is not pure-LRU (cache-hit-conditional
TLB probes break the stack-inclusion property), so requesting the
stack-distance engine raises a ``ValueError`` instead of being silently
ignored (the PR 4 policy).  Its Pallas backend is
``repro.kernels.system_sim.system_sim_batched``: all THREE stacked LRU
structures (cache, accel TLB, partitioned mem TLB) stay resident in VMEM
scratch per config, each trace block streams HBM->VMEM once with all six
(set, tag) key views, and per-config structure presence / probe policy ride
along as data flags; the batched scan oracle lives in
``repro.kernels.system_sim.ref`` (re-exported here as
``_scan_system_batched``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import dispatch, stackdist
from repro.core.sparta import TLBConfig
from repro.core.tlbsim import (
    LINE_SHIFT,
    SystemEvents,
    SystemSimConfig,
    TLBResult,
    _geom,
    _prepare_keys,
    _scan_tlb_batched,
    padded_tlb_state,
)
from repro.kernels.common import resolve_mode
from repro.kernels.system_sim import resolve_system_mode, system_sim_batched
from repro.kernels.system_sim.ref import system_sim_batched_ref as _scan_system_batched
from repro.runtime import telemetry

__all__ = [
    "TLBSweepSpec",
    "BatchedTLBResult",
    "BatchedSystemEvents",
    "TLBSweepStream",
    "SystemSweepStream",
    "sweep_tlb",
    "sweep_system",
]


def _note_envelope(stream) -> None:
    """Telemetry event + gauge describing a stream's VMEM-envelope grouping
    (how the chunker packed the batch, and the carried-state footprint).
    Free when no telemetry run is active."""
    tr = telemetry.get_tracer()
    if not tr.active:
        return
    state = stream.export_state()
    state_bytes = int(sum(v.nbytes for k, v in state.items() if k != "now"))
    tr.event("vmem_envelope", engine=stream.engine,
             configs=stream.batch_size, groups=len(stream.groups),
             group_sizes=[len(g) for g in stream.groups],
             state_bytes=state_bytes, block=stream.block)
    tr.gauge(f"{stream.engine}.state_bytes").set(state_bytes)


def _count_sim_accesses(stream, n: int) -> None:
    """Counters for one committed chunk: trace accesses consumed and
    simulated (config x access) pairs advanced."""
    tr = telemetry.get_tracer()
    if not tr.active:
        return
    tr.counter(f"{stream.engine}.trace_accesses").add(int(n))
    tr.counter(f"{stream.engine}.sim_accesses").add(int(n) * stream.batch_size)


# ---------------------------------------------------------------------------
# TLB sweep.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TLBSweepSpec:
    """One point of a TLB sweep: geometry + partitioning + page size.

    ``page_shift=None`` means the input stream is already a VPN stream;
    otherwise the input is a 64-byte line-address stream and VPNs are derived
    per spec (``lines >> (page_shift - LINE_SHIFT)``), so 4 KB and 2 MB
    configs can ride in one batch.
    """

    cfg: TLBConfig
    num_partitions: int = 1
    page_shift: Optional[int] = None

    @property
    def geometry(self) -> Tuple[int, int]:
        """(total_sets, ways) of the simulated structure."""
        sets, ways = _geom(self.cfg)
        return sets * self.num_partitions, ways


@dataclasses.dataclass(frozen=True)
class BatchedTLBResult:
    """Per-access hit bits for B configs sharing one trace."""

    hits: np.ndarray   # bool [B, N] (full stream, incl. warmup)
    n_warm: int

    def __len__(self) -> int:
        return self.hits.shape[0]

    def __getitem__(self, i: int) -> TLBResult:
        return TLBResult(hits=self.hits[i], n_warm=self.n_warm)

    @property
    def miss_ratios(self) -> np.ndarray:
        """Post-warmup miss ratio per config, [B]."""
        w = self.hits[:, self.hits.shape[1] - self.n_warm:]
        if w.shape[1] == 0:
            return np.ones(self.hits.shape[0])
        return 1.0 - w.mean(axis=1)


# Per-core VMEM is ~16 MB on current TPUs; cap the stacked scratch state
# (2 x B x S x W x int32) well below that and chunk the batch when a sweep's
# padded envelope would not fit.  Chunks still stream the trace once each.
_VMEM_STATE_BUDGET_BYTES = 8 * 1024 * 1024


def _keys_for_mapping(
    addrs: np.ndarray, sets: int, num_partitions: int, page_shift: Optional[int]
) -> Tuple[np.ndarray, np.ndarray]:
    """(set, tag) streams for one set-mapping — the single address-to-key rule
    every sweep backend shares (bit-identity depends on it)."""
    vpns = addrs if page_shift is None else addrs >> (page_shift - LINE_SHIFT)
    return _prepare_keys(vpns, sets, num_partitions)


def _sweep_keys(
    addrs: np.ndarray, specs: Sequence[TLBSweepSpec]
) -> Tuple[np.ndarray, np.ndarray]:
    """Stacked [B, N] (set, tag) streams, one row per spec."""
    rows = [_keys_for_mapping(addrs, *_mapping_key(sp)) for sp in specs]
    return np.stack([r[0] for r in rows]), np.stack([r[1] for r in rows])


def sweep_tlb(
    addrs: np.ndarray,
    specs: Sequence[TLBSweepSpec],
    *,
    warmup_frac: float = 0.25,
    kernel_mode: str = "auto",
    block: int = 512,
) -> BatchedTLBResult:
    """Simulate every spec on one address stream in a single trace pass.

    ``addrs`` is a VPN stream for specs with ``page_shift=None`` and a line
    stream otherwise (mixing both in one batch is a caller error).  Results
    are bit-identical to calling :func:`repro.core.tlbsim.simulate_tlb` once
    per spec.
    """
    if not specs:
        raise ValueError("sweep_tlb needs at least one spec")
    shifted = [sp.page_shift is not None for sp in specs]
    if any(shifted) and not all(shifted):
        raise ValueError(
            "sweep_tlb batch mixes page_shift=None (VPN-stream) specs with "
            "page_shift-set (line-stream) specs; one input stream cannot be both"
        )
    # Backend selection is the dispatch layer's job; a bare (unorchestrated)
    # call makes a cold-start decision — the orchestrator passes calibrated,
    # already-concrete modes down to the streams instead.
    mode = dispatch.decide_tlb(
        kernel_mode, specs, n_accesses=len(addrs)).mode
    if mode == "stackdist":
        hits = _sweep_tlb_stackdist(addrs, specs)
        n0 = int(hits.shape[1] * warmup_frac)
        return BatchedTLBResult(hits=hits, n_warm=hits.shape[1] - n0)
    set_b, tag_b = _sweep_keys(addrs, specs)
    geoms = [sp.geometry for sp in specs]
    total_sets = max(g[0] for g in geoms)
    ways = max(g[1] for g in geoms)
    valid_ways = tuple(g[1] for g in geoms)

    n = set_b.shape[1]
    if mode == "reference":
        hits = np.asarray(
            _scan_tlb_batched(jnp.asarray(set_b), jnp.asarray(tag_b), total_sets, ways, valid_ways)
        )
    else:
        from repro.kernels.tlb_sim import tlb_sim_batched

        pad = (-n) % min(block, n)
        hits = np.empty((len(specs), n), dtype=bool)
        for chunk in _vmem_chunks(geoms, block=min(block, n)):
            c_sets = max(geoms[i][0] for i in chunk)
            c_ways = max(geoms[i][1] for i in chunk)
            s_c, t_c = set_b[chunk], tag_b[chunk]
            if pad:
                # The kernel streams whole blocks; park padding accesses in an
                # extra set row (index c_sets) that no real config ever
                # indexes, then drop their hit bits.
                s_c = np.pad(s_c, ((0, 0), (0, pad)), constant_values=c_sets)
                t_c = np.pad(t_c, ((0, 0), (0, pad)), constant_values=0)
            hits[chunk] = np.asarray(
                tlb_sim_batched(
                    jnp.asarray(s_c), jnp.asarray(t_c),
                    c_sets + (1 if pad else 0), c_ways,
                    tuple(geoms[i][1] for i in chunk),
                    block=block, kernel_mode=mode,
                )
            )[:, :n]
    n0 = int(n * warmup_frac)
    return BatchedTLBResult(hits=hits, n_warm=n - n0)


def envelope_chunks(
    dims: Sequence[Tuple[int, ...]],
    state_elems,
    *,
    stream_words: int,
    budget_bytes: int,
) -> list:
    """Greedy VMEM chunker shared by every batched engine (TLB sweep here,
    timeline sweep in :mod:`repro.core.timeline`): partition item indices so
    each chunk's scratch footprint — per-item state on the chunk's
    elementwise-max envelope (``state_elems(dims)`` 4-byte words) plus the
    streamed trace columns (``stream_words`` per item) — fits the budget.

    Sorting by padded footprint groups like-sized configurations, so a few
    huge items don't inflate the envelope of every small one.  A chunk always
    takes at least one item.
    """
    order = sorted(range(len(dims)), key=lambda i: state_elems(dims[i]))
    chunks, cur = [], []
    env: Tuple[int, ...] = ()
    for i in order:
        new_env = dims[i] if not cur else tuple(map(max, env, dims[i]))
        vmem_bytes = (state_elems(new_env) + stream_words) * (len(cur) + 1) * 4
        if cur and vmem_bytes > budget_bytes:
            chunks.append(cur)
            cur, new_env = [], dims[i]
        cur.append(i)
        env = new_env
    chunks.append(cur)
    return chunks


def _vmem_chunks(geoms: Sequence[Tuple[int, int]], *, block: int = 512) -> list:
    """TLB-sweep instantiation of :func:`envelope_chunks`: stacked LRU state
    is 2 x (sets + 1) x ways int32 per config (+1 set row because
    trace-padding accesses may get parked there) and each config streams
    3 x block words (set/tag/hit)."""
    return envelope_chunks(
        geoms, lambda g: 2 * (g[0] + 1) * g[1],
        stream_words=3 * block, budget_bytes=_VMEM_STATE_BUDGET_BYTES)


class TLBSweepStream:
    """Resumable chunked execution of :func:`sweep_tlb` (minus the
    non-chunkable ``"stackdist"`` backend).

    The stream owns the carried per-config LRU state; each
    :meth:`run_chunk` call advances every config through one slice of the
    address stream and returns that slice's hit bits.  Feeding the chunks of
    a trace in order is **bit-identical** to one monolithic
    :func:`sweep_tlb` call — in any backend, and across backend *changes* at
    chunk boundaries (the orchestrator's degradation ladder): the batch is
    always grouped by the Pallas VMEM envelope (:func:`_vmem_chunks`) and
    every group's state always allocates the spare parked set row, so the
    state layout is independent of the mode a chunk happens to run in.

    :meth:`export_state` / :meth:`import_state` round-trip the carried state
    through plain numpy arrays (the checkpoint payload of
    :mod:`repro.core.orchestrator`).
    """

    engine = "sweep_tlb"

    def __init__(self, specs: Sequence[TLBSweepSpec], *, block: int = 512):
        if not specs:
            raise ValueError("TLBSweepStream needs at least one spec")
        shifted = [sp.page_shift is not None for sp in specs]
        if any(shifted) and not all(shifted):
            raise ValueError(
                "TLBSweepStream batch mixes page_shift=None (VPN-stream) specs "
                "with page_shift-set (line-stream) specs; one input stream "
                "cannot be both")
        self.specs = tuple(specs)
        self.block = int(block)
        self._geoms = [sp.geometry for sp in self.specs]
        self.groups = _vmem_chunks(self._geoms, block=self.block)
        self._state = []
        for g in self.groups:
            sets = max(self._geoms[i][0] for i in g)
            ways = max(self._geoms[i][1] for i in g)
            valid = tuple(self._geoms[i][1] for i in g)
            # One spare parked set row (index `sets`) in every mode, so a
            # chunk may be block-padded mid-stream without observable effect.
            self._state.append(padded_tlb_state(len(g), sets + 1, ways, valid))
        self.now = 0
        _note_envelope(self)

    @property
    def batch_size(self) -> int:
        return len(self.specs)

    def fingerprint(self) -> dict:
        """JSON-able identity of the stream's layout: a checkpoint taken by
        one stream may only be imported by a stream with an equal one."""
        return {
            "engine": self.engine,
            "block": self.block,
            "specs": [[g[0], g[1], sp.num_partitions,
                       sp.page_shift if sp.page_shift is not None else -1]
                      for g, sp in zip(self._geoms, self.specs)],
        }

    def run_chunk(self, addrs: np.ndarray, *, kernel_mode: str = "auto") -> np.ndarray:
        """Advance every config through ``addrs`` (the next trace slice);
        returns hit bits bool [B, len(addrs)].  State commits only after the
        whole chunk computed, so a failed call leaves the stream unchanged
        and the chunk can be retried (possibly in a different mode)."""
        mode = resolve_mode(kernel_mode)
        set_b, tag_b = _sweep_keys(np.asarray(addrs), self.specs)
        n = set_b.shape[1]
        from repro.kernels.tlb_sim import tlb_sim_batched_carry

        hits = np.empty((len(self.specs), n), dtype=bool)
        new_state = []
        for gi, g in enumerate(self.groups):
            h, tags, last = tlb_sim_batched_carry(
                jnp.asarray(set_b[g]), jnp.asarray(tag_b[g]),
                *self._state[gi], self.now,
                block=self.block, kernel_mode=mode)
            hits[g] = np.asarray(h)   # forces the computation (commit gate)
            new_state.append((tags, last))
        self._state = new_state
        self.now += n
        _count_sim_accesses(self, n)
        return hits

    def export_state(self) -> dict:
        out = {"now": np.array([self.now], np.int64)}
        for gi, (tags, last) in enumerate(self._state):
            out[f"g{gi}_tags"] = np.asarray(tags)
            out[f"g{gi}_last"] = np.asarray(last)
        return out

    def import_state(self, arrays: dict) -> None:
        state = []
        for gi in range(len(self.groups)):
            pair = []
            for part in ("tags", "last"):
                key = f"g{gi}_{part}"
                if key not in arrays:
                    raise ValueError(f"{self.engine} state missing array {key!r}")
                arr = np.asarray(arrays[key])
                want = tuple(np.asarray(self._state[gi][0]).shape)
                if tuple(arr.shape) != want:
                    raise ValueError(
                        f"{self.engine} state array {key!r} has shape "
                        f"{tuple(arr.shape)}, expected {want}")
                pair.append(jnp.asarray(arr.astype(np.int32)))
            state.append(tuple(pair))
        self._state = state
        self.now = int(np.asarray(arrays["now"]).reshape(-1)[0])


# ---------------------------------------------------------------------------
# Stack-distance backend: bucket specs by set-mapping, one depth pass each.
# ---------------------------------------------------------------------------

def _mapping_key(sp: TLBSweepSpec) -> Tuple[int, int, Optional[int]]:
    """The (set, tag) stream of a spec depends only on this triple — specs
    differing only in associativity share one stack-depth pass."""
    sets, _ = _geom(sp.cfg)
    return sets, sp.num_partitions, sp.page_shift


def _sweep_tlb_stackdist(addrs: np.ndarray, specs: Sequence[TLBSweepSpec]) -> np.ndarray:
    """Hit bits [B, N] via one stack-depth pass per distinct set-mapping.

    Keys are prepared once per *mapping* (not per spec), every mapping's
    depth pass runs data-parallel (no per-element sequential scan), and each
    spec reads its hit bits off its bucket's depths at its own associativity.
    """
    keys = [_mapping_key(sp) for sp in specs]
    uniq = list(dict.fromkeys(keys))
    rows = [_keys_for_mapping(addrs, *k) for k in uniq]
    set_rows = [r[0] for r in rows]
    tag_rows = [r[1] for r in rows]
    cap = max(sp.cfg.effective_ways for sp in specs)
    depth = stackdist.stack_depths_batched(
        np.stack(set_rows), np.stack(tag_rows), cap=cap
    )
    bucket = {k: i for i, k in enumerate(uniq)}
    return np.stack([
        stackdist.hits_from_depths(depth[bucket[k]], sp.cfg.effective_ways)
        for k, sp in zip(keys, specs)
    ])


# ---------------------------------------------------------------------------
# Joint system sweep: cache + accel TLB + memory-side TLBs, B configs at once.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BatchedSystemEvents:
    """Stacked per-access hit bits for B system configs on one trace."""

    cache_hit: np.ndarray      # bool [B, N]
    accel_tlb_hit: np.ndarray  # bool [B, N]
    mem_tlb_hit: np.ndarray    # bool [B, N]
    n_warm: int

    def __len__(self) -> int:
        return self.cache_hit.shape[0]

    def __getitem__(self, i: int) -> SystemEvents:
        return SystemEvents(
            cache_hit=self.cache_hit[i],
            accel_tlb_hit=self.accel_tlb_hit[i],
            mem_tlb_hit=self.mem_tlb_hit[i],
            n_warm=self.n_warm,
        )


def _system_vmem_chunks(
    dims: Sequence[Tuple[int, int, int, int, int, int]], *, block: int = 512
) -> list:
    """Joint-system instantiation of :func:`envelope_chunks`: per config the
    stacked LRU state is ``2 x ((cs+1)*cw + (as+1)*aw + (ms+1)*mw)`` int32
    words (tags + last-use for each of the three structures, each with one
    extra set row because trace-tail padding accesses may get parked there)
    and each config streams 7 x block words per grid step (six (set, tag) key
    views in, one packed hit word out)."""
    return envelope_chunks(
        dims,
        lambda g: 2 * ((g[0] + 1) * g[1] + (g[2] + 1) * g[3] + (g[4] + 1) * g[5]),
        stream_words=7 * block, budget_bytes=_VMEM_STATE_BUDGET_BYTES)


def _system_keys(lines: np.ndarray, cfg: SystemSimConfig):
    """Per-config (cache, accel, mem) (set, tag) streams — the exact key
    preparation of :func:`repro.core.tlbsim.simulate_system`."""
    vpns = lines >> (cfg.page_shift - LINE_SHIFT)
    n = lines.shape[0]
    zeros = np.zeros(n, np.int32)

    cs, _ = _geom(cfg.cache)
    c_set, c_tag = _prepare_keys(lines, cs, 1) if cfg.cache is not None else (zeros, zeros)
    asets, _ = _geom(cfg.accel_tlb)
    a_set, a_tag = _prepare_keys(vpns, asets, 1) if cfg.accel_tlb is not None else (zeros, zeros)
    ms, _ = _geom(cfg.mem_tlb)
    m_set, m_tag = _prepare_keys(vpns, ms, cfg.num_partitions)
    return c_set, c_tag, a_set, a_tag, m_set, m_tag


def sweep_system(
    lines: np.ndarray,
    cfgs: Sequence[SystemSimConfig],
    *,
    warmup_frac: float = 0.25,
    kernel_mode: str = "auto",
    block: int = 512,
) -> BatchedSystemEvents:
    """Run the joint cache + accel-TLB + memory-TLB pipeline for every config
    in ONE pass over the line trace.

    Configs may differ in every dimension (cache/accel presence, geometries,
    partitions, page size, probe policy); results are bit-identical to
    calling :func:`repro.core.tlbsim.simulate_system` once per config.

    ``kernel_mode`` selects the batched scan reference or the batched Pallas
    kernel (``repro.kernels.system_sim``); ``"stackdist"`` raises (no exact
    stack-distance execution exists for cache-hit-conditional probes).
    """
    if not cfgs:
        raise ValueError("sweep_system needs at least one config")
    mode = dispatch.decide_system(
        kernel_mode, cfgs, n_accesses=int(lines.shape[0])).mode

    streams = [np.stack(rows) for rows in zip(*(_system_keys(lines, c) for c in cfgs))]

    def envelope(geoms):
        return max(g[0] for g in geoms), max(g[1] for g in geoms), tuple(g[1] for g in geoms)

    c_geo = [_geom(c.cache) for c in cfgs]
    a_geo = [_geom(c.accel_tlb) for c in cfgs]
    m_geo = [(_geom(c.mem_tlb)[0] * c.num_partitions, _geom(c.mem_tlb)[1]) for c in cfgs]

    n = lines.shape[0]
    n0 = int(n * warmup_frac)
    if mode == "reference":
        cs, cw, c_valid = envelope(c_geo)
        asets, aw, a_valid = envelope(a_geo)
        ms, mw, m_valid = envelope(m_geo)
        flags = tuple(
            jnp.asarray([f(c) for c in cfgs], jnp.bool_)
            for f in (
                lambda c: c.cache is not None,
                lambda c: c.accel_tlb is not None,
                lambda c: c.accel_probe_on_miss_only,
            )
        )
        ys = _scan_system_batched(
            tuple(jnp.asarray(s) for s in streams),
            flags,
            (cs, cw, asets, aw, ms, mw),
            (c_valid, a_valid, m_valid),
        )
        c_hit, a_hit, m_hit = (np.asarray(y) for y in ys)
        return BatchedSystemEvents(c_hit, a_hit, m_hit, n_warm=n - n0)

    # Pallas path: chunk the batch so each chunk's three-structure envelope
    # fits the VMEM scratch budget, and pad the trace tail to whole blocks
    # with accesses parked in an extra set row (index = envelope sets) that
    # no real config ever indexes.
    flags_np = np.asarray(
        [[c.cache is not None, c.accel_tlb is not None, c.accel_probe_on_miss_only]
         for c in cfgs], np.int32)
    dims = [c_geo[i] + a_geo[i] + m_geo[i] for i in range(len(cfgs))]
    blk = min(block, n)
    pad = (-n) % blk
    hits = [np.empty((len(cfgs), n), dtype=bool) for _ in range(3)]
    for chunk in _system_vmem_chunks(dims, block=blk):
        geom, valid, chunk_streams = [], [], []
        for k, geos in enumerate((c_geo, a_geo, m_geo)):
            sets = max(geos[i][0] for i in chunk)
            ways = max(geos[i][1] for i in chunk)
            s_c, t_c = streams[2 * k][chunk], streams[2 * k + 1][chunk]
            if pad:
                s_c = np.pad(s_c, ((0, 0), (0, pad)), constant_values=sets)
                t_c = np.pad(t_c, ((0, 0), (0, pad)), constant_values=0)
            geom += [sets + (1 if pad else 0), ways]
            valid.append(tuple(geos[i][1] for i in chunk))
            chunk_streams += [jnp.asarray(s_c), jnp.asarray(t_c)]
        ys = system_sim_batched(
            *chunk_streams, jnp.asarray(flags_np[chunk]),
            tuple(geom), tuple(valid), block=blk, kernel_mode=mode)
        for h, y in zip(hits, ys):
            h[chunk] = np.asarray(y)[:, :n]
    return BatchedSystemEvents(*hits, n_warm=n - n0)


class SystemSweepStream:
    """Resumable chunked execution of :func:`sweep_system`.

    Same contract as :class:`TLBSweepStream`, with three carried LRU
    structures per config (cache, accel TLB, partitioned mem TLB): feeding a
    line trace chunk by chunk is bit-identical to one monolithic
    :func:`sweep_system` call in any backend and across backend changes at
    chunk boundaries.  The batch grouping (:func:`_system_vmem_chunks`) and
    the spare parked set row per structure are mode-independent.
    """

    engine = "sweep_system"
    _STRUCTS = ("c", "a", "m")

    def __init__(self, cfgs: Sequence[SystemSimConfig], *, block: int = 512):
        if not cfgs:
            raise ValueError("SystemSweepStream needs at least one config")
        self.cfgs = tuple(cfgs)
        self.block = int(block)
        c_geo = [_geom(c.cache) for c in self.cfgs]
        a_geo = [_geom(c.accel_tlb) for c in self.cfgs]
        m_geo = [(_geom(c.mem_tlb)[0] * c.num_partitions, _geom(c.mem_tlb)[1])
                 for c in self.cfgs]
        self._geos = (c_geo, a_geo, m_geo)
        dims = [c_geo[i] + a_geo[i] + m_geo[i] for i in range(len(self.cfgs))]
        self.groups = _system_vmem_chunks(dims, block=self.block)
        self._flags = np.asarray(
            [[c.cache is not None, c.accel_tlb is not None,
              c.accel_probe_on_miss_only] for c in self.cfgs], np.int32)
        self._state = []
        for g in self.groups:
            st = []
            for geos in self._geos:
                sets = max(geos[i][0] for i in g)
                ways = max(geos[i][1] for i in g)
                valid = tuple(geos[i][1] for i in g)
                st += list(padded_tlb_state(len(g), sets + 1, ways, valid))
            self._state.append(tuple(st))
        self.now = 0
        _note_envelope(self)

    @property
    def batch_size(self) -> int:
        return len(self.cfgs)

    def fingerprint(self) -> dict:
        return {
            "engine": self.engine,
            "block": self.block,
            "cfgs": [[*self._geos[0][i], *self._geos[1][i], *self._geos[2][i],
                      int(self._flags[i][0]), int(self._flags[i][1]),
                      int(self._flags[i][2]), c.num_partitions, c.page_shift]
                     for i, c in enumerate(self.cfgs)],
        }

    def run_chunk(self, lines: np.ndarray, *, kernel_mode: str = "auto"):
        """Advance every config through ``lines`` (the next trace slice);
        returns (cache, accel_tlb, mem_tlb) hit bits, each bool
        [B, len(lines)].  Commit-on-success like :class:`TLBSweepStream`."""
        mode = resolve_system_mode(kernel_mode)
        lines = np.asarray(lines)
        streams = [np.stack(rows) for rows in
                   zip(*(_system_keys(lines, c) for c in self.cfgs))]
        n = lines.shape[0]
        from repro.kernels.system_sim import system_sim_batched_carry

        hits = [np.empty((len(self.cfgs), n), dtype=bool) for _ in range(3)]
        new_state = []
        for gi, g in enumerate(self.groups):
            ys, st = system_sim_batched_carry(
                *(jnp.asarray(s[g]) for s in streams),
                jnp.asarray(self._flags[g]), self._state[gi], self.now,
                block=self.block, kernel_mode=mode)
            for h, y in zip(hits, ys):
                h[g] = np.asarray(y)   # forces the computation (commit gate)
            new_state.append(st)
        self._state = new_state
        self.now += n
        _count_sim_accesses(self, n)
        return tuple(hits)

    def export_state(self) -> dict:
        out = {"now": np.array([self.now], np.int64)}
        for gi, st in enumerate(self._state):
            for k, s in enumerate(self._STRUCTS):
                out[f"g{gi}_{s}_tags"] = np.asarray(st[2 * k])
                out[f"g{gi}_{s}_last"] = np.asarray(st[2 * k + 1])
        return out

    def import_state(self, arrays: dict) -> None:
        state = []
        for gi in range(len(self.groups)):
            st = []
            for k, s in enumerate(self._STRUCTS):
                for j, part in enumerate(("tags", "last")):
                    key = f"g{gi}_{s}_{part}"
                    if key not in arrays:
                        raise ValueError(
                            f"{self.engine} state missing array {key!r}")
                    arr = np.asarray(arrays[key])
                    want = tuple(np.asarray(self._state[gi][2 * k + j]).shape)
                    if tuple(arr.shape) != want:
                        raise ValueError(
                            f"{self.engine} state array {key!r} has shape "
                            f"{tuple(arr.shape)}, expected {want}")
                    st.append(jnp.asarray(arr.astype(np.int32)))
            state.append(tuple(st))
        self._state = state
        self.now = int(np.asarray(arrays["now"]).reshape(-1)[0])
