"""Explicit collective schedules (shard_map building blocks).

``hierarchical_psum`` — the multi-pod gradient reduction: reduce-scatter
over the intra-pod ICI axes, all-reduce the (1/N-sized) shards over the DCN
``pod`` axis, all-gather back.  DCN traffic per device drops from
full-gradient to gradient/N_intra; combine with
``repro.distributed.compression`` for another 4-20x.

``local_dispatch_ep`` (NEXT ITERATION — EXPERIMENTS.md §Perf cell C):
the landed MoE layer uses a *global* sort-based dispatch whose argsort +
scatter over the [T*K]-sharded assignment stream is the dominant collective
in every MoE train/prefill cell (8.6 GiB all-reduce x L on qwen3-moe).  The
fix keeps dispatch local-first:

  1. per data shard: top-k, LOCAL argsort by expert, LOCAL capacity rank
     (no cross-shard traffic at all);
  2. one ``all_to_all`` over the model axis moves each shard's per-expert
     slices to the expert owners ([tokens_local*K, D] bf16);
  3. expert FFN on local experts;
  4. reverse ``all_to_all`` + weighted combine (local scatter-add).

Predicted per-device collective bytes/layer: 2 x tokens_local*K*D*2B
(~0.5 GiB for qwen3-moe train_4k) vs ~23 GiB measured for the global sort —
about 45x less.  The schedule is deterministic under shard_map, so it also
removes the GSPMD resharding sensitivity that refuted iteration C-1.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def hierarchical_psum(mesh: Mesh, *, intra_axes=("data",), inter_axis="pod"):
    """Returns f(grads)->grads performing RS(intra) -> AR(inter) -> AG(intra).

    Equivalent to a flat psum over all axes but moves only 1/N_intra of the
    bytes over the inter-pod (DCN) axis."""
    def reduce_tree(grads):
        def one(g):
            flat = g.reshape(-1)
            n = jax.lax.psum(1, intra_axes)
            pad = (-flat.shape[0]) % n
            if pad:
                flat = jnp.pad(flat, (0, pad))
            shard = jax.lax.psum_scatter(
                flat.reshape(n, -1), intra_axes, scatter_dimension=0, tiled=False,
            )
            shard = jax.lax.psum(shard, inter_axis)
            full = jax.lax.all_gather(shard, intra_axes, tiled=True)
            return full[: g.size].reshape(g.shape)
        return jax.tree.map(one, grads)

    in_spec = jax.tree.map(lambda _: P(), {})  # caller supplies specs
    return reduce_tree


def hierarchical_psum_shardmapped(mesh: Mesh, grads_spec):
    """shard_map-wrapped variant for replicated-gradient pytrees."""
    fn = hierarchical_psum(mesh)
    return shard_map(
        fn, mesh=mesh, in_specs=(grads_spec,), out_specs=grads_spec,
        check_rep=False,
    )
