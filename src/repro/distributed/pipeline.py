"""GPipe-style pipeline parallelism via shard_map + ppermute.

An optional ``stage`` mesh axis runs layer groups as pipeline stages;
microbatches stream through with the classic (M + S - 1)-tick schedule.
Each device holds only its stage's weights; activations hop stage->stage
with ``ppermute`` (point-to-point, no broadcast traffic).

This is the third parallelism dimension for the 1000+-node regime (e.g.
(pp=4, data=8, model=16) x pods); the dry-run meshes use (data, model) only,
so pipeline is exercised by tests/examples on small meshes.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(
    stage_fn: Callable,     # (stage_params, x [mb, ...]) -> y [mb, ...]
    stage_params,           # pytree, leaves with leading [S] stage axis
    x: jnp.ndarray,         # [M, mb, ...] microbatched input (stage-0 feed)
    mesh: Mesh,
    *,
    axis: str = "stage",
) -> jnp.ndarray:
    """Returns the last stage's outputs [M, mb, ...]."""
    S = mesh.shape[axis]
    M = x.shape[0]

    def per_stage(params, xs):
        params = jax.tree.map(lambda a: a[0], params)  # drop sharded stage dim
        me = jax.lax.axis_index(axis)
        T = M + S - 1
        buf = jnp.zeros_like(xs[0])          # activation entering this stage
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            # Stage 0 injects microbatch t (if any) — others use the buffer.
            inject = jnp.where(t < M, t, M - 1)
            x_in = jnp.where(me == 0, xs[inject], buf)
            y = stage_fn(params, x_in)
            # Valid iff this stage is processing a real microbatch: stage s
            # works on microbatch (t - s) when 0 <= t - s < M.
            mb = t - me
            valid = (mb >= 0) & (mb < M)
            # Collect at the last stage.
            outs = jax.lax.cond(
                valid & (me == S - 1),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y.astype(o.dtype), jnp.maximum(mb, 0), 0
                ),
                lambda o: o,
                outs,
            )
            # Shift activations to the next stage.
            y_masked = jnp.where(valid, y, jnp.zeros_like(y))
            buf = jax.lax.ppermute(
                y_masked, axis, [(i, (i + 1) % S) for i in range(S)]
            )
            return buf, outs

        _, outs = jax.lax.fori_loop(0, T, tick, (buf, outs))
        # Stack per-stage outputs; only the last stage's slice is real.
        return outs[None]

    fn = shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(P(axis), P()),    # params sharded by stage; x replicated
        out_specs=P(axis),
        check_rep=False,
    )
    outs = fn(stage_params, x)
    return outs[-1]


def split_layers_into_stages(stacked_layer_params, num_stages: int):
    """[L, ...] layer stack -> [S, L/S, ...] stage-major stack."""
    def reshape(a):
        L = a.shape[0]
        assert L % num_stages == 0, (L, num_stages)
        return a.reshape((num_stages, L // num_stages) + a.shape[1:])
    return jax.tree.map(reshape, stacked_layer_params)
