"""PartitionSpec rules: parameters, optimizer state, inputs, outputs.

Train: TP over ``model`` on heads / FFN-hidden / vocab / experts, FSDP
(ZeRO-3-style) over ``data`` (and ``pod``) on the complementary dim of every
large matrix; optimizer state inherits the parameter specs.

Serve: TP over ``model`` only (weights must be gatherable per token without
FSDP all-gathers on the critical path); SPARTA KV pools shard their explicit
partition axis over ``model`` — or over (data, model) jointly for the
single-sequence long-context shape.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


def data_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else "data"


# (regex on path suffix, trailing-dim axes) — earlier rules win.
# `F` = fsdp axis placeholder, `T` = tensor axis, None = replicated dim.
_TRAIN_RULES: Tuple[Tuple[str, Tuple[Any, ...]], ...] = (
    (r"moe/(w_gate|w_up)$",   ("T", "F", None)),      # [E, D, F]
    (r"moe/w_down$",          ("T", None, "F")),      # [E, F, D]
    (r"moe/router$",          ("F", None)),           # [D, E]
    (r"embed$",               ("T", "F")),            # [V, D]
    (r"lm_head$",             ("F", "T")),            # [D, V]
    (r"dec_pos$",             ("F", None)),           # [maxpos, D]
    (r"(attn|cm)/(wq|wk|wv)$", ("F", "T")),
    (r"attn/wo$",             ("T", "F")),
    (r"tm/(wr|wk|wv|wg)$",    ("F", "T")),
    (r"tm/wo$",               ("T", "F")),
    (r"tm/w_lora_a$",         ("F", None)),
    (r"tm/w_lora_b$",         (None, "F")),
    (r"cm/wr$",               ("F", "T")),
    (r"(mlp/)?(w_gate|w_up)$", ("F", "T")),           # [D, F]
    (r"(mlp/)?w_down$",       ("T", "F")),            # [F, D]
    (r"in_proj$",             ("F", "T")),
    (r"out_proj$",            ("T", "F")),
    (r"conv_w$",              (None, "T")),
    (r"(conv_b|gate_norm)$",  ("T",)),
)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


def spec_for_param(path_str: str, ndim: int, fsdp, tp, *, serve: bool = False):
    for pat, dims in _TRAIN_RULES:
        if re.search(pat, path_str):
            axes = []
            for d in dims:
                if d == "F":
                    axes.append(None if serve else fsdp)
                elif d == "T":
                    axes.append(tp)
                else:
                    axes.append(None)
            pad = ndim - len(axes)
            if pad < 0:  # scalar-ish param matched a matrix rule; replicate
                return P()
            return P(*([None] * pad + axes))
    return P()  # norms, biases, small vectors: replicated


def param_specs(abstract_params, cfg: ModelConfig, *, mode: str = "train",
                multi_pod: bool = False):
    """PartitionSpec pytree matching the parameter pytree."""
    fsdp = data_axes(multi_pod)
    serve = mode == "serve"

    def one(path, leaf):
        return spec_for_param(_path_str(path), leaf.ndim, fsdp, "model", serve=serve)

    return jax.tree_util.tree_map_with_path(one, abstract_params)


def opt_state_specs(abstract_params, cfg: ModelConfig, *, multi_pod: bool = False):
    ps = param_specs(abstract_params, cfg, mode="train", multi_pod=multi_pod)
    return {"m": ps, "v": ps, "step": P()}


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, *, multi_pod: bool = False) -> Dict[str, P]:
    """Input shardings for train/prefill batches."""
    dp = data_axes(multi_pod)
    if cfg.family == "vlm":
        return {"patch_embeds": P(dp, None, None), "tokens": P(dp, None)}
    if cfg.family == "encdec":
        return {"frames": P(dp, None, None), "tokens": P(dp, None)}
    return {"tokens": P(dp, None)}


def serve_partition_axes(shape: ShapeConfig, *, multi_pod: bool = False):
    """Mesh axes acting as SPARTA partitions for this decode shape.

    Normal decode: the ``model`` axis (batch shards over data).  The
    single-sequence long-context shape spreads pages over EVERY axis."""
    if shape.kind == "long_decode":
        return (("pod", "data", "model") if multi_pod else ("data", "model"))
    return "model"


def serve_input_specs(cfg: ModelConfig, shape: ShapeConfig, *, multi_pod: bool = False) -> Dict[str, P]:
    dp = data_axes(multi_pod)
    part = serve_partition_axes(shape, multi_pod=multi_pod)
    long = shape.kind == "long_decode"
    bdp = None if long else dp  # batch=1 cannot shard
    specs: Dict[str, P] = {"tokens": P(bdp), "ctx_len": P(bdp)}
    if cfg.family == "ssm":
        tp = "model"
        specs.update({
            "tm_shift": P(None, bdp, tp),
            "cm_shift": P(None, bdp, tp),
            "wkv": P(None, bdp, tp, None, None),
        })
        return specs
    pool = P(None, bdp, part, None, None, None, None)
    specs.update({
        "k_pools": pool,
        "v_pools": pool,
        "tables": P(bdp, part, None),
    })
    if cfg.family == "hybrid":
        specs["conv_state"] = P(None, None, bdp, None, "model" if not long else None)
        specs["ssm_state"] = P(None, None, bdp, "model" if not long else None, None, None)
    if cfg.family == "encdec":
        specs["cross_k"] = P(None, bdp, None, "model", None)
        specs["cross_v"] = P(None, bdp, None, "model", None)
    return specs


def serve_output_specs(cfg: ModelConfig, shape: ShapeConfig, *, multi_pod: bool = False):
    """(logits spec, new-state specs dict)."""
    dp = data_axes(multi_pod)
    long = shape.kind == "long_decode"
    bdp = None if long else dp
    inp = serve_input_specs(cfg, shape, multi_pod=multi_pod)
    state_keys = {
        "ssm": ("tm_shift", "cm_shift", "wkv"),
        "hybrid": ("conv_state", "ssm_state", "k_pools", "v_pools"),
    }.get(cfg.family, ("k_pools", "v_pools"))  # cross KV is input-only
    return P(bdp, "model"), {k: inp[k] for k in state_keys}


# ---------------------------------------------------------------------------
# Activation sharding policy (perf iteration 1, EXPERIMENTS.md §Perf).
#
# With small-KV-head GQA archs (starcoder2 kv=4 vs model=16) GSPMD loses the
# batch sharding inside the attention layer and falls back to all-reducing
# full [B, T, D] f32 activations INSIDE the layer x KV-block loops (observed:
# 3 x 19.3 GB x 256 trips on starcoder2 train_4k).  Explicit constraints at
# block boundaries pin activations to (batch->data, heads->model-if-divisible)
# and cut per-device collective traffic by ~100x.
# ---------------------------------------------------------------------------

_ACT_POLICY: dict = {}


def set_activation_policy(*, dp, tp: str = "model", tp_size: int = 0):
    """Enable activation constraints (requires an ambient mesh via
    ``jax.sharding.use_mesh`` at trace time)."""
    _ACT_POLICY.update(dp=dp, tp=tp, tp_size=tp_size)


def clear_activation_policy():
    _ACT_POLICY.clear()


def constrain_btd(x):
    """[B, T, D] residual-stream activations: batch over data."""
    if not _ACT_POLICY:
        return x
    import jax
    return jax.lax.with_sharding_constraint(x, P(_ACT_POLICY["dp"], None, None))


def constrain_bthd(x, n_heads: int):
    """[B, T, H, hd] head-major activations: heads over model if divisible."""
    if not _ACT_POLICY:
        return x
    import jax
    tp = _ACT_POLICY["tp"] if _ACT_POLICY["tp_size"] and n_heads % _ACT_POLICY["tp_size"] == 0 else None
    return jax.lax.with_sharding_constraint(x, P(_ACT_POLICY["dp"], None, tp, None))
