"""Gradient compression for cross-pod (DCN) reduction.

Intra-pod gradients reduce over ICI at full precision; the pod axis crosses
DCN where bandwidth is ~10x scarcer.  Two compressors:

* **top-k + error feedback** — keep the k largest-|g| entries per tensor,
  accumulate the residual locally (Stich et al.); unbiased over time.
* **int8 row-scaled quantisation** — 4x cheaper transport, cheap to fuse.

Both are pure pytree transforms usable as ``compress_grads`` in
``make_train_step`` (applied before the optimizer; the all-reduce that GSPMD
inserts then moves the compressed representation's worth of bytes — for the
dry-run roofline we model DCN bytes as raw_bytes * ratio).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "topk"        # topk | int8 | none
    topk_ratio: float = 0.05  # fraction of entries kept


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def topk_compress_leaf(g: jnp.ndarray, err: jnp.ndarray, ratio: float):
    """Returns (compressed-dense g', new error).  g' keeps the top-k entries
    of (g + err); the remainder accumulates into the error state."""
    gf = g.astype(jnp.float32) + err
    flat = gf.reshape(-1)
    k = max(1, int(flat.size * ratio))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(gf) >= thresh
    kept = jnp.where(mask, gf, 0.0)
    return kept.astype(g.dtype), gf - kept


def topk_compress(grads, err_state, ratio: float):
    out = jax.tree.map(
        lambda g, e: topk_compress_leaf(g, e, ratio), grads, err_state
    )
    kept = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return kept, new_err


def int8_quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row (last-dim) absmax int8 quantisation."""
    gf = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(gf), axis=-1, keepdims=True) / 127.0
    scale = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def int8_roundtrip(grads):
    """Quantise + dequantise every leaf (what crosses DCN is the int8)."""
    def one(g):
        q, s = int8_quantize(g)
        return int8_dequantize(q, s, g.dtype)
    return jax.tree.map(one, grads)


def compressed_bytes(grads, cfg: CompressionConfig) -> int:
    """Bytes that would cross DCN per step under this compressor."""
    raw = sum(g.size * g.dtype.itemsize for g in jax.tree.leaves(grads))
    if cfg.kind == "topk":
        # value (4B) + index (4B) per kept entry
        n = sum(g.size for g in jax.tree.leaves(grads))
        return int(n * cfg.topk_ratio * 8)
    if cfg.kind == "int8":
        return int(raw // 4 if raw else 0)
    return int(raw)
