"""Run telemetry: spans, counters/gauges and per-run append-only JSONL logs.

SPARTA's headline claim is an *attribution* claim — translation overhead is
where the cycles go — yet a sweep run is otherwise opaque: the orchestrator
records retries/halves/downgrades, but nothing says how long each chunk
took, which backend it ran on, or what accesses/s the engine actually
achieved (the measured-crossover feed the roofline ``kernel_mode="auto"``
item needs).  This module is the one place all of that flows through:

* :class:`RunLog` — an append-only JSONL sink, one file per figure/bench
  run, one self-describing record per line (``kind`` = ``run_start`` /
  ``span`` / ``event`` / ``run_end``; every record carries ``ts`` wall-clock
  seconds and ``t_mono`` = ``time.perf_counter()``).  The first record
  stamps ``schema_version`` (:data:`SCHEMA_VERSION`) like BENCH_sweep.json
  rows do.
* :class:`Span` — a context manager recording wall duration (and optionally
  device-blocked time via :meth:`Span.block`, which routes through
  :func:`repro.core.benchtime.block`); spans nest, with ``span_id`` /
  ``parent_id`` linking the records.  :meth:`Tracer.record_span` logs a
  span whose duration was measured externally (``benchtime.measure``).
* :class:`Counter` / :class:`Gauge` — a per-run registry (simulated-access
  counts, VMEM state footprints, ...), aggregated into the ``run_end``
  summary.
* :class:`Tracer` — the global instance (:func:`get_tracer`).  When no run
  is active every call is a no-op returning shared null objects, so hot
  loops can be instrumented unconditionally (tests/test_telemetry.py holds
  the <2% overhead guard on a disabled-tracer ``run_sweep_tlb``).

Lifecycle: :func:`run_scope` (or :func:`start_run`/:func:`end_run`) brackets
one run; ``run_scope`` catches ``BaseException`` so a ``Preempted`` exit
still closes the log with an ``error`` on the ``run_end`` record.
:meth:`Tracer.summary` is the in-memory aggregate the figure drivers stamp
into their JSON as ``_telemetry`` (next to ``_device`` / ``_crash_safety``).

Deliberately stdlib-only: ``benchtime`` (which imports jax) is pulled in
lazily inside :meth:`Span.block`, so importing telemetry never costs a jax
import and ``benchmarks/obs_report.py`` can read the logs without one.
"""
from __future__ import annotations

import contextlib
import json
import logging
import pathlib
import sys
import threading
import time
from typing import IO, Any, Dict, List, Optional, Union

# Version of the JSONL record schema below; bump on any incompatible change
# (the BENCH_sweep.json `schema_version` discipline).
SCHEMA_VERSION = 1

_LOG = logging.getLogger("repro.runtime.telemetry")


def _stamp() -> Dict[str, float]:
    """Wall-clock + monotonic timestamps carried by every record."""
    return {"ts": time.time(), "t_mono": time.perf_counter()}


def _jsonable(x: Any):
    """json.dumps default: numpy scalars/arrays degrade to Python values."""
    item = getattr(x, "item", None)
    if callable(item):
        try:
            return x.item()
        except (TypeError, ValueError):
            pass
    tolist = getattr(x, "tolist", None)
    if callable(tolist):
        return x.tolist()
    return str(x)


class _NullSpan:
    """The disabled-tracer span: every method is a do-nothing returning
    something sensible, so instrumented code needs no ``if enabled`` guard.
    ``block`` returns its argument *without* blocking — the disabled path
    must not add device synchronization the uninstrumented code lacked."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self

    def block(self, x):
        return x


class _NullInstrument:
    """Disabled-tracer counter/gauge."""

    __slots__ = ()

    def add(self, n=1):
        return self

    def set(self, value):
        return self


_NULL_SPAN = _NullSpan()
_NULL_INSTRUMENT = _NullInstrument()

# One lock for all counter/gauge mutation: scheduler worker threads update
# shared instruments concurrently, and `+=` on a float is not atomic.  The
# disabled-tracer path never reaches these (it returns _NULL_INSTRUMENT), so
# the <2% no-op overhead guard is unaffected.
_AGG_LOCK = threading.Lock()


class Counter:
    """Monotonically accumulated value (e.g. simulated accesses)."""

    __slots__ = ("name", "value", "updates")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self.updates = 0

    def add(self, n=1):
        with _AGG_LOCK:
            self.value += n
            self.updates += 1
        return self

    def summary(self) -> dict:
        return {"value": self.value, "updates": self.updates}


class Gauge:
    """Last-set value with min/max tracking (e.g. VMEM state bytes)."""

    __slots__ = ("name", "value", "min", "max", "updates")

    def __init__(self, name: str):
        self.name = name
        self.value: Optional[float] = None
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self.updates = 0

    def set(self, value):
        value = float(value)
        with _AGG_LOCK:
            self.value = value
            self.min = value if self.min is None else min(self.min, value)
            self.max = value if self.max is None else max(self.max, value)
            self.updates += 1
        return self

    def summary(self) -> dict:
        return {"value": self.value, "min": self.min, "max": self.max,
                "updates": self.updates}


class RunLog:
    """Append-only JSONL sink for one run: one json record per line,
    flushed per write so a crashed/preempted run keeps every completed
    record (at worst the final line is torn, which readers tolerate)."""

    def __init__(self, path: Union[str, pathlib.Path]):
        self.path = pathlib.Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._f: Optional[IO[str]] = open(self.path, "w", encoding="utf-8")

    def write(self, rec: dict) -> None:
        if self._f is None:
            return
        self._f.write(json.dumps(rec, default=_jsonable) + "\n")
        self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


class Span:
    """An in-progress span; obtained from :meth:`Tracer.span` and used as a
    context manager.  ``set(**attrs)`` attaches attributes discovered while
    the span runs (e.g. achieved accesses/s); ``block(x)`` blocks on a jax
    value via ``benchtime.block`` and accumulates the wait into the span's
    ``blocked_s`` attribute."""

    __slots__ = ("_tracer", "name", "attrs", "span_id", "parent_id", "ts",
                 "_t0", "_blocked_s")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.span_id: Optional[int] = None
        self.parent_id: Optional[int] = None
        self._blocked_s = 0.0

    def __enter__(self) -> "Span":
        tr = self._tracer
        self.parent_id = tr._stack[-1].span_id if tr._stack else None
        self.span_id = tr._next_id()
        tr._stack.append(self)
        self.ts = time.time()
        self._t0 = time.perf_counter()
        return self

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def block(self, x):
        from repro.core.benchtime import block

        t0 = time.perf_counter()
        block(x)
        self._blocked_s += time.perf_counter() - t0
        return x

    def __exit__(self, et, ev, tb) -> bool:
        dur_s = time.perf_counter() - self._t0
        tr = self._tracer
        if tr._stack and tr._stack[-1] is self:
            tr._stack.pop()
        if et is not None:
            self.attrs.setdefault("error", f"{et.__name__}: {ev}")
        if self._blocked_s:
            self.attrs.setdefault("blocked_s", round(self._blocked_s, 6))
        tr._finish_span(self.name, dur_s, self.span_id, self.parent_id,
                        self.ts, self.attrs)
        return False


class Tracer:
    """The global spans/counters/events registry for one run.

    ``active`` is the no-op gate: with no run started (the default), every
    instrument call returns a shared null object and records nothing.  The
    per-name aggregates (``summary()``) survive :meth:`end_run`, so a driver
    can stamp the finished run's summary into its figure JSON.

    Thread-safety: scheduler worker *threads* share this tracer, so the
    span stack is thread-local (each thread nests its own spans; a worker
    span never claims another thread's span as parent) while the shared
    registries (span stats, event counts, counters/gauges, id allocation)
    and the JSONL sink are guarded by one re-entrant lock.  The disabled
    path stays lock-free — the <2% no-op overhead guard still holds."""

    def __init__(self):
        self._lock = threading.RLock()
        self._tls = threading.local()
        self._reset()

    def _reset(self) -> None:
        self.active = False
        self.run: Optional[str] = None
        self._log: Optional[RunLog] = None
        self._tls.stack = []
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._span_stats: Dict[str, dict] = {}
        self._event_counts: Dict[str, int] = {}
        self._id = 0

    @property
    def _stack(self) -> List[Span]:
        """This thread's open-span stack (created lazily per thread)."""
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    # -- lifecycle ----------------------------------------------------------

    def start_run(self, path: Union[str, pathlib.Path, None] = None, *,
                  run: Optional[str] = None, **meta) -> "Tracer":
        """Begin a run, resetting all registries.  ``path=None`` keeps the
        run in-memory only (aggregates, no JSONL)."""
        with self._lock:
            if self.active:
                _LOG.warning("telemetry run %r still active; closing it to start %r",
                             self.run, run)
                self.end_run(error=f"superseded by run {run!r}")
            self._reset()
            self.run = run
            self.active = True
            if path is not None:
                self._log = RunLog(path)
            rec = {"kind": "run_start", "schema_version": SCHEMA_VERSION,
                   "run": run, **_stamp()}
            if meta:
                rec["meta"] = meta
            self._emit(rec)
            return self

    def end_run(self, error: Optional[str] = None) -> dict:
        """Close the run (writing the ``run_end`` summary record) and return
        the summary.  No-op returning ``{}`` when no run is active."""
        with self._lock:
            if not self.active:
                return {}
            s = self.summary()
            rec = {"kind": "run_end", "run": self.run, **_stamp(), "summary": s}
            if error is not None:
                rec["error"] = str(error)
            self._emit(rec)
            if self._log is not None:
                self._log.close()
                self._log = None
            self.active = False
            del self._stack[:]
            return s

    # -- instruments --------------------------------------------------------

    # `name` is positional-only so callers can attach a `name=...` attribute
    # (e.g. the orchestrator labels chunk spans with the figure name).
    def span(self, name: str, /, **attrs):
        """Open a span context manager (a shared no-op when disabled)."""
        if not self.active:
            return _NULL_SPAN
        return Span(self, name, attrs)

    def record_span(self, name: str, dur_s: float, /, **attrs) -> None:
        """Record an already-measured span (duration timed externally)."""
        if not self.active:
            return
        parent = self._stack[-1].span_id if self._stack else None
        self._finish_span(name, float(dur_s), self._next_id(), parent,
                          time.time(), attrs)

    def event(self, name: str, /, **attrs) -> None:
        """Record a point-in-time structured event (retry, downgrade, ...)."""
        if not self.active:
            return
        with self._lock:
            self._event_counts[name] = self._event_counts.get(name, 0) + 1
            rec = {"kind": "event", "name": name, **_stamp()}
            if attrs:
                rec["attrs"] = attrs
            self._emit(rec)

    def counter(self, name: str):
        if not self.active:
            return _NULL_INSTRUMENT
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name)
            return c

    def gauge(self, name: str):
        if not self.active:
            return _NULL_INSTRUMENT
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name)
            return g

    def summary(self) -> dict:
        """Aggregate view of the (last) run: per-name span stats, event
        counts, counter/gauge values — the figure-JSON ``_telemetry`` base."""
        with self._lock:
            return self._summary_locked()

    def _summary_locked(self) -> dict:
        return {
            "schema_version": SCHEMA_VERSION,
            "run": self.run,
            "n_spans": sum(s["count"] for s in self._span_stats.values()),
            "spans": {k: {"count": v["count"],
                          "total_s": round(v["total_s"], 6)}
                      for k, v in sorted(self._span_stats.items())},
            "events": dict(sorted(self._event_counts.items())),
            "counters": {k: c.summary()
                         for k, c in sorted(self._counters.items())},
            "gauges": {k: g.summary()
                       for k, g in sorted(self._gauges.items())},
        }

    # -- internals ----------------------------------------------------------

    def _next_id(self) -> int:
        with self._lock:
            self._id += 1
            return self._id

    def _emit(self, rec: dict) -> None:
        with self._lock:
            if self._log is not None:
                self._log.write(rec)

    def _finish_span(self, name: str, dur_s: float, span_id: Optional[int],
                     parent_id: Optional[int], ts: float, attrs: dict) -> None:
        with self._lock:
            st = self._span_stats.setdefault(name, {"count": 0, "total_s": 0.0})
            st["count"] += 1
            st["total_s"] += dur_s
            rec = {"kind": "span", "name": name, "span_id": span_id,
                   "parent_id": parent_id, "ts": ts,
                   "t_mono": time.perf_counter(), "dur_s": round(dur_s, 6)}
            if attrs:
                rec["attrs"] = dict(attrs)
            self._emit(rec)


_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def start_run(path=None, *, run=None, **meta) -> Tracer:
    return _TRACER.start_run(path, run=run, **meta)


def end_run(error: Optional[str] = None) -> dict:
    return _TRACER.end_run(error=error)


@contextlib.contextmanager
def run_scope(path=None, *, run=None, **meta):
    """Bracket one run.  Catches ``BaseException`` deliberately: a
    :class:`repro.core.orchestrator.Preempted` (or KeyboardInterrupt) must
    still close the JSONL log, with the error recorded on ``run_end``."""
    _TRACER.start_run(path, run=run, **meta)
    try:
        yield _TRACER
    except BaseException as exc:
        _TRACER.end_run(error=f"{type(exc).__name__}: {exc}")
        raise
    else:
        _TRACER.end_run()


def setup_logging(verbosity: int = 0,
                  stream: Optional[IO[str]] = None) -> logging.Logger:
    """Configure the ``repro`` logger hierarchy for driver narration.

    The handler writes to **stderr** so stdout stays machine output (CSV
    rows, claim lines, figure paths).  ``verbosity < 0`` -> WARNING
    (``--quiet``), ``0`` -> INFO (default), ``>= 1`` -> DEBUG (``-v``).
    Idempotent: repeated calls adjust the level instead of stacking
    handlers."""
    level = (logging.WARNING if verbosity < 0
             else logging.INFO if verbosity == 0 else logging.DEBUG)
    root = logging.getLogger("repro")
    root.setLevel(level)
    if not any(getattr(h, "_repro_narration", False) for h in root.handlers):
        h = logging.StreamHandler(stream if stream is not None else sys.stderr)
        h.setFormatter(logging.Formatter("%(levelname).1s %(name)s: %(message)s"))
        h._repro_narration = True
        root.addHandler(h)
    root.propagate = False
    return root
