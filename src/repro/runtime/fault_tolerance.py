"""Fault-tolerance runtime: heartbeats, straggler detection, step retry,
preemption-aware training loop.

Designed for the 1000+-node regime: per-host step-time EWMAs feed a
straggler report; because the data pipeline is stateless-deterministic
(repro.data.pipeline) a flagged host can be evicted and its shard
reassigned without replaying any loader state.
"""
from __future__ import annotations

import dataclasses
import errno
import logging
import random
import signal
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

_LOG = logging.getLogger("repro.runtime.fault_tolerance")


@dataclasses.dataclass
class HostStats:
    ewma: float = 0.0
    count: int = 0
    last_seen: float = 0.0


class HeartbeatTracker:
    """Tracks per-host step durations; flags stragglers and dead hosts."""

    def __init__(self, *, alpha: float = 0.2, straggler_factor: float = 1.5,
                 dead_after_s: float = 60.0):
        self.alpha = alpha
        self.straggler_factor = straggler_factor
        self.dead_after_s = dead_after_s
        self.hosts: Dict[int, HostStats] = {}

    def record(self, host: int, step_time_s: float, now: Optional[float] = None):
        st = self.hosts.setdefault(host, HostStats())
        st.ewma = step_time_s if st.count == 0 else (
            self.alpha * step_time_s + (1 - self.alpha) * st.ewma
        )
        st.count += 1
        st.last_seen = time.time() if now is None else now

    def _median_ewma(self) -> float:
        vals = sorted(s.ewma for s in self.hosts.values() if s.count > 0)
        return vals[len(vals) // 2] if vals else 0.0

    def stragglers(self) -> List[int]:
        med = self._median_ewma()
        if med <= 0:
            return []
        return [h for h, s in self.hosts.items() if s.ewma > self.straggler_factor * med]

    def dead(self, now: Optional[float] = None) -> List[int]:
        t = time.time() if now is None else now
        return [h for h, s in self.hosts.items() if t - s.last_seen > self.dead_after_s]


class PreemptionHandler:
    """SIGTERM/SIGINT => checkpoint-and-exit at the next step boundary.

    Any *user-installed* handler that was registered before us is chained
    (called after ``requested`` is set) instead of silently replaced; the
    interpreter defaults (``SIG_DFL`` / ``SIG_IGN`` / Python's
    ``default_int_handler``, which would raise ``KeyboardInterrupt`` straight
    through the graceful shutdown) are replaced, which is the point of
    installing a preemption handler at all.  ``uninstall()`` restores
    whatever was there before.

    Off the main thread ``signal.signal`` raises ``ValueError`` by CPython
    design — exactly where scheduler worker threads construct orchestrators.
    Construction there is a *documented no-op with a warning*: ``requested``
    stays drivable (the parent forwards preemption by constructing workers
    with ``install=False`` and setting ``requested`` itself), and
    ``uninstall()`` is safe to call.
    """

    SIGNALS = (signal.SIGTERM, signal.SIGINT)

    def __init__(self, install: bool = True,
                 signals: Optional[Tuple[int, ...]] = None):
        self.requested = False
        self.installed = False
        self._previous: Dict[int, object] = {}
        if install:
            if threading.current_thread() is not threading.main_thread():
                _LOG.warning(
                    "PreemptionHandler constructed off the main thread "
                    "(%s): signal handlers cannot be installed there "
                    "(signal.signal raises ValueError); continuing as a "
                    "no-op — forward preemption from the main thread via "
                    "an injected handler (install=False).",
                    threading.current_thread().name)
                return
            for sig in (signals if signals is not None else self.SIGNALS):
                self._previous[sig] = signal.signal(sig, self._on_signal)
            self.installed = True

    def _on_signal(self, signum, frame):
        self.requested = True
        prev = self._previous.get(signum)
        if callable(prev) and prev is not signal.default_int_handler:
            prev(signum, frame)

    def uninstall(self):
        """Restore the handlers that were installed before us."""
        for sig, prev in self._previous.items():
            try:
                signal.signal(sig, prev)
            except (ValueError, TypeError):
                pass
        self._previous = {}


# XLA surfaces runtime faults as XlaRuntimeError (a RuntimeError subclass)
# whose message starts with an absl status code.  These codes are the
# machine-transient ones (device OOM, preempted backend, flaky transport);
# INVALID_ARGUMENT / compile-time failures are NOT here on purpose — they are
# deterministic and retrying them just burns the budget before surfacing.
_TRANSIENT_STATUS = ("RESOURCE_EXHAUSTED", "DEADLINE_EXCEEDED", "UNAVAILABLE",
                     "ABORTED", "CANCELLED", "INTERNAL", "UNKNOWN",
                     "out of memory", "OOM")

# OSError is mostly deterministic (missing file, bad permissions, dir-vs-file,
# full disk): retrying those just replays the failure — and, worse, walks a
# retry ladder for errors that will never clear.  Only the classic
# "try again" errnos are worth a retry.
_TRANSIENT_ERRNOS = frozenset(
    getattr(errno, nm) for nm in (
        "EINTR", "EAGAIN", "EWOULDBLOCK", "EBUSY", "EIO", "ETIMEDOUT",
        "ESTALE", "ENOBUFS", "ECONNRESET", "ECONNABORTED", "ENETRESET",
        "ENETDOWN", "ENETUNREACH", "EHOSTUNREACH",
    ) if hasattr(errno, nm))


def is_transient(exc: BaseException) -> bool:
    """Is this exception a transient runtime fault worth retrying?

    Policy: deterministic program bugs (ValueError, TypeError, KeyError,
    AssertionError, ...) are never transient.  XLA runtime errors are
    transient only for the retryable status codes above — this applies to
    any RuntimeError carrying one of those markers, so old jax without
    ``jax.errors.JaxRuntimeError`` still classifies; a RuntimeError without
    one is a program bug and surfaces immediately.  OS-level errors are
    transient only for MemoryError/TimeoutError/ConnectionError and the
    "try again" errnos in :data:`_TRANSIENT_ERRNOS`; deterministic
    filesystem failures (FileNotFoundError, PermissionError, ENOSPC, ...)
    are not retried.
    """
    try:
        from jax.errors import JaxRuntimeError
    except Exception:  # pragma: no cover - ancient jax
        JaxRuntimeError = ()
    if JaxRuntimeError and isinstance(exc, JaxRuntimeError):
        msg = str(exc)
        return any(code in msg for code in _TRANSIENT_STATUS)
    if isinstance(exc, (MemoryError, TimeoutError, ConnectionError)):
        return True
    if isinstance(exc, OSError):
        return exc.errno in _TRANSIENT_ERRNOS
    if isinstance(exc, RuntimeError) and not isinstance(
            exc, (NotImplementedError, RecursionError)):
        msg = str(exc)
        return any(code in msg for code in _TRANSIENT_STATUS)
    return False


def backoff_delays(retries: int, *, base_s: float = 0.05, cap_s: float = 2.0,
                   jitter: float = 0.25,
                   rng: Optional[random.Random] = None) -> List[float]:
    """Bounded exponential backoff schedule with multiplicative jitter."""
    rng = rng or random.Random()
    out = []
    for attempt in range(retries):
        d = min(base_s * (2.0 ** attempt), cap_s)
        out.append(d * (1.0 + jitter * rng.random()))
    return out


def retry_step(fn: Callable, *args, retries: int = 2,
               on_retry: Optional[Callable[[int, BaseException], None]] = None,
               base_delay_s: float = 0.05, max_delay_s: float = 2.0,
               rng: Optional[random.Random] = None):
    """Run one step with bounded retry of *transient* runtime faults.

    Only exceptions classified by :func:`is_transient` are retried —
    deterministic bugs (ValueError/TypeError/...) surface immediately instead
    of burning every retry first.  Retries sleep a bounded exponential
    backoff with jitter (``base_delay_s`` doubling up to ``max_delay_s``);
    pass ``base_delay_s=0`` to disable sleeping (tests).
    """
    delays = backoff_delays(retries, base_s=base_delay_s, cap_s=max_delay_s,
                            rng=rng)
    for attempt in range(retries + 1):
        try:
            return fn(*args)
        except Exception as e:
            if attempt == retries or not is_transient(e):
                raise
            if on_retry:
                on_retry(attempt, e)
            if delays[attempt] > 0:
                time.sleep(delays[attempt])


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    checkpoint_every: int = 100
    keep: int = 3
    retries: int = 2


def run_training_loop(
    step_fn: Callable,
    state: tuple,
    batch_fn: Callable[[int], dict],
    ckpt_root,
    loop: LoopConfig,
    *,
    start_step: int = 0,
    tracker: Optional[HeartbeatTracker] = None,
    preemption: Optional[PreemptionHandler] = None,
    host_id: int = 0,
    on_metrics: Optional[Callable[[int, dict], None]] = None,
):
    """The fault-tolerant driver: retries steps, heartbeats, periodic async
    checkpoints, checkpoint-and-exit on preemption.  Returns (state, step)."""
    from repro.checkpoint.checkpoint import AsyncCheckpointer

    tracker = tracker or HeartbeatTracker()
    ckpt = AsyncCheckpointer(ckpt_root, keep=loop.keep)
    step = start_step
    try:
        while step < loop.total_steps:
            t0 = time.time()
            batch = batch_fn(step)
            params, opt_state, metrics = retry_step(
                step_fn, *state, batch, retries=loop.retries
            )
            state = (params, opt_state)
            tracker.record(host_id, time.time() - t0)
            if on_metrics:
                on_metrics(step, metrics)
            step += 1
            if step % loop.checkpoint_every == 0:
                ckpt.submit(step, {"params": params, "opt_state": opt_state})
            if preemption is not None and preemption.requested:
                ckpt.submit(step, {"params": params, "opt_state": opt_state})
                break
    finally:
        ckpt.close()
    return state, step
