"""Fault-tolerance runtime: heartbeats, straggler detection, step retry,
preemption-aware training loop.

Designed for the 1000+-node regime: per-host step-time EWMAs feed a
straggler report; because the data pipeline is stateless-deterministic
(repro.data.pipeline) a flagged host can be evicted and its shard
reassigned without replaying any loader state.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class HostStats:
    ewma: float = 0.0
    count: int = 0
    last_seen: float = 0.0


class HeartbeatTracker:
    """Tracks per-host step durations; flags stragglers and dead hosts."""

    def __init__(self, *, alpha: float = 0.2, straggler_factor: float = 1.5,
                 dead_after_s: float = 60.0):
        self.alpha = alpha
        self.straggler_factor = straggler_factor
        self.dead_after_s = dead_after_s
        self.hosts: Dict[int, HostStats] = {}

    def record(self, host: int, step_time_s: float, now: Optional[float] = None):
        st = self.hosts.setdefault(host, HostStats())
        st.ewma = step_time_s if st.count == 0 else (
            self.alpha * step_time_s + (1 - self.alpha) * st.ewma
        )
        st.count += 1
        st.last_seen = time.time() if now is None else now

    def _median_ewma(self) -> float:
        vals = sorted(s.ewma for s in self.hosts.values() if s.count > 0)
        return vals[len(vals) // 2] if vals else 0.0

    def stragglers(self) -> List[int]:
        med = self._median_ewma()
        if med <= 0:
            return []
        return [h for h, s in self.hosts.items() if s.ewma > self.straggler_factor * med]

    def dead(self, now: Optional[float] = None) -> List[int]:
        t = time.time() if now is None else now
        return [h for h, s in self.hosts.items() if t - s.last_seen > self.dead_after_s]


class PreemptionHandler:
    """SIGTERM => checkpoint-and-exit at the next step boundary."""

    def __init__(self, install: bool = True):
        self.requested = False
        if install:
            try:
                signal.signal(signal.SIGTERM, self._on_signal)
            except ValueError:
                pass  # not main thread (tests)

    def _on_signal(self, *_):
        self.requested = True


def retry_step(fn: Callable, *args, retries: int = 2,
               on_retry: Optional[Callable[[int, BaseException], None]] = None):
    """Run one step with bounded retry (transient XLA/runtime faults)."""
    for attempt in range(retries + 1):
        try:
            return fn(*args)
        except Exception as e:  # noqa: BLE001
            if attempt == retries:
                raise
            if on_retry:
                on_retry(attempt, e)


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    checkpoint_every: int = 100
    keep: int = 3
    retries: int = 2


def run_training_loop(
    step_fn: Callable,
    state: tuple,
    batch_fn: Callable[[int], dict],
    ckpt_root,
    loop: LoopConfig,
    *,
    start_step: int = 0,
    tracker: Optional[HeartbeatTracker] = None,
    preemption: Optional[PreemptionHandler] = None,
    host_id: int = 0,
    on_metrics: Optional[Callable[[int, dict], None]] = None,
):
    """The fault-tolerant driver: retries steps, heartbeats, periodic async
    checkpoints, checkpoint-and-exit on preemption.  Returns (state, step)."""
    from repro.checkpoint.checkpoint import AsyncCheckpointer

    tracker = tracker or HeartbeatTracker()
    ckpt = AsyncCheckpointer(ckpt_root, keep=loop.keep)
    step = start_step
    try:
        while step < loop.total_steps:
            t0 = time.time()
            batch = batch_fn(step)
            params, opt_state, metrics = retry_step(
                step_fn, *state, batch, retries=loop.retries
            )
            state = (params, opt_state)
            tracker.record(host_id, time.time() - t0)
            if on_metrics:
                on_metrics(step, metrics)
            step += 1
            if step % loop.checkpoint_every == 0:
                ckpt.submit(step, {"params": params, "opt_state": opt_state})
            if preemption is not None and preemption.requested:
                ckpt.submit(step, {"params": params, "opt_state": opt_state})
                break
    finally:
        ckpt.close()
    return state, step
