"""Elastic scaling: restart a job on a different device count.

Checkpoints are mesh-agnostic (numpy + manifest), so elasticity is a policy
question: pick a new mesh factorisation for the surviving devices, rebuild
the PartitionSpecs, and ``restore_resharded``.  The model axis is kept fixed
(TP degree is baked into kernel-efficiency choices); the data (and pod) axes
absorb the change.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    dropped_devices: int

    @property
    def size(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def plan_remesh(available_devices: int, *, model_axis: int = 16,
                pod_size: Optional[int] = None) -> RemeshPlan:
    """Largest (data, model) mesh fitting the surviving devices.

    E.g. 256 chips with 3 dead -> 253 available -> 15x16 = 240 used,
    13 idle spares (kept warm as replacements)."""
    if available_devices < model_axis:
        raise ValueError(f"need >= {model_axis} devices, have {available_devices}")
    data = available_devices // model_axis
    used = data * model_axis
    return RemeshPlan(shape=(data, model_axis), axes=("data", "model"),
                      dropped_devices=available_devices - used)


def elastic_restore(ckpt_root, cfg: ModelConfig, plan: RemeshPlan, template,
                    *, step: Optional[int] = None):
    """Rebuild (params, opt_state) on the new mesh. Returns
    (state, step, mesh)."""
    from repro.checkpoint.checkpoint import restore_resharded

    mesh = make_mesh(plan.shape, plan.axes)
    multi_pod = "pod" in plan.axes
    pspecs = shd.param_specs(template["params"], cfg, mode="train", multi_pod=multi_pod)
    ospecs = shd.opt_state_specs(template["params"], cfg, multi_pod=multi_pod)
    tree, step = restore_resharded(
        ckpt_root, template, mesh, {"params": pspecs, "opt_state": ospecs}, step=step,
    )
    return tree, step, mesh
