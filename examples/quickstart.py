"""Quickstart: the paper's result in three steps on one CPU.

1. Generate an index-traversal trace (paper Table 2 workload).
2. Compare conventional vs SPARTA memory-side TLBs (Fig 4).
3. Run the Fig 10 CPI model: end-to-end speedup + overhead reduction.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import cpi, tlbsim, traces
from repro.core.sparta import SystemLatencies, TLBConfig
from repro.core.tlbsim import SystemSimConfig, simulate_system

GIB = 1 << 30

print("=== SPARTA quickstart ===")
tr = traces.generate("bst_internal", n_ops=20_000, footprint_bytes=128 * GIB)
print(f"workload=bst_internal accesses={tr.num_accesses:,} footprint=128GiB")

for P in (1, 4, 32, 128):
    miss = tlbsim.miss_ratio(tr.vpns(12), 128, num_partitions=P)
    label = "conventional" if P == 1 else f"SPARTA-{P}  "
    print(f"  {label} 128-entry TLB{'s' if P > 1 else ' '}: miss ratio {miss:.3f}")

lat = SystemLatencies(n_sockets=8)
base_ev = simulate_system(tr.lines, SystemSimConfig(
    accel_tlb=TLBConfig(entries=128, ways=4), num_partitions=1))
sp_ev = simulate_system(tr.lines, SystemSimConfig(num_partitions=32))
base = cpi.evaluate_design("conventional", base_ev, lat, instr_per_access=tr.instr_per_access)
sp = cpi.evaluate_design("sparta", sp_ev, lat, instr_per_access=tr.instr_per_access)
ideal = cpi.evaluate_design("ideal", sp_ev, lat, instr_per_access=tr.instr_per_access)
print(f"\nspeedup over conventional: SPARTA-32 {sp.speedup_over(base):.2f}x "
      f"(ideal {ideal.speedup_over(base):.2f}x)")
print(f"translation overhead: {base.access.translation_overhead:.0f} -> "
      f"{sp.access.translation_overhead:.1f} cycles/access "
      f"({base.access.translation_overhead / sp.access.translation_overhead:.1f}x reduction)")
