"""Distributed training driver: FSDP+TP mesh, fault-tolerant loop, elastic
restart.  Runs on 8 forced host devices (set by this script) — the same code
path the 256/512-chip dry-run compiles.

Run:  PYTHONPATH=src python examples/train_distributed.py [--steps 60]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import models
from repro.configs import registry
from repro.data.pipeline import DataConfig, batch_for_model
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh
from repro.runtime.fault_tolerance import (
    HeartbeatTracker, LoopConfig, PreemptionHandler, run_training_loop,
)
from repro.runtime import elastic
from repro.train.optimizer import OptimizerConfig, init_state
from repro.train.train_step import make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=60)
ap.add_argument("--arch", default="qwen3-14b")
args = ap.parse_args()

cfg = registry.get_smoke(args.arch)
mesh = make_mesh((2, 4), ("data", "model"))
print(f"mesh {dict(mesh.shape)}; arch family={cfg.family}")

params = models.init(jax.random.PRNGKey(0), cfg)
opt = init_state(params)
pspecs = shd.param_specs(params, cfg, mode="train")
ospecs = shd.opt_state_specs(params, cfg)
nps = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs, is_leaf=lambda x: isinstance(x, P))
nos = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs, is_leaf=lambda x: isinstance(x, P))
params = jax.tree.map(jax.device_put, params, nps)
opt = jax.tree.map(jax.device_put, opt, nos)
step = jax.jit(make_train_step(cfg, OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=args.steps)),
               in_shardings=(nps, nos, NamedSharding(mesh, P("data", None))),
               out_shardings=(nps, nos, None))

data = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
def batch_fn(i):
    return {k: jnp.asarray(v) for k, v in batch_for_model(data, cfg, i).items()}

tracker = HeartbeatTracker()
losses = []
state, stopped = run_training_loop(
    step, (params, opt), batch_fn, "/tmp/repro_ckpt",
    LoopConfig(total_steps=args.steps, checkpoint_every=20),
    tracker=tracker, preemption=PreemptionHandler(install=False),
    on_metrics=lambda s, m: losses.append(float(m["loss"])),
)
print(f"steps={stopped} loss {losses[0]:.3f} -> {losses[-1]:.3f} "
      f"(mean step {tracker.hosts[0].ewma:.2f}s)")
assert losses[-1] < losses[0], "loss must decrease"

# Elastic restart: pretend 3 of 8 devices died -> 5 left -> 1x4 mesh + 1 spare.
plan = elastic.plan_remesh(available_devices=5, model_axis=4)
tree, step_no, new_mesh = elastic.elastic_restore(
    "/tmp/repro_ckpt", cfg, plan, {"params": state[0], "opt_state": state[1]})
print(f"elastic restart: restored step {step_no} onto mesh {plan.shape} "
      f"({plan.dropped_devices} spare devices)")
