"""End-to-end serving driver: SPARTA-paged KV cache with batched requests.

Continuous batching, demand page allocation, prefix sharing (fork) with
copy-on-write — the paper's VM machinery running an LM server.

Run:  PYTHONPATH=src python examples/serve_paged.py
"""
import time

import jax
import numpy as np

from repro.configs import registry
from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.serve.engine import SpartaEngine

base = registry.get_smoke("stablelm-12b").__dict__.copy()
base.update(dtype="float32", kv_page_size=8, num_layers=4, d_model=128,
            num_heads=8, num_kv_heads=4, head_dim=16, d_ff=256)
cfg = ModelConfig(**base)
params = tfm.init(jax.random.PRNGKey(0), cfg)
print(f"model: {sum(x.size for x in jax.tree.leaves(params)):,} params; "
      f"page={cfg.kv_page_size} tokens")

eng = SpartaEngine(cfg, params, num_partitions=4, slots_per_partition=64, max_batch=4)
rng = np.random.default_rng(0)
rids = [eng.submit(list(rng.integers(0, cfg.vocab, rng.integers(4, 12))),
                   max_new_tokens=12) for _ in range(8)]
t0 = time.time()
steps = 0
while eng.step() or eng.waiting:
    steps += 1
dt = time.time() - t0
done = len(eng.finished)
toks = sum(len(r.generated) for r in eng.finished.values())
print(f"served {done} requests / {toks} tokens in {steps} engine steps ({dt:.1f}s)")
print("free pages per partition:", [eng.kv.num_free(p) for p in range(4)])

# Prefix sharing: branch the first finished request 3 ways (zero-copy fork,
# CoW only on the shared tail page).
free_before = sum(eng.kv.num_free(p) for p in range(4))
branches = [eng.fork_request(rids[0], max_new_tokens=6) for _ in range(3)]
print(f"forked 3 branches: pages allocated by fork = "
      f"{free_before - sum(eng.kv.num_free(p) for p in range(4))} (expect 0)")
eng.run_to_completion()
for b in branches:
    print(f"  branch {b}: +{len(eng.finished[b].generated)} tokens")
eng.kv.check_invariants()
print("invariants OK")
