"""Cycle-approximate timeline engine: cpi consistency + kernel bit-exactness.

Two contracts:

* **Oracle property** — with every queueing resource unbounded, the
  post-warmup mean of the per-access timeline latency / translation overhead
  reproduces :mod:`repro.core.cpi`'s analytical averages (<= 1e-6 relative)
  for all four designs and all six workloads.
* **Kernel** — the Pallas timeline kernel is bit-identical to the jnp
  ``lax.scan`` reference (they share one ``timeline_step``), and with the
  integral default latency table every latency is an integer cycle count.
"""
import numpy as np
import pytest

from repro.core import cpi, timeline, traces
from repro.core.sparta import SystemLatencies, TLBConfig
from repro.core.sweep import sweep_system
from repro.core.tlbsim import SystemSimConfig
from repro.kernels.timeline import TimelineParams, timeline_sim

LAT = SystemLatencies()
CACHE = TLBConfig(entries=256, ways=4)
ACCEL_TLB = TLBConfig(entries=128, ways=4)
MEM_TLB = TLBConfig(entries=128, ways=4)
PARTITIONS = 32


def _events(lines):
    """(conventional, sparta) SystemEvents for one trace, one batched pass."""
    evs = sweep_system(lines, [
        SystemSimConfig(cache=CACHE, accel_tlb=ACCEL_TLB, mem_tlb=MEM_TLB,
                        num_partitions=1, page_shift=12),
        SystemSimConfig(cache=CACHE, accel_tlb=None, mem_tlb=MEM_TLB,
                        num_partitions=PARTITIONS, page_shift=12),
    ])
    return evs[0], evs[1]


@pytest.mark.parametrize("workload", traces.WORKLOADS)
def test_unbounded_timeline_mean_matches_cpi(workload):
    tr = traces.generate(workload, n_ops=1200, max_accesses=8000)
    ev_conv, ev_sparta = _events(tr.lines)
    for design in timeline.DESIGNS:
        ev = ev_conv if design == "conventional" else ev_sparta
        P = PARTITIONS if design == "sparta" else 1
        perf = cpi.evaluate_design(design, ev, LAT, instr_per_access=5.0,
                                   workload=workload)
        res = timeline.simulate_timeline(
            tr.lines, ev, design, LAT, cfg=timeline.TimelineConfig.unbounded(),
            num_partitions=P, workload=workload, kernel_mode="reference")
        rel = abs(res.mean_latency - perf.access.total) / perf.access.total
        assert rel <= 1e-6, (workload, design, res.mean_latency, perf.access.total)
        ov = perf.access.translation_overhead
        rel_ov = abs(res.mean_overhead - ov) / max(ov, 1e-9)
        assert rel_ov <= 1e-6, (workload, design, res.mean_overhead, ov)


def _random_inputs(rng, n, params):
    return (
        rng.integers(0, params.num_accels, n).astype(np.int32),
        rng.integers(0, params.num_partitions, n).astype(np.int32),
        rng.integers(0, max(params.dram_banks, 1), n).astype(np.int32),
        rng.integers(0, max(params.dram_banks, 1), n).astype(np.int32),
        (rng.random(n) < 0.5).astype(np.int32),
        (rng.random(n) < 0.6).astype(np.int32),
        (rng.random(n) < 0.7).astype(np.int32),
    )


@pytest.mark.parametrize("serial_walk,mem_tlb,pen", [
    (True, False, 0.0),    # conventional
    (False, True, 0.0),    # sparta
    (False, False, 24.0),  # dipta (integral penalty)
    (False, False, 0.0),   # ideal
])
@pytest.mark.parametrize("blk", [128, 512])
def test_timeline_kernel_bit_exact(rng, serial_walk, mem_tlb, pen, blk):
    n = 1500  # not a block multiple: exercises the padding path
    params = TimelineParams(
        serial_walk=serial_walk, mem_tlb=mem_tlb, num_accels=4, mshrs=4,
        num_partitions=8, tlb_ports=2, dram_banks=8)
    inputs = _random_inputs(rng, n, params)
    pen_arr = np.full(n, pen, np.float32)
    ref = timeline_sim(*inputs, pen_arr, params, kernel_mode="reference")
    pal = timeline_sim(*inputs, pen_arr, params, block=blk,
                       kernel_mode="pallas_interpret")
    for r, p in zip(ref, pal):
        assert np.array_equal(np.asarray(r), np.asarray(p))
    # Integral latency table => integer cycle counts, exactly.
    lat = np.asarray(ref[0])
    assert np.array_equal(lat, np.round(lat))
    assert (lat >= params.l_cache).all()


def test_timeline_kernel_bit_exact_unbounded(rng):
    params = TimelineParams(mem_tlb=True, num_accels=2, num_partitions=4)
    inputs = _random_inputs(rng, 1024, params)
    pen = np.zeros(1024, np.float32)
    ref = timeline_sim(*inputs, pen, params, kernel_mode="reference")
    pal = timeline_sim(*inputs, pen, params, kernel_mode="pallas_interpret")
    for r, p in zip(ref, pal):
        assert np.array_equal(np.asarray(r), np.asarray(p))


def test_queueing_only_adds_latency():
    """Finite resources can only delay: per-access latency dominates the
    unbounded run's access-by-access, and tails grow."""
    streams = traces.thread_traces("skip_list", 4, n_ops=800, seed=7)
    inter = traces.interleave(streams)[:8000]
    _, ev = _events(inter)
    kw = dict(num_partitions=PARTITIONS, num_accelerators=4,
              kernel_mode="reference")
    free = timeline.simulate_timeline(
        inter, ev, "sparta", LAT, cfg=timeline.TimelineConfig.unbounded(), **kw)
    tight = timeline.simulate_timeline(
        inter, ev, "sparta", LAT,
        cfg=timeline.TimelineConfig(mshrs=4, tlb_ports=1, dram_banks=4), **kw)
    assert (tight.latency >= free.latency - 1e-5).all()
    assert tight.mean_latency > free.mean_latency
    assert tight.overhead_percentile(99) >= free.overhead_percentile(99)
    assert tight.total_cycles > free.total_cycles
    assert tight.throughput < free.throughput


def test_mshr_window_throttles_issue():
    """With one MSHR and one bank, an all-miss stream serializes completely:
    miss i cannot issue before miss i-1 completed."""
    n = 64
    lines = (np.arange(n, dtype=np.int64) * 4096) >> 6  # all distinct pages
    ev_conv, _ = _events(lines)
    res = timeline.simulate_timeline(
        lines, ev_conv, "ideal", LAT,
        cfg=timeline.TimelineConfig(mshrs=1, tlb_ports=0, dram_banks=0),
        kernel_mode="reference")
    miss = ~res.cache_hit
    done_miss = res.done[miss]
    issue_miss = done_miss - res.latency[miss]
    assert (issue_miss[1:] >= done_miss[:-1] - 1e-5).all()


def test_result_reductions_and_accel_ids():
    ids = timeline.round_robin_accel_ids(8, 4)
    np.testing.assert_array_equal(ids, [0, 1, 2, 3, 0, 1, 2, 3])
    ids_g = timeline.round_robin_accel_ids(8, 2, granularity=2)
    np.testing.assert_array_equal(ids_g, [0, 0, 1, 1, 0, 0, 1, 1])

    tr = traces.generate("hash_table", n_ops=600, max_accesses=4000)
    ev_conv, _ = _events(tr.lines)
    res = timeline.simulate_timeline(tr.lines, ev_conv, "conventional", LAT,
                                     kernel_mode="reference")
    s = res.summary()
    assert s["p50_latency"] <= s["p95_latency"] <= s["p99_latency"]
    assert s["total_cycles"] >= res.done.max() - 1e-6
    assert 0 < s["throughput"] < 1e9
    # Overhead tail on the translated (cache-missing) stream only.
    assert res.overhead_percentile(99) >= res.overhead_percentile(50)
    with pytest.raises(ValueError):
        timeline.simulate_timeline(tr.lines, ev_conv, "bogus", LAT)
