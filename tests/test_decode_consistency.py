"""Decode == forward consistency for every serving path (the correctness
contract of the SPARTA paged-KV serve step)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import rwkv6, transformer as tfm
from repro.models.paged_global import decode_block_global


def _tiny(**kw):
    base = dict(name="t", family="dense", num_layers=2, d_model=32, num_heads=4,
                num_kv_heads=2, head_dim=8, d_ff=64, vocab=61, qk_norm=True,
                dtype="float32", kv_page_size=4)
    base.update(kw)
    return ModelConfig(**base)


def test_paged_decode_matches_forward():
    cfg = _tiny()
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    B, T = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    logits, _ = tfm.forward(params, tokens, cfg, kernel_mode="reference")
    n_pages = (T + 3) // 4
    slots = B * n_pages
    kp = jnp.zeros((cfg.num_layers, slots, 4, 2, 8), jnp.float32)
    vp = jnp.zeros_like(kp)
    table = jnp.asarray(np.arange(slots, dtype=np.int32).reshape(B, n_pages))
    errs = []
    for t in range(T):
        ctx = jnp.full((B,), t + 1, jnp.int32)
        lg, kp, vp = tfm.decode_step(params, tokens[:, t], cfg, kp, vp, table, ctx,
                                     kernel_mode="reference")
        errs.append(float(jnp.abs(lg - logits[:, t]).max()))
    assert max(errs) < 2e-4, errs


@pytest.mark.parametrize("P", [1, 2, 4])
def test_global_view_decode_matches_forward(P):
    """The GSPMD-friendly partition-explicit layout, at several partition
    counts — including the partition-local ctx masking."""
    cfg = _tiny(num_layers=2)
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    B, T, page = 2, 13, 4
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    logits, _ = tfm.forward(params, tokens, cfg, kernel_mode="reference")

    n_pages = (T + page - 1) // page
    pl = (n_pages + P - 1) // P
    kp = jnp.zeros((cfg.num_layers, B, P, pl, page, 2, 8), jnp.float32)
    vp = jnp.zeros_like(kp)
    # slot = local page index (identity demand allocation)
    tables = jnp.asarray(np.tile(np.arange(pl, dtype=np.int32), (B, P, 1)))

    x_errs = []
    for t in range(T):
        ctx = jnp.full((B,), t + 1, jnp.int32)
        x = tfm.embed_tokens(params, cfg, tokens[:, t][:, None])

        def body(x, scanned):
            lp, kpool, vpool = scanned
            x, kpool, vpool = decode_block_global(lp, x, cfg, kpool, vpool, tables, ctx)
            return x, (kpool, vpool)

        x, (kp, vp) = jax.lax.scan(body, x, (params["layers"], kp, vp))
        lg = tfm.unembed(params, cfg, x)[:, 0]
        x_errs.append(float(jnp.abs(lg - logits[:, t]).max()))
    assert max(x_errs) < 2e-4, x_errs


def test_rwkv6_decode_matches_forward():
    cfg = ModelConfig(name="r", family="ssm", num_layers=2, d_model=32,
                      num_heads=0, num_kv_heads=0, head_dim=0, d_ff=64,
                      vocab=61, norm="ln", ssm_headdim=16, dtype="float32")
    params = rwkv6.init(jax.random.PRNGKey(0), cfg)
    B, T = 2, 10
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    logits, _ = rwkv6.forward(params, tokens, cfg, kernel_mode="reference")
    state = rwkv6.init_decode_state(cfg, B)
    errs = []
    for t in range(T):
        lg, state = rwkv6.decode_step(params, tokens[:, t], cfg, state,
                                      kernel_mode="reference")
        errs.append(float(jnp.abs(lg - logits[:, t]).max()))
    assert max(errs) < 2e-4, errs


def test_local_ctx_partitioning_covers_exactly():
    """Sum of per-partition local contexts == global context, for any ctx."""
    from repro.models.paged_global import local_ctx_all_partitions
    page = 4
    for P in (1, 2, 3, 4, 8):
        for c in range(0, 50):
            lc = local_ctx_all_partitions(jnp.asarray([c], jnp.int32), P, page)
            assert int(lc.sum()) == c, (P, c, lc)
