"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba2_scan import mamba2_scan
from repro.kernels.paged_attention import merge_partials, paged_attention
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.kernels.tlb_sim import tlb_sim, tlb_sim_batched
from repro.models.flash_ref import flash_attention_jnp


@pytest.mark.parametrize("B,Hq,Hkv,Tq,Tk,D,causal,dtype", [
    (1, 4, 2, 64, 64, 32, True, jnp.float32),
    (2, 8, 8, 96, 96, 64, True, jnp.float32),
    (1, 4, 1, 33, 80, 64, False, jnp.float32),
    (2, 2, 2, 128, 128, 128, True, jnp.bfloat16),
    (1, 4, 2, 1, 96, 32, True, jnp.float32),  # decode: single query
])
def test_flash_attention_vs_oracle(rng, B, Hq, Hkv, Tq, Tk, D, causal, dtype):
    q = jnp.asarray(rng.standard_normal((B, Hq, Tq, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Hkv, Tk, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Hkv, Tk, D)), dtype)
    ref = attention_ref(q, k, v, causal=causal)
    pal = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32,
                          kernel_mode="pallas_interpret")
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(pal, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_ref_chunked_equals_naive(rng):
    q = jnp.asarray(rng.standard_normal((2, 4, 50, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 2, 70, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 2, 70, 32)), jnp.float32)
    for causal in (True, False):
        a = flash_attention_jnp(q, k, v, causal=causal, block_k=16)
        b = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@pytest.mark.parametrize("B,Hq,Hkv,D,page,pages,slots", [
    (2, 8, 2, 64, 16, 4, 32),
    (3, 4, 4, 32, 8, 6, 64),
    (1, 16, 8, 128, 32, 3, 16),
])
def test_paged_attention_vs_oracle(rng, B, Hq, Hkv, D, page, pages, slots):
    q = jnp.asarray(rng.standard_normal((B, Hq, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((slots, page, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((slots, page, Hkv, D)), jnp.float32)
    tbl = np.full((B, pages), -1, np.int32)
    ctx = np.zeros(B, np.int32)
    for b in range(B):
        n = int(rng.integers(1, pages + 1))
        tbl[b, :n] = rng.choice(slots, n, replace=False)
        ctx[b] = (n - 1) * page + int(rng.integers(1, page + 1))
    tbl, ctx = jnp.asarray(tbl), jnp.asarray(ctx)
    ref = paged_attention(q, kp, vp, tbl, ctx, kernel_mode="reference")
    pal = paged_attention(q, kp, vp, tbl, ctx, kernel_mode="pallas_interpret")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref), atol=2e-5)


def test_merge_partials_is_exact_partition_of_softmax(rng):
    """Splitting the KV across partitions then merging == one-shot attention."""
    from repro.kernels.paged_attention import paged_attention_partial
    B, Hq, Hkv, D, page = 2, 4, 2, 32, 8
    slots, pages = 16, 4
    q = jnp.asarray(rng.standard_normal((B, Hq, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((slots, page, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((slots, page, Hkv, D)), jnp.float32)
    tbl = jnp.asarray(rng.choice(slots, (B, pages), replace=False).astype(np.int32))
    ctx = jnp.asarray(np.full(B, pages * page, np.int32))
    full = paged_attention(q, kp, vp, tbl, ctx, kernel_mode="reference")
    # Partition pages across 2 "devices": mask halves of the table.
    parts = []
    for half in range(2):
        t = np.asarray(tbl).copy()
        t[:, half::2] = -1  # this partition owns the other pages... keep ctx
        acc, m, l = paged_attention_partial(q, kp, vp, jnp.asarray(t), ctx,
                                            kernel_mode="reference")
        parts.append((acc, m, l))
    merged = merge_partials(
        jnp.stack([p[0] for p in parts]),
        jnp.stack([p[1] for p in parts]),
        jnp.stack([p[2] for p in parts]),
    )
    np.testing.assert_allclose(np.asarray(merged), np.asarray(full), atol=2e-5)


@pytest.mark.parametrize("B,H,T,N,chunk", [(2, 2, 64, 32, 32), (1, 4, 96, 16, 16)])
def test_rwkv6_chunked_vs_exact(rng, B, H, T, N, chunk):
    r = jnp.asarray(rng.standard_normal((B, H, T, N)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, T, N)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, T, N)) * 0.5, jnp.float32)
    w = jnp.asarray(rng.uniform(0.75, 0.999, (B, H, T, N)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, N)) * 0.5, jnp.float32)
    o_ref, s_ref = rwkv6_scan(r, k, v, w, u, kernel_mode="reference")
    o_pal, s_pal = rwkv6_scan(r, k, v, w, u, chunk=chunk, kernel_mode="pallas_interpret")
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref), atol=5e-4)
    np.testing.assert_allclose(np.asarray(s_pal), np.asarray(s_ref), atol=5e-4)


@pytest.mark.parametrize("B,H,T,P,N,chunk", [(2, 2, 64, 32, 16, 32), (1, 4, 96, 16, 32, 16)])
def test_mamba2_chunked_vs_exact(rng, B, H, T, P, N, chunk):
    x = jnp.asarray(rng.standard_normal((B, H, T, P)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, H, T)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 4.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, T, N)) * 0.5, jnp.float32)
    C = jnp.asarray(rng.standard_normal((B, T, N)) * 0.5, jnp.float32)
    D = jnp.asarray(rng.standard_normal((H,)), jnp.float32)
    y_ref, s_ref = mamba2_scan(x, dt, A, Bm, C, D, kernel_mode="reference")
    y_pal, s_pal = mamba2_scan(x, dt, A, Bm, C, D, chunk=chunk, kernel_mode="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref), atol=5e-4)
    np.testing.assert_allclose(np.asarray(s_pal), np.asarray(s_ref), atol=5e-4)


@pytest.mark.parametrize("TS,W,N,blk", [(16, 4, 1024, 256), (64, 4, 2048, 512), (8, 2, 512, 128)])
def test_tlb_sim_kernel_bit_exact(rng, TS, W, N, blk):
    s = jnp.asarray(rng.integers(0, TS, N), jnp.int32)
    t = jnp.asarray(rng.integers(0, 50, N), jnp.int32)
    ref = tlb_sim(s, t, TS, W, kernel_mode="reference")
    pal = tlb_sim(s, t, TS, W, block=blk, kernel_mode="pallas_interpret")
    assert (np.asarray(ref) == np.asarray(pal)).all()


@pytest.mark.parametrize("TS,W,N,blk,valid", [
    (16, 4, 1024, 256, (4, 2, 1)),    # heterogeneous associativity
    (32, 4, 512, 128, (4, 4, 4, 3)),
])
def test_tlb_sim_batched_kernel_bit_exact(rng, TS, W, N, blk, valid):
    B = len(valid)
    s = jnp.asarray(rng.integers(0, TS, (B, N)), jnp.int32)
    t = jnp.asarray(rng.integers(0, 50, (B, N)), jnp.int32)
    ref = tlb_sim_batched(s, t, TS, W, valid, kernel_mode="reference")
    pal = tlb_sim_batched(s, t, TS, W, valid, block=blk, kernel_mode="pallas_interpret")
    assert (np.asarray(ref) == np.asarray(pal)).all()
    # Each batched row == the single-config kernel on that config's geometry.
    for b in range(B):
        one = tlb_sim(s[b], t[b], TS, valid[b], kernel_mode="reference")
        assert (np.asarray(ref[b]) == np.asarray(one)).all()
