"""Checkpoint atomicity/reshard, fault-tolerant loop, elastic restart,
gradient compression, demand paging."""
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.distributed import compression as comp
from repro.runtime import elastic
from repro.runtime.fault_tolerance import (
    HeartbeatTracker, LoopConfig, PreemptionHandler, retry_step,
    run_training_loop,
)


def _tree(rng):
    return {
        "a": rng.standard_normal((8, 16)).astype(np.float32),
        "nested": {"b": rng.standard_normal((4,)).astype(np.float32),
                   "c": np.int32(7)},
    }


def test_checkpoint_roundtrip_and_keep(tmp_path, rng):
    t1 = _tree(rng)
    for step in (10, 20, 30, 40):
        ckpt.save(tmp_path, step, t1, keep=2)
    assert ckpt.latest_step(tmp_path) == 40
    kept = sorted(p.name for p in pathlib.Path(tmp_path).iterdir())
    assert kept == ["step_00000030", "step_00000040"]
    restored, step = ckpt.restore(tmp_path, template=t1)
    assert step == 40
    np.testing.assert_array_equal(restored["a"], t1["a"])
    np.testing.assert_array_equal(restored["nested"]["b"], t1["nested"]["b"])


def test_checkpoint_atomic_no_partial_reads(tmp_path, rng):
    t1 = _tree(rng)
    ckpt.save(tmp_path, 1, t1)
    # A stale tmp dir from a "crashed" writer must be ignored and swept.
    junk = pathlib.Path(tmp_path) / "step_00000002.tmp-dead"
    junk.mkdir()
    (junk / "garbage.npy").write_bytes(b"xx")
    assert ckpt.latest_step(tmp_path) == 1
    ckpt.save(tmp_path, 3, t1)
    assert not junk.exists()


def test_async_checkpointer(tmp_path, rng):
    t1 = _tree(rng)
    ac = ckpt.AsyncCheckpointer(tmp_path, keep=2)
    for s in (5, 10):
        ac.submit(s, t1)
    ac.close()
    assert ckpt.latest_step(tmp_path) == 10


def test_elastic_restore_onto_smaller_mesh(tmp_path):
    """Save on one layout, restore resharded onto a different device count."""
    from repro.configs import registry
    from repro import models
    from repro.train.optimizer import init_state

    cfg = registry.get_smoke("qwen3-14b")
    params = models.init(jax.random.PRNGKey(0), cfg)
    opt = init_state(params)
    ckpt.save(tmp_path, 100, {"params": params, "opt_state": opt})

    plan = elastic.plan_remesh(available_devices=1, model_axis=1)
    assert plan.shape == (1, 1)
    (state, step, mesh) = elastic.elastic_restore(
        tmp_path, cfg, plan, {"params": params, "opt_state": opt},
    )
    assert step == 100
    chk = jax.tree.map(
        lambda a, b: np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32)),
        state["params"], params,
    )
    assert all(jax.tree.leaves(chk))


def test_heartbeat_straggler_detection():
    tr = HeartbeatTracker(straggler_factor=1.5)
    for host in range(8):
        for _ in range(5):
            tr.record(host, 1.0 if host != 3 else 2.5)
    assert tr.stragglers() == [3]


def test_retry_step_recovers():
    calls = {"n": 0}

    def flaky(x):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("UNAVAILABLE: transient backend hiccup")
        return x + 1

    assert retry_step(flaky, 41, retries=3, base_delay_s=0) == 42
    with pytest.raises(RuntimeError):
        retry_step(lambda: (_ for _ in ()).throw(
            RuntimeError("UNAVAILABLE: always")), retries=1, base_delay_s=0)


def test_is_transient_classification():
    import errno

    from repro.runtime.fault_tolerance import is_transient

    # Retryable environment hiccups.
    assert is_transient(MemoryError())
    assert is_transient(TimeoutError())
    assert is_transient(ConnectionResetError(errno.ECONNRESET, "reset"))
    assert is_transient(InterruptedError(errno.EINTR, "interrupted"))
    assert is_transient(OSError(errno.EIO, "flaky disk"))
    # XLA-status-coded runtime faults classify even as bare RuntimeError
    # (old jax without jax.errors.JaxRuntimeError).
    assert is_transient(RuntimeError("RESOURCE_EXHAUSTED: out of memory"))
    # Deterministic filesystem failures surface immediately: retrying a
    # missing file / bad permission / full disk just replays the failure.
    assert not is_transient(FileNotFoundError(errno.ENOENT, "gone"))
    assert not is_transient(PermissionError(errno.EACCES, "denied"))
    assert not is_transient(IsADirectoryError(errno.EISDIR, "a dir"))
    assert not is_transient(OSError(errno.ENOSPC, "disk full"))
    # Program bugs are never transient.
    assert not is_transient(ValueError("bad config"))
    assert not is_transient(RuntimeError("refusing to overwrite history"))
    assert not is_transient(NotImplementedError())


def test_training_loop_checkpoints_and_preempts(tmp_path):
    from repro.configs import registry
    from repro import models
    from repro.train.optimizer import OptimizerConfig, init_state
    from repro.train.train_step import make_train_step

    cfg = registry.get_smoke("stablelm-12b")
    params = models.init(jax.random.PRNGKey(0), cfg)
    opt = init_state(params)
    step_fn = jax.jit(make_train_step(cfg, OptimizerConfig(lr=1e-3, warmup_steps=1)))
    rng = np.random.default_rng(0)

    def batch_fn(step):
        return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)).astype(np.int32))}

    pre = PreemptionHandler(install=False)
    losses = []

    def on_metrics(step, m):
        losses.append(float(m["loss"]))
        if step == 5:
            pre.requested = True  # simulated SIGTERM

    state, stopped = run_training_loop(
        step_fn, (params, opt), batch_fn, tmp_path,
        LoopConfig(total_steps=100, checkpoint_every=3),
        preemption=pre, on_metrics=on_metrics,
    )
    assert stopped == 6                      # checkpoint-and-exit at the boundary
    assert ckpt.latest_step(tmp_path) == 6   # preemption checkpoint committed
    assert all(np.isfinite(losses))


def test_topk_error_feedback_conserves_gradient():
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((32, 32)), jnp.float32)}
    err = comp.init_error_state(g)
    kept, err = comp.topk_compress(g, err, ratio=0.1)
    # kept + error == original (nothing lost, just deferred)
    np.testing.assert_allclose(
        np.asarray(kept["w"]) + np.asarray(err["w"]), np.asarray(g["w"]), atol=1e-6)
    nz = float((np.asarray(kept["w"]) != 0).mean())
    assert nz <= 0.15


def test_int8_roundtrip_error_bounded():
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((16, 64)), jnp.float32)}
    out = comp.int8_roundtrip(g)
    err = np.abs(np.asarray(out["w"]) - np.asarray(g["w"])).max()
    scale = np.abs(np.asarray(g["w"])).max(axis=-1).max()
    assert err <= scale / 127.0 + 1e-6
    assert comp.compressed_bytes(g, comp.CompressionConfig("int8")) == g["w"].size


def test_os_model_shared_mapping_adjustment():
    """Paper §5 worked example: [V5..V9] with partitions (3,0,1,2,3), P=4 -> V7."""
    from repro.core.pagetable import adjust_virtual_region, alloc_page_vma, make_partitions
    assert adjust_virtual_region(5, [3, 0, 1, 2, 3], 4) == 7
    parts = make_partitions(4, frames_per_partition=8)
    p, frame = alloc_page_vma(vaddr_vpn=6, asid=1, partitions=parts)
    assert p == 6 % 4
    assert parts[p].page_table.lookup(1, 6) == frame
    assert parts[p].page_table.invalidate(1, 6)
    assert parts[p].page_table.lookup(1, 6) is None
