"""End-to-end system behaviour: training convergence, the SPARTA serving
engine (continuous batching + prefix-share CoW), and loss-path equivalence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs import registry
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, batch_for_model
from repro.models import transformer as tfm
from repro.serve.engine import SpartaEngine
from repro.train.optimizer import OptimizerConfig, init_state
from repro.train.train_step import make_train_step


def test_training_loss_decreases():
    """A few dozen steps on structured synthetic data must cut the loss."""
    cfg = registry.get_smoke("stablelm-12b")
    params = models.init(jax.random.PRNGKey(0), cfg)
    opt = init_state(params)
    step = jax.jit(make_train_step(cfg, OptimizerConfig(lr=3e-3, warmup_steps=5)))
    data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    losses = []
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in batch_for_model(data, cfg, i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_chunked_loss_equals_full_logits_loss():
    cfg = registry.get_smoke("qwen3-14b")
    params = models.init(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    batch = {"tokens": tok}
    chunked = float(models.loss_fn(params, batch, cfg, kernel_mode="reference", ce_block=8))
    logits, aux = models.forward(params, batch, cfg, kernel_mode="reference")
    from repro.models.layers import cross_entropy
    full = float(cross_entropy(logits[:, :-1], tok[:, 1:]) + aux)
    assert abs(chunked - full) < 1e-3, (chunked, full)


def _engine_cfg():
    base = registry.get_smoke("stablelm-12b").__dict__.copy()
    base.update(dtype="float32", kv_page_size=4)
    return ModelConfig(**base)


def test_engine_matches_direct_greedy_decode():
    cfg = _engine_cfg()
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    prompt = [3, 14, 15, 9, 2, 6]
    n_new = 6

    # Direct greedy decode with full forward each step (oracle).
    toks = list(prompt)
    for _ in range(n_new):
        logits, _ = tfm.forward(params, jnp.asarray(toks)[None], cfg, kernel_mode="reference")
        toks.append(int(jnp.argmax(logits[0, -1])))
    expected = toks[len(prompt):]

    eng = SpartaEngine(cfg, params, num_partitions=2, slots_per_partition=32, max_batch=2)
    rid = eng.submit(prompt, max_new_tokens=n_new)
    eng.run_to_completion()
    got = eng.finished[rid].generated[:n_new]
    assert got == expected, (got, expected)


def test_engine_continuous_batching_and_fork_cow():
    cfg = _engine_cfg()
    params = tfm.init(jax.random.PRNGKey(1), cfg)
    eng = SpartaEngine(cfg, params, num_partitions=2, slots_per_partition=32, max_batch=2)
    r1 = eng.submit([1, 2, 3, 4, 5], max_new_tokens=4)
    r2 = eng.submit([7, 8, 9], max_new_tokens=4)
    r3 = eng.submit([4, 4, 4, 4], max_new_tokens=3)  # waits for a slot
    eng.run_to_completion()
    assert set(eng.finished) == {r1, r2, r3}
    assert len(eng.finished[r1].generated) == 4
    eng.kv.check_invariants()

    # Prefix sharing: fork r1's sequence, decode a few more tokens (CoW).
    free_before = sum(eng.kv.num_free(p) for p in range(2))
    r4 = eng.fork_request(r1, max_new_tokens=3)
    assert sum(eng.kv.num_free(p) for p in range(2)) == free_before  # zero-copy fork
    eng.run_to_completion()
    assert len(eng.finished[r4].generated) == 3
    eng.kv.check_invariants()


def test_prefill_with_kv_matches_decode_path():
    """Prefill-emitted KV pages == the pages decode writes token-by-token."""
    cfg = _engine_cfg()
    params = tfm.init(jax.random.PRNGKey(2), cfg)
    B, T, page = 1, 8, 4
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, T), 0, cfg.vocab)
    _, kpages, vpages = tfm.prefill_with_kv(params, tokens, cfg, kernel_mode="reference")

    n_pages = (T + page - 1) // page
    kp = jnp.zeros((cfg.num_layers, n_pages, page, cfg.num_kv_heads, cfg.head_dim), jnp.float32)
    vp = jnp.zeros_like(kp)
    table = jnp.arange(n_pages, dtype=jnp.int32)[None]
    for t in range(T):
        ctx = jnp.full((B,), t + 1, jnp.int32)
        _, kp, vp = tfm.decode_step(params, tokens[:, t], cfg, kp, vp, table, ctx,
                                    kernel_mode="reference")
    got = kp.reshape(cfg.num_layers, -1, cfg.num_kv_heads, cfg.head_dim)[:, :T]
    want = kpages[:, 0].reshape(cfg.num_layers, -1, cfg.num_kv_heads, cfg.head_dim)[:, :T]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
