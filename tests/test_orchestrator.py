"""Crash-safety of the streaming sweep orchestrator (repro.core.orchestrator).

The contract under test, for all three batched engines:

* **Kill-at-any-chunk-boundary + resume is bit-identical** to an
  uninterrupted monolithic run — parametrized over every interior chunk
  boundary of a 4-chunk run (and the boundary after the *final* chunk).
* **Corrupt, truncated, foreign or layout-mismatched checkpoints are
  refused** with a clear error (never silently regenerated over).
* **The degradation ladder** fires in order — retry (with backoff), then
  block-aligned halving, then a sticky backend downgrade — on transient
  faults only, records every event in the run meta, and still produces
  bit-identical results.  Non-transient errors raise immediately.
* **Preemption** checkpoints and exits at the next chunk boundary
  (:class:`Preempted`); the rerun resumes bit-identically.

Faults come from tests/_faultinject.py via ``SweepRunConfig``'s two test
seams (``fault_hook`` before each attempt, ``on_chunk_committed`` after each
durable commit)."""
import numpy as np
import pytest
from _faultinject import SimulatedKill, corrupt_file, kill_after, transient_faults

from repro.checkpoint.checkpoint import CheckpointCorruptError
from repro.core.orchestrator import (Preempted, SweepRunConfig,
                                     run_sweep_system, run_sweep_timeline,
                                     run_sweep_tlb)
from repro.core.sparta import SystemLatencies, TLBConfig
from repro.core.sweep import TLBSweepSpec, sweep_system, sweep_tlb
from repro.core.timeline import TimelineConfig, TimelineSpec, sweep_timeline
from repro.core.tlbsim import SystemSimConfig
from repro.runtime.fault_tolerance import PreemptionHandler

LAT = SystemLatencies()
BLOCK = 128


def _cfg(tmp_path, **kw):
    kw.setdefault("backoff_base_s", 0.0)
    kw.setdefault("backoff_cap_s", 0.0)
    kw.setdefault("preemption", PreemptionHandler(install=False))
    return SweepRunConfig(checkpoint_dir=str(tmp_path), **kw)


# ---------------------------------------------------------------------------
# One harness per engine: run(cfg) -> (list of output arrays, meta); the
# oracle is the monolithic engine on the same inputs.  Every case is sized to
# exactly 4 macro-chunks so the kill points cover every interior boundary.
# ---------------------------------------------------------------------------

def _tlb_engine():
    rng = np.random.default_rng(7)
    addrs = rng.integers(0, 1 << 22, 4096).astype(np.int64)
    specs = [TLBSweepSpec(TLBConfig(entries=64, ways=4), num_partitions=p)
             for p in (1, 8)]

    def run(cfg, kernel_mode="reference"):
        res, meta = run_sweep_tlb(addrs, specs, kernel_mode=kernel_mode,
                                  block=BLOCK, run=cfg, name="tlb")
        return [res.hits], meta

    oracle = [sweep_tlb(addrs, specs, kernel_mode="reference",
                        block=BLOCK).hits]
    return run, oracle, 4096, 1024, "tlb.ckpt"


def _system_engine():
    rng = np.random.default_rng(11)
    lines = rng.integers(0, 1 << 26, 4096).astype(np.int64)
    cfgs = [
        SystemSimConfig(num_partitions=8),
        SystemSimConfig(accel_tlb=TLBConfig(entries=16, ways=4),
                        num_partitions=4),
        SystemSimConfig(cache=None, page_shift=21, num_partitions=32),
    ]

    def run(cfg, kernel_mode="reference"):
        bev, meta = run_sweep_system(lines, cfgs, kernel_mode=kernel_mode,
                                     block=BLOCK, run=cfg, name="system")
        return [bev.cache_hit, bev.accel_tlb_hit, bev.mem_tlb_hit], meta

    o = sweep_system(lines, cfgs, kernel_mode="reference", block=BLOCK)
    oracle = [o.cache_hit, o.accel_tlb_hit, o.mem_tlb_hit]
    return run, oracle, 4096, 1024, "system.ckpt"


def _timeline_engine():
    rng = np.random.default_rng(3)
    lines_a = rng.integers(0, 1 << 24, 2048).astype(np.int64)
    lines_b = rng.integers(0, 1 << 24, 1200).astype(np.int64)
    ev_a = sweep_system(lines_a, [SystemSimConfig(num_partitions=8)])[0]
    ev_b = sweep_system(lines_b, [SystemSimConfig(num_partitions=2)])[0]
    specs = [
        TimelineSpec(lines_a, ev_a, "sparta",
                     cfg=TimelineConfig(mshrs=4, tlb_ports=1, dram_banks=8),
                     num_partitions=8, num_accelerators=2),
        TimelineSpec(lines_b, ev_b, "ideal",
                     cfg=TimelineConfig(mshrs=2, tlb_ports=1, dram_banks=4),
                     num_accelerators=4),
    ]

    def run(cfg, kernel_mode="reference"):
        res, meta = run_sweep_timeline(specs, LAT, kernel_mode=kernel_mode,
                                       block=BLOCK, run=cfg, name="timeline")
        return [a for r in res for a in (r.latency, r.overhead, r.done)], meta

    oracle = [a for r in sweep_timeline(specs, LAT, kernel_mode="reference",
                                        block=BLOCK)
              for a in (r.latency, r.overhead, r.done)]
    return run, oracle, 2048, 512, "timeline.ckpt"


_BUILDERS = {"tlb": _tlb_engine, "system": _system_engine,
             "timeline": _timeline_engine}
_CASES = {}


def _engine(name):
    if name not in _CASES:   # trace + oracle built once per engine
        _CASES[name] = _BUILDERS[name]()
    return _CASES[name]


def _assert_bits(got, want, ctx=""):
    assert len(got) == len(want)
    for i, (a, b) in enumerate(zip(got, want)):
        np.testing.assert_array_equal(a, b, err_msg=f"{ctx} output {i}")


# ---------------------------------------------------------------------------
# Kill-at-every-chunk-boundary + resume == uninterrupted run.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["tlb", "system", "timeline"])
@pytest.mark.parametrize("kill", [1, 2, 3])
def test_kill_and_resume_bit_identical(tmp_path, engine, kill):
    run, oracle, total, chunk, blob = _engine(engine)
    with pytest.raises(SimulatedKill):
        run(_cfg(tmp_path, chunk_accesses=chunk,
                 on_chunk_committed=kill_after(kill)))
    assert (tmp_path / blob).exists()  # the commit the kill tore us from

    outs, meta = run(_cfg(tmp_path, chunk_accesses=chunk, resume=True))
    assert meta["resumed_from"] == kill * chunk
    assert meta["chunks_committed"] == 4  # killed run's commits carry over
    _assert_bits(outs, oracle, ctx=f"{engine} kill@{kill}")


@pytest.mark.parametrize("engine", ["tlb", "system", "timeline"])
def test_kill_after_final_chunk_then_resume(tmp_path, engine):
    """Death between the last chunk commit and the completed-marker write:
    resume re-enters at now == total, runs zero chunks, and finalises."""
    run, oracle, total, chunk, _ = _engine(engine)
    with pytest.raises(SimulatedKill):
        run(_cfg(tmp_path, chunk_accesses=chunk,
                 on_chunk_committed=kill_after(4)))
    outs, meta = run(_cfg(tmp_path, chunk_accesses=chunk, resume=True))
    assert meta["resumed_from"] == total
    _assert_bits(outs, oracle, ctx=f"{engine} kill@final")


def test_clean_run_leaves_no_blob_and_matches_oracle(tmp_path):
    run, oracle, _, chunk, blob = _engine("tlb")
    outs, meta = run(_cfg(tmp_path, chunk_accesses=chunk))
    _assert_bits(outs, oracle)
    assert meta["chunks_committed"] == 4 and meta["resumable"]
    assert not (tmp_path / blob).exists()   # fresh clean run cleans up

    outs2, _ = run(_cfg(tmp_path, chunk_accesses=chunk, keep_checkpoint=True))
    _assert_bits(outs2, oracle)
    assert (tmp_path / blob).exists()       # unless asked to keep the blob


def test_completed_checkpoint_short_circuits_rerun(tmp_path):
    run, oracle, total, chunk, _ = _engine("system")
    with pytest.raises(SimulatedKill):
        run(_cfg(tmp_path, chunk_accesses=chunk,
                 on_chunk_committed=kill_after(2)))
    outs1, meta1 = run(_cfg(tmp_path, chunk_accesses=chunk, resume=True))
    assert meta1["resumed_from"] == 2 * chunk
    # A --resume run keeps its completed blob; rerunning is a pure read.
    outs2, meta2 = run(_cfg(tmp_path, chunk_accesses=chunk, resume=True))
    assert meta2["completed_from_checkpoint"]
    assert meta2["resumed_from"] == total
    _assert_bits(outs1, oracle)
    _assert_bits(outs2, oracle)


# ---------------------------------------------------------------------------
# Refusal: corrupt / truncated / foreign / mismatched checkpoints.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("damage", ["flip", "truncate"])
def test_corrupt_checkpoint_refused(tmp_path, damage):
    run, _, _, chunk, blob = _engine("tlb")
    with pytest.raises(SimulatedKill):
        run(_cfg(tmp_path, chunk_accesses=chunk,
                 on_chunk_committed=kill_after(1)))
    corrupt_file(tmp_path / blob, mode=damage)
    with pytest.raises(CheckpointCorruptError, match="refusing to resume"):
        run(_cfg(tmp_path, chunk_accesses=chunk, resume=True))


def test_fingerprint_mismatch_refused(tmp_path):
    """A valid blob taken on a *different trace* must not resume this one."""
    rng = np.random.default_rng(0)
    specs = [TLBSweepSpec(TLBConfig(entries=64, ways=4))]
    a = rng.integers(0, 1 << 20, 2048).astype(np.int64)
    with pytest.raises(SimulatedKill):
        run_sweep_tlb(a, specs, kernel_mode="reference", block=BLOCK,
                      name="fp",
                      run=_cfg(tmp_path, chunk_accesses=512,
                               on_chunk_committed=kill_after(1)))
    with pytest.raises(CheckpointCorruptError, match="fingerprint mismatch"):
        run_sweep_tlb(a + 1, specs, kernel_mode="reference", block=BLOCK,
                      name="fp",
                      run=_cfg(tmp_path, chunk_accesses=512, resume=True))


def test_wrong_engine_checkpoint_refused(tmp_path):
    """A blob written by one engine is refused by another under the same
    name (engine tag checked before anything is imported)."""
    run, _, _, chunk, blob = _engine("tlb")
    with pytest.raises(SimulatedKill):
        run(_cfg(tmp_path, chunk_accesses=chunk,
                 on_chunk_committed=kill_after(1)))
    lines = np.arange(1024, dtype=np.int64) * 64
    with pytest.raises(CheckpointCorruptError, match="was written by"):
        run_sweep_system(lines, [SystemSimConfig()], kernel_mode="reference",
                         block=BLOCK, name="tlb",   # collides with tlb.ckpt
                         run=_cfg(tmp_path, resume=True))


# ---------------------------------------------------------------------------
# The degradation ladder.
# ---------------------------------------------------------------------------

def test_ladder_retry_halve_downgrade_order_and_bit_identity(tmp_path):
    """Every non-reference attempt faults with RESOURCE_EXHAUSTED: the run
    must retry, then halve (block-aligned), then downgrade — in that order —
    finish on 'reference', log every step, and still match the oracle."""
    run, oracle, _, chunk, _ = _engine("tlb")
    seen = []
    outs, meta = run(
        _cfg(tmp_path, chunk_accesses=chunk, max_retries=1,
             fault_hook=transient_faults(log=seen)),
        kernel_mode="pallas_interpret")
    _assert_bits(outs, oracle, ctx="ladder")
    assert meta["start_mode"] == "pallas_interpret"
    assert meta["final_mode"] == "reference"          # sticky downgrade
    names = [e["event"] for e in meta["events"]]
    # Order within the first macro-chunk: retries exhaust, the span halves,
    # retries exhaust on the first half, the backend downgrades.
    assert names[:5] == ["retry", "retry", "halve", "retry", "retry"]
    assert "downgrade" in names
    down = meta["events"][names.index("downgrade")]
    assert down["to_mode"] == "reference"
    assert "RESOURCE_EXHAUSTED" in down["error"]
    h = next(e for e in meta["events"] if e["event"] == "halve")
    assert (h["mid"] - h["lo"]) % BLOCK == 0          # block-aligned split
    # After the downgrade no attempt ran a failing mode again.
    first_ref = next(i for i, s in enumerate(seen) if s[3] == "reference")
    assert all(s[3] == "reference" for s in seen[first_ref:])


def test_ladder_events_survive_resume(tmp_path):
    """Downgrades are sticky across a kill: the resumed run re-enters at the
    checkpointed rung and its meta still carries the pre-kill events."""
    run, oracle, _, chunk, _ = _engine("tlb")
    kill = kill_after(2)

    def fault_then_kill(i):
        kill(i)

    with pytest.raises(SimulatedKill):
        run(_cfg(tmp_path, chunk_accesses=chunk, max_retries=0,
                 fault_hook=transient_faults(),
                 on_chunk_committed=fault_then_kill),
            kernel_mode="pallas_interpret")
    outs, meta = run(_cfg(tmp_path, chunk_accesses=chunk, resume=True),
                     kernel_mode="pallas_interpret")
    _assert_bits(outs, oracle, ctx="resume-after-downgrade")
    assert meta["final_mode"] == "reference"
    assert any(e["event"] == "downgrade" for e in meta["events"])
    # Halving had shrunk the spans to single blocks before the downgrade, so
    # the two pre-kill commits cover exactly two kernel blocks.
    assert meta["resumed_from"] == 2 * BLOCK


def test_checkpoint_write_failure_propagates_without_double_apply(
        tmp_path, monkeypatch):
    """The high-stakes seam: run_chunk succeeds (stream state has advanced),
    then the checkpoint write fails with an OSError whose errno *is* in the
    transient whitelist.  The failure must propagate — NOT be retried as if
    the chunk itself had failed, which would re-apply the chunk to the
    already-advanced state and then checkpoint the corrupted prefix — and
    the previous blob must remain the durable resume point."""
    import errno

    import repro.core.orchestrator as orch

    run, oracle, _, chunk, blob = _engine("tlb")
    real_write = orch.write_checkpoint_blob
    writes = {"n": 0}

    def flaky_write(path, arrays, meta):
        writes["n"] += 1
        if writes["n"] == 3:            # fail the 3rd chunk's commit
            raise OSError(errno.EIO, "injected EIO on checkpoint write")
        return real_write(path, arrays, meta)

    attempts = []
    monkeypatch.setattr(orch, "write_checkpoint_blob", flaky_write)
    with pytest.raises(OSError, match="injected EIO"):
        run(_cfg(tmp_path, chunk_accesses=chunk,
                 fault_hook=lambda eng, lo, hi, mode, att:
                     attempts.append((lo, att))))
    # Every chunk was attempted exactly once — the write failure was never
    # fed back into the retry/halve/downgrade ladder.
    assert [a for _, a in attempts] == [0, 0, 0]
    assert len({lo for lo, _ in attempts}) == 3
    assert (tmp_path / blob).exists()   # chunk 2's blob survived untouched

    monkeypatch.setattr(orch, "write_checkpoint_blob", real_write)
    outs, meta = run(_cfg(tmp_path, chunk_accesses=chunk, resume=True))
    assert meta["resumed_from"] == 2 * chunk   # chunk 3's commit never landed
    assert meta["chunks_committed"] == 4       # 2 durable + 2 resumed
    _assert_bits(outs, oracle, ctx="resume-after-ckpt-write-failure")


def test_non_transient_error_raises_immediately(tmp_path):
    run, _, _, chunk, blob = _engine("tlb")
    seen = []

    def hook(engine, lo, hi, mode, attempt):
        seen.append(attempt)
        raise ValueError("config bug — not a runtime fault")

    with pytest.raises(ValueError, match="config bug"):
        run(_cfg(tmp_path, chunk_accesses=chunk, fault_hook=hook))
    assert seen == [0]                     # no retry, no ladder
    assert not (tmp_path / blob).exists()  # nothing was committed


# ---------------------------------------------------------------------------
# Preemption and the stackdist monolithic path.
# ---------------------------------------------------------------------------

def test_preemption_checkpoints_at_chunk_boundary_then_resumes(tmp_path):
    run, oracle, _, chunk, blob = _engine("tlb")
    handler = PreemptionHandler(install=False)

    def sigterm_mid_run(i):
        if i >= 1:           # "signal" lands during chunk 2
            handler.requested = True

    with pytest.raises(Preempted) as exc:
        run(_cfg(tmp_path, chunk_accesses=chunk, preemption=handler,
                 on_chunk_committed=sigterm_mid_run))
    assert exc.value.now == 2 * chunk
    assert "--resume" in str(exc.value)
    assert (tmp_path / blob).exists()
    outs, meta = run(_cfg(tmp_path, chunk_accesses=chunk, resume=True))
    assert meta["resumed_from"] == 2 * chunk
    _assert_bits(outs, oracle, ctx="preempted")


def test_stackdist_path_is_monolithic_and_not_resumable(tmp_path):
    """'auto' on a pure-LRU TLB sweep resolves to the sort-based stackdist
    engine, which needs the whole trace: it runs monolithically, writes no
    checkpoint, and says so in its meta."""
    rng = np.random.default_rng(5)
    addrs = rng.integers(0, 1 << 20, 2048).astype(np.int64)
    specs = [TLBSweepSpec(TLBConfig(entries=64, ways=4), num_partitions=p)
             for p in (1, 4)]
    res, meta = run_sweep_tlb(addrs, specs, kernel_mode="auto", block=BLOCK,
                              name="sd", run=_cfg(tmp_path, chunk_accesses=512))
    assert meta["resumable"] is False
    assert meta["start_mode"] == "stackdist"
    assert not list(tmp_path.glob("*.ckpt"))
    ref = sweep_tlb(addrs, specs, kernel_mode="reference", block=BLOCK)
    np.testing.assert_array_equal(res.hits, ref.hits)
