"""Oracle-equivalence for the batched timeline engine (sweep_timeline).

The per-sim :func:`repro.core.timeline.simulate_timeline` is the reference
path; every ``sweep_timeline`` result must match it **bit-exactly** across
heterogeneous envelopes — mixed designs, accelerator counts,
bounded/unbounded resources, partition counts, page sizes and *unequal trace
lengths* — on both the batched ``lax.scan`` and the batched Pallas
(interpret) backends.  A padding-poisoning property test asserts that a
sim's outputs are independent of how much envelope/trace padding its
batch-mates force on it.
"""
import numpy as np
import pytest
from _propcheck import given, settings, st  # hypothesis, or deterministic fallback

from repro.core import timeline, traces
from repro.core.sparta import SystemLatencies, TLBConfig
from repro.core.sweep import sweep_system
from repro.core.timeline import TimelineConfig, TimelineSpec, sweep_timeline
from repro.core.tlbsim import SystemSimConfig
from repro.kernels.timeline import resolve_timeline_mode, timeline_sim
from repro.kernels.timeline.ref import TimelineParams

LAT = SystemLatencies()
CACHE = TLBConfig(entries=256, ways=4)
MEM_TLB = TLBConfig(entries=128, ways=4)


def _events(lines, num_partitions=32, accel_tlb=None, page_shift=12):
    return sweep_system(lines, [SystemSimConfig(
        cache=CACHE, accel_tlb=accel_tlb, mem_tlb=MEM_TLB,
        num_partitions=num_partitions, page_shift=page_shift)])[0]


def _reference(sp: TimelineSpec):
    """The per-sim oracle run of one spec."""
    return timeline.simulate_timeline(
        sp.lines, sp.events, sp.design, sp.lat or LAT, cfg=sp.cfg,
        num_partitions=sp.num_partitions, page_shift=sp.page_shift,
        num_accelerators=sp.num_accelerators, accel_ids=sp.accel_ids,
        workload=sp.workload, way_accuracy=sp.way_accuracy,
        kernel_mode="reference")


def _assert_bit_identical(got, want, ctx=""):
    for k in ("latency", "overhead", "done"):
        a, b = getattr(got, k), getattr(want, k)
        assert np.array_equal(a, b), (ctx, k, np.abs(a - b).max())


def _heterogeneous_specs(seed: int):
    """Mixed designs / accel counts / resource bounds / trace lengths."""
    rng = np.random.default_rng(seed)
    tr_a = traces.generate("bst_external", n_ops=350, max_accesses=2600)
    tr_b = traces.generate("hash_table", n_ops=250, max_accesses=1700)
    lines_c = rng.integers(0, 1 << 26, 900).astype(np.int64)
    ev_conv = _events(tr_a.lines, num_partitions=1,
                      accel_tlb=TLBConfig(entries=128, ways=4))
    ev_sparta = _events(tr_a.lines, num_partitions=32)
    ev_b = _events(tr_b.lines, num_partitions=8)
    ev_c = _events(lines_c, num_partitions=4, page_shift=21)
    return [
        TimelineSpec(tr_a.lines, ev_conv, "conventional",
                     cfg=TimelineConfig(mshrs=8, tlb_ports=1, dram_banks=16),
                     num_accelerators=4),
        TimelineSpec(tr_a.lines, ev_sparta, "sparta",
                     cfg=TimelineConfig(mshrs=4, tlb_ports=2, dram_banks=8),
                     num_partitions=32, num_accelerators=2),
        TimelineSpec(tr_b.lines, ev_b, "sparta",
                     cfg=TimelineConfig.unbounded(),  # no queueing anywhere
                     num_partitions=8, num_accelerators=16),
        TimelineSpec(tr_b.lines, ev_b, "dipta", workload="hash_table",
                     cfg=TimelineConfig(mshrs=2, tlb_ports=0, dram_banks=4)),
        TimelineSpec(lines_c, ev_c, "ideal", page_shift=21,
                     cfg=TimelineConfig(mshrs=1, tlb_ports=0, dram_banks=2),
                     num_accelerators=8),
    ]


@settings(deadline=None, max_examples=3)
@given(st.integers(0, 10_000))
def test_sweep_timeline_bitexact_vs_oracle(seed):
    specs = _heterogeneous_specs(seed)
    res = sweep_timeline(specs, LAT, kernel_mode="reference")
    assert len(res) == len(specs)
    for i, sp in enumerate(specs):
        ref = _reference(sp)
        assert res[i].latency.shape == (sp.lines.shape[0],)
        _assert_bit_identical(res[i], ref, ctx=(i, sp.design))
        assert res[i].n_warm == ref.n_warm
        # Derived reductions ride along exactly.
        assert res[i].mean_latency == ref.mean_latency
        assert res[i].overhead_percentile(99) == ref.overhead_percentile(99)


def test_sweep_timeline_pallas_interpret_matches_reference():
    specs = _heterogeneous_specs(3)
    ref = sweep_timeline(specs, LAT, kernel_mode="reference")
    pal = sweep_timeline(specs, LAT, kernel_mode="pallas_interpret", block=256)
    for i in range(len(specs)):
        _assert_bit_identical(pal[i], ref[i], ctx=i)


def test_sweep_timeline_vmem_chunking(monkeypatch):
    """A tight VMEM budget splits the sim axis into chunks — results
    unchanged, every sim lands in exactly one chunk."""
    monkeypatch.setattr(timeline, "_VMEM_STATE_BUDGET_BYTES", 48 * 1024)
    specs = _heterogeneous_specs(5)
    dims = [(sp.num_accelerators, max(sp.cfg.mshrs, 1),
             max(sp.num_partitions if sp.design == "sparta" else 1, 1),
             max(sp.cfg.tlb_ports, 1), max(sp.cfg.dram_banks, 1))
            for sp in specs]
    chunks = timeline._timeline_vmem_chunks(dims, block=256)
    assert len(chunks) > 1  # the budget actually forces a split
    assert sorted(i for c in chunks for i in c) == list(range(len(specs)))
    ref = sweep_timeline(specs, LAT, kernel_mode="reference")
    pal = sweep_timeline(specs, LAT, kernel_mode="pallas_interpret", block=256)
    for i in range(len(specs)):
        _assert_bit_identical(pal[i], ref[i], ctx=i)


@settings(deadline=None, max_examples=4)
@given(st.integers(0, 10_000), st.sampled_from([1, 317, 900]))
def test_padding_poisoning_is_unobservable(seed, cut):
    """The property behind the batching discipline: a sim's outputs do not
    depend on its batch-mates.  A short sim (trace cut to ``cut`` accesses,
    small resources) is padded up to whatever envelope the largest mate
    forces — trailing poisoned cache hits, poisoned port columns, untouched
    MSHR/bank slots — and must come out bit-identical to its solo run."""
    rng = np.random.default_rng(seed)
    lines = rng.integers(0, 1 << 24, 900).astype(np.int64)
    short_lines = lines[:cut]
    ev_short = _events(short_lines, num_partitions=2)
    short = TimelineSpec(short_lines, ev_short, "sparta",
                         cfg=TimelineConfig(mshrs=2, tlb_ports=1, dram_banks=4),
                         num_partitions=2, num_accelerators=2)
    big = TimelineSpec(lines, _events(lines, num_partitions=64), "sparta",
                       cfg=TimelineConfig(mshrs=16, tlb_ports=4, dram_banks=32),
                       num_partitions=64, num_accelerators=16)
    solo = _reference(short)
    for batch in ([short], [short, big], [big, short, big]):
        res = sweep_timeline(batch, LAT, kernel_mode="reference")
        got = res[batch.index(short)]
        _assert_bit_identical(got, solo, ctx=("batch-size", len(batch)))


def test_sweep_timeline_rejects_empty_and_missing_lat():
    with pytest.raises(ValueError, match="at least one"):
        sweep_timeline([], LAT)
    lines = np.arange(64, dtype=np.int64)
    sp = TimelineSpec(lines, _events(lines), "ideal")
    with pytest.raises(ValueError, match="lat"):
        sweep_timeline([sp])  # no sweep-level lat, no per-spec lat
    # Per-spec lat alone is fine.
    sweep_timeline([TimelineSpec(lines, _events(lines), "ideal", lat=LAT)])


def test_timeline_rejects_sweep_only_modes():
    """No silent coercion: sweep-only backends raise, naming the valid
    timeline modes (the old fig11 behaviour mapped "stackdist" -> "auto")."""
    lines = np.arange(128, dtype=np.int64)
    ev = _events(lines)
    sp = TimelineSpec(lines, ev, "ideal")
    for call in (
        lambda: sweep_timeline([sp], LAT, kernel_mode="stackdist"),
        lambda: timeline.simulate_timeline(lines, ev, "ideal", LAT,
                                           kernel_mode="stackdist"),
    ):
        with pytest.raises(ValueError, match="stackdist.*timeline"):
            call()
    with pytest.raises(ValueError):
        resolve_timeline_mode("bogus")


def test_auto_mode_is_batch_aware(monkeypatch):
    """The degenerate batch (1 sim) never auto-selects the Pallas path — a
    single sequential sim gives the kernel nothing to amortize (the measured
    0.87x BENCH_sweep.json regression) — while multi-sim batches auto-select
    the batched kernel on TPU backends.  Explicit modes are honoured."""
    import repro.kernels.common as kc

    for backend in ("cpu", "tpu"):
        monkeypatch.setattr(kc.jax, "default_backend", lambda b=backend: b)
        assert resolve_timeline_mode("auto", batch=1) == "reference"
    assert resolve_timeline_mode("auto", batch=8) == "pallas"  # still "tpu"
    monkeypatch.setattr(kc.jax, "default_backend", lambda: "cpu")
    assert resolve_timeline_mode("auto", batch=8) == "reference"
    assert resolve_timeline_mode("pallas", batch=1) == "pallas"
    assert resolve_timeline_mode("pallas_interpret", batch=8) == "pallas_interpret"


def test_single_sim_auto_runs_reference_even_if_kernel_breaks(monkeypatch):
    """simulate_timeline(kernel_mode="auto") must never reach the Pallas
    path for its single sequential sim, whatever the backend."""
    import repro.kernels.timeline.ops as ops

    monkeypatch.setattr(
        ops, "timeline_sim_pallas",
        lambda *a, **k: pytest.fail("auto selected the single-sim Pallas path"))
    lines = np.arange(256, dtype=np.int64) * 64
    ev = _events(lines)
    timeline.simulate_timeline(lines, ev, "sparta", LAT, num_partitions=32,
                               kernel_mode="auto")


def test_batched_engine_single_scan(monkeypatch):
    """sweep_timeline invokes ONE batched scan per sweep — never the per-sim
    scan — however many sims ride along (the fig11 property)."""
    import repro.kernels.timeline.ops as ops
    from repro.kernels.timeline import ref as tlref

    calls = {"batched": 0}
    real = tlref.timeline_scan_batched_ref

    def counting(*a, **k):
        calls["batched"] += 1
        return real(*a, **k)

    monkeypatch.setattr(ops, "timeline_scan_batched_ref", counting)
    monkeypatch.setattr(
        ops, "timeline_scan_ref",
        lambda *a, **k: pytest.fail("per-sim scan used inside sweep_timeline"))
    specs = _heterogeneous_specs(1)
    sweep_timeline(specs, LAT, kernel_mode="reference")
    assert calls["batched"] == 1


def test_pack_params_roundtrip():
    """The packed rows carry exactly the step's parameterisation, including
    the pre-rounded conventional walk round-trip term."""
    from repro.kernels.timeline import pack_params

    p = TimelineParams(serial_walk=True, num_accels=3, mshrs=5,
                       num_partitions=7, tlb_ports=2, dram_banks=9,
                       l_cache=2.0, l_tlb=3.0, l_dram=111.0, t_net=390.5,
                       tlb_occ=4.0, dram_occ=100.0, issue_interval=2.0)
    fp, ip = pack_params(p)
    assert fp.dtype == np.float32 and ip.dtype == np.int32
    assert fp[4] == np.float32(2.0 * 390.5)
    assert list(ip) == [1, 0, 3, 5, 7, 2, 9]
