"""Tests for the perf-measurement core (`repro.core.benchtime`) and the
ReFrame-style perf-regression gate (`benchmarks/perfcheck.py` +
`benchmarks.kernel_bench.check_bench_history`)."""
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks import kernel_bench, perfcheck
from repro.core import benchtime

SLEEP_S = 0.05


# ---------------------------------------------------------------- benchtime


def _sleepy_fn(counter):
    """A jit function whose compute takes >= SLEEP_S wall time but whose
    dispatch may return immediately (async) — the case the old timers got
    wrong."""

    def host_sleep(x):
        counter["calls"] += 1
        time.sleep(SLEEP_S)
        return np.asarray(x)

    @jax.jit
    def fn(x):
        y = jax.pure_callback(host_sleep, jax.ShapeDtypeStruct(x.shape, x.dtype), x)
        return y + 1.0

    return fn


def test_measure_blocks_every_rep():
    counter = {"calls": 0}
    fn = _sleepy_fn(counter)
    x = jnp.ones(8, jnp.float32)
    m = benchtime.measure(fn, x, reps=3, warmup=1)
    # Warm-up + every rep actually ran the computation.
    assert counter["calls"] == 4
    # Every rep's timed window contains the full >= SLEEP_S compute: a
    # timer that stops at dispatch (no block_until_ready) records ~0 here.
    assert all(t >= SLEEP_S * 0.9 for t in m.times_s), m.times_s
    assert m.best_s >= SLEEP_S * 0.9


def test_measure_statistics_monotonic():
    m = benchtime.Measurement(times_s=(0.5, 0.2, 0.4))
    assert m.best_s == 0.2
    assert m.best_s <= m.mean_s <= max(m.times_s)
    assert m.spread_frac == pytest.approx((0.5 - 0.2) / 0.2)
    assert m.best_us == pytest.approx(0.2e6)


def test_measure_rejects_zero_reps():
    with pytest.raises(ValueError):
        benchtime.measure(lambda: None, reps=0)


def test_block_traverses_containers_and_dataclasses():
    @dataclasses.dataclass(frozen=True)
    class Res:
        a: object
        b: object

    x = jnp.arange(4)
    obj = Res(a=[x, np.arange(3)], b={"k": (x, None)})
    assert benchtime.block(obj) is obj
    assert benchtime.block(None) is None


def test_device_metadata_schema():
    md = benchtime.device_metadata()
    assert md["schema_version"] == benchtime.SCHEMA_VERSION
    for k in ("device_kind", "platform", "device_count", "jax_version"):
        assert md[k], md


# ---------------------------------------------------------------- perfcheck


def _row(**kw):
    base = {
        "schema_version": 2, "written_at": "2026-08-08 00:00:00",
        "bench": "sweep", "backend": "cpu", "quick": True,
        "device_kind": "cpu", "platform": "cpu", "device_count": 1,
        "jax_version": jax.__version__,
        "t_reference_s": 1.0, "t_stackdist_s": 0.2,
        "speedup": 5.0, "bit_identical": True,
    }
    base.update(kw)
    return base


def _refs(tol=(-0.5, 0.5)):
    return {"schema_version": 2, "references": {
        "sweep|cpu|-|quick": {
            "device_kind": "cpu",
            "metrics": {
                "t_reference_s": {"ref": 1.0, "tol": list(tol)},
                "t_stackdist_s": {"ref": 0.2, "tol": list(tol)},
            },
        },
    }}


def test_check_rows_within_band_passes():
    fails, warns, n_checked, n_legacy = perfcheck.check_rows(
        [_row(t_reference_s=1.2, t_stackdist_s=0.15)], _refs())
    assert not fails and not warns
    assert n_checked == 1 and n_legacy == 0


def test_check_rows_regression_fails():
    fails, _, _, _ = perfcheck.check_rows([_row(t_reference_s=2.0)], _refs())
    assert len(fails) == 1
    assert "t_reference_s" in fails[0] and "regression" in fails[0]


def test_check_rows_too_fast_fails():
    # Below the lower band: usually a broken timer or skipped workload.
    fails, _, _, _ = perfcheck.check_rows([_row(t_stackdist_s=0.01)], _refs())
    assert len(fails) == 1 and "suspiciously" in fails[0]


def test_check_rows_abs_slack_widens_upper_bound_only():
    refs = _refs()
    metrics = refs["references"]["sweep|cpu|-|quick"]["metrics"]
    for spec in metrics.values():
        spec["abs_slack_s"] = 1.0
    # 2.0 > 1.0*1.5 relatively, but within the +1s absolute slack.
    fails, _, _, _ = perfcheck.check_rows([_row(t_reference_s=2.0)], refs)
    assert not fails
    # The slack does not protect the lower (too-fast) bound.
    fails, _, _, _ = perfcheck.check_rows([_row(t_stackdist_s=0.01)], refs)
    assert len(fails) == 1


def test_check_rows_unknown_device_warns_and_passes():
    fails, warns, n_checked, _ = perfcheck.check_rows(
        [_row(device_kind="TPU v4", t_reference_s=99.0)], _refs())
    assert not fails and len(warns) == 1
    assert "TPU v4" in warns[0]
    assert n_checked == 0


def test_warn_pass_is_string_with_key_and_reason():
    # Warn-pass messages stay plain strings for human logs but carry the
    # machine-readable row key + reason the summary aggregates.
    _, warns, _, _ = perfcheck.check_rows(
        [_row(device_kind="TPU v4")], _refs())
    w = warns[0]
    assert isinstance(w, str)
    assert w.key == "sweep|cpu|-|quick" and w.reason == "device_mismatch"
    _, warns, _, _ = perfcheck.check_rows([_row(bench="timeline")], _refs())
    assert warns[0].reason == "unreferenced"


def test_check_perf_history_returns_parseable_summary(tmp_path, capsys):
    hist = tmp_path / "BENCH_sweep.json"
    hist.write_text(json.dumps(
        {"history": [_row(bench="timeline"), _row(bench="timeline")]}))
    summary = perfcheck.check_perf_history(hist, tmp_path / "refs.json")
    assert summary["n_failures"] == 0 and summary["n_checked"] == 0
    assert summary["warn_pass"]["count"] == 2
    assert summary["warn_pass"]["keys"] == ["timeline|cpu|-|quick"]
    assert summary["warn_pass"]["reasons"] == {"unreferenced": 2}
    # The CI log carries the summary as one parseable JSON line.
    line = [ln for ln in capsys.readouterr().out.splitlines()
            if "perfcheck summary:" in ln][0]
    assert json.loads(line.split("perfcheck summary:", 1)[1]) == summary


def test_check_rows_unreferenced_key_warns_and_passes():
    fails, warns, _, _ = perfcheck.check_rows(
        [_row(bench="timeline", mode="pallas", backend="tpu")], _refs())
    assert not fails and len(warns) == 1


def test_check_rows_legacy_rows_skipped():
    legacy = _row(t_reference_s=500.0)
    del legacy["schema_version"]
    fails, warns, n_checked, n_legacy = perfcheck.check_rows([legacy], _refs())
    assert not fails and not warns
    assert n_checked == 0 and n_legacy == 1


def test_check_rows_missing_metric_fails():
    row = _row()
    del row["t_stackdist_s"]
    fails, _, _, _ = perfcheck.check_rows([row], _refs())
    assert len(fails) == 1 and "missing" in fails[0]


def test_check_perf_history_raises_on_failure(tmp_path):
    hist = tmp_path / "BENCH_sweep.json"
    refs = tmp_path / "references.json"
    hist.write_text(json.dumps({"history": [_row(t_reference_s=3.0)]}))
    refs.write_text(json.dumps(_refs()))
    with pytest.raises(SystemExit, match="perf-regression gate"):
        perfcheck.check_perf_history(hist, refs)


def test_load_history_corrupt_fails_loudly(tmp_path):
    bad = tmp_path / "BENCH_sweep.json"
    bad.write_text("{not json")
    with pytest.raises(SystemExit, match="corrupt"):
        perfcheck.load_history(bad)
    bad.write_text(json.dumps([1, 2, 3]))
    with pytest.raises(SystemExit, match="history"):
        perfcheck.load_history(bad)


def test_update_references_baselines_and_preserves_tol(tmp_path):
    refs = tmp_path / "references.json"
    refs.write_text(json.dumps(_refs(tol=(-0.2, 0.1))))
    # Latest row per key wins; hand-edited tolerance survives re-baselining.
    hist = [_row(t_reference_s=9.0), _row(t_reference_s=4.0, t_stackdist_s=0.8)]
    doc = perfcheck.update_references(hist, refs)
    entry = doc["references"]["sweep|cpu|-|quick"]
    assert entry["metrics"]["t_reference_s"]["ref"] == 4.0
    assert entry["metrics"]["t_reference_s"]["tol"] == [-0.2, 0.1]
    # The freshly baselined history now passes its own gate.
    fails, warns, n_checked, _ = perfcheck.check_rows(
        [hist[-1]], json.loads(refs.read_text()))
    assert not fails and not warns and n_checked == 1


# ------------------------------------------------- kernel_bench --check gate


def _full_history(**overrides):
    rows = [
        _row(),
        _row(bench="timeline", mode="pallas_interpret", t_pallas_s=0.1),
        _row(bench="timeline_batched", mode="pallas_interpret",
             t_looped_s=1.0, t_batched_s=0.2, t_pallas_s=0.9),
        _row(bench="system_batched", mode="pallas_interpret",
             t_looped_s=1.0, t_batched_s=0.5, t_pallas_s=0.6),
    ]
    for r in rows:
        r.update(overrides)
    return {"history": rows}


def test_check_bench_history_passes_on_clean_history(tmp_path, capsys):
    hist = tmp_path / "BENCH_sweep.json"
    hist.write_text(json.dumps(_full_history()))
    summary = kernel_bench.check_bench_history(
        hist, refs_path=tmp_path / "refs.json")
    out = capsys.readouterr().out
    assert "bit-identical" in out and "perfcheck" in out
    assert summary["warn_pass"]["count"] == len(_full_history()["history"])


def test_check_bench_history_missing_bench_fails(tmp_path):
    hist = tmp_path / "BENCH_sweep.json"
    doc = _full_history()
    doc["history"] = doc["history"][:2]  # drop the batched engines
    hist.write_text(json.dumps(doc))
    with pytest.raises(SystemExit, match="timeline_batched"):
        kernel_bench.check_bench_history(hist, refs_path=tmp_path / "refs.json")


def test_check_bench_history_bit_identity_fails(tmp_path):
    hist = tmp_path / "BENCH_sweep.json"
    hist.write_text(json.dumps(_full_history(bit_identical=False)))
    with pytest.raises(SystemExit, match="non-bit-identical"):
        kernel_bench.check_bench_history(hist, refs_path=tmp_path / "refs.json")


def test_check_bench_history_corrupt_history_fails(tmp_path):
    hist = tmp_path / "BENCH_sweep.json"
    hist.write_text("]{ definitely not json")
    with pytest.raises(SystemExit, match="corrupt"):
        kernel_bench.check_bench_history(hist, refs_path=tmp_path / "refs.json")


def test_append_bench_entry_refuses_corrupt_history(tmp_path, monkeypatch):
    bad = tmp_path / "BENCH_sweep.json"
    bad.write_text("{corrupt")
    monkeypatch.setattr(kernel_bench, "BENCH_SWEEP_PATH", bad)
    with pytest.raises(RuntimeError, match="refusing to overwrite"):
        kernel_bench._append_bench_entry({"bench": "sweep"})
    assert bad.read_text() == "{corrupt"  # history untouched


def test_append_bench_entry_stamps_schema(tmp_path, monkeypatch):
    path = tmp_path / "BENCH_sweep.json"
    monkeypatch.setattr(kernel_bench, "BENCH_SWEEP_PATH", path)
    kernel_bench._append_bench_entry({"bench": "sweep", "t_reference_s": 1.0})
    row = json.loads(path.read_text())["history"][0]
    assert row["schema_version"] == benchtime.SCHEMA_VERSION
    for k in ("device_kind", "platform", "device_count", "jax_version"):
        assert k in row, row


def test_repo_references_cover_required_cpu_benches():
    """The committed references.json must gate every required bench's quick
    CPU rows — the configuration CI actually records."""
    refs = perfcheck.load_references()["references"]
    for bench in kernel_bench.REQUIRED_BENCHES:
        matching = [k for k in refs
                    if k.startswith(f"{bench}|cpu|") and k.endswith("|quick")]
        assert matching, f"references.json has no quick CPU baseline for {bench}"
