"""Oracle-equivalence + poisoning properties for the batched joint-system
Pallas kernel (repro.kernels.system_sim) and its sweep_system wiring.

The per-config simulator ``simulate_system`` is the reference path; the
batched scan (``system_sim_batched_ref``) and the batched Pallas kernel
(``system_sim_batched_pallas``, run under the interpreter on CPU) must match
it **bit-exactly** across heterogeneous batches: mixed cache/accel presence,
probe policies, partition counts, page sizes, way-envelope padding, VMEM
chunking, and non-block-multiple trace tails.
"""
import numpy as np
import pytest
from _propcheck import given, settings, st  # hypothesis, or deterministic fallback

from repro.core import sweep
from repro.core.sparta import TLBConfig
from repro.core.sweep import _system_vmem_chunks, sweep_system
from repro.core.tlbsim import SystemSimConfig, simulate_system
from repro.kernels.system_sim import resolve_system_mode

HIT_KEYS = ("cache_hit", "accel_tlb_hit", "mem_tlb_hit")


def _random_lines(seed: int, n: int = 1111) -> np.ndarray:
    # Deliberately not a multiple of any block size: every kernel run
    # exercises the trace-tail padding parked in the extra set row.
    return np.random.default_rng(seed).integers(0, 1 << 28, n).astype(np.int64)


def _assert_rows_match(bev, cfgs, lines):
    for i, c in enumerate(cfgs):
        ev = simulate_system(lines, c)
        for k in HIT_KEYS:
            np.testing.assert_array_equal(
                getattr(bev, k)[i], getattr(ev, k), err_msg=f"cfg {i} {k}")


# ---------------------------------------------------------------------------
# Oracle equivalence.
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=3)
@given(st.integers(0, 10_000))
def test_system_kernel_bitexact_vs_oracle_heterogeneous(seed):
    """All three backends on a heterogeneous batch: every structure-presence
    combination, both probe policies, mixed partitions and page sizes."""
    lines = _random_lines(seed)
    cfgs = [
        SystemSimConfig(),                               # cache, no accel TLB
        SystemSimConfig(cache=None, num_partitions=8),   # cacheless
        SystemSimConfig(accel_tlb=TLBConfig(entries=8, ways=4),
                        num_partitions=4, accel_probe_on_miss_only=False),
        SystemSimConfig(accel_tlb=TLBConfig(entries=2, ways=4),   # entries < ways
                        page_shift=21, num_partitions=32),
        SystemSimConfig(mem_tlb=TLBConfig(entries=64, ways=8)),
        SystemSimConfig(cache=TLBConfig(entries=512, ways=8), num_partitions=16),
        SystemSimConfig(cache=None, accel_tlb=TLBConfig(entries=16, ways=2),
                        num_partitions=2, accel_probe_on_miss_only=False),
        SystemSimConfig(page_shift=21, num_partitions=128),
    ]
    ref = sweep_system(lines, cfgs, kernel_mode="reference")
    pal = sweep_system(lines, cfgs, kernel_mode="pallas_interpret", block=256)
    _assert_rows_match(ref, cfgs, lines)
    _assert_rows_match(pal, cfgs, lines)


def test_system_kernel_flags_are_data_not_structure():
    """One pallas_call serves present AND absent structures: flipping a
    config's flags must not perturb its batch neighbours (the flag-gating
    analogue of way poisoning)."""
    lines = _random_lines(3, n=900)
    base = SystemSimConfig(accel_tlb=TLBConfig(entries=16, ways=4),
                           num_partitions=4)
    neighbours = [
        SystemSimConfig(cache=None, num_partitions=4),
        SystemSimConfig(accel_tlb=None, num_partitions=4),
        SystemSimConfig(accel_tlb=TLBConfig(entries=16, ways=4),
                        num_partitions=4, accel_probe_on_miss_only=False),
    ]
    solo = sweep_system(lines, [base], kernel_mode="pallas_interpret", block=256)
    batched = sweep_system(lines, [base] + neighbours,
                           kernel_mode="pallas_interpret", block=256)
    for k in HIT_KEYS:
        np.testing.assert_array_equal(getattr(batched, k)[0], getattr(solo, k)[0])
    _assert_rows_match(batched, [base] + neighbours, lines)


# ---------------------------------------------------------------------------
# Padding / poisoning properties.
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=3)
@given(st.integers(0, 10_000))
def test_system_kernel_envelope_poisoning_invariance(seed):
    """A small config's rows are identical whether it runs alone (tight
    envelope) or stacked with a much larger config (every structure padded in
    sets AND ways): poisoned padding must be invisible."""
    lines = _random_lines(seed, n=800)
    small = SystemSimConfig(cache=TLBConfig(entries=8, ways=2),
                            accel_tlb=TLBConfig(entries=4, ways=2),
                            mem_tlb=TLBConfig(entries=8, ways=2),
                            num_partitions=2)
    big = SystemSimConfig(cache=TLBConfig(entries=1024, ways=8),
                          accel_tlb=TLBConfig(entries=256, ways=8),
                          mem_tlb=TLBConfig(entries=256, ways=8),
                          num_partitions=32)
    for mode in ("reference", "pallas_interpret"):
        solo = sweep_system(lines, [small], kernel_mode=mode, block=256)
        pair = sweep_system(lines, [small, big], kernel_mode=mode, block=256)
        for k in HIT_KEYS:
            np.testing.assert_array_equal(
                getattr(pair, k)[0], getattr(solo, k)[0], err_msg=f"{mode} {k}")


def test_system_kernel_block_multiple_trace_skips_padding():
    """Exact block-multiple traces take the no-padding path (no extra set
    row) and still match the oracle."""
    lines = _random_lines(5, n=1024)
    cfgs = [SystemSimConfig(num_partitions=p) for p in (1, 8)]
    pal = sweep_system(lines, cfgs, kernel_mode="pallas_interpret", block=256)
    _assert_rows_match(pal, cfgs, lines)


# ---------------------------------------------------------------------------
# VMEM chunking.
# ---------------------------------------------------------------------------

def test_system_sweep_chunking_under_tight_vmem_budget(monkeypatch):
    """When the three-structure envelope exceeds the scratch budget the
    kernel path splits the batch into like-sized chunks — results unchanged
    and every config lands in exactly one chunk."""
    monkeypatch.setattr(sweep, "_VMEM_STATE_BUDGET_BYTES", 64 * 1024)
    lines = _random_lines(11, n=700)
    cfgs = [
        SystemSimConfig(cache=TLBConfig(entries=1024, ways=8), num_partitions=64),
        SystemSimConfig(),
        SystemSimConfig(cache=None, num_partitions=4),
        SystemSimConfig(accel_tlb=TLBConfig(entries=4, ways=4), num_partitions=2),
    ]
    c_geo = [sweep._geom(c.cache) for c in cfgs]
    a_geo = [sweep._geom(c.accel_tlb) for c in cfgs]
    m_geo = [(sweep._geom(c.mem_tlb)[0] * c.num_partitions,
              sweep._geom(c.mem_tlb)[1]) for c in cfgs]
    dims = [c_geo[i] + a_geo[i] + m_geo[i] for i in range(len(cfgs))]
    chunks = _system_vmem_chunks(dims, block=256)
    assert len(chunks) > 1  # budget actually forces a split
    assert sorted(i for c in chunks for i in c) == list(range(len(cfgs)))
    pal = sweep_system(lines, cfgs, kernel_mode="pallas_interpret", block=256)
    _assert_rows_match(pal, cfgs, lines)


# ---------------------------------------------------------------------------
# Mode resolution policy.
# ---------------------------------------------------------------------------

def test_system_sweep_rejects_stackdist_loudly():
    """PR 4 policy: a sweep-only backend raises (stack inclusion does not
    hold for cache-hit-conditional probes) instead of being silently run as
    the scan."""
    with pytest.raises(ValueError, match="stack-inclusion"):
        sweep_system(_random_lines(0, n=64), [SystemSimConfig()],
                     kernel_mode="stackdist")
    with pytest.raises(ValueError, match="stack-inclusion"):
        resolve_system_mode("stackdist")


def test_system_mode_resolution():
    import jax

    with pytest.raises(ValueError):
        resolve_system_mode("not-a-mode")
    expect = "pallas" if jax.default_backend() == "tpu" else "reference"
    assert resolve_system_mode("auto") == expect
    assert resolve_system_mode("pallas_interpret") == "pallas_interpret"
