"""Oracle-equivalence for the batched sweep engine (repro.core.sweep).

The per-config simulators ``simulate_tlb`` / ``simulate_system`` are the
reference path; every batched result must match them **bit-exactly** across
randomized traces, mixed geometries (including entries < ways), partition
counts, page sizes, and absent structures.
"""
import numpy as np
import pytest
from _propcheck import given, settings, st  # hypothesis, or deterministic fallback

from repro.core import sweep, tlbsim, traces
from repro.core.sparta import TLBConfig
from repro.core.sweep import TLBSweepSpec, sweep_system, sweep_tlb
from repro.core.tlbsim import SystemSimConfig, _prepare_keys, simulate_system, simulate_tlb

PARTITIONS = (1, 4, 32)


def _random_vpns(seed: int, n: int = 2500, span: int = 6000) -> np.ndarray:
    return np.random.default_rng(seed).integers(0, span, n).astype(np.int64)


# ---------------------------------------------------------------------------
# sweep_tlb vs simulate_tlb
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=6)
@given(st.integers(0, 10_000), st.sampled_from(PARTITIONS))
def test_sweep_tlb_bitexact_vs_oracle(seed, P):
    vpns = _random_vpns(seed)
    specs = [
        TLBSweepSpec(TLBConfig(entries=2, ways=4), num_partitions=P),   # entries < ways
        TLBSweepSpec(TLBConfig(entries=16, ways=2), num_partitions=P),
        TLBSweepSpec(TLBConfig(entries=64, ways=4), num_partitions=1),
        TLBSweepSpec(TLBConfig(entries=128, ways=8), num_partitions=P),
        TLBSweepSpec(TLBConfig(entries=1, ways=1), num_partitions=P),   # degenerate
    ]
    res = sweep_tlb(vpns, specs)
    assert res.hits.shape == (len(specs), vpns.shape[0])
    for i, sp in enumerate(specs):
        ref = simulate_tlb(vpns, sp.cfg, num_partitions=sp.num_partitions)
        np.testing.assert_array_equal(res.hits[i], ref.hits)
        assert res[i].miss_ratio == ref.miss_ratio
    np.testing.assert_allclose(
        res.miss_ratios, [res[i].miss_ratio for i in range(len(specs))]
    )


def test_sweep_tlb_mixed_page_shifts_on_line_trace():
    """4 KB and 2 MB configs in one batch over a line-address trace."""
    tr = traces.generate("bst_internal", n_ops=1500, footprint_bytes=1 << 32)
    specs = [
        TLBSweepSpec(TLBConfig(entries=64, ways=4), num_partitions=4, page_shift=12),
        TLBSweepSpec(TLBConfig(entries=64, ways=4), num_partitions=4, page_shift=21),
        TLBSweepSpec(TLBConfig(entries=256, ways=4), num_partitions=1, page_shift=12),
    ]
    res = sweep_tlb(tr.lines, specs)
    for i, sp in enumerate(specs):
        vpns = tr.lines >> (sp.page_shift - tlbsim.LINE_SHIFT)
        ref = simulate_tlb(vpns, sp.cfg, num_partitions=sp.num_partitions)
        np.testing.assert_array_equal(res.hits[i], ref.hits)


def test_sweep_tlb_matches_kernel_interpret_path():
    """Pallas interpret path == reference path, incl. trace padding to blocks."""
    vpns = _random_vpns(7, n=1111)  # deliberately not a multiple of any block
    specs = [
        TLBSweepSpec(TLBConfig(entries=8, ways=4), num_partitions=4),
        TLBSweepSpec(TLBConfig(entries=32, ways=2)),
    ]
    ref = sweep_tlb(vpns, specs, kernel_mode="reference")
    pal = sweep_tlb(vpns, specs, kernel_mode="pallas_interpret", block=256)
    np.testing.assert_array_equal(pal.hits, ref.hits)


def test_miss_ratio_curve_equals_per_config_loop():
    """The rewired miss_ratio_curve (sweep engine) == looping the oracle."""
    tr = traces.generate("hash_table", n_ops=1500, footprint_bytes=1 << 30)
    sizes = (4, 16, 64, 256)
    curve = tlbsim.miss_ratio_curve(tr.lines, sizes, num_partitions=4)
    vpns = tr.lines >> (12 - tlbsim.LINE_SHIFT)
    loop = [tlbsim.miss_ratio(vpns, e, num_partitions=4) for e in sizes]
    np.testing.assert_allclose(curve, loop)


def test_sweep_tlb_single_trace_pass(monkeypatch):
    """The engine invokes ONE batched scan per sweep — never the per-config
    scan — regardless of how many configs ride along (the fig4 property)."""
    calls = {"batched": 0}
    real_batched = sweep._scan_tlb_batched

    def counting_batched(*a, **k):
        calls["batched"] += 1
        return real_batched(*a, **k)

    monkeypatch.setattr(sweep, "_scan_tlb_batched", counting_batched)
    monkeypatch.setattr(
        tlbsim, "_scan_tlb",
        lambda *a, **k: pytest.fail("per-config scan used inside sweep"),
    )
    vpns = _random_vpns(3, n=800)
    specs = [
        TLBSweepSpec(TLBConfig(entries=e, ways=4), num_partitions=p)
        for e in (4, 16, 64) for p in PARTITIONS
    ]
    sweep_tlb(vpns, specs, kernel_mode="reference")
    assert calls["batched"] == 1


# ---------------------------------------------------------------------------
# sweep_system vs simulate_system
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=4)
@given(st.integers(0, 10_000))
def test_sweep_system_bitexact_vs_oracle(seed):
    lines = np.random.default_rng(seed).integers(0, 1 << 28, 2000).astype(np.int64)
    cfgs = [
        SystemSimConfig(),  # defaults: cache, no accel TLB, P=1
        SystemSimConfig(cache=None, num_partitions=8),  # cacheless accelerator
        SystemSimConfig(  # physical cache: accel TLB probed every access
            accel_tlb=TLBConfig(entries=8, ways=4),
            num_partitions=4, accel_probe_on_miss_only=False),
        SystemSimConfig(  # 2 MB pages + tiny (entries < ways) accel TLB
            accel_tlb=TLBConfig(entries=2, ways=4),
            page_shift=21, num_partitions=32),
        SystemSimConfig(mem_tlb=TLBConfig(entries=64, ways=8), num_partitions=1),
    ]
    bev = sweep_system(lines, cfgs)
    assert len(bev) == len(cfgs)
    for i, c in enumerate(cfgs):
        ev = simulate_system(lines, c)
        np.testing.assert_array_equal(bev.cache_hit[i], ev.cache_hit)
        np.testing.assert_array_equal(bev.accel_tlb_hit[i], ev.accel_tlb_hit)
        np.testing.assert_array_equal(bev.mem_tlb_hit[i], ev.mem_tlb_hit)
        one = bev[i]
        assert one.cache_hit_ratio == ev.cache_hit_ratio
        assert one.mem_tlb_hit_ratio_given_cache_miss() == ev.mem_tlb_hit_ratio_given_cache_miss()


def test_sweep_system_heterogeneous_batch_matches_kernel_interpret_path():
    """Pallas interpret path == per-config oracle on a heterogeneous batch
    (mixed cache/accel presence, probe policies, partitions, page sizes),
    with a non-block-multiple trace length so the tail-padding accesses
    (parked in each structure's extra set row) are exercised too."""
    lines = np.random.default_rng(17).integers(0, 1 << 28, 1111).astype(np.int64)
    cfgs = [
        SystemSimConfig(),                               # cache, no accel TLB
        SystemSimConfig(cache=None, num_partitions=8),   # cacheless accelerator
        SystemSimConfig(accel_tlb=TLBConfig(entries=8, ways=4),
                        num_partitions=4, accel_probe_on_miss_only=False),
        SystemSimConfig(accel_tlb=TLBConfig(entries=2, ways=4),  # entries < ways
                        page_shift=21, num_partitions=32),
        SystemSimConfig(mem_tlb=TLBConfig(entries=64, ways=8), num_partitions=1),
        SystemSimConfig(cache=TLBConfig(entries=512, ways=8), num_partitions=16),
        SystemSimConfig(cache=None, accel_tlb=TLBConfig(entries=16, ways=2),
                        num_partitions=2, accel_probe_on_miss_only=False),
        SystemSimConfig(page_shift=21, num_partitions=128),
    ]
    bev = sweep_system(lines, cfgs, kernel_mode="pallas_interpret", block=256)
    for i, c in enumerate(cfgs):
        ev = simulate_system(lines, c)
        np.testing.assert_array_equal(bev.cache_hit[i], ev.cache_hit)
        np.testing.assert_array_equal(bev.accel_tlb_hit[i], ev.accel_tlb_hit)
        np.testing.assert_array_equal(bev.mem_tlb_hit[i], ev.mem_tlb_hit)


def test_sweep_rejects_empty_batches():
    with pytest.raises(ValueError):
        sweep_tlb(np.zeros(4, np.int64), [])
    with pytest.raises(ValueError):
        sweep_system(np.zeros(4, np.int64), [])


def test_sweep_tlb_rejects_mixed_stream_kinds():
    """One batch cannot interpret the input as both VPNs and line addresses."""
    specs = [
        TLBSweepSpec(TLBConfig(entries=8, ways=4), page_shift=12),
        TLBSweepSpec(TLBConfig(entries=8, ways=4)),  # page_shift=None
    ]
    with pytest.raises(ValueError, match="mixes"):
        sweep_tlb(np.zeros(16, np.int64), specs)


def test_sweep_tlb_kernel_chunking_under_tight_vmem_budget(monkeypatch):
    """When the padded envelope exceeds the VMEM scratch budget the kernel
    path splits the batch into like-sized chunks — results unchanged."""
    monkeypatch.setattr(sweep, "_VMEM_STATE_BUDGET_BYTES", 16 * 1024)
    vpns = _random_vpns(11, n=1000)
    specs = [
        TLBSweepSpec(TLBConfig(entries=e, ways=4), num_partitions=p)
        for e in (4, 64, 256) for p in (1, 4)
    ]
    geoms = [sp.geometry for sp in specs]
    assert len(sweep._vmem_chunks(geoms)) > 1  # budget actually forces a split
    ref = sweep_tlb(vpns, specs, kernel_mode="reference")
    pal = sweep_tlb(vpns, specs, kernel_mode="pallas_interpret", block=256)
    np.testing.assert_array_equal(pal.hits, ref.hits)
    # Every config index lands in exactly one chunk.
    seen = sorted(i for c in sweep._vmem_chunks(geoms) for i in c)
    assert seen == list(range(len(specs)))


# ---------------------------------------------------------------------------
# Key-preparation regressions.
# ---------------------------------------------------------------------------

def test_prepare_keys_raises_on_int32_tag_overflow():
    vpns = np.array([2**42], np.int64)  # tag = vpn // sets >= 2**31 at sets=1
    with pytest.raises(ValueError, match="tag overflow"):
        _prepare_keys(vpns, sets=1, num_partitions=1)
    # The same key space partitioned enough is fine (tag shrinks by P * sets).
    set_idx, tag = _prepare_keys(vpns, sets=1 << 10, num_partitions=4)
    assert tag.dtype == np.int32


@settings(deadline=None, max_examples=8)
@given(st.integers(0, 2**25 - 1), st.sampled_from(PARTITIONS), st.sampled_from([1, 4, 64]))
def test_partition_invariant_of_prepare_keys(vpn, P, sets):
    """The paper's invariant: the global set index always lands inside the
    partition named by MEM_PARTITION_INDEX_HASH (set_idx // sets == vpn % P)."""
    set_idx, _ = _prepare_keys(np.array([vpn], np.int64), sets, P)
    assert set_idx[0] // sets == vpn % P
    assert 0 <= set_idx[0] < sets * P
