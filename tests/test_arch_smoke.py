"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import registry
from repro.configs.base import SHAPES_BY_NAME, cell_applicable
from repro.data.pipeline import DataConfig, batch_for_model
from repro.train.optimizer import OptimizerConfig, init_state
from repro.train.train_step import make_train_step

B, T = 2, 16


def _batch(cfg, rng):
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)).astype(np.int32))
    if cfg.family == "vlm":
        return {"patch_embeds": jnp.asarray(
            rng.standard_normal((B, cfg.num_image_tokens, cfg.d_model)).astype(np.float32)),
            "tokens": tok}
    if cfg.family == "encdec":
        return {"frames": jnp.asarray(
            rng.standard_normal((B, 8, cfg.d_model)).astype(np.float32)),
            "tokens": tok}
    return {"tokens": tok}


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch, rng):
    cfg = registry.get_smoke(arch)
    params = models.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, rng)
    logits, aux = models.forward(params, batch, cfg, kernel_mode="reference")
    t_out = batch["tokens"].shape[1]
    assert logits.shape == (B, t_out, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_train_step_decreases_nothing_nan(arch, rng):
    cfg = registry.get_smoke(arch)
    params = models.init(jax.random.PRNGKey(0), cfg)
    opt = init_state(params)
    step = jax.jit(make_train_step(cfg, OptimizerConfig(lr=1e-3, warmup_steps=1)))
    batch = _batch(cfg, rng)
    params, opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"])) and float(metrics["grad_norm"]) > 0
    # one more step: loss is finite and the optimizer actually moved weights
    params2, opt, metrics2 = step(params, opt, batch)
    assert np.isfinite(float(metrics2["loss"]))
    moved = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda x, y: float(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32)).sum()), params, params2),
    )
    assert moved > 0


def test_full_configs_match_assignment():
    """The full (non-smoke) configs carry the exact assigned dimensions."""
    spec = {
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "rwkv6-1.6b": (24, 2048, 0, 0, 7168, 65536),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92608),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51904),
    }
    for arch, (L, D, H, KV, F, V) in spec.items():
        c = registry.get_config(arch)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab) == (L, D, H, KV, F, V), arch
    # MoE structure
    q = registry.get_config("qwen3-moe-30b-a3b").moe
    assert (q.num_experts, q.top_k) == (128, 8)
    d = registry.get_config("dbrx-132b").moe
    assert (d.num_experts, d.top_k) == (16, 4)


def test_cell_applicability_matches_assignment():
    cells = list(registry.all_cells())
    assert len(cells) == 32  # 40 - 8 long_500k skips for pure-attention archs
    long_archs = {a for a, s in cells if s.name == "long_500k"}
    assert long_archs == {"rwkv6-1.6b", "zamba2-7b"}


def test_data_pipeline_deterministic_and_host_sharded():
    cfg = registry.get_smoke("stablelm-12b")
    d0 = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8, num_hosts=2, host_id=0)
    d1 = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8, num_hosts=2, host_id=1)
    a = batch_for_model(d0, cfg, step=7)["tokens"]
    b = batch_for_model(d0, cfg, step=7)["tokens"]
    c = batch_for_model(d1, cfg, step=7)["tokens"]
    assert (a == b).all()          # deterministic: any host can recompute
    assert not (a == c).all()      # hosts get different shards
    assert a.shape == (4, 16)
