"""Tests for the run-telemetry layer (repro.runtime.telemetry), its threading
through the orchestrator/engines, and the obs_report renderer.

The contract under test:

* **JSONL schema round-trip** — one run produces ``run_start`` / ``event`` /
  ``span`` / ``run_end`` records, every record stamped with wall-clock
  (``ts``) and monotonic (``t_mono``) time, and the ``run_end`` summary
  aggregates spans/events/counters/gauges.
* **Nesting** — spans link ``parent_id`` -> ``span_id``; ``Span.block``
  accumulates device-blocked time.
* **No-op fast path** — with no active run every instrument call returns a
  shared null object, and the total instrument cost of a disabled-tracer
  ``run_sweep_tlb`` stays under 2% of the sweep's own wall time.
* **Orchestrator threading** — ladder events carry timestamps and
  per-attempt elapsed time; chunk spans and per-backend achieved accesses/s
  land in the run log and in ``meta["throughput"]`` (streamed and
  monolithic-stackdist paths both).
* **obs_report** — renders, diffs, tolerates torn tails, and fails on
  banned events (the CI ``--fail-on-event downgrade`` gate).
"""
import json
import logging

import numpy as np
import pytest

from benchmarks import obs_report
from repro.core import benchtime
from repro.core.orchestrator import SweepRunConfig, run_sweep_tlb
from repro.core.sparta import TLBConfig
from repro.core.sweep import TLBSweepSpec, sweep_tlb
from repro.runtime import telemetry

BLOCK = 128


@pytest.fixture(autouse=True)
def _clean_tracer():
    tr = telemetry.get_tracer()
    if tr.active:
        tr.end_run(error="leaked from a previous test")
    yield
    if tr.active:
        tr.end_run(error="leaked by test")


def _sweep_inputs():
    rng = np.random.default_rng(7)
    addrs = rng.integers(0, 1 << 22, 4096).astype(np.int64)
    specs = [TLBSweepSpec(TLBConfig(entries=64, ways=4), num_partitions=p)
             for p in (1, 8)]
    return addrs, specs


def _read(path):
    return [json.loads(line) for line in path.read_text().splitlines()]


# ------------------------------------------------------------ schema/lifecycle


def test_jsonl_schema_roundtrip(tmp_path):
    path = tmp_path / "run.jsonl"
    with telemetry.run_scope(path, run="t", device={"platform": "cpu"}):
        tr = telemetry.get_tracer()
        with tr.span("phase", k=1):
            tr.event("retry", lo=0, hi=10)
        tr.counter("c").add(3)
        tr.gauge("g").set(2.0)
    recs = _read(path)
    assert [r["kind"] for r in recs] == ["run_start", "event", "span", "run_end"]
    for r in recs:
        assert isinstance(r["ts"], float) and r["ts"] > 1e9
        assert isinstance(r["t_mono"], float)
    start, event, span, end = recs
    assert start["schema_version"] == telemetry.SCHEMA_VERSION
    assert start["run"] == "t" and start["meta"]["device"]["platform"] == "cpu"
    assert event["name"] == "retry" and event["attrs"] == {"lo": 0, "hi": 10}
    assert span["name"] == "phase" and span["dur_s"] >= 0
    assert span["attrs"]["k"] == 1
    s = end["summary"]
    assert s["n_spans"] == 1 and s["events"] == {"retry": 1}
    assert s["counters"]["c"] == {"value": 3, "updates": 1}
    assert s["gauges"]["g"]["value"] == 2.0


def test_run_scope_closes_log_on_error(tmp_path):
    path = tmp_path / "crash.jsonl"
    with pytest.raises(KeyboardInterrupt):
        with telemetry.run_scope(path, run="t"):
            raise KeyboardInterrupt  # BaseException still closes the log
    end = _read(path)[-1]
    assert end["kind"] == "run_end" and "KeyboardInterrupt" in end["error"]
    assert not telemetry.get_tracer().active


def test_span_nesting_parent_ids(tmp_path):
    path = tmp_path / "nest.jsonl"
    with telemetry.run_scope(path, run="t"):
        tr = telemetry.get_tracer()
        with tr.span("outer") as outer:
            with tr.span("inner") as inner:
                assert inner.parent_id == outer.span_id
            tr.record_span("measured", 0.01)  # also parented to the stack top
    spans = {r["name"]: r for r in _read(path) if r["kind"] == "span"}
    assert spans["outer"]["parent_id"] is None
    assert spans["inner"]["parent_id"] == spans["outer"]["span_id"]
    assert spans["measured"]["parent_id"] == spans["outer"]["span_id"]


def test_span_block_accumulates_blocked_time(tmp_path):
    path = tmp_path / "blk.jsonl"
    x = np.arange(8)
    with telemetry.run_scope(path, run="t"):
        with telemetry.get_tracer().span("s") as sp:
            assert sp.block(x) is x
    rec = [r for r in _read(path) if r["kind"] == "span"][0]
    assert rec["attrs"]["blocked_s"] > 0


def test_counter_and_gauge_aggregation():
    tr = telemetry.get_tracer()
    tr.start_run(None, run="mem")
    c = tr.counter("hits")
    assert tr.counter("hits") is c  # registry, not a new object per call
    c.add().add(5)
    g = tr.gauge("bytes")
    g.set(5).set(3)
    s = tr.end_run()
    assert s["counters"]["hits"] == {"value": 6, "updates": 2}
    assert s["gauges"]["bytes"] == {"value": 3.0, "min": 3.0, "max": 5.0,
                                    "updates": 2}


def test_start_run_supersedes_leaked_run(tmp_path):
    a, b = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    telemetry.start_run(a, run="a")
    telemetry.start_run(b, run="b")   # closes "a" with an error, no raise
    telemetry.end_run()
    assert "superseded" in _read(a)[-1]["error"]
    assert _read(b)[-1]["kind"] == "run_end"


# -------------------------------------------------------------- no-op fast path


def test_disabled_tracer_is_noop():
    tr = telemetry.get_tracer()
    assert not tr.active
    assert tr.span("x", a=1) is telemetry._NULL_SPAN
    assert tr.counter("c") is telemetry._NULL_INSTRUMENT
    assert tr.gauge("g") is telemetry._NULL_INSTRUMENT
    tr.event("e")             # records nothing, raises nothing
    tr.record_span("s", 0.5)
    assert tr.end_run() == {}
    obj = object()
    assert telemetry._NULL_SPAN.block(obj) is obj  # no device sync added
    with tr.span("x") as sp:
        sp.set(k=1).block(obj)


def test_disabled_tracer_overhead_under_2_percent():
    """The <2% guard: the instrument ops one sweep performs, costed at the
    measured disabled-tracer per-op price, must stay under 2% of the sweep's
    own measured wall time.  (Op-counting x micro-cost instead of an A/B
    wall-time diff: a 2% delta drowns in run-to-run noise.)"""
    addrs, specs = _sweep_inputs()
    cfg = SweepRunConfig(chunk_accesses=1024)
    tr = telemetry.get_tracer()

    # Probe run (in-memory) counts the ops an instrumented sweep performs.
    tr.start_run(None, run="probe")
    run_sweep_tlb(addrs, specs, kernel_mode="reference", block=BLOCK, run=cfg)
    s = tr.end_run()
    n_ops = (s["n_spans"] + sum(s["events"].values())
             + sum(c["updates"] for c in s["counters"].values())
             + sum(g["updates"] for g in s["gauges"].values()))
    assert n_ops >= 4  # at least the four chunk spans

    # Disabled per-op cost (4 instrument calls per iteration).
    def ops(k=1000):
        for _ in range(k):
            with tr.span("x"):
                pass
            tr.record_span("y", 0.0)
            tr.event("e")
            tr.counter("c").add()

    assert not tr.active
    per_op = benchtime.measure(ops, reps=3).best_s / (1000 * 4)

    m_sweep = benchtime.measure(run_sweep_tlb, addrs, specs,
                                kernel_mode="reference", block=BLOCK, run=cfg,
                                reps=2)
    assert n_ops * per_op < 0.02 * m_sweep.best_s, (
        f"{n_ops} ops x {per_op:.2e}s/op vs sweep {m_sweep.best_s:.4f}s")


# ------------------------------------------------------- orchestrator threading


def test_ladder_events_carry_timestamps_and_elapsed():
    addrs, specs = _sweep_inputs()
    failures = {"left": 1}

    def hook(engine, lo, hi, mode, attempt):
        if failures["left"]:
            failures["left"] -= 1
            raise RuntimeError("RESOURCE_EXHAUSTED: injected")

    cfg = SweepRunConfig(fault_hook=hook, backoff_base_s=0.0,
                         backoff_cap_s=0.0, chunk_accesses=1024)
    res, meta = run_sweep_tlb(addrs, specs, kernel_mode="reference",
                              block=BLOCK, run=cfg)
    retries = [e for e in meta["events"] if e["event"] == "retry"]
    assert len(retries) == 1
    e = retries[0]
    assert e["ts"] > 1e9 and isinstance(e["t_mono"], float)
    assert e["elapsed_s"] >= 0 and e["attempt"] == 0
    assert "RESOURCE_EXHAUSTED" in e["error"]
    # The faulted-then-retried run stays bit-identical to the oracle.
    oracle = sweep_tlb(addrs, specs, kernel_mode="reference", block=BLOCK)
    np.testing.assert_array_equal(res.hits, oracle.hits)


def test_runlog_chunks_and_throughput_meta(tmp_path):
    addrs, specs = _sweep_inputs()
    path = tmp_path / "fig.jsonl"
    with telemetry.run_scope(path, run="fig"):
        _, meta = run_sweep_tlb(addrs, specs, kernel_mode="reference",
                                block=BLOCK,
                                run=SweepRunConfig(chunk_accesses=1024),
                                name="tlb")
    tp = meta["throughput"]["reference"]
    assert tp["chunks"] == 4 and tp["accesses"] == 4096
    assert tp["sim_accesses"] == 4096 * len(specs)
    assert tp["accesses_per_s"] > 0 and tp["sim_accesses_per_s"] > 0

    recs = _read(path)
    chunks = [r for r in recs
              if r["kind"] == "span" and r["name"] == "chunk"]
    assert len(chunks) == 4
    a = chunks[0]["attrs"]
    assert a["engine"] == "sweep_tlb" and a["name"] == "tlb"
    assert a["mode"] == "reference" and a["configs"] == len(specs)
    assert (a["lo"], a["hi"]) == (0, 1024) and a["accesses_per_s"] > 0
    env = [r for r in recs
           if r["kind"] == "event" and r["name"] == "vmem_envelope"]
    assert env and env[0]["attrs"]["configs"] == len(specs)
    assert env[0]["attrs"]["state_bytes"] > 0
    summary = recs[-1]["summary"]
    assert summary["counters"]["sweep_tlb.sim_accesses"]["value"] == \
        4096 * len(specs)
    assert summary["gauges"]["sweep_tlb.state_bytes"]["value"] > 0


def test_stackdist_monolithic_path_records_throughput(tmp_path):
    addrs, specs = _sweep_inputs()
    path = tmp_path / "sd.jsonl"
    with telemetry.run_scope(path, run="sd"):
        _, meta = run_sweep_tlb(addrs, specs, kernel_mode="stackdist",
                                block=BLOCK, name="tlb")
    assert meta["resumable"] is False
    tp = meta["throughput"]["stackdist"]
    assert tp["chunks"] == 1 and tp["accesses"] == 4096
    assert tp["accesses_per_s"] > 0
    chunks = [r for r in _read(path)
              if r["kind"] == "span" and r["name"] == "chunk"]
    assert len(chunks) == 1 and chunks[0]["attrs"]["mode"] == "stackdist"


def test_measure_label_records_span(tmp_path):
    path = tmp_path / "m.jsonl"
    with telemetry.run_scope(path, run="m"):
        benchtime.measure(lambda: np.arange(16), reps=2, label="unit:probe")
    spans = [r for r in _read(path)
             if r["kind"] == "span" and r["name"] == "measure"]
    assert len(spans) == 1
    a = spans[0]["attrs"]
    assert a["label"] == "unit:probe" and a["reps"] == 2
    assert a["best_s"] >= 0 and a["spread_frac"] >= 0


# --------------------------------------------------------------- setup_logging


def test_setup_logging_levels_and_idempotent():
    log = telemetry.setup_logging(0)
    n_handlers = len(log.handlers)
    assert log.level == logging.INFO
    assert telemetry.setup_logging(1).level == logging.DEBUG
    assert telemetry.setup_logging(-1).level == logging.WARNING
    assert len(log.handlers) == n_handlers  # no handler stacking
    telemetry.setup_logging(0)


# ------------------------------------------------------------------ obs_report


def _mklog(tmp_path, name, rate, events=("retry",)):
    path = tmp_path / name
    with telemetry.run_scope(path, run=name):
        tr = telemetry.get_tracer()
        for i in range(2):
            tr.record_span(
                "chunk", 0.5, engine="sweep_tlb", name="tlb",
                lo=1024 * i, hi=1024 * (i + 1), mode="reference", attempt=0,
                accesses=1024, configs=2, accesses_per_s=rate,
                sim_accesses_per_s=2 * rate)
        for ev in events:
            tr.event(ev, lo=0, hi=1024)
    return path


def test_obs_report_render(tmp_path, capsys):
    path = _mklog(tmp_path, "a.jsonl", rate=2048.0)
    assert obs_report.main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "phase breakdown" in out and "chunk" in out
    assert "engine throughput" in out and "sweep_tlb" in out
    assert "throughput timeline" in out
    assert "retry" in out and "end=clean" in out


def test_obs_report_aggregates(tmp_path):
    recs = obs_report.load_log(_mklog(tmp_path, "a.jsonl", rate=2048.0))
    phases = obs_report.phase_breakdown(recs)
    assert phases["chunk"] == {"count": 2, "total_s": 1.0}
    tput = obs_report.engine_throughput(recs)
    st = tput[("sweep_tlb", "reference")]
    assert st["chunks"] == 2 and st["accesses"] == 2048
    assert st["accesses_per_s"] == pytest.approx(2048.0)
    assert obs_report.event_counts(recs) == {"retry": 1}


def test_obs_report_diff(tmp_path, capsys):
    a = _mklog(tmp_path, "a.jsonl", rate=1000.0)
    b = _mklog(tmp_path, "b.jsonl", rate=2000.0, events=("downgrade",))
    assert obs_report.main([str(a), str(b), "--diff"]) == 0
    out = capsys.readouterr().out
    assert "phase totals" in out and "->" in out
    assert "downgrade" in out
    with pytest.raises(SystemExit):   # --diff needs exactly two logs
        obs_report.main([str(a), "--diff"])


def test_obs_report_fail_on_event(tmp_path, capsys):
    path = _mklog(tmp_path, "a.jsonl", rate=100.0, events=("downgrade",))
    assert obs_report.main([str(path), "--fail-on-event", "preempt"]) == 0
    capsys.readouterr()
    assert obs_report.main([str(path), "--fail-on-event",
                            "downgrade,preempt"]) == 1
    assert "downgrade" in capsys.readouterr().err


def test_obs_report_tolerates_torn_tail(tmp_path):
    path = _mklog(tmp_path, "a.jsonl", rate=100.0)
    n = len(obs_report.load_log(path))
    with open(path, "a", encoding="utf-8") as f:
        f.write('{"kind": "event", "name": "tr')   # crashed mid-write
    recs = obs_report.load_log(path)
    assert len(recs) == n and recs[-1]["kind"] == "run_end"


def test_obs_report_rejects_mid_log_corruption(tmp_path):
    path = tmp_path / "bad.jsonl"
    path.write_text('{"kind": "run_start"}\nnot json\n{"kind": "run_end"}\n')
    with pytest.raises(SystemExit, match="corrupt record"):
        obs_report.load_log(path)
