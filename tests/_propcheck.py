"""Property-test shim: real ``hypothesis`` when installed, otherwise a
deterministic fallback with the same surface (``given``, ``settings``,
``strategies as st``) so the suite passes either way.

The fallback enumerates a fixed, seeded set of examples per strategy —
boundary values plus a few interior points — and runs the test body once per
combination.  It intentionally implements only what this repo's tests use:
``st.integers(lo, hi)``, ``st.sampled_from(seq)``, ``st.randoms()`` and
``st.composite``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import itertools
    import random

    class _Strategy:
        def __init__(self, examples):
            self.examples = list(examples)

    class st:  # noqa: N801 — mirrors `hypothesis.strategies` import style
        @staticmethod
        def integers(min_value, max_value):
            rng = random.Random(f"{min_value}:{max_value}")
            interior = (rng.randint(min_value, max_value) for _ in range(4))
            return _Strategy(dict.fromkeys([min_value, max_value, *interior]))

        @staticmethod
        def sampled_from(elements):
            return _Strategy(elements)

        @staticmethod
        def randoms():
            return _Strategy([random.Random(seed) for seed in range(3)])

        @staticmethod
        def composite(fn):
            """fn(draw, *args) -> example; the strategy enumerates a few
            seeded draw sequences."""

            def call(*args, **kwargs):
                examples = []
                for seed in range(8):
                    rng = random.Random(seed)
                    draw = lambda strategy, rng=rng: rng.choice(strategy.examples)
                    examples.append(fn(draw, *args, **kwargs))
                return _Strategy(examples)

            return call

    def given(*strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                pools = [s.examples for s in strats]
                total = 1
                for p in pools:
                    total *= len(p)
                if total <= 64:
                    combos = itertools.product(*pools)
                else:  # align pools by cycling the shorter ones
                    n = max(len(p) for p in pools)
                    combos = zip(*(itertools.islice(itertools.cycle(p), n) for p in pools))
                for combo in combos:
                    fn(*args, *combo, **kwargs)

            # Hide the strategy-filled parameters from pytest's fixture
            # resolution (hypothesis's @given does the same).
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco

    def settings(**_kwargs):
        def deco(fn):
            return fn

        return deco
