"""Fault-injection harness for the crash-safe sweep orchestrator.

Three seams, matching ``SweepRunConfig``'s test hooks:

* :func:`kill_after` — a simulated **hard kill** (power loss, OOM-killer,
  preemption without grace).  Raised from ``on_chunk_committed``, i.e. the
  instant *after* a chunk's checkpoint is durably on disk — the worst
  legitimate crash point: everything later is torn away, everything earlier
  must survive.  ``SimulatedKill`` is a BaseException so no retry machinery
  can absorb it (a real kill cannot be caught either).

* :func:`transient_faults` — simulated **transient runtime faults**
  (``RESOURCE_EXHAUSTED``, the XLA OOM status), raised from ``fault_hook``
  *before* a chunk attempt executes.  Filtered by engine/mode so a test can
  e.g. fail every non-``reference`` attempt and force the full
  retry -> halve -> downgrade ladder.

* :func:`corrupt_file` — post-crash disk damage: flip one payload byte or
  truncate the blob, to prove resume *refuses* rather than trusts it.

Plus the shard scheduler's ``on_shard_start`` seams — picklable
module-level classes (the spawn-based process executor ships them to
workers): :class:`KillWorkerOnShard` (worker self-SIGKILL mid-shard),
:class:`PoisonShard` (deterministic per-shard failure -> quarantine),
:class:`HoldShard` (injected straggler).
"""
from __future__ import annotations

import pathlib


class SimulatedKill(BaseException):
    """A process death at a chunk boundary (after the checkpoint commit).

    BaseException on purpose: the orchestrator's transient-fault ladder
    catches ``Exception`` only, so a kill — like a real SIGKILL — must tear
    straight through it.
    """


def kill_after(n_chunks: int):
    """``on_chunk_committed`` hook: die once ``n_chunks`` chunks committed.

    The hook fires after commit ``i`` (0-based) with its checkpoint already
    fsync'd + renamed, so killing at ``i == n_chunks - 1`` leaves exactly
    ``n_chunks`` chunks' worth of durable state behind.
    """

    def hook(chunk_idx: int) -> None:
        if chunk_idx + 1 >= n_chunks:
            raise SimulatedKill(
                f"simulated process death after chunk commit #{chunk_idx}")

    return hook


def transient_faults(*, fail_modes=("pallas", "pallas_interpret"),
                     max_faults: int | None = None, log=None):
    """``fault_hook``: raise RESOURCE_EXHAUSTED for attempts in ``fail_modes``.

    With the default filter every non-``reference`` attempt fails, so a run
    entering the ladder above ``reference`` must walk the whole
    retry -> halve -> downgrade sequence to finish.  ``max_faults`` bounds
    the total injections (None = unbounded); ``log`` (a list) records every
    ``(engine, lo, hi, mode, attempt)`` the hook saw, injected or not.
    """
    import jax

    state = {"n": 0}

    def hook(engine: str, lo: int, hi: int, mode: str, attempt: int) -> None:
        if log is not None:
            log.append((engine, lo, hi, mode, attempt))
        if mode in fail_modes and (max_faults is None or state["n"] < max_faults):
            state["n"] += 1
            raise jax.errors.JaxRuntimeError(
                f"RESOURCE_EXHAUSTED: injected fault #{state['n']} "
                f"({engine} [{lo}:{hi}) {mode} attempt {attempt})")

    return hook


class KillWorkerOnShard:
    """Scheduler ``on_shard_start`` seam: a worker that picks up the matching
    ``(shard, attempt)`` SIGKILLs *itself* — a deterministic stand-in for
    "SIGKILL one worker mid-shard" with no timing race.  Module-level class
    (not a closure) so the spawn-based process executor can pickle it.

    Only meaningful with the process executor: SIGKILL from a thread would
    take down the whole test process.
    """

    def __init__(self, shard: int, attempts=(0,)):
        self.shard = int(shard)
        self.attempts = tuple(attempts)

    def __call__(self, shard: int, attempt: int, worker: int) -> None:
        if shard == self.shard and attempt in self.attempts:
            import os
            import signal

            os.kill(os.getpid(), signal.SIGKILL)


class PoisonShard:
    """Scheduler ``on_shard_start`` seam: the matching shard fails
    deterministically on every attempt (a poison config — the quarantine
    path), while all other shards run normally.  Picklable."""

    def __init__(self, shard: int):
        self.shard = int(shard)

    def __call__(self, shard: int, attempt: int, worker: int) -> None:
        if shard == self.shard:
            raise ValueError(
                f"poisoned shard {shard} (attempt {attempt}, worker {worker})")


class HoldShard:
    """Scheduler ``on_shard_start`` seam: sleep the matching shard's first
    attempt — an injected straggler for deadline/duplicate tests.
    Picklable."""

    def __init__(self, shard: int, hold_s: float, attempts=(0,)):
        self.shard = int(shard)
        self.hold_s = float(hold_s)
        self.attempts = tuple(attempts)

    def __call__(self, shard: int, attempt: int, worker: int) -> None:
        if shard == self.shard and attempt in self.attempts:
            import time

            time.sleep(self.hold_s)


def corrupt_file(path, mode: str = "flip") -> None:
    """Damage a checkpoint blob in place: ``"flip"`` one payload byte, or
    ``"truncate"`` the file to half its length (mid-payload)."""
    path = pathlib.Path(path)
    data = bytearray(path.read_bytes())
    if mode == "flip":
        data[len(data) // 2] ^= 0xFF
    elif mode == "truncate":
        del data[len(data) // 2:]
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    path.write_bytes(bytes(data))
