"""Core SPARTA invariants: partition hash, timelines, TLB simulator."""
import numpy as np
import pytest
from _propcheck import given, settings, st  # hypothesis, or deterministic fallback

from repro.core import tlbsim, traces
from repro.core.sparta import (
    SystemLatencies, TLBConfig, conventional_timelines,
    mem_partition_index_hash, partition_local_vpn, sparta_timelines,
)


@given(st.integers(0, 2**40), st.sampled_from([1, 2, 4, 8, 32, 128]))
def test_partition_hash_bijective(vpn, P):
    import jax.numpy as jnp
    p = int(mem_partition_index_hash(jnp.int32(vpn % 2**25), P))
    local = int(partition_local_vpn(jnp.int32(vpn % 2**25), P))
    assert 0 <= p < P
    assert local * P + p == vpn % 2**25  # (p, local) reconstructs the vpn


def test_sparta_miss_penalty_is_local_dram():
    lat = SystemLatencies()
    _, _, _, conv = conventional_timelines(lat)
    _, _, _, sp = sparta_timelines(lat)
    assert sp == lat.l_tlb + lat.l_dram   # no network in the SPARTA walk
    assert conv > sp                      # conventional pays round trips


def test_sparta_penalty_grows_slower_with_machine_size():
    red = {}
    for n in (2, 8):
        lat = SystemLatencies(n_sockets=n)
        _, _, _, conv = conventional_timelines(lat)
        _, _, _, sp = sparta_timelines(lat)
        red[n] = conv / sp
    assert red[8] > red[2]


def test_tlb_lru_exact_small_case():
    # 1-set, 2-way LRU: [1, 2, 1, 3, 2] -> hits [F, F, T, F, F]
    vpns = np.array([1, 2, 1, 3, 2])
    res = tlbsim.simulate_tlb(vpns, TLBConfig(entries=2, ways=2), warmup_frac=0.0)
    assert list(res.hits) == [False, False, True, False, False]


def test_partitioning_never_hurts_capacity():
    """P partitions x E entries >= 1 partition x E entries (same per-TLB size)."""
    tr = traces.generate("bst_internal", n_ops=4000, footprint_bytes=1 << 33)
    vp = tr.vpns(12)
    m1 = tlbsim.miss_ratio(vp, 128, num_partitions=1)
    m16 = tlbsim.miss_ratio(vp, 128, num_partitions=16)
    assert m16 <= m1 + 0.02


@settings(deadline=None, max_examples=10)
@given(st.integers(2, 64), st.integers(1, 4))
def test_bigger_tlb_never_worse(sets_pow, ways):
    tr = traces.generate("hash_table", n_ops=1500, footprint_bytes=1 << 30)
    vp = tr.vpns(12)
    small = tlbsim.miss_ratio(vp, 8 * ways, ways=ways)
    big = tlbsim.miss_ratio(vp, 8 * ways * 8, ways=ways)
    assert big <= small + 0.02


def test_joint_system_sim_consistency():
    tr = traces.generate("bst_internal", n_ops=2000, footprint_bytes=1 << 32)
    ev = tlbsim.simulate_system(tr.lines, tlbsim.SystemSimConfig(num_partitions=4))
    assert 0.0 <= ev.cache_hit_ratio <= 1.0
    assert 0.0 <= ev.mem_tlb_hit_ratio_given_cache_miss() <= 1.0


def test_2mb_pages_reduce_misses():
    tr = traces.generate("bst_internal", n_ops=4000, footprint_bytes=1 << 33)
    m4k = tlbsim.miss_ratio_curve(tr.lines, [256], page_shift=12)[0]
    m2m = tlbsim.miss_ratio_curve(tr.lines, [256], page_shift=21)[0]
    assert m2m <= m4k
