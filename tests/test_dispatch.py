"""The unified cost-model dispatch layer (repro.core.dispatch).

The contract under test:

* **Calibration store round-trips** with the checkpoint-blob header
  discipline; corrupt (bit-flipped / truncated) and foreign files are
  **refused** with :class:`CalibrationCorruptError` — never silently
  regenerated.
* **Cold start == the legacy heuristics**, exactly: stackdist for an
  eligible TLB sweep, the batch-aware scan preference for the timeline,
  "pallas on TPU else reference" everywhere else.  A half-measured table
  (default unmeasured, or no measured rival) also stays on the cold-start
  mode — ``pallas_interpret`` can never be chosen merely for being the only
  thing measured.
* **Calibrated choice is argmax measured rate** once the cold-start default
  and at least one rival are both measured — the mechanism by which a CPU
  host's ``"auto"`` stops selecting ``pallas_interpret`` where the scan
  measured faster.
* **Resume stickiness**: the DispatchDecision rides in the checkpoint blob
  meta, so a calibration table that changed between runs cannot flip the
  backend mid-stream — kill, recalibrate to prefer a different mode,
  resume, and the run completes bit-identically on the original backend.
* **GC** deletes only stale files bearing the calibration magic header;
  fresh tables and foreign files are never touched.
"""
import json
import os

import numpy as np
import pytest
from _faultinject import SimulatedKill, kill_after

from repro.core import dispatch
from repro.core.dispatch import (CalibrationCorruptError, CalibrationStore,
                                 DispatchDecision)
from repro.core.orchestrator import SweepRunConfig, run_sweep_tlb
from repro.core.sparta import TLBConfig
from repro.core.sweep import TLBSweepSpec, sweep_tlb
from repro.core.tlbsim import SystemSimConfig
from repro.runtime import telemetry
from repro.runtime.fault_tolerance import PreemptionHandler

BLOCK = 128
W = dispatch.MIN_CALIB_WEIGHT  # the smallest trusted measurement weight


def _store(tmp_path, **rates):
    """A store for a synthetic device, pre-seeded with bN rates for
    ``sweep_timeline`` (the engine most tests decide for)."""
    st = CalibrationStore(tmp_path / "calib-test.json",
                          device={"device_kind": "TestCPU"})
    st.record_many([("sweep_timeline", mode, 8, r, 10 * W)
                    for mode, r in rates.items()])
    return st


def _specs(ways):
    return [TLBSweepSpec(TLBConfig(entries=64, ways=ways), num_partitions=p)
            for p in (1, 8)]


# ---------------------------------------------------------------------------
# Store round-trip + integrity refusal.
# ---------------------------------------------------------------------------

def test_store_round_trip_and_weighted_merge(tmp_path):
    st = _store(tmp_path)
    st.record("sweep_tlb", "reference", 4, 1e6, weight=2 * W)
    st.record("sweep_tlb", "reference", 4, 2e6, weight=2 * W)
    # A fresh store object re-reads the same table from disk.
    st2 = CalibrationStore(st.path, device={"device_kind": "TestCPU"})
    assert st2.rate("sweep_tlb", "reference", 4) == pytest.approx(1.5e6)
    # Batch buckets are independent; unknown cells are None.
    assert st2.rate("sweep_tlb", "reference", 1) is None
    assert st2.rate("sweep_system", "reference", 4) is None


def test_store_rate_untrusted_below_min_weight(tmp_path):
    st = _store(tmp_path)
    st.record("sweep_tlb", "reference", 4, 1e6, weight=W / 10)
    assert st.rate("sweep_tlb", "reference", 4) is None  # one tiny smoke chunk
    st.record("sweep_tlb", "reference", 4, 1e6, weight=W)
    assert st.rate("sweep_tlb", "reference", 4) == pytest.approx(1e6)


def test_store_old_weight_cap_keeps_table_adapting(tmp_path):
    st = _store(tmp_path)
    st.record("sweep_tlb", "reference", 4, 1.0, weight=1e9)
    st.record("sweep_tlb", "reference", 4, 101.0, weight=W)
    # Without the cap the 1e9-weight history would pin the rate at ~1.0.
    assert st.rate("sweep_tlb", "reference", 4) == pytest.approx(
        (1.0 * 10 + 101.0) / 11)


def test_corrupt_table_is_refused_not_regenerated(tmp_path):
    st = _store(tmp_path, reference=1e6)
    data = bytearray(st.path.read_bytes())
    data[-10] ^= 0x40  # bit-flip inside the JSON payload
    st.path.write_bytes(bytes(data))
    fresh = CalibrationStore(st.path, device={"device_kind": "TestCPU"})
    with pytest.raises(CalibrationCorruptError, match="checksum"):
        fresh.load()
    with pytest.raises(CalibrationCorruptError):  # writes refuse too
        fresh.record("sweep_tlb", "reference", 4, 1e6, weight=W)
    assert b"\x40" not in b"" or st.path.exists()  # file left in place


def test_truncated_and_foreign_tables_are_refused(tmp_path):
    p = tmp_path / "calib-test.json"
    p.write_text('{"rates": {}}\n')  # plain JSON: not a calibration table
    st = CalibrationStore(p, device={"device_kind": "TestCPU"})
    with pytest.raises(CalibrationCorruptError,
                       match="not a repro-dispatch-calib"):
        st.load()
    p.write_bytes(b"no newline header at all")
    with pytest.raises(CalibrationCorruptError):
        st.load()


def test_decision_json_round_trip(tmp_path):
    st = _store(tmp_path, reference=2e6, pallas_interpret=1e5)
    d = dispatch.decide_timeline("auto", batch=8, n_accesses=4096, store=st)
    assert DispatchDecision.from_json(d.to_json()) == d
    assert DispatchDecision.from_json(json.loads(json.dumps(d.to_json()))) == d


# ---------------------------------------------------------------------------
# Cold-start parity with the legacy heuristics.
# ---------------------------------------------------------------------------

def test_cold_start_matches_legacy_heuristics(monkeypatch):
    import repro.kernels.common as kc

    for backend, generic in (("cpu", "reference"), ("tpu", "pallas")):
        monkeypatch.setattr(kc.jax, "default_backend", lambda b=backend: b)
        # TLB: eligible pure-LRU sweep -> stackdist on every backend.
        d = dispatch.decide_tlb("auto", _specs(4))
        assert (d.mode, d.calibration) == ("stackdist", "cold_start")
        # TLB: ways > AUTO_MAX_WAYS -> ineligible -> the generic rule, and
        # stackdist is not even a candidate (hard shape constraint).
        d = dispatch.decide_tlb("auto", _specs(32))
        assert d.mode == generic and "stackdist" not in d.candidates
        # System: the generic rule.
        assert dispatch.decide_system(
            "auto", [SystemSimConfig(num_partitions=8)]).mode == generic
        # Timeline: degenerate batch -> scan everywhere; real batch -> generic.
        assert dispatch.decide_timeline("auto", batch=1).mode == "reference"
        assert dispatch.decide_timeline("auto", batch=8).mode == generic


def test_explicit_mode_is_honoured_verbatim(tmp_path):
    # Even a table that says reference is 100x faster cannot override an
    # explicitly requested mode.
    st = _store(tmp_path, reference=1e7, pallas_interpret=1e5)
    d = dispatch.decide_timeline("pallas_interpret", batch=8, store=st)
    assert (d.mode, d.calibration) == ("pallas_interpret", "explicit")
    d = dispatch.decide_tlb("stackdist", _specs(4))
    assert (d.mode, d.calibration) == ("stackdist", "explicit")


def test_sweep_only_modes_still_raise_for_other_engines():
    with pytest.raises(ValueError, match="timeline"):
        dispatch.decide_timeline("stackdist", batch=8)
    with pytest.raises(ValueError, match="stack"):
        dispatch.decide_system("stackdist", [SystemSimConfig()])
    with pytest.raises(ValueError, match="bogus"):
        dispatch.decide_tlb("bogus", _specs(4))


# ---------------------------------------------------------------------------
# Calibrated choice.
# ---------------------------------------------------------------------------

def test_calibrated_choice_is_argmax_measured_rate(tmp_path, monkeypatch):
    import repro.kernels.common as kc

    monkeypatch.setattr(kc.jax, "default_backend", lambda: "cpu")
    # The acceptance behaviour: a CPU host that measured the batched scan
    # faster than pallas_interpret stops auto-selecting the interpreter.
    st = _store(tmp_path, reference=1.8e6, pallas_interpret=2.7e5)
    d = dispatch.decide_timeline("auto", batch=8, n_accesses=4096, store=st)
    assert d.mode == "reference" and d.calibration.startswith("measured:")
    # ...and the flip side: a genuinely faster measured rival wins.
    st2 = _store(tmp_path / "other", reference=1e5, pallas_interpret=9e5)
    d = dispatch.decide_timeline("auto", batch=8, n_accesses=4096, store=st2)
    assert d.mode == "pallas_interpret"
    # Predictions are coherent: the chosen mode has the smallest predicted_s.
    preds = {m: c["predicted_s"] for m, c in d.candidates.items()
             if c["predicted_s"] is not None}
    assert min(preds, key=preds.get) == d.mode


def test_half_measured_table_stays_on_cold_start(tmp_path, monkeypatch):
    import repro.kernels.common as kc

    monkeypatch.setattr(kc.jax, "default_backend", lambda: "cpu")
    # Only the rival measured: without a rate for the cold-start default the
    # comparison is vacuous — pallas_interpret is never chosen by default.
    st = _store(tmp_path, pallas_interpret=9e9)
    d = dispatch.decide_timeline("auto", batch=8, n_accesses=4096, store=st)
    assert d.mode == "reference" and "not measured" in d.reason
    # Only the default measured: nothing to compare against, same outcome.
    st2 = _store(tmp_path / "other", reference=1e6)
    d = dispatch.decide_timeline("auto", batch=8, n_accesses=4096, store=st2)
    assert d.mode == "reference" and "rival" in d.reason


def test_observe_records_achieved_rates_and_residual_events(tmp_path):
    st = _store(tmp_path)
    d = dispatch.decide_timeline("auto", batch=8, n_accesses=4096, store=st)
    log = tmp_path / "run.jsonl"
    with telemetry.run_scope(log, run="t"):
        dispatch.record_decision(d, name="fig")
        dispatch.observe(d, {"reference": {"sim_accesses_per_s": 5e5,
                                           "sim_accesses": 4e6}},
                         store=st, name="fig")
    assert st.rate("sweep_timeline", "reference", 8) == pytest.approx(5e5)
    kinds = [(r.get("kind"), r.get("name"))
             for r in map(json.loads, log.read_text().splitlines())]
    assert ("event", "dispatch") in kinds
    assert ("event", "dispatch_residual") in kinds


# ---------------------------------------------------------------------------
# Resume stickiness: the checkpointed decision outlives recalibration.
# ---------------------------------------------------------------------------

def test_resume_sticks_to_checkpointed_decision(tmp_path):
    calib = tmp_path / "calibration"
    store = CalibrationStore.for_dir(calib)  # the orchestrator's own store
    store.record_many([("sweep_tlb", "reference", 2, 2e6, 10 * W),
                       ("sweep_tlb", "pallas_interpret", 2, 1e3, 10 * W)])
    rng = np.random.default_rng(7)
    addrs = rng.integers(0, 1 << 22, 4096).astype(np.int64)
    specs = _specs(32)  # stackdist-ineligible -> the chunked stream path
    oracle = sweep_tlb(addrs, specs, kernel_mode="reference", block=BLOCK).hits

    def cfg(**kw):
        return SweepRunConfig(checkpoint_dir=str(tmp_path / "ckpt"),
                              calibration_dir=str(calib), chunk_accesses=1024,
                              backoff_base_s=0.0, backoff_cap_s=0.0,
                              preemption=PreemptionHandler(install=False), **kw)

    with pytest.raises(SimulatedKill):
        run_sweep_tlb(addrs, specs, kernel_mode="auto", block=BLOCK,
                      run=cfg(on_chunk_committed=kill_after(2)), name="tlb")

    # Recalibrate between runs so a *fresh* decision would flip the backend.
    store.record_many([("sweep_tlb", "pallas_interpret", 2, 1e9, 1e9)])
    fresh = dispatch.decide_tlb("auto", specs, n_accesses=4096, store=store)
    assert fresh.mode == "pallas_interpret"

    # Resume: the blob's decision wins — same backend, bit-identical output.
    res, meta = run_sweep_tlb(addrs, specs, kernel_mode="auto", block=BLOCK,
                              run=cfg(resume=True), name="tlb")
    assert meta["final_mode"] == "reference"
    assert meta["dispatch"]["mode"] == "reference"
    assert meta["dispatch"]["calibration"].startswith("checkpoint:")
    assert "reused from checkpoint" in meta["dispatch"]["reason"]
    np.testing.assert_array_equal(res.hits, oracle)


def test_run_meta_carries_decision_cold_and_explicit(tmp_path):
    rng = np.random.default_rng(9)
    addrs = rng.integers(0, 1 << 22, 1024).astype(np.int64)
    run = SweepRunConfig(preemption=PreemptionHandler(install=False))
    # Explicit mode: stamped as such.
    _, meta = run_sweep_tlb(addrs, _specs(32), kernel_mode="reference",
                            block=BLOCK, run=run, name="t")
    assert meta["dispatch"]["calibration"] == "explicit"
    assert meta["dispatch"]["mode"] == "reference"
    # Cold-start auto on the monolithic stackdist path stamps too.
    _, meta = run_sweep_tlb(addrs, _specs(4), kernel_mode="auto",
                            block=BLOCK, run=run, name="t")
    assert meta["dispatch"]["mode"] == "stackdist"
    assert meta["dispatch"]["calibration"] == "cold_start"
    assert meta["final_mode"] == "stackdist" and "throughput" in meta


# ---------------------------------------------------------------------------
# Bootstrap ingesters + GC.
# ---------------------------------------------------------------------------

def test_ingest_bench_entries_filters_by_device(tmp_path):
    st = _store(tmp_path)
    n = dispatch.ingest_bench_entries(st, [
        {"device_kind": "TestCPU", "bench": "sweep", "n_accesses": 1e5,
         "n_configs": 8, "t_reference_s": 0.5, "t_stackdist_s": 0.1},
        {"device_kind": "SomeTPU", "bench": "sweep", "n_accesses": 1e5,
         "n_configs": 8, "t_reference_s": 0.01},  # foreign device: skipped
        {"device_kind": "TestCPU", "bench": "timeline_batched",
         "n_accesses": 1e4, "n_sims": 12, "mode": "pallas_interpret",
         "t_batched_s": 0.2, "t_pallas_s": 2.0},
    ])
    assert n == 4  # reference+stackdist from sweep, reference+interpret batched
    assert st.rate("sweep_tlb", "reference", 8) == pytest.approx(8e5 / 0.5)
    assert st.rate("sweep_tlb", "stackdist", 8) == pytest.approx(8e5 / 0.1)
    assert st.rate("sweep_timeline", "reference", 12) == pytest.approx(
        1.2e5 / 0.2)
    assert st.rate("sweep_timeline", "pallas_interpret", 12) == pytest.approx(
        1.2e5 / 2.0)


def test_ingest_runlogs_reads_chunk_spans(tmp_path):
    st = _store(tmp_path)
    log = tmp_path / "fig.jsonl"
    recs = [
        {"kind": "run_start", "meta": {"device": {"device_kind": "TestCPU"}}},
        {"kind": "span", "name": "chunk",
         "attrs": {"engine": "sweep_system", "mode": "reference",
                   "configs": 3, "accesses": 2048,
                   "sim_accesses_per_s": 7e5}},
        {"kind": "span", "name": "chunk",  # auto is never a measured mode
         "attrs": {"engine": "sweep_system", "mode": "auto", "configs": 3,
                   "accesses": 2048, "sim_accesses_per_s": 1e9}},
    ]
    log.write_text("\n".join(json.dumps(r) for r in recs) + "\n")
    foreign = tmp_path / "foreign.jsonl"
    foreign.write_text(json.dumps(
        {"kind": "run_start",
         "meta": {"device": {"device_kind": "SomeTPU"}}}) + "\n" +
        json.dumps(recs[1]) + "\n")
    assert dispatch.ingest_runlogs(st, [log, foreign, tmp_path / "nope"]) == 1
    # weight 3*2048 = 6144 >= MIN_CALIB_WEIGHT -> trusted
    assert st.rate("sweep_system", "reference", 3) == pytest.approx(7e5)


def test_gc_sweeps_stale_tables_but_never_fresh_or_foreign(tmp_path):
    stale = _store(tmp_path, reference=1e6)
    fresh = CalibrationStore(tmp_path / "calib-fresh.json",
                             device={"device_kind": "Fresh"})
    fresh.record("sweep_tlb", "reference", 1, 1e6, weight=W)
    foreign = tmp_path / "notes.json"
    foreign.write_text("{}")
    tmpfile = tmp_path / "calib-x.json.tmp-deadbeef"
    tmpfile.write_text("torn")
    old = 30 * 86400.0
    for p in (stale.path, foreign, tmpfile):
        os.utime(p, (p.stat().st_mtime - old, p.stat().st_mtime - old))

    dry = dispatch.gc_calibration(tmp_path, age_s=7 * 86400.0, dry_run=True)
    assert dry["dry_run"] and stale.path.exists()

    out = dispatch.gc_calibration(tmp_path, age_s=7 * 86400.0)
    assert sorted(out["deleted"]) == sorted([str(stale.path), str(tmpfile)])
    assert str(foreign) in out["skipped_foreign"]
    assert not stale.path.exists() and not tmpfile.exists()
    assert fresh.path.exists() and foreign.exists()  # never touched
    assert str(fresh.path) in out["kept_young"]
