"""Fault tolerance of the sharded sweep scheduler (repro.core.scheduler).

The contract under test:

* **Bit-identity.**  A sharded run — any executor — merges to exactly the
  monolithic engine's output, for all three batched engines (the engines
  are batch-mate invariant, so splitting the config axis must not change a
  single bit).
* **Dead workers are survived.**  With the process executor, a worker that
  SIGKILLs itself mid-shard stops heartbeating; the parent observes the
  death, respawns the slot, waits out the lease TTL, re-dispatches the
  shard, and the run still merges bit-identically with nothing quarantined.
* **Poison shards are quarantined, not fatal.**  A shard that fails every
  attempt is quarantined after ``max_shard_attempts``: the run *completes*,
  the quarantined rows are zero placeholders, the manifest lands in
  ``meta["scheduler"]["quarantined_shards"]`` and is hoisted into
  ``crash_safety()["quarantined_shards"]``, and the healthy rows are still
  bit-identical.
* **Stragglers are duplicated, first completion wins**, and the loser is
  verified bit-identical (``duplicate_verified``).
* **Leases** (claim/contend/expire/refresh/release), **gc_checkpoints**
  (age- and header-aware, refuses foreign files, protects in-progress
  runs), **concurrent figure/bench writers** (advisory lock + atomic
  replace), and the **off-main-thread PreemptionHandler no-op** round out
  the satellite coverage.

Faults come from tests/_faultinject.py's picklable ``on_shard_start``
classes (the spawn-based process executor ships them to workers).
"""
import json
import logging
import os
import threading
import time

import numpy as np
import pytest
from _faultinject import HoldShard, KillWorkerOnShard, PoisonShard

from repro.checkpoint.checkpoint import (BLOB_MAGIC, LeaseHeld, acquire_lease,
                                         file_lock, read_lease, refresh_lease,
                                         release_lease)
from repro.core.orchestrator import SweepRunConfig
from repro.core.scheduler import (EX_DEGRADED, ScheduleConfig, gc_checkpoints,
                                  run_sweep_system, run_sweep_timeline,
                                  run_sweep_tlb)
from repro.core.sparta import SystemLatencies, TLBConfig
from repro.core.sweep import TLBSweepSpec, sweep_system, sweep_tlb
from repro.core.timeline import TimelineConfig, TimelineSpec, sweep_timeline
from repro.core.tlbsim import SystemSimConfig
from repro.runtime.fault_tolerance import PreemptionHandler

LAT = SystemLatencies()
BLOCK = 128


def _cfg(tmp_path, **kw):
    kw.setdefault("backoff_base_s", 0.0)
    kw.setdefault("backoff_cap_s", 0.0)
    kw.setdefault("keep_checkpoint", True)
    kw.setdefault("preemption", PreemptionHandler(install=False))
    return SweepRunConfig(checkpoint_dir=str(tmp_path), **kw)


def _sched(**kw):
    kw.setdefault("shards", 2)
    kw.setdefault("workers", 2)
    kw.setdefault("executor", "thread")
    kw.setdefault("poll_s", 0.01)
    kw.setdefault("lease_ttl_s", 5.0)
    kw.setdefault("heartbeat_s", 0.2)
    return ScheduleConfig(**kw)


# ---------------------------------------------------------------------------
# One harness per engine: run(run_cfg, sched) -> (list of arrays, meta); the
# oracle is the monolithic engine.  4 sweep items each, so shards=2 splits
# every engine's config axis down the middle.
# ---------------------------------------------------------------------------

def _tlb_engine():
    rng = np.random.default_rng(7)
    addrs = rng.integers(0, 1 << 22, 4096).astype(np.int64)
    specs = [TLBSweepSpec(TLBConfig(entries=64, ways=4), num_partitions=p)
             for p in (1, 4, 8, 16)]

    def run(cfg, sched):
        res, meta = run_sweep_tlb(addrs, specs, kernel_mode="reference",
                                  block=BLOCK, run=cfg, sched=sched,
                                  name="tlb")
        return [res.hits], meta

    oracle = [sweep_tlb(addrs, specs, kernel_mode="reference",
                        block=BLOCK).hits]
    return run, oracle


def _system_engine():
    rng = np.random.default_rng(11)
    lines = rng.integers(0, 1 << 26, 4096).astype(np.int64)
    cfgs = [
        SystemSimConfig(num_partitions=8),
        SystemSimConfig(accel_tlb=TLBConfig(entries=16, ways=4),
                        num_partitions=4),
        SystemSimConfig(cache=None, page_shift=21, num_partitions=32),
        SystemSimConfig(num_partitions=2),
    ]

    def run(cfg, sched):
        bev, meta = run_sweep_system(lines, cfgs, kernel_mode="reference",
                                     block=BLOCK, run=cfg, sched=sched,
                                     name="system")
        return [bev.cache_hit, bev.accel_tlb_hit, bev.mem_tlb_hit], meta

    o = sweep_system(lines, cfgs, kernel_mode="reference", block=BLOCK)
    return run, [o.cache_hit, o.accel_tlb_hit, o.mem_tlb_hit]


def _timeline_engine():
    rng = np.random.default_rng(3)
    lines_a = rng.integers(0, 1 << 24, 2048).astype(np.int64)
    lines_b = rng.integers(0, 1 << 24, 1200).astype(np.int64)
    ev_a = sweep_system(lines_a, [SystemSimConfig(num_partitions=8)])[0]
    ev_b = sweep_system(lines_b, [SystemSimConfig(num_partitions=2)])[0]
    specs = [
        TimelineSpec(lines_a, ev_a, "sparta",
                     cfg=TimelineConfig(mshrs=4, tlb_ports=1, dram_banks=8),
                     num_partitions=8, num_accelerators=2),
        TimelineSpec(lines_b, ev_b, "ideal",
                     cfg=TimelineConfig(mshrs=2, tlb_ports=1, dram_banks=4),
                     num_accelerators=4),
        TimelineSpec(lines_a, ev_a, "conventional",
                     cfg=TimelineConfig(mshrs=4, tlb_ports=1, dram_banks=8),
                     num_accelerators=1),
        TimelineSpec(lines_b, ev_b, "sparta",
                     cfg=TimelineConfig(mshrs=2, tlb_ports=1, dram_banks=4),
                     num_partitions=2, num_accelerators=2),
    ]

    def run(cfg, sched):
        res, meta = run_sweep_timeline(specs, LAT, kernel_mode="reference",
                                       block=BLOCK, run=cfg, sched=sched,
                                       name="timeline")
        return [a for r in res for a in (r.latency, r.overhead, r.done)], meta

    oracle = [a for r in sweep_timeline(specs, LAT, kernel_mode="reference",
                                        block=BLOCK)
              for a in (r.latency, r.overhead, r.done)]
    return run, oracle


_BUILDERS = {"tlb": _tlb_engine, "system": _system_engine,
             "timeline": _timeline_engine}
_CASES = {}


def _engine(name):
    if name not in _CASES:   # trace + oracle built once per engine
        _CASES[name] = _BUILDERS[name]()
    return _CASES[name]


def _assert_bits(got, want, ctx=""):
    assert len(got) == len(want)
    for i, (a, b) in enumerate(zip(got, want)):
        np.testing.assert_array_equal(a, b, err_msg=f"{ctx} output {i}")


def _event_names(meta):
    return [e["event"] for e in meta["scheduler"]["events"]]


# ---------------------------------------------------------------------------
# Bit-identity of the happy path, serial and threaded.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["tlb", "system", "timeline"])
@pytest.mark.parametrize("executor,workers", [("serial", 1), ("thread", 2)])
def test_sharded_bit_identity(tmp_path, engine, executor, workers):
    run, oracle = _engine(engine)
    got, meta = run(_cfg(tmp_path),
                    _sched(executor=executor, workers=workers))
    _assert_bits(got, oracle, f"{engine}/{executor}")
    s = meta["scheduler"]
    assert s["shards"] == 2 and s["executor"] == executor
    assert not s["quarantined_shards"]
    assert all(sm["state"] == "done" for sm in s["shard_map"])
    assert meta["final_mode"] == "reference"


@pytest.mark.parametrize("engine", ["tlb", "system", "timeline"])
def test_resume_completes_from_shard_checkpoints(tmp_path, engine):
    run, oracle = _engine(engine)
    run(_cfg(tmp_path), _sched(executor="serial", workers=1))
    got, meta = run(_cfg(tmp_path, resume=True),
                    _sched(executor="serial", workers=1))
    _assert_bits(got, oracle, f"{engine}/resume")
    assert meta["completed_from_checkpoint"] is True


# ---------------------------------------------------------------------------
# Kill a worker mid-shard (process executor): lease expiry -> re-dispatch.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["tlb", "system", "timeline"])
def test_kill_worker_redispatch(tmp_path, engine):
    run, oracle = _engine(engine)
    sched = _sched(executor="process", lease_ttl_s=1.0, heartbeat_s=0.2,
                   on_shard_start=KillWorkerOnShard(0, attempts=(0,)))
    got, meta = run(_cfg(tmp_path), sched)
    _assert_bits(got, oracle, f"{engine}/kill")
    names = _event_names(meta)
    assert "worker_dead" in names
    assert "worker_respawn" in names
    assert "lease_expire" in names
    assert "redispatch" in names
    assert not meta["scheduler"]["quarantined_shards"]
    sm0 = meta["scheduler"]["shard_map"][0]
    assert sm0["state"] == "done" and sm0["dispatches"] >= 2


# ---------------------------------------------------------------------------
# Poison shard: quarantine, zero placeholders, run completes.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["tlb", "system", "timeline"])
def test_poison_shard_quarantine(tmp_path, engine):
    run, oracle = _engine(engine)
    sched = _sched(executor="serial", workers=1, max_shard_attempts=2,
                   on_shard_start=PoisonShard(0))
    got, meta = run(_cfg(tmp_path), sched)
    q = meta["scheduler"]["quarantined_shards"]
    assert len(q) == 1 and q[0]["shard"] == 0 and q[0]["failures"] == 2
    assert "poisoned shard 0" in q[0]["errors"][-1]
    names = _event_names(meta)
    assert names.count("shard_failed") == 2 and "quarantine" in names
    # Quarantined rows are zero placeholders; the healthy shard's rows are
    # still bit-identical to the oracle.  Shard 0 covers items [0, 2).
    lo, hi = q[0]["items"]
    assert (lo, hi) == (0, 2)
    if engine == "timeline":
        # 3 arrays per spec; specs [2, 4) are the healthy ones.
        _assert_bits(got[3 * hi:], oracle[3 * hi:], "timeline/healthy")
        for a in got[:3 * hi]:
            assert not np.any(a)
    else:
        for a, b in zip(got, oracle):
            np.testing.assert_array_equal(a[hi:], b[hi:])
            assert not np.any(a[:hi])


def test_quarantine_hoisted_into_crash_safety(tmp_path):
    from benchmarks import common

    run, _ = _engine("tlb")
    _, meta = run(_cfg(tmp_path),
                  _sched(executor="serial", workers=1, max_shard_attempts=1,
                         on_shard_start=PoisonShard(1)))
    before = list(common._DEGRADED_RUNS)
    try:
        common._DEGRADED_RUNS.clear()
        cs = common.crash_safety({"tlb": meta})
        assert cs["quarantined_shards"]["tlb"][0]["shard"] == 1
        assert cs["tlb"]["scheduler"]["shards"] == 2
        assert "quarantine" in cs["tlb"]["scheduler"]["events"]
        assert common.degraded_runs(), "degraded run not registered"
    finally:
        common._DEGRADED_RUNS[:] = before
    assert EX_DEGRADED == 79   # distinct from EX_TEMPFAIL (75) and 0/1


def test_clean_run_has_empty_quarantine_manifest(tmp_path):
    from benchmarks import common

    run, _ = _engine("tlb")
    _, meta = run(_cfg(tmp_path), _sched(executor="serial", workers=1))
    cs = common.crash_safety({"tlb": meta})
    assert cs["quarantined_shards"] == {}


# ---------------------------------------------------------------------------
# Straggler duplication: first completion wins, loser verified identical.
# ---------------------------------------------------------------------------

def test_straggler_duplicate_first_wins(tmp_path):
    run, oracle = _engine("tlb")
    sched = _sched(deadline_s=0.2,
                   on_shard_start=HoldShard(0, 2.5, attempts=(0,)))
    t0 = time.monotonic()
    got, meta = run(_cfg(tmp_path), sched)
    _assert_bits(got, oracle, "tlb/straggler")
    names = _event_names(meta)
    dup = [e for e in meta["scheduler"]["events"]
           if e["event"] == "duplicate_verified"]
    assert dup and all(e["identical"] for e in dup)
    straggled = [e for e in meta["scheduler"]["events"]
                 if e["event"] == "redispatch" and e.get("reason") == "straggler"]
    assert straggled
    assert "quarantine" not in names
    # The held original still reports (that is what gets verified), so the
    # run lasts at least the hold — but the winning result came earlier.
    assert time.monotonic() - t0 >= 2.5


# ---------------------------------------------------------------------------
# Lease primitives.
# ---------------------------------------------------------------------------

def test_lease_acquire_contend_release(tmp_path):
    p = tmp_path / "shard0.lease"
    acquire_lease(p, "owner-a", ttl_s=30.0, shard=0)
    lease = read_lease(p)
    assert lease["owner"] == "owner-a" and lease["shard"] == 0
    with pytest.raises(LeaseHeld):
        acquire_lease(p, "owner-b", ttl_s=30.0)
    # Re-acquire by the same owner refreshes instead of raising.
    acquire_lease(p, "owner-a", ttl_s=30.0)
    assert refresh_lease(p, "owner-a", ttl_s=30.0)
    assert not refresh_lease(p, "owner-b", ttl_s=30.0)
    assert release_lease(p, "owner-a")
    assert read_lease(p) is None


def test_stale_lease_is_broken(tmp_path):
    p = tmp_path / "shard0.lease"
    acquire_lease(p, "dead-worker", ttl_s=0.05, shard=0)
    time.sleep(0.15)
    acquire_lease(p, "owner-b", ttl_s=30.0, shard=0)   # takeover, no raise
    assert read_lease(p)["owner"] == "owner-b"
    # The usurped owner can no longer refresh.
    assert not refresh_lease(p, "dead-worker", ttl_s=30.0)


# ---------------------------------------------------------------------------
# Concurrent writers (satellite: locked, atomic figure/bench writes).
# ---------------------------------------------------------------------------

def test_bench_history_two_writer_stress(tmp_path, monkeypatch):
    from benchmarks import kernel_bench

    path = tmp_path / "BENCH_sweep.json"
    monkeypatch.setattr(kernel_bench, "BENCH_SWEEP_PATH", path)
    n_each, errors = 25, []

    def writer(tag):
        try:
            for i in range(n_each):
                kernel_bench._append_bench_entry(
                    {"bench": f"{tag}-{i}", "us_per_call": float(i)})
        except Exception as e:   # surfaces in the main thread
            errors.append(e)

    ts = [threading.Thread(target=writer, args=(t,)) for t in ("a", "b")]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errors
    hist = json.loads(path.read_text())["history"]   # never torn
    assert len(hist) == 2 * n_each                   # no lost updates
    assert {e["bench"] for e in hist} == {
        f"{t}-{i}" for t in ("a", "b") for i in range(n_each)}


def test_save_fig_two_writer_stress(tmp_path, monkeypatch):
    from benchmarks import common

    monkeypatch.setattr(common, "FIGS", tmp_path)
    errors = []

    def writer(tag):
        try:
            for i in range(20):
                common.save_fig("stress", {"who": tag, "i": i})
        except Exception as e:
            errors.append(e)

    ts = [threading.Thread(target=writer, args=(t,)) for t in ("a", "b")]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errors
    payload = json.loads((tmp_path / "stress.json").read_text())
    assert payload["who"] in ("a", "b") and payload["i"] == 19
    assert not list(tmp_path.glob("*.tmp-*"))   # atomic replace, no litter


def test_file_lock_times_out(tmp_path):
    lock = tmp_path / "x.lck"
    with file_lock(lock):
        with pytest.raises(TimeoutError):
            with file_lock(lock, timeout_s=0.1):
                pass


# ---------------------------------------------------------------------------
# Checkpoint/lease GC.
# ---------------------------------------------------------------------------

def _age(p, age_s):
    t = time.time() - age_s
    os.utime(p, (t, t))


def test_gc_checkpoints(tmp_path):
    old = tmp_path / "done" / "old.ckpt"
    old.parent.mkdir()
    old.write_bytes(BLOB_MAGIC.encode() + b"\n{}")
    _age(old, 3600)
    young = tmp_path / "done" / "young.ckpt"
    young.write_bytes(BLOB_MAGIC.encode() + b"\n{}")
    foreign = tmp_path / "done" / "foreign.ckpt"
    foreign.write_bytes(b"not-a-repro-blob")
    _age(foreign, 3600)
    tmpfile = tmp_path / "done" / "x.ckpt.tmp-123"
    tmpfile.write_bytes(b"partial")
    _age(tmpfile, 3600)
    # An in-progress run: fresh lease protects its (old) blob.
    live = tmp_path / "live" / "shard.ckpt"
    live.parent.mkdir()
    live.write_bytes(BLOB_MAGIC.encode() + b"\n{}")
    _age(live, 3600)
    acquire_lease(tmp_path / "live" / "shard.lease", "w0", ttl_s=300.0)
    # A stale lease from a dead run.
    acquire_lease(tmp_path / "done" / "dead.lease", "w1", ttl_s=0.01)
    time.sleep(0.05)

    dry = gc_checkpoints(tmp_path, age_s=600.0, dry_run=True)
    assert old.exists() and str(old) in dry["deleted"]

    summary = gc_checkpoints(tmp_path, age_s=600.0)
    assert not old.exists() and not tmpfile.exists()
    assert young.exists() and str(young) in summary["kept_young"]
    assert foreign.exists() and str(foreign) in summary["skipped_foreign"]
    assert live.exists() and str(live) in summary["kept_in_progress"]
    assert not (tmp_path / "done" / "dead.lease").exists()
    assert (tmp_path / "live" / "shard.lease").exists()


# ---------------------------------------------------------------------------
# PreemptionHandler off the main thread: documented no-op + warning.
# ---------------------------------------------------------------------------

def test_preemption_handler_off_main_thread_is_noop(caplog):
    box = {}

    def build():
        with caplog.at_level(logging.WARNING,
                             logger="repro.runtime.fault_tolerance"):
            box["h"] = PreemptionHandler(install=True)

    t = threading.Thread(target=build)
    t.start()
    t.join()
    h = box["h"]
    assert h.installed is False
    assert not h.requested
    h.uninstall()   # must be safe even though nothing was installed
    assert any("off the main thread" in r.message for r in caplog.records)
    # The documented forwarding path still works: requested stays drivable.
    h.requested = True
    assert h.requested


def test_preemption_handler_main_thread_installs():
    h = PreemptionHandler(install=True)
    try:
        assert h.installed is True
    finally:
        h.uninstall()


# ---------------------------------------------------------------------------
# obs_report merging.
# ---------------------------------------------------------------------------

def test_obs_report_merge_groups(tmp_path, capsys):
    from benchmarks import obs_report

    def rec(kind, t, **kw):
        return json.dumps({"kind": kind, "t_mono": t, **kw})

    parent = tmp_path / "fig.jsonl"
    parent.write_text("\n".join([
        rec("run_start", 0.0, run="fig", meta={}),
        rec("event", 1.0, name="dispatch",
            attrs={"kind": "scheduler", "shard": 0}),
        rec("run_end", 9.0, run="fig"),
    ]) + "\n")
    worker = tmp_path / "fig-w0-1.jsonl"
    worker.write_text("\n".join([
        rec("run_start", 0.5, run="fig-w0", meta={}),
        rec("span", 2.0, name="shard", dur_s=1.5,
            attrs={"shard": 0, "attempt": 0, "worker": 0, "name": "tlb.s0"}),
        rec("event", 2.1, name="downgrade", attrs={}),
        rec("run_end", 8.0, run="fig-w0"),
    ]) + "\n")

    merged = obs_report.merge_logs(
        [obs_report.load_log(parent), obs_report.load_log(worker)])
    assert [r["t_mono"] for r in merged] == sorted(r["t_mono"] for r in merged)
    assert obs_report.shard_table(merged)[("tlb.s0", 0)]["attempts"] == 1
    assert len(obs_report.scheduler_events(merged)) == 1

    # Comma-joined group renders as one merged run...
    assert obs_report.main([f"{parent},{worker}"]) == 0
    out = capsys.readouterr().out
    assert "shards (scheduler" in out and "scheduler events" in out
    # ...and --fail-on-event sees events from every member of the group.
    assert obs_report.main([f"{parent},{worker}",
                            "--fail-on-event", "downgrade"]) == 1
