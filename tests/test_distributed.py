"""Multi-device tests (run in a subprocess with 8 forced host devices so the
main test process keeps the default single-device view)."""
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _run_subprocess(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    _run_subprocess("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import registry
        from repro import models
        from repro.distributed import sharding as shd
        from repro.train.optimizer import OptimizerConfig, init_state
        from repro.train.train_step import make_train_step
        from repro.launch.mesh import make_mesh

        cfg = registry.get_smoke("qwen3-14b")
        params = models.init(jax.random.PRNGKey(0), cfg)
        opt = init_state(params)
        batch = {"tokens": jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (4, 16)).astype(np.int32))}
        step = make_train_step(cfg, OptimizerConfig(lr=1e-3, warmup_steps=1))

        # single device
        p1, o1, m1 = jax.jit(step)(params, opt, batch)

        # 2x4 mesh with the production sharding rules
        mesh = make_mesh((2, 4), ("data", "model"))
        pspecs = shd.param_specs(params, cfg, mode="train")
        ospecs = shd.opt_state_specs(params, cfg)
        nps = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs, is_leaf=lambda x: isinstance(x, P))
        nos = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs, is_leaf=lambda x: isinstance(x, P))
        params_s = jax.tree.map(lambda x, s: jax.device_put(x, s), params, nps)
        opt_s = jax.tree.map(lambda x, s: jax.device_put(x, s), opt, nos)
        batch_s = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
        p2, o2, m2 = jax.jit(step, in_shardings=(nps, nos, NamedSharding(mesh, P("data", None))),
                             out_shardings=(nps, nos, None))(params_s, opt_s, batch_s)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3, (m1["loss"], m2["loss"])
        d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
        assert max(jax.tree.leaves(d)) < 5e-3, max(jax.tree.leaves(d))
        print("sharded == single OK")
    """)


def test_serve_step_sharded_lowers_and_runs():
    _run_subprocess("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import registry
        from repro.configs.base import ModelConfig
        from repro import models
        from repro.models import transformer as tfm
        from repro.models.paged_global import decode_block_global
        from repro.launch.mesh import make_mesh

        cfg0 = registry.get_smoke("stablelm-12b")
        cfg = ModelConfig(**{**cfg0.__dict__, "kv_page_size": 4})
        params = tfm.init(jax.random.PRNGKey(0), cfg)
        B, T, page, Pn = 4, 12, 4, 4
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
        logits_ref, _ = tfm.forward(params, tokens, cfg, kernel_mode="reference")

        mesh = make_mesh((2, 4), ("data", "model"))
        n_pages = (T + page - 1)//page
        pl = (n_pages + Pn - 1)//Pn
        kp = jnp.zeros((cfg.num_layers, B, Pn, pl, page, cfg.num_kv_heads, cfg.head_dim), jnp.float32)
        vp = jnp.zeros_like(kp)
        tables = jnp.asarray(np.tile(np.arange(pl, dtype=np.int32), (B, Pn, 1)))
        pool_sh = NamedSharding(mesh, P(None, "data", "model", None, None, None, None))
        kp = jax.device_put(kp, pool_sh); vp = jax.device_put(vp, pool_sh)

        def serve(params, tok, kp, vp, tables, ctx):
            x = tfm.embed_tokens(params, cfg, tok[:, None])
            def body(x, scanned):
                lp, kpool, vpool = scanned
                x, kpool, vpool = decode_block_global(lp, x, cfg, kpool, vpool, tables, ctx)
                return x, (kpool, vpool)
            x, (kp2, vp2) = jax.lax.scan(body, x, (params["layers"], kp, vp))
            return tfm.unembed(params, cfg, x)[:, 0], kp2, vp2

        jit = jax.jit(serve, out_shardings=(NamedSharding(mesh, P("data", "model")), pool_sh, pool_sh))
        errs = []
        for t in range(T):
            ctx = jnp.full((B,), t+1, jnp.int32)
            lg, kp, vp = jit(params, tokens[:, t], kp, vp, tables, ctx)
            errs.append(float(jnp.abs(lg - logits_ref[:, t]).max()))
        assert max(errs) < 2e-3, errs
        print("sharded serve OK", max(errs))
    """)


def test_pipeline_parallel_matches_sequential():
    _run_subprocess("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.distributed.pipeline import pipeline_apply, split_layers_into_stages
        from repro.launch.mesh import make_mesh

        L, D, M, mb = 8, 16, 6, 4
        rng = np.random.default_rng(0)
        Ws = jnp.asarray(rng.standard_normal((L, D, D)) * 0.2, jnp.float32)

        def layer(w, x):
            return jnp.tanh(x @ w)

        x = jnp.asarray(rng.standard_normal((M, mb, D)), jnp.float32)
        # sequential reference
        ref = x
        for l in range(L):
            ref = layer(Ws[l], ref)

        mesh = make_mesh((4,), ("stage",))
        stages = split_layers_into_stages(Ws, 4)  # [4, 2, D, D]

        def stage_fn(wpair, xx):
            for i in range(wpair.shape[0]):
                xx = layer(wpair[i], xx)
            return xx

        out = pipeline_apply(stage_fn, stages, x, mesh)
        err = float(jnp.abs(out - ref).max())
        assert err < 1e-5, err
        print("pipeline OK", err)
    """)
