"""Oracle-equivalence for the stack-distance engine (repro.core.stackdist).

The sequential simulators remain the bit-exactness reference: stackdist hit
bits must match ``simulate_tlb`` exactly across random geometries (including
entries < ways degenerates), partition counts, and both page shifts; exact
distances must match a brute-force distinct-count; and the grouping layer in
``repro.core.sweep`` must collapse a sweep to one depth pass per distinct
set-mapping.
"""
import numpy as np
import pytest
from _propcheck import given, settings, st  # hypothesis, or deterministic fallback

from repro.core import stackdist, sweep, tlbsim, traces
from repro.core.sparta import TLBConfig
from repro.core.stackdist import (
    STACKDIST_INF,
    hits_from_depths,
    prev_occurrence,
    reuse_distances,
    stack_depths,
)
from repro.core.sweep import TLBSweepSpec, sweep_tlb
from repro.core.tlbsim import _prepare_keys, simulate_tlb

PARTITIONS = (1, 4, 32)
PAGE_SHIFTS = (12, 21)


def _random_lines(seed: int, n: int = 1500, span_pages: int = 3000) -> np.ndarray:
    rng = np.random.default_rng(seed)
    pages = rng.integers(0, span_pages, n).astype(np.int64)
    return (pages << (12 - tlbsim.LINE_SHIFT)) + rng.integers(0, 64, n)


def _brute_distances(set_idx, tag):
    """Reference stack distances via explicit per-set MRU lists."""
    stacks = {}
    out = np.empty(set_idx.shape[0], np.int64)
    for i, (s, t) in enumerate(zip(set_idx.tolist(), tag.tolist())):
        st_ = stacks.setdefault(s, [])
        out[i] = st_.index(t) if t in st_ else -1
        if t in st_:
            st_.remove(t)
        st_.insert(0, t)
    return out


# ---------------------------------------------------------------------------
# sweep_tlb(kernel_mode="stackdist") vs simulate_tlb, property grid.
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=6)
@given(st.integers(0, 10_000), st.sampled_from(PARTITIONS), st.sampled_from(PAGE_SHIFTS))
def test_stackdist_sweep_bitexact_vs_oracle(seed, P, shift):
    lines = _random_lines(seed)
    specs = [
        TLBSweepSpec(TLBConfig(entries=2, ways=4), num_partitions=P, page_shift=shift),
        TLBSweepSpec(TLBConfig(entries=16, ways=2), num_partitions=P, page_shift=shift),
        TLBSweepSpec(TLBConfig(entries=64, ways=4), num_partitions=1, page_shift=shift),
        TLBSweepSpec(TLBConfig(entries=128, ways=8), num_partitions=P, page_shift=shift),
        TLBSweepSpec(TLBConfig(entries=1, ways=1), num_partitions=P, page_shift=shift),
    ]
    res = sweep_tlb(lines, specs, kernel_mode="stackdist")
    assert res.hits.shape == (len(specs), lines.shape[0])
    for i, sp in enumerate(specs):
        vpns = lines >> (shift - tlbsim.LINE_SHIFT)
        ref = simulate_tlb(vpns, sp.cfg, num_partitions=sp.num_partitions)
        np.testing.assert_array_equal(res.hits[i], ref.hits)
        assert res[i].miss_ratio == ref.miss_ratio


def test_auto_mode_uses_stackdist_for_pure_lru_sweeps(monkeypatch):
    """On a pure-LRU small-ways sweep, auto must route to the stack-distance
    backend — never the sequential scans."""
    monkeypatch.setattr(
        sweep, "_scan_tlb_batched",
        lambda *a, **k: pytest.fail("sequential batched scan used under auto"),
    )
    monkeypatch.setattr(
        tlbsim, "_scan_tlb",
        lambda *a, **k: pytest.fail("per-config scan used under auto"),
    )
    vpns = np.random.default_rng(3).integers(0, 4000, 1200).astype(np.int64)
    specs = [
        TLBSweepSpec(TLBConfig(entries=e, ways=4), num_partitions=p)
        for e in (16, 64) for p in (1, 4)
    ]
    res = sweep_tlb(vpns, specs)  # kernel_mode="auto"
    assert res.hits.shape == (len(specs), vpns.shape[0])


def test_auto_mode_falls_back_for_huge_associativity(monkeypatch):
    """ways beyond AUTO_MAX_WAYS must not pick the capped-stack engine."""
    monkeypatch.setattr(
        stackdist, "stack_depths_batched",
        lambda *a, **k: pytest.fail("stackdist used for huge associativity"),
    )
    vpns = np.random.default_rng(5).integers(0, 2000, 600).astype(np.int64)
    specs = [TLBSweepSpec(TLBConfig(entries=1024, ways=64))]
    res = sweep_tlb(vpns, specs)  # auto -> reference scan
    ref = simulate_tlb(vpns, specs[0].cfg)
    np.testing.assert_array_equal(res.hits[0], ref.hits)


def test_grouping_one_pass_per_set_mapping(monkeypatch):
    """A fig4-style sweep collapses to ONE batched depth pass whose group
    count equals the number of distinct (sets, partitions, page_shift)
    mappings — specs differing only in associativity share a pass."""
    calls = []
    real = stackdist.stack_depths_batched

    def counting(set_b, tag_b, **kw):
        calls.append(set_b.shape[0])
        return real(set_b, tag_b, **kw)

    monkeypatch.setattr(stackdist, "stack_depths_batched", counting)
    vpns = np.random.default_rng(7).integers(0, 5000, 1000).astype(np.int64)
    specs = [
        # 3 sizes x 2 partition counts at ways=4, plus two ways-variants that
        # share the (sets=16, P) mappings of the entries=64 specs.
        *(TLBSweepSpec(TLBConfig(entries=e, ways=4), num_partitions=p)
          for e in (16, 64, 256) for p in (1, 4)),
        TLBSweepSpec(TLBConfig(entries=128, ways=8), num_partitions=1),  # sets=16
        TLBSweepSpec(TLBConfig(entries=32, ways=2), num_partitions=4),   # sets=16
    ]
    n_mappings = len({sweep._mapping_key(sp) for sp in specs})
    assert n_mappings == 6  # the ways-variants dedup onto existing mappings
    res = sweep_tlb(vpns, specs, kernel_mode="stackdist")
    assert calls == [n_mappings]
    for i, sp in enumerate(specs):
        ref = simulate_tlb(vpns, sp.cfg, num_partitions=sp.num_partitions)
        np.testing.assert_array_equal(res.hits[i], ref.hits)


# ---------------------------------------------------------------------------
# Distances: exactness, infinity semantics, kernel paths.
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=6)
@given(st.integers(0, 10_000), st.sampled_from((1, 3, 16)))
def test_reuse_distances_match_bruteforce(seed, total_sets):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 300, 800)
    set_idx = (keys % total_sets).astype(np.int64)
    tag = (keys // total_sets).astype(np.int64)
    cap = 8
    d = reuse_distances(set_idx, tag, cap=cap)
    ref = _brute_distances(set_idx, tag)
    exact = (ref >= 0) & (ref < cap)
    np.testing.assert_array_equal(d[exact], ref[exact])
    # Cold accesses are at infinite distance; deep reuses clip to the cap.
    cold = prev_occurrence(set_idx, tag) < 0
    assert (d[cold] == STACKDIST_INF).all()
    clipped = ~cold & ~exact
    assert (d[clipped] == cap).all()


def test_infinite_distance_iff_reuse():
    rng = np.random.default_rng(11)
    keys = rng.integers(0, 900, 2000)
    set_idx = (keys % 8).astype(np.int64)
    tag = (keys // 8).astype(np.int64)
    d = reuse_distances(set_idx, tag, cap=4)
    prev = prev_occurrence(set_idx, tag)
    np.testing.assert_array_equal(d < STACKDIST_INF, prev >= 0)
    # An effectively infinite TLB (cap above every set's distinct-tag count)
    # hits exactly on reuses.
    max_distinct = max(len(set(tag[set_idx == s])) for s in range(8))
    assert max_distinct < 256
    depth = stack_depths(set_idx, tag, cap=256)
    np.testing.assert_array_equal(hits_from_depths(depth, 256), prev >= 0)


def test_stack_depths_pallas_interpret_matches_reference():
    """The Pallas kernel path (interpreter on CPU) is bit-identical through
    both phases — empty-init lane walk and carry-in re-walk."""
    rng = np.random.default_rng(13)
    keys = rng.integers(0, 500, 700)
    set_idx = (keys % 4).astype(np.int64)
    tag = (keys // 4).astype(np.int64)
    ref = stack_depths(set_idx, tag, cap=4, kernel_mode="reference", block=64)
    pal = stack_depths(set_idx, tag, cap=4, kernel_mode="pallas_interpret", block=64)
    np.testing.assert_array_equal(ref, pal)


def test_degenerate_shapes():
    # shorter than one lane block; single access; all-same tag
    one = stack_depths(np.zeros(1, np.int64), np.zeros(1, np.int64), cap=2)
    np.testing.assert_array_equal(one, [-1])
    same = stack_depths(np.zeros(5, np.int64), np.full(5, 7, np.int64), cap=2)
    np.testing.assert_array_equal(same, [-1, 0, 0, 0, 0])
    d = reuse_distances(np.zeros(0, np.int64), np.zeros(0, np.int64), cap=2)
    assert d.shape == (0,)


def test_cap_validation():
    with pytest.raises(ValueError, match="cap"):
        stack_depths(np.zeros(4, np.int64), np.zeros(4, np.int64), cap=0)
    with pytest.raises(ValueError, match="MAX_CAP"):
        stack_depths(np.zeros(4, np.int64), np.zeros(4, np.int64), cap=100_000)


def test_tag_range_validation():
    """Tags that would alias on the int32 cast (or collide with the -1/-2
    stack sentinels) must raise, not silently corrupt distances."""
    sets = np.zeros(2, np.int64)
    with pytest.raises(ValueError, match="int32"):
        stack_depths(sets, np.array([2**31 + 5, 2**31 + 5 + 2**32]), cap=4)
    with pytest.raises(ValueError, match="int32"):
        stack_depths(sets, np.array([-1, 3]), cap=4)


def test_mode_registry():
    """stackdist is a sweep-level mode: sweeps accept it, per-op kernels don't."""
    vpns = np.zeros(16, np.int64)
    specs = [TLBSweepSpec(TLBConfig(entries=8, ways=4))]
    res = sweep_tlb(vpns, specs, kernel_mode="stackdist")
    assert res.hits.shape == (1, 16)
    with pytest.raises(ValueError, match="kernel_mode"):
        sweep_tlb(vpns, specs, kernel_mode="bogus")
    from repro.kernels.tlb_sim import tlb_sim
    with pytest.raises(ValueError, match="kernel_mode"):
        tlb_sim(np.zeros(4, np.int32), np.zeros(4, np.int32), 4, 2,
                kernel_mode="stackdist")
    # The joint system sweep rejects it loudly (not pure-LRU: cache-hit-
    # conditional probes break stack inclusion) — PR 4 policy, no coercion.
    lines = np.random.default_rng(0).integers(0, 1 << 20, 500).astype(np.int64)
    from repro.core.sweep import sweep_system
    from repro.core.tlbsim import SystemSimConfig
    with pytest.raises(ValueError, match="stack-inclusion"):
        sweep_system(lines, [SystemSimConfig()], kernel_mode="stackdist")


# ---------------------------------------------------------------------------
# Trace-generator regression (rocksdb scan interleaving).
# ---------------------------------------------------------------------------

def test_rocksdb_scans_interleaved_not_appended():
    tr = traces.generate("rocksdb", n_ops=4000, footprint_bytes=1 << 30)
    n_point = 4000 * 7
    n_scan_lines = (4000 // 20) * 32
    assert tr.num_accesses == n_point + n_scan_lines
    # Scan bursts are 32 consecutive line addresses; if they were appended at
    # the tail, all +1-strided runs would live in the last n_scan_lines
    # accesses.  Interleaving must place some in the first half.
    diffs = np.diff(tr.lines[: tr.num_accesses // 2])
    run = 0
    longest = 0
    for d in diffs:
        run = run + 1 if d == 1 else 0
        longest = max(longest, run)
    assert longest >= 16, "no scan burst found in the first half of the trace"


def test_interleave_bursts_is_a_riffle():
    rng = np.random.default_rng(3)
    stream = np.arange(100, dtype=np.int64)
    bursts = 1000 + np.arange(12, dtype=np.int64).reshape(3, 4)
    out = traces._interleave_bursts(stream, bursts, rng)
    assert out.shape[0] == 112
    # stream order preserved
    np.testing.assert_array_equal(out[out < 1000], stream)
    # each burst stays contiguous and in row order
    starts = np.flatnonzero(np.isin(out, bursts[:, 0]))
    for k, s in enumerate(sorted(starts.tolist())):
        np.testing.assert_array_equal(out[s:s + 4], bursts[k])
