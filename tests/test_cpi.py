"""Direct unit tests for the analytical CPI / timeline model (repro.core.cpi).

The model's contract: each design's ``AccessTimes`` is the exact *mean* of
the per-access Fig 3 latency composition over the event stream (that is what
lets the cycle-approximate timeline engine degrade to it — see
tests/test_timeline.py for the cross-subsystem check).
"""
import numpy as np
import pytest

from repro.core import cpi
from repro.core.sparta import SystemLatencies
from repro.core.tlbsim import SystemEvents

LAT = SystemLatencies()  # defaults: l_cache=2, l_tlb=2, l_dram=120, t_net=390


def make_events(cache_hit, accel_tlb_hit=None, mem_tlb_hit=None, n_warm=None):
    """SystemEvents from explicit bit arrays.

    Mirrors simulate_system's convention: structures not probed on an access
    (accel/mem TLB on a cache hit) carry a forced True bit.
    """
    c = np.asarray(cache_hit, bool)
    a = np.where(c, True, np.asarray(
        accel_tlb_hit if accel_tlb_hit is not None else np.ones_like(c), bool))
    m = np.where(c, True, np.asarray(
        mem_tlb_hit if mem_tlb_hit is not None else np.ones_like(c), bool))
    return SystemEvents(cache_hit=c, accel_tlb_hit=a, mem_tlb_hit=m,
                        n_warm=c.shape[0] if n_warm is None else n_warm)


def per_access_total(ev, design, way_accuracy=0.75):
    """Mean of the explicit per-access Fig 3 composition (the timeline
    engine's unqueued latency) — the closed form each design must match."""
    c = ev.cache_hit.astype(float)
    a = ev.accel_tlb_hit.astype(float)
    m = ev.mem_tlb_hit.astype(float)
    walk = 2 * LAT.t_net + LAT.l_dram
    data = 2 * LAT.t_net + LAT.l_dram
    fetch = LAT.l_cache + (1 - c) * data
    if design == "conventional":
        ov = (1 - c) * (LAT.l_tlb + (1 - a) * walk)
    elif design == "sparta":
        ov = (1 - c) * (LAT.l_tlb + (1 - m) * LAT.l_dram)
    elif design == "dipta":
        ov = (1 - c) * (1 - way_accuracy) * 2 * LAT.l_dram
    else:
        ov = np.zeros_like(c)
    return float((fetch + ov).mean()), float(ov.mean())


DESIGN_FNS = {
    "conventional": lambda ev: cpi.conventional_access(ev, LAT),
    "sparta": lambda ev: cpi.sparta_access(ev, LAT),
    "dipta": lambda ev: cpi.dipta_access(ev, LAT, 0.75),
    "ideal": lambda ev: cpi.ideal_access(ev, LAT),
}


@pytest.mark.parametrize("design", list(DESIGN_FNS))
def test_access_times_equal_per_access_mean(design):
    rng = np.random.default_rng(3)
    ev = make_events(rng.random(400) < 0.6,
                     rng.random(400) < 0.5, rng.random(400) < 0.7)
    acc = DESIGN_FNS[design](ev)
    total, ov = per_access_total(ev, design)
    np.testing.assert_allclose(acc.total, total, rtol=1e-12)
    np.testing.assert_allclose(acc.translation_overhead, ov, rtol=1e-12)
    np.testing.assert_allclose(acc.total, acc.fetch + acc.translation_overhead,
                               rtol=1e-12)


def test_closed_form_corner_cases():
    walk = 2 * LAT.t_net + LAT.l_dram
    # All cache hits: no design exposes any translation overhead.
    ev = make_events(np.ones(16, bool))
    for fn in DESIGN_FNS.values():
        acc = fn(ev)
        assert acc.translation_overhead == 0.0
        assert acc.total == LAT.l_cache
    # All cache misses, all TLBs hit: overhead is exactly one probe.
    ev = make_events(np.zeros(16, bool), np.ones(16, bool), np.ones(16, bool))
    assert cpi.conventional_access(ev, LAT).translation_overhead == LAT.l_tlb
    assert cpi.sparta_access(ev, LAT).translation_overhead == LAT.l_tlb
    # All cache misses, all TLBs miss: conventional pays a full remote walk,
    # SPARTA one *local* DRAM access.
    ev = make_events(np.zeros(16, bool), np.zeros(16, bool), np.zeros(16, bool))
    assert cpi.conventional_access(ev, LAT).translation_overhead == LAT.l_tlb + walk
    assert cpi.sparta_access(ev, LAT).translation_overhead == LAT.l_tlb + LAT.l_dram


def test_conventional_walk_term_conditions_on_cache_miss_stream():
    """The walk term must weight P(cache miss AND TLB miss), not the product
    of marginals: craft events where the unconditioned accel-TLB rate (with
    its forced-True bits on cache hits) would understate the walks."""
    c = np.array([True, True, True, False, False, False, False, False])
    a = np.array([False, False, False, False, False, False, False, True])
    ev = make_events(c, a)
    walk = 2 * LAT.t_net + LAT.l_dram
    miss_ratio = 5 / 8     # 5 of 8 accesses miss the cache (and probe the TLB)
    misses_that_walk = 4 / 8
    expect = miss_ratio * LAT.l_tlb + misses_that_walk * walk
    np.testing.assert_allclose(
        cpi.conventional_access(ev, LAT).translation_overhead, expect, rtol=1e-12)


def test_design_ordering_on_shared_events():
    """On identical event bits (same TLB behaviour for both designs):
    ideal <= sparta <= conventional <= (conventional with more walks)."""
    rng = np.random.default_rng(11)
    for _ in range(5):
        tlb = rng.random(300) < rng.uniform(0.2, 0.9)
        ev = make_events(rng.random(300) < rng.uniform(0.1, 0.9), tlb, tlb)
        ideal = cpi.ideal_access(ev, LAT).total
        sparta = cpi.sparta_access(ev, LAT).total
        conv = cpi.conventional_access(ev, LAT).total
        assert ideal <= sparta <= conv


def test_dipta_way_prediction_penalty_path():
    ev = make_events(np.zeros(32, bool))  # every access misses the cache
    # Exact penalty: (1-h_c) * (1-accuracy) * 2 DRAM accesses.
    for acc in (1.0, 0.9, 0.5, 0.0):
        got = cpi.dipta_access(ev, LAT, acc).translation_overhead
        np.testing.assert_allclose(got, (1 - acc) * 2 * LAT.l_dram, rtol=1e-12)
    # Perfect prediction degrades to ideal; worse prediction is monotonic.
    assert cpi.dipta_access(ev, LAT, 1.0).total == cpi.ideal_access(ev, LAT).total
    assert (cpi.dipta_access(ev, LAT, 0.4).total
            > cpi.dipta_access(ev, LAT, 0.8).total)


def test_evaluate_design_dipta_accuracy_lookup():
    ev = make_events(np.zeros(32, bool))
    per_workload = cpi.evaluate_design(
        "dipta", ev, LAT, instr_per_access=5.0, workload="hash_table")
    fallback = cpi.evaluate_design(
        "dipta", ev, LAT, instr_per_access=5.0, workload="nonexistent")
    acc_ht = cpi.DIPTA_WAY_PREDICTION_ACCURACY["hash_table"]
    np.testing.assert_allclose(
        per_workload.access.translation_overhead, (1 - acc_ht) * 2 * LAT.l_dram)
    np.testing.assert_allclose(
        fallback.access.translation_overhead, (1 - 0.75) * 2 * LAT.l_dram)
    with pytest.raises(ValueError):
        cpi.evaluate_design("bogus", ev, LAT, instr_per_access=5.0)


def test_cycles_per_instruction_and_speedup():
    ev = make_events(np.zeros(8, bool), np.ones(8, bool))
    base = cpi.evaluate_design("conventional", ev, LAT, instr_per_access=4.0)
    fast = cpi.evaluate_design("ideal", ev, LAT, instr_per_access=4.0)
    # CPI = base_cpi + access_time / instr_per_access.
    np.testing.assert_allclose(
        base.cycles_per_instr, 1.0 + base.access.total / 4.0, rtol=1e-12)
    assert fast.speedup_over(base) > 1.0
    np.testing.assert_allclose(
        fast.speedup_over(base), base.cycles_per_instr / fast.cycles_per_instr)
