"""Hypothesis property tests for the SPARTA paged-KV manager (paper §5
transplanted to serving: demand allocation, CoW, partition invariant)."""
import numpy as np
import pytest
from _propcheck import given, settings, st  # hypothesis, or deterministic fallback

from repro.core.paged_kv import FREE, PagedKVConfig, SpartaKVManager, partition_of


@st.composite
def op_sequences(draw):
    n_ops = draw(st.integers(1, 40))
    return [draw(st.sampled_from(["new", "append", "fork", "free"])) for _ in range(n_ops)], draw(st.randoms())


@settings(deadline=None, max_examples=60)
@given(op_sequences())
def test_manager_invariants_hold_under_any_op_sequence(ops_rng):
    ops, rnd = ops_rng
    cfg = PagedKVConfig(num_partitions=4, slots_per_partition=64, page_size=8)
    m = SpartaKVManager(cfg)
    live = []
    for op in ops:
        try:
            if op == "new" or not live:
                live.append(m.new_sequence())
            elif op == "append":
                m.append_tokens(rnd.choice(live), rnd.randint(1, 30))
            elif op == "fork":
                live.append(m.fork(rnd.choice(live)))
            elif op == "free":
                sid = rnd.choice(live)
                live.remove(sid)
                m.free_sequence(sid)
        except MemoryError:
            pass  # pool exhaustion is a legal outcome, not an invariant break
        m.check_invariants()


def test_partition_hash_invariant():
    """Logical page l lives on partition l % P — always."""
    cfg = PagedKVConfig(num_partitions=4, slots_per_partition=32, page_size=4)
    m = SpartaKVManager(cfg)
    s = m.new_sequence()
    m.append_tokens(s, 40)  # 10 pages
    tables = m.local_block_tables([s], max_pages=10)
    for lp in range(10):
        p = partition_of(lp, 4)
        assert tables[p, 0, lp // 4] >= 0
        # all other partitions have no entry for this local index... (packed)


def test_cow_preserves_partition_and_parent():
    cfg = PagedKVConfig(num_partitions=2, slots_per_partition=16, page_size=4)
    m = SpartaKVManager(cfg)
    a = m.new_sequence()
    m.append_tokens(a, 6)              # page 1 is partial (2/4 tokens)
    b = m.fork(a)
    parent_pages = m.seq_pages(a)
    written = m.append_tokens(b, 1)    # CoW on the shared tail page
    assert m.seq_pages(a) == parent_pages          # parent untouched
    assert m.seq_pages(b)[0] == parent_pages[0]    # full page still shared
    assert m.seq_pages(b)[1] != parent_pages[1]    # tail copied
    # copy stayed in the same partition (hash depends on logical index only)
    lp = 1
    assert partition_of(lp, 2) == partition_of(lp, 2)
    m.check_invariants()


def test_demand_allocation_is_lazy():
    cfg = PagedKVConfig(num_partitions=4, slots_per_partition=8, page_size=16)
    m = SpartaKVManager(cfg)
    s = m.new_sequence()
    free_before = [m.num_free(p) for p in range(4)]
    m.append_tokens(s, 1)  # only page 0 allocated
    assert m.num_free(0) == free_before[0] - 1
    assert all(m.num_free(p) == free_before[p] for p in range(1, 4))


def test_fork_shares_without_copying():
    cfg = PagedKVConfig(num_partitions=2, slots_per_partition=8, page_size=4)
    m = SpartaKVManager(cfg)
    a = m.new_sequence()
    m.append_tokens(a, 8)
    free0 = m.num_free(0) + m.num_free(1)
    b = m.fork(a)
    assert m.num_free(0) + m.num_free(1) == free0  # zero new pages
    assert m.seq_pages(a) == m.seq_pages(b)
    m.free_sequence(a)
    m.check_invariants()  # b keeps the pages alive
    assert m.seq_pages(b)


def test_exhaustion_raises_memoryerror():
    cfg = PagedKVConfig(num_partitions=1, slots_per_partition=2, page_size=4)
    m = SpartaKVManager(cfg)
    s = m.new_sequence()
    with pytest.raises(MemoryError):
        m.append_tokens(s, 100)


def test_global_vs_local_tables_agree():
    cfg = PagedKVConfig(num_partitions=4, slots_per_partition=16, page_size=4)
    m = SpartaKVManager(cfg)
    s = m.new_sequence()
    m.append_tokens(s, 30)
    loc = m.local_block_tables([s], 8)
    glob = m.global_block_table([s], 8)
    for lp in range(8):
        p = partition_of(lp, 4)
        if glob[0, lp] == FREE:
            assert loc[p, 0, lp // 4] == FREE
        else:
            assert glob[0, lp] == p * cfg.slots_per_partition + loc[p, 0, lp // 4]
