"""Fig 8: multiprogramming impact on BST-External's TLB miss ratio.

Thread mixes: 1/2/4 BST-E threads (shared dataset — SPARTA avoids redundant
caching of shared translations), then unrelated apps join: +4 HashTable,
then +4 BST-I and +4 SkipList.  Partitioning absorbs the added contention
(claims fold into C3)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (Claim, GIB, crash_safety, print_csv,
                               run_config, save_fig, telemetry_stamp,
                               with_runlog)
from repro.core import traces
from repro.core.scheduler import run_sweep_tlb
from repro.core.sparta import TLBConfig
from repro.core.sweep import TLBSweepSpec

PARTS = (1, 4, 16, 64)
TLB = TLBConfig(entries=128, ways=4)


def _mix(n_ops, seed, spec):
    """spec: list of (workload, threads, footprint, base_offset_gb)."""
    streams = []
    for w, t, fp, off in spec:
        for i in range(t):
            tr = traces.generate(w, n_ops=n_ops, seed=seed + 31 * i + hash(w) % 97,
                                 footprint_bytes=fp,
                                 thread_slice=(i / t, (i + 1) / t) if t > 1 else (0.0, 1.0),
                                 scatter_nodes=True)
            streams.append((w, tr.lines + (off * GIB >> 6)))
    n = min(s.shape[0] for _, s in streams)
    n -= n % 1
    inter = traces.interleave([s[:n] for _, s in streams])
    who = np.tile(np.arange(len(streams)), n)[: inter.shape[0]]
    names = [w for w, _ in streams]
    return inter, who, names


@with_runlog("fig8")
def run(quick: bool = False, kernel_mode: str = "auto",
        resume: bool = False, chunk_accesses=None, sched=None):
    n_ops = 4_000 if quick else 10_000
    fp32 = 32 * GIB
    rc = run_config("fig8", resume=resume, chunk_accesses=chunk_accesses)
    metas = {}
    mixes = {
        "bst_e_x1": [("bst_external", 1, fp32, 0)],
        "bst_e_x2": [("bst_external", 2, fp32, 0)],
        "bst_e_x4": [("bst_external", 4, fp32, 0)],
        "+hash_x4": [("bst_external", 4, fp32, 0), ("hash_table", 4, fp32, 32)],
        "+bsti+skip": [("bst_external", 4, fp32, 0), ("hash_table", 4, fp32, 32),
                        ("bst_internal", 4, fp32, 64), ("skip_list", 4, fp32, 96)],
    }
    results, rows = {}, []
    for name, spec in mixes.items():
        inter, who, names = _mix(n_ops, 11, spec)
        cap = 2_400_000
        inter = inter[:cap]
        who = who[:inter.shape[0]]
        # All partition counts ride one batched sweep over the mixed trace
        # (one stack-distance pass per partition count under the default
        # kernel_mode: each P is its own set-mapping bucket).
        batched, metas[f"tlb-{name}"] = run_sweep_tlb(
            inter >> (12 - 6),
            [TLBSweepSpec(TLB, num_partitions=p) for p in PARTS],
            kernel_mode=kernel_mode, run=rc, name=f"tlb-{name}",
            sched=sched,
        )
        line = []
        for i_p, _ in enumerate(PARTS):
            res = batched[i_p]
            n0 = res.hits.shape[0] - res.n_warm
            # Miss ratio observed by the BST-E threads only.
            is_bste = np.array([names[i] == "bst_external" for i in range(len(names))])[who[n0:]]
            hits = res.hits[n0:][is_bste]
            line.append(float(1.0 - hits.mean()) if hits.size else 1.0)
        results[name] = line
        rows.append([name] + line)

    # Paper §7.3.1: unrelated apps increase contention, but "despite the
    # increased contention, SPARTA manages to significantly reduce the TLB
    # miss ratio through partitioning".
    bump1 = results["+bsti+skip"][0] - results["bst_e_x4"][0]
    c3c = Claim("C3c", "unrelated apps raise BST-E misses on the shared TLB (bump@P1)",
                float(bump1), (0.005, 1.0), "")
    full = results["+bsti+skip"]
    c3d = Claim("C3d", "partitioning cuts BST-E misses under the full multiprogrammed mix ((P1-P64)/P1)",
                float((full[0] - full[-1]) / max(full[0], 1e-9)), (0.15, 1.0), "")
    print_csv("Fig8 BST-E miss ratio vs partitions", ["mix"] + [f"P{p}" for p in PARTS], rows)
    print(c3c); print(c3d)
    save_fig("fig8", {"parts": PARTS, "results": results,
                      "claims": [c3c.row(), c3d.row()],
                      "_crash_safety": crash_safety(metas),
                      "_telemetry": telemetry_stamp(metas)})
    return [c3c, c3d]
