"""CI fault-injection smoke: SIGTERM a fig11 run mid-sweep, resume it, and
fail if the resumed figure differs from an uninterrupted run.

Three phases, all ``--quick`` with a small ``--chunk-accesses`` so even the
CI-sized trace crosses many checkpoint boundaries:

1. **Reference run** — fig11 start to finish; its ``fig11.json`` is the
   ground truth.
2. **Interrupted run** — a fresh fig11 is SIGTERMed as soon as its first
   chunk checkpoint is durably on disk; the process must exit with code 75
   (EX_TEMPFAIL, the orchestrator's ``Preempted`` convention) and leave
   checkpoint blobs behind.
3. **Resumed run** — fig11 with ``--resume`` re-enters from the last
   committed chunk and must finish; its ``fig11.json`` must equal the
   reference byte-for-byte after dropping the ``_``-prefixed stamp keys
   (``_written_at``, ``_device``, ``_crash_safety`` — the crash-safety
   record legitimately differs: the resumed run says where it re-entered).

Exit 0 on success, 1 on any mismatch, with a diff summary on stderr.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import signal
import subprocess
import sys
import time

HERE = pathlib.Path(__file__).resolve().parent
FIG = HERE / "_cache" / "figs" / "fig11.json"
CKPT = HERE / "_cache" / "ckpt" / "fig11"
CHUNK = 4_096   # small enough that a --quick 24k-access trace has ~6 chunks
CMD = [sys.executable, "-m", "benchmarks.fig11_tail_latency", "--quick",
       "--chunk-accesses", str(CHUNK)]


def _strip(payload: dict) -> dict:
    return {k: v for k, v in payload.items() if not k.startswith("_")}


def _load_fig() -> dict:
    return json.loads(FIG.read_text())


def _clear():
    shutil.rmtree(CKPT, ignore_errors=True)
    if FIG.exists():
        FIG.unlink()


def main() -> int:
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")

    print("[smoke_resume] phase 1: uninterrupted reference run")
    _clear()
    p = subprocess.run(CMD, env=env, cwd=HERE.parent)
    if p.returncode not in (0, 1):   # 1 = a claim out of band, still a figure
        print(f"[smoke_resume] reference run failed (exit {p.returncode})",
              file=sys.stderr)
        return 1
    reference = _strip(_load_fig())

    print("[smoke_resume] phase 2: fresh run, SIGTERM at first chunk checkpoint")
    _clear()
    child = subprocess.Popen(CMD, env=env, cwd=HERE.parent)
    deadline = time.monotonic() + 600
    while time.monotonic() < deadline:
        if child.poll() is not None:
            break
        if CKPT.exists() and any(CKPT.glob("*.ckpt")):
            child.send_signal(signal.SIGTERM)
            break
        time.sleep(0.05)
    rc = child.wait(timeout=600)
    if rc == 75:
        print("[smoke_resume] interrupted cleanly (exit 75), checkpoints on disk")
    elif rc in (0, 1):
        # The run beat the signal; resume must then be a pure checkpoint read.
        print("[smoke_resume] run finished before the signal landed; "
              "resume still must reproduce it")
    else:
        print(f"[smoke_resume] interrupted run exited {rc}, expected 75",
              file=sys.stderr)
        return 1

    print("[smoke_resume] phase 3: rerun with --resume")
    p = subprocess.run(CMD + ["--resume"], env=env, cwd=HERE.parent)
    if p.returncode not in (0, 1):
        print(f"[smoke_resume] resumed run failed (exit {p.returncode})",
              file=sys.stderr)
        return 1
    resumed = _strip(_load_fig())

    if resumed != reference:
        ref_s = json.dumps(reference, sort_keys=True, indent=1).splitlines()
        res_s = json.dumps(resumed, sort_keys=True, indent=1).splitlines()
        diff = [f"-{a}\n+{b}" for a, b in zip(ref_s, res_s) if a != b]
        print("[smoke_resume] FAIL: resumed figure differs from reference:",
              file=sys.stderr)
        print("\n".join(diff[:40]), file=sys.stderr)
        return 1
    print("[smoke_resume] PASS: resumed fig11.json is identical to the "
          "uninterrupted run")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
