"""Shared benchmark plumbing: trace cache, CSV output, claim checks, and the
per-run telemetry scope (JSONL run logs + the ``_telemetry`` figure stamp)."""
from __future__ import annotations

import functools
import json
import os
import pathlib
import time
import uuid
from typing import Dict, List, Optional

import numpy as np

from repro.core import traces
from repro.runtime import telemetry

CACHE = pathlib.Path(__file__).resolve().parent / "_cache"
FIGS = CACHE / "figs"
RUNLOGS = CACHE / "runlogs"
GIB = 1 << 30

_TRACE_CACHE: Dict = {}

# Paper's four index workloads (Table 2) + server workload.
W4 = ("bst_external", "bst_internal", "hash_table", "skip_list")


def trace(workload: str, *, n_ops: int = 40_000, seed: int = 0,
          footprint_bytes: int = 128 * GIB, max_accesses: int = 1_400_000):
    key = (workload, n_ops, seed, footprint_bytes, max_accesses)
    if key not in _TRACE_CACHE:
        _TRACE_CACHE[key] = traces.generate(
            workload, n_ops=n_ops, seed=seed,
            footprint_bytes=footprint_bytes, max_accesses=max_accesses,
        )
    return _TRACE_CACHE[key]


class Claim:
    """A checked reproduction claim (paper §7), printed and persisted."""

    def __init__(self, name: str, desc: str, value: float, band: tuple, unit: str = ""):
        self.name, self.desc, self.value, self.band, self.unit = name, desc, value, band, unit
        self.ok = band[0] <= value <= band[1]

    def row(self) -> dict:
        return {
            "claim": self.name, "description": self.desc,
            "value": self.value, "band": list(self.band),
            "unit": self.unit, "ok": self.ok,
        }

    def __str__(self):
        mark = "PASS" if self.ok else "MISS"
        return (f"[{mark}] {self.name}: {self.value:.3g}{self.unit} "
                f"(band {self.band[0]:.3g}..{self.band[1]:.3g}) — {self.desc}")


def run_config(fig: str, *, resume: bool = False, chunk_accesses=None):
    """The :class:`repro.core.orchestrator.SweepRunConfig` of one figure
    driver: checkpoints live under ``_cache/ckpt/<fig>/`` (one blob per
    engine call), ``resume`` re-enters them, ``chunk_accesses`` overrides
    the commit granularity (the CI fault-injection smoke shrinks it so a
    quick run still crosses several chunk boundaries).  ``calibration_dir``
    points ``kernel_mode="auto"`` at the measured-rate tables under
    ``_cache/calibration/`` (fed by kernel_bench and every orchestrated
    run), so bench drivers pick backends by measured speed — library users
    and tests that build their own ``SweepRunConfig`` stay on the
    deterministic cold-start heuristics."""
    from repro.core.orchestrator import SweepRunConfig

    kw = {"checkpoint_dir": str(CACHE / "ckpt" / fig), "resume": bool(resume),
          "calibration_dir": str(CACHE / "calibration")}
    if chunk_accesses:
        kw["chunk_accesses"] = int(chunk_accesses)
    return SweepRunConfig(**kw)


def sched_config(*, workers: int = 1, shards: int = 0,
                 deadline: Optional[float] = None, executor: str = "auto"):
    """Build the driver-facing :class:`repro.core.scheduler.ScheduleConfig`
    — or ``None`` (pure unsharded passthrough) when nothing asks for
    scheduling.  Worker run logs land next to the figure's own
    (``_cache/runlogs/``); ``REPRO_SCHED_HOLD_S`` is the CI smoke's seam for
    holding each shard's first attempt open long enough to SIGKILL a worker
    mid-shard."""
    from repro.core.scheduler import ScheduleConfig

    sched = ScheduleConfig(
        workers=int(workers), shards=int(shards), deadline_s=deadline,
        executor=executor,
        lease_ttl_s=float(os.environ.get("REPRO_SCHED_LEASE_TTL_S", 5.0)),
        heartbeat_s=float(os.environ.get("REPRO_SCHED_HEARTBEAT_S", 1.0)),
        hold_s=float(os.environ.get("REPRO_SCHED_HOLD_S", 0.0) or 0.0),
        runlog_dir=str(RUNLOGS))
    return sched if sched.enabled else None


# Figures whose last run completed degraded (quarantined shards): the run.py
# driver loop and standalone figure mains exit with scheduler.EX_DEGRADED
# when this is non-empty.
_DEGRADED_RUNS: List[str] = []


def degraded_runs() -> List[str]:
    return list(_DEGRADED_RUNS)


def crash_safety(metas: Dict[str, dict]) -> dict:
    """Figure-JSON stamp of how each orchestrated engine call executed:
    backend ladder start/end, every retry/halve/downgrade event, where a
    resumed run re-entered — and, for scheduled (sharded) calls, the shard
    map and the quarantined-shard manifest.  Underscore-prefixed in payloads
    (like ``_written_at`` / ``_device``) so resume-identity comparisons drop
    it."""
    out = {}
    quarantined = {}
    for name, m in metas.items():
        rec = {
            "start_mode": m["start_mode"], "final_mode": m["final_mode"],
            "resumable": m["resumable"], "resumed_from": m["resumed_from"],
            "completed_from_checkpoint": m["completed_from_checkpoint"],
            "events": m["events"],
        }
        s = m.get("scheduler")
        if s:
            rec["scheduler"] = {
                "shards": s["shards"], "workers": s["workers"],
                "executor": s["executor"], "shard_map": s["shard_map"],
                "events": [e["event"] for e in s["events"]],
            }
            if s.get("quarantined_shards"):
                quarantined[name] = s["quarantined_shards"]
        out[name] = rec
    out["quarantined_shards"] = quarantined
    if quarantined:
        run = telemetry.get_tracer().run or "?"
        if run not in _DEGRADED_RUNS:
            _DEGRADED_RUNS.append(run)
    return out


def with_runlog(fig: str):
    """Decorator bracketing a figure/bench driver's ``run()`` in a telemetry
    run scope: every orchestrated engine call, chunk span, ladder event and
    measured row of the run lands in ``_cache/runlogs/<fig>.jsonl`` (one
    file per driver, overwritten per run — the stable paths CI uploads and
    ``benchmarks/obs_report.py`` renders)."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            from repro.core import benchtime

            with telemetry.run_scope(RUNLOGS / f"{fig}.jsonl", run=fig,
                                     device=benchtime.device_metadata()):
                return fn(*args, **kwargs)
        return wrapper
    return deco


def telemetry_stamp(metas: Dict[str, dict] = None) -> dict:
    """Figure-JSON ``_telemetry`` stamp: the tracer's run summary (total
    spans, event counts, counters/gauges) plus, per orchestrated engine
    call, the achieved accesses/s of every backend that actually executed.
    ``_crash_safety`` says *what degraded*; this says *what it cost*."""
    stamp = telemetry.get_tracer().summary()
    if metas:
        stamp["engines"] = {
            name: {"engine": m.get("engine"),
                   "final_mode": m.get("final_mode"),
                   "throughput": m.get("throughput", {}),
                   "dispatch": m.get("dispatch")}
            for name, m in metas.items()}
    return stamp


def save_fig(name: str, payload: dict):
    from repro.checkpoint.checkpoint import file_lock
    from repro.core import benchtime

    FIGS.mkdir(parents=True, exist_ok=True)
    payload = dict(payload)
    payload["_written_at"] = time.strftime("%Y-%m-%d %H:%M:%S")
    # Same schema stamp as BENCH_sweep.json rows: figure outputs say what
    # device they were produced on (interpret-mode CPU vs real TPU).
    payload["_device"] = benchtime.device_metadata()
    # Drivers with orchestrated engine calls pass an explicit stamp (with
    # per-engine throughput); anything else written inside a telemetry run
    # gets the plain run summary.
    if "_telemetry" not in payload and telemetry.get_tracer().active:
        payload["_telemetry"] = telemetry_stamp()
    # Lock + write-tmp + atomic replace: concurrent scheduler workers (or
    # two driver invocations) can never interleave into a torn figure JSON.
    path = FIGS / f"{name}.json"
    with file_lock(path.with_name(path.name + ".lock")):
        tmp = path.with_name(f"{path.name}.tmp-{uuid.uuid4().hex[:8]}")
        tmp.write_text(json.dumps(payload, indent=1, default=float))
        os.replace(tmp, path)


def load_fig(name: str):
    p = FIGS / f"{name}.json"
    return json.loads(p.read_text()) if p.exists() else None


def print_csv(title: str, header: List[str], rows: List[list]):
    print(f"\n# {title}")
    print(",".join(header))
    for r in rows:
        print(",".join(f"{x:.4g}" if isinstance(x, float) else str(x) for x in r))
