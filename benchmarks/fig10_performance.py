"""Fig 10 (+ §7.7): end-to-end performance — SPARTA vs conventional vs DIPTA
vs ideal, 8-socket 128 GB machine, 16 KB virtual caches.

Per workload: the joint trace simulation provides (cache, accel-TLB,
memory-TLB) hit rates, the Fig 3 timeline/CPI model turns them into
speedups over conventional-4K.  Claims (C6): conventional 2MB gains only
~14%; SPARTA-32 improves ~1.57x (4K), within ~94% of ideal; translation
overhead drops ~31.5x on average (up to 47x); (C8) idealized DIPTA trails
SPARTA due to way misprediction."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (Claim, W4, crash_safety, print_csv, run_config,
                               save_fig, telemetry_stamp, trace, with_runlog)
from repro.core import cpi
from repro.core.scheduler import run_sweep_system
from repro.core.sparta import SystemLatencies, TLBConfig
from repro.core.tlbsim import SystemSimConfig

CACHE = TLBConfig(entries=256, ways=4)      # 16 KB virtual cache
ACCEL_TLB = TLBConfig(entries=128, ways=4)  # baseline accel-side TLB
MEM_TLB = TLBConfig(entries=128, ways=4)
CONFIGS = (  # (label, partitions, page_shift, design)
    ("conv-4K", 1, 12, "conventional"),
    ("conv-2M", 1, 21, "conventional"),
    ("sparta8-4K", 8, 12, "sparta"),
    ("sparta8-2M", 8, 21, "sparta"),
    ("sparta32-4K", 32, 12, "sparta"),
    ("sparta32-2M", 32, 21, "sparta"),
    ("sparta128-2M", 128, 21, "sparta"),
    ("dipta", 1, 12, "dipta"),
    ("ideal", 1, 12, "ideal"),
)


@with_runlog("fig10")
def run(quick: bool = False, kernel_mode: str = "auto",
        resume: bool = False, chunk_accesses=None, sched=None):
    n_ops = 8_000 if quick else 25_000
    lat = SystemLatencies(n_sockets=8)
    rc = run_config("fig10", resume=resume, chunk_accesses=chunk_accesses)
    metas = {}
    speedups = {c[0]: [] for c in CONFIGS}
    overhead_reduction = []
    overhead_reduction_2m = []
    rows = []
    for w in W4:
        tr = trace(w, n_ops=n_ops)
        ipa = tr.instr_per_access
        # All nine designs (4K/2M x partition counts x DIPTA/ideal) share one
        # batched pass over the trace.
        evs, metas[f"system-{w}"] = run_sweep_system(tr.lines, [
            SystemSimConfig(
                cache=CACHE,
                accel_tlb=ACCEL_TLB if design == "conventional" else None,
                mem_tlb=MEM_TLB, num_partitions=parts, page_shift=shift,
                accel_probe_on_miss_only=True,
            )
            for _, parts, shift, design in CONFIGS
        ], kernel_mode=kernel_mode, run=rc, name=f"system-{w}", sched=sched)
        perfs = {}
        for i_c, (label, parts, shift, design) in enumerate(CONFIGS):
            perfs[label] = cpi.evaluate_design(
                design, evs[i_c], lat, instr_per_access=ipa, workload=w,
            )
        base = perfs["conv-4K"]
        row = [w]
        for label, *_ in CONFIGS:
            s = perfs[label].speedup_over(base)
            speedups[label].append(float(s))
            row.append(float(s))
        rows.append(row)
        overhead_reduction.append(
            base.access.translation_overhead
            / max(perfs["sparta128-2M"].access.translation_overhead, 1e-9)
        )
        overhead_reduction_2m.append(
            perfs["conv-2M"].access.translation_overhead
            / max(perfs["sparta128-2M"].access.translation_overhead, 1e-9)
        )

    mean = {k: float(np.mean(v)) for k, v in speedups.items()}
    frac_ideal = mean["sparta32-4K"] / mean["ideal"]
    c6a = Claim("C6a", "conventional 2MB mean speedup (paper: ~1.14x)",
                mean["conv-2M"], (1.0, 1.45), "x")
    c6b = Claim("C6b", "SPARTA-32 4K mean speedup (paper: ~1.57x)",
                mean["sparta32-4K"], (1.3, 1.9), "x")
    c6c = Claim("C6c", "SPARTA-32 4K fraction of ideal (paper: 93.7%)",
                frac_ideal, (0.85, 1.0), "")
    c6d = Claim("C6d", "translation overhead reduction, mean (paper: 31.5x)",
                float(np.mean(overhead_reduction)), (10.0, 80.0), "x")
    c6e = Claim("C6e", "translation overhead reduction, max (paper: up to 47x)",
                float(np.max(overhead_reduction)), (15.0, 200.0), "x")
    c6f = Claim("C6f", "overhead reduction over huge pages, mean (paper: 19x)",
                float(np.mean(overhead_reduction_2m)), (4.0, 60.0), "x")
    c8 = Claim("C8", "SPARTA-32 4K beats idealized DIPTA (workloads won)",
               float(sum(1 for a, b in zip(speedups["sparta32-4K"], speedups["dipta"]) if a >= b)),
               (3, 4), "/4")

    print_csv("Fig10 speedup over conventional-4K",
              ["workload"] + [c[0] for c in CONFIGS], rows)
    for c in (c6a, c6b, c6c, c6d, c6e, c6f, c8):
        print(c)
    save_fig("fig10", {"configs": [c[0] for c in CONFIGS], "rows": rows,
                       "mean": mean,
                       "overhead_reduction": list(map(float, overhead_reduction)),
                       "claims": [x.row() for x in (c6a, c6b, c6c, c6d, c6e, c6f, c8)],
                       "_crash_safety": crash_safety(metas),
                       "_telemetry": telemetry_stamp(metas)})
    return [c6a, c6b, c6c, c6d, c6e, c6f, c8]
