"""Fig 11 (beyond-paper): translation-induced tail latency under contention.

The paper's figures stop at averages; this benchmark uses the
cycle-approximate timeline engine (:mod:`repro.core.timeline`) to put 1-16
accelerators on the shared memory-side structures and measure the p50/p95/p99
of the *translation-induced* per-access latency (queue waits included) for
conventional vs SPARTA-32, with bounded MSHRs, one service port per
partition TLB and banked DRAM (EXPERIMENTS.md logs the queueing assumptions).

Batched execution: each workload's reference stream is the interleave of
``A_MAX`` thread traces, generated ONCE; every accelerator count replays the
*same* stream with a different round-robin issuer assignment, so one
``sweep_system`` call per workload feeds every cell in its accel loop (no
per-cell event re-derivation) and the full (workload x accel-count x design)
matrix — 40 cells at defaults — runs as ONE ``sweep_timeline`` pass.  Paying
the scan overhead once is what lets the default trace cap sit at 150k
accesses (2.5x the looped engine's 60k).

``kernel_mode`` is passed through unmodified; sweep-only modes such as
``"stackdist"`` raise a ValueError naming the valid timeline backends
instead of being silently coerced.

Claims (C9): at 16 accelerators SPARTA's p99 translation-induced latency is
below conventional's for every workload (the serialized page walk queues on
the same DRAM banks as the data stream, while SPARTA's probes spread over
P partition ports and its PTE walks stay local).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (Claim, W4, crash_safety, print_csv, run_config,
                               save_fig, telemetry_stamp, with_runlog)
from repro.core import timeline, traces
from repro.core.scheduler import run_sweep_system, run_sweep_timeline
from repro.core.sparta import SystemLatencies, TLBConfig
from repro.core.tlbsim import SystemSimConfig

CACHE = TLBConfig(entries=256, ways=4)      # 16 KB virtual cache
ACCEL_TLB = TLBConfig(entries=128, ways=4)  # conventional accel-side TLB
MEM_TLB = TLBConfig(entries=128, ways=4)    # per-partition memory-side TLB
PARTITIONS = 32
QUEUES = timeline.TimelineConfig(mshrs=8, tlb_ports=1, dram_banks=16)


@with_runlog("fig11")
def run(quick: bool = False, kernel_mode: str = "auto",
        resume: bool = False, chunk_accesses=None, sched=None):
    accels = (1, 4, 16) if quick else (1, 2, 4, 8, 16)
    n_ops = 1_000 if quick else 8_000
    # The crash-safe chunked engines stream the trace with a bounded
    # per-chunk working set, so the full-mode cap is no longer pinned to the
    # monolithic pass's 150k ceiling.
    cap = 24_000 if quick else 400_000
    lat = SystemLatencies(n_sockets=8)
    a_max = accels[-1]
    rc = run_config("fig11", resume=resume, chunk_accesses=chunk_accesses)
    metas = {}

    # One trace + one system sweep per workload, shared by the whole accel
    # loop; one timeline sweep pass for the whole figure.  Every sweep runs
    # through the crash-safe orchestrator: chunked, checkpointed, resumable.
    specs, cells = [], []
    for w in W4:
        streams = traces.thread_traces(w, a_max, n_ops=n_ops, seed=7)
        inter = traces.interleave(streams)[:cap]
        evs, metas[f"system-{w}"] = run_sweep_system(inter, [
            SystemSimConfig(cache=CACHE, accel_tlb=ACCEL_TLB,
                            mem_tlb=MEM_TLB, num_partitions=1, page_shift=12),
            SystemSimConfig(cache=CACHE, accel_tlb=None,
                            mem_tlb=MEM_TLB, num_partitions=PARTITIONS,
                            page_shift=12),
        ], kernel_mode=kernel_mode, run=rc, name=f"system-{w}", sched=sched)
        for A in accels:
            ids = timeline.round_robin_accel_ids(inter.shape[0], A)
            specs.append(timeline.TimelineSpec(
                inter, evs[0], "conventional", cfg=QUEUES,
                num_accelerators=A, accel_ids=ids))
            specs.append(timeline.TimelineSpec(
                inter, evs[1], "sparta", cfg=QUEUES,
                num_partitions=PARTITIONS, num_accelerators=A, accel_ids=ids))
            cells.append((w, A))
    results, metas["timeline"] = run_sweep_timeline(
        specs, lat, kernel_mode=kernel_mode, run=rc, name="timeline",
        sched=sched)

    rows = []
    p99 = {}       # (workload, A) -> (conventional, sparta)
    for i, (w, A) in enumerate(cells):
        conv, spa = results[2 * i], results[2 * i + 1]
        p99[(w, A)] = (conv.overhead_percentile(99), spa.overhead_percentile(99))
        rows.append([
            w, A,
            conv.overhead_percentile(50), spa.overhead_percentile(50),
            conv.overhead_percentile(99), spa.overhead_percentile(99),
            conv.mean_latency, spa.mean_latency,
            conv.throughput, spa.throughput,
        ])

    wins = sum(1 for w in W4 if p99[(w, a_max)][1] < p99[(w, a_max)][0])
    c9a = Claim("C9a", f"SPARTA p99 translation latency < conventional at {a_max} accels (workloads won)",
                float(wins), (4, 4), "/4")
    red = [p99[(w, a_max)][0] / max(p99[(w, a_max)][1], 1e-9) for w in W4]
    c9b = Claim("C9b", f"p99 translation-tail reduction conv/SPARTA at {a_max} accels (mean)",
                float(np.mean(red)), (1.5, 100.0), "x")

    print_csv(
        "Fig11 translation-induced latency tails vs accelerators",
        ["workload", "accels", "conv_p50", "sparta_p50", "conv_p99",
         "sparta_p99", "conv_mean_lat", "sparta_mean_lat",
         "conv_throughput", "sparta_throughput"],
        rows)
    print(c9a); print(c9b)
    save_fig("fig11", {
        "accels": list(accels), "partitions": PARTITIONS,
        "queues": {"mshrs": QUEUES.mshrs, "tlb_ports": QUEUES.tlb_ports,
                   "dram_banks": QUEUES.dram_banks,
                   "issue_interval": QUEUES.issue_interval},
        "rows": rows,
        "claims": [c9a.row(), c9b.row()],
        "_crash_safety": crash_safety(metas),
        "_telemetry": telemetry_stamp(metas),
    })
    return [c9a, c9b]


def main(argv=None) -> int:
    """Standalone entry point with resume + scheduler support (the CI
    fault-injection smokes SIGTERM this mid-sweep and rerun it with
    ``--resume``, or SIGKILL one of its ``--workers`` mid-shard)."""
    import argparse
    import sys

    from benchmarks import common
    from repro.core.orchestrator import Preempted
    from repro.core.scheduler import EX_DEGRADED
    from repro.runtime import telemetry

    telemetry.setup_logging()
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--kernel-mode", default="auto")
    ap.add_argument("--resume", action="store_true",
                    help="re-enter from the last committed chunk checkpoint")
    ap.add_argument("--chunk-accesses", type=int, default=None,
                    help="checkpoint-commit granularity (trace accesses)")
    ap.add_argument("--workers", type=int, default=1,
                    help="parallel sweep workers (sharded scheduler)")
    ap.add_argument("--shards", type=int, default=0,
                    help="shards per engine call (0 = auto, 2x workers)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-shard straggler deadline (seconds)")
    ap.add_argument("--executor", default="auto",
                    choices=("auto", "serial", "thread", "process"))
    args = ap.parse_args(argv)
    sched = common.sched_config(workers=args.workers, shards=args.shards,
                                deadline=args.deadline, executor=args.executor)
    try:
        claims = run(quick=args.quick, kernel_mode=args.kernel_mode,
                     resume=args.resume, chunk_accesses=args.chunk_accesses,
                     sched=sched)
    except Preempted as p:
        print(f"fig11: {p}", file=sys.stderr)
        return 75   # EX_TEMPFAIL: rerun with --resume
    if common.degraded_runs():
        print(f"fig11: degraded — quarantined shards "
              f"(see _crash_safety in the figure JSON)", file=sys.stderr)
        return EX_DEGRADED
    return 0 if all(c.ok for c in claims) else 1


if __name__ == "__main__":
    raise SystemExit(main())
