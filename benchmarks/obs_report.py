"""Render per-run telemetry JSONL logs (``benchmarks/_cache/runlogs/``).

``python -m benchmarks.obs_report LOG [LOG...]`` prints, per log: the run
header (name, device, clean/errored end), the per-phase wall-clock breakdown
(span name -> count / total seconds, sorted by where the time went), the
achieved per-(engine, backend) throughput from the orchestrator's ``chunk``
spans, a predicted-vs-achieved backend-dispatch table (each ``dispatch``
event's calibrated per-candidate rate predictions against what the run's
chunk spans actually achieved), a throughput timeline (chunk-by-chunk
accesses/s against the run's monotonic clock), and the structured-event
table (retries, halves, downgrades, resumes, preemptions, checkpoint
writes).

Sharded scheduler runs write one log per *worker process*
(``<run>-wN-<pid>.jsonl``) beside the parent's: a positional argument may be
a **comma-joined group** (``fig11.jsonl,fig11-w0-123.jsonl,...``) and the
group is merged into one record stream ordered by ``t_mono`` before
rendering — the interleaved cross-process view of a run.  ``--merge``
instead merges *all* positional logs into a single set.  A merged run with
scheduler activity additionally prints the shard table (per-shard attempts,
workers, wall time) and the scheduler event sequence (lease acquisitions
and expiries, re-dispatches, duplicates, quarantines).

``--diff A B`` compares two logs — or two comma-joined merged groups —
phase-by-phase and engine-by-engine: the before/after view for a perf
change, a backend downgrade, or a 1-worker vs N-worker run.

``--fail-on-event NAMES`` (comma-separated) exits 1 if any named event
occurs in any log or merged group: CI runs it with ``--fail-on-event
downgrade`` so a silent backend downgrade on a runner that should handle
the load turns into a red build instead of a slow green one (and the
fault-injection smoke asserts ``lease_expire``/``redispatch`` *are*
present the same way, via :func:`event_counts`).

Deliberately stdlib-only (reads what :mod:`repro.runtime.telemetry` wrote;
never imports jax) so it runs anywhere the logs land, CI artifact viewers
included.  Torn final lines — a crashed or preempted writer — are
tolerated: every complete record still renders.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, List, Tuple


def load_log(path: pathlib.Path) -> List[dict]:
    """Parse one JSONL run log, skipping a torn (incomplete) final line."""
    recs: List[dict] = []
    lines = path.read_text(encoding="utf-8").splitlines()
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            recs.append(json.loads(line))
        except json.JSONDecodeError:
            if i == len(lines) - 1:
                break  # torn tail from a crashed writer — expected
            raise SystemExit(
                f"{path}:{i + 1}: corrupt record mid-log (only the final "
                f"line may be torn)")
    return recs


def merge_logs(rec_sets: List[List[dict]]) -> List[dict]:
    """Merge several run logs into one record stream ordered by ``t_mono``.

    Worker processes share the parent's monotonic clock domain (same host,
    ``time.perf_counter``), so a global sort reconstructs the interleaved
    timeline.  Records without ``t_mono`` sort first, keeping their original
    relative order (stable sort).
    """
    merged = [r for recs in rec_sets for r in recs]
    merged.sort(key=lambda r: r.get("t_mono", float("-inf")))
    return merged


def phase_breakdown(recs: List[dict]) -> Dict[str, dict]:
    """span name -> {count, total_s}, sorted by descending total."""
    agg: Dict[str, dict] = {}
    for r in recs:
        if r.get("kind") != "span":
            continue
        st = agg.setdefault(r["name"], {"count": 0, "total_s": 0.0})
        st["count"] += 1
        st["total_s"] += float(r.get("dur_s", 0.0))
    return dict(sorted(agg.items(), key=lambda kv: -kv[1]["total_s"]))


def engine_throughput(recs: List[dict]) -> Dict[Tuple[str, str], dict]:
    """(engine, mode) -> aggregate chunk throughput from ``chunk`` spans."""
    agg: Dict[Tuple[str, str], dict] = {}
    for r in recs:
        if r.get("kind") != "span" or r.get("name") != "chunk":
            continue
        a = r.get("attrs", {})
        key = (str(a.get("engine", "?")), str(a.get("mode", "?")))
        st = agg.setdefault(key, {"chunks": 0, "accesses": 0, "elapsed_s": 0.0})
        st["chunks"] += 1
        st["accesses"] += int(a.get("accesses", 0))
        st["elapsed_s"] += float(r.get("dur_s", 0.0))
    for st in agg.values():
        st["accesses_per_s"] = (
            st["accesses"] / st["elapsed_s"] if st["elapsed_s"] > 0 else None)
    return agg


def throughput_timeline(recs: List[dict]) -> List[dict]:
    """chunk-by-chunk rows, t_rel measured from the run_start record."""
    t0 = next((r["t_mono"] for r in recs if r.get("kind") == "run_start"), None)
    rows = []
    for r in recs:
        if r.get("kind") != "span" or r.get("name") != "chunk":
            continue
        a = r.get("attrs", {})
        rows.append({
            "t_rel_s": (round(r["t_mono"] - t0, 3)
                        if t0 is not None and "t_mono" in r else None),
            "engine": a.get("engine"), "name": a.get("name"),
            "mode": a.get("mode"), "lo": a.get("lo"), "hi": a.get("hi"),
            "accesses_per_s": a.get("accesses_per_s"),
        })
    return rows


def shard_table(recs: List[dict]) -> Dict[Tuple[str, int], dict]:
    """(engine-call name, shard) -> attempts / workers / total busy seconds,
    from the scheduler's ``shard`` spans (one per attempt, any worker)."""
    agg: Dict[Tuple[str, int], dict] = {}
    for r in recs:
        if r.get("kind") != "span" or r.get("name") != "shard":
            continue
        a = r.get("attrs", {})
        key = (str(a.get("name", "?")), int(a.get("shard", -1)))
        st = agg.setdefault(key, {"attempts": 0, "workers": set(),
                                  "total_s": 0.0})
        st["attempts"] += 1
        st["workers"].add(a.get("worker"))
        st["total_s"] += float(r.get("dur_s", 0.0))
    return dict(sorted(agg.items()))


def scheduler_events(recs: List[dict]) -> List[dict]:
    """The scheduler's own event records (dispatch, lease_expire, redispatch,
    straggler duplicates, quarantine, worker death/respawn), in stream
    order."""
    return [r for r in recs
            if r.get("kind") == "event"
            and r.get("attrs", {}).get("kind") == "scheduler"]


def dispatch_table(recs: List[dict]) -> List[dict]:
    """Predicted-vs-achieved backend dispatch rows: one per (engine call,
    candidate mode), pairing each ``dispatch`` event's calibrated rate
    predictions with the rates the run actually achieved (from its ``chunk``
    spans, simulated accesses per second)."""
    achieved: Dict[Tuple[str, str, str], dict] = {}
    for r in recs:
        if r.get("kind") != "span" or r.get("name") != "chunk":
            continue
        a = r.get("attrs", {})
        key = (str(a.get("engine", "?")), str(a.get("name", "?")),
               str(a.get("mode", "?")))
        st = achieved.setdefault(key, {"sim_accesses": 0, "elapsed_s": 0.0})
        st["sim_accesses"] += (int(a.get("accesses", 0) or 0)
                               * int(a.get("configs", 1) or 1))
        st["elapsed_s"] += float(r.get("dur_s", 0.0))
    rows = []
    for r in recs:
        if r.get("kind") != "event" or r.get("name") != "dispatch":
            continue
        a = r.get("attrs", {})
        eng, name, chosen = a.get("engine"), a.get("name"), a.get("mode")
        for mode, rate in (a.get("candidates") or {}).items():
            st = achieved.get((str(eng), str(name), str(mode)))
            ach = (st["sim_accesses"] / st["elapsed_s"]
                   if st and st["elapsed_s"] > 0 else None)
            rows.append({
                "engine": eng, "name": name, "mode": mode,
                "chosen": mode == chosen, "predicted_rate": rate,
                "achieved_rate": ach, "calibration": a.get("calibration"),
            })
    return rows


def event_counts(recs: List[dict]) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for r in recs:
        if r.get("kind") == "event":
            counts[r["name"]] = counts.get(r["name"], 0) + 1
    return dict(sorted(counts.items()))


def _fmt_rate(x) -> str:
    if x is None:
        return "-"
    return f"{x / 1e6:.2f}M/s" if x >= 1e6 else f"{x / 1e3:.1f}k/s"


def render(path: pathlib.Path, recs: List[dict]) -> None:
    start = next((r for r in recs if r.get("kind") == "run_start"), None)
    end = next((r for r in recs if r.get("kind") == "run_end"), None)
    run = start.get("run") if start else "?"
    dev = (start or {}).get("meta", {}).get("device", {})
    dur = (end["t_mono"] - start["t_mono"]
           if start and end and "t_mono" in start and "t_mono" in end else None)
    print(f"\n# run {run!r} ({path})")
    status = ("no run_end (crashed/torn)" if end is None
              else f"error: {end['error']}" if "error" in end else "clean")
    print(f"  records={len(recs)}  wall={dur:.2f}s" if dur is not None
          else f"  records={len(recs)}  wall=?", end="")
    print(f"  end={status}"
          + (f"  device={dev.get('platform')}/{dev.get('device_kind')}"
             if dev else ""))

    phases = phase_breakdown(recs)
    if phases:
        print("  ## phase breakdown (span name, count, total seconds)")
        for name, st in phases.items():
            print(f"    {name:<16} x{st['count']:<5} {st['total_s']:9.3f}s")

    tput = engine_throughput(recs)
    if tput:
        print("  ## engine throughput (from chunk spans)")
        for (eng, mode), st in sorted(tput.items()):
            print(f"    {eng:<16} {mode:<18} chunks={st['chunks']:<4} "
                  f"accesses={st['accesses']:<9} "
                  f"rate={_fmt_rate(st['accesses_per_s'])}")

    shards = shard_table(recs)
    if shards:
        print("  ## shards (scheduler attempts per shard)")
        for (call, idx), st in shards.items():
            workers = ",".join(str(w) for w in sorted(
                st["workers"], key=lambda x: (x is None, x)))
            print(f"    {call:<24} shard={idx:<3} attempts={st['attempts']:<2} "
                  f"workers=[{workers}] busy={st['total_s']:.3f}s")
    sev = scheduler_events(recs)
    if sev:
        print(f"  ## scheduler events ({len(sev)})")
        t0s = next((r["t_mono"] for r in recs if r.get("kind") == "run_start"),
                   None)
        for r in sev:
            a = r.get("attrs", {})
            t = (f"{r['t_mono'] - t0s:8.2f}s"
                 if t0s is not None and "t_mono" in r else "       ?")
            detail = " ".join(
                f"{k}={a[k]}" for k in ("name", "shard", "attempt", "worker",
                                        "duplicate", "owner")
                if k in a and a[k] is not None)
            print(f"    {t}  {r['name']:<20} {detail}")

    disp = dispatch_table(recs)
    if disp:
        print("  ## dispatch (predicted vs achieved, sim accesses/s)")
        for row in disp:
            mark = "*" if row["chosen"] else " "
            print(f"   {mark} {str(row['name']):<16} {str(row['mode']):<18} "
                  f"predicted={_fmt_rate(row['predicted_rate'])} "
                  f"achieved={_fmt_rate(row['achieved_rate'])}  "
                  f"[{row['calibration']}]")

    timeline = throughput_timeline(recs)
    if timeline:
        print(f"  ## throughput timeline ({len(timeline)} chunks)")
        for row in timeline:
            t = f"{row['t_rel_s']:8.2f}s" if row["t_rel_s"] is not None else "       ?"
            print(f"    {t}  {str(row['name']):<16} {str(row['mode']):<18} "
                  f"[{row['lo']}, {row['hi']})  {_fmt_rate(row['accesses_per_s'])}")

    events = event_counts(recs)
    if events:
        print("  ## events")
        for name, n in events.items():
            print(f"    {name:<20} x{n}")


def diff(a_path: pathlib.Path, a: List[dict],
         b_path: pathlib.Path, b: List[dict]) -> None:
    print(f"\n# diff {a_path} -> {b_path}")
    pa, pb = phase_breakdown(a), phase_breakdown(b)
    print("  ## phase totals (seconds, A -> B)")
    for name in sorted(set(pa) | set(pb)):
        ta = pa.get(name, {}).get("total_s", 0.0)
        tb = pb.get(name, {}).get("total_s", 0.0)
        delta = f"{(tb - ta) / ta:+.0%}" if ta > 0 else "new" if tb else "-"
        print(f"    {name:<16} {ta:9.3f}s -> {tb:9.3f}s  ({delta})")
    ea, eb = engine_throughput(a), engine_throughput(b)
    if ea or eb:
        print("  ## engine throughput (accesses/s, A -> B)")
        for key in sorted(set(ea) | set(eb)):
            ra = (ea.get(key) or {}).get("accesses_per_s")
            rb = (eb.get(key) or {}).get("accesses_per_s")
            delta = (f"{(rb - ra) / ra:+.0%}" if ra and rb else "-")
            print(f"    {key[0]:<16} {key[1]:<18} "
                  f"{_fmt_rate(ra)} -> {_fmt_rate(rb)}  ({delta})")
    ca, cb = event_counts(a), event_counts(b)
    if ca or cb:
        print("  ## event counts (A -> B)")
        for name in sorted(set(ca) | set(cb)):
            print(f"    {name:<20} {ca.get(name, 0)} -> {cb.get(name, 0)}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("logs", nargs="+",
                    help="run-log JSONL files (benchmarks/_cache/runlogs/); "
                         "a comma-joined argument is one merged group "
                         "(parent + worker logs of a sharded run)")
    ap.add_argument("--diff", action="store_true",
                    help="compare exactly two logs (or merged groups) "
                         "phase-by-phase")
    ap.add_argument("--merge", action="store_true",
                    help="merge ALL given logs into one t_mono-ordered set")
    ap.add_argument("--fail-on-event", default=None, metavar="NAMES",
                    help="comma-separated event names; exit 1 if any occurs "
                         "(CI: --fail-on-event downgrade)")
    args = ap.parse_args(argv)

    # Each positional arg is a group: one file, or comma-joined files merged
    # by t_mono into a single record stream.
    loaded = []
    for spec in args.logs:
        paths = [pathlib.Path(s) for s in spec.split(",") if s]
        recs = merge_logs([load_log(p) for p in paths])
        label = paths[0] if len(paths) == 1 else pathlib.Path(
            f"{paths[0]}(+{len(paths) - 1})")
        loaded.append((label, recs))
    if args.merge and len(loaded) > 1:
        label = pathlib.Path(f"{loaded[0][0]}(+{len(loaded) - 1})")
        loaded = [(label, merge_logs([recs for _, recs in loaded]))]

    if args.diff:
        if len(loaded) != 2:
            ap.error("--diff needs exactly two logs or merged groups")
        diff(*loaded[0], *loaded[1])
    else:
        for p, recs in loaded:
            render(p, recs)

    if args.fail_on_event:
        banned = {s.strip() for s in args.fail_on_event.split(",") if s.strip()}
        offenders = [
            f"{p}: {name} x{n}"
            for p, recs in loaded
            for name, n in event_counts(recs).items() if name in banned
        ]
        if offenders:
            print("\nbanned event(s) present:", file=sys.stderr)
            for line in offenders:
                print(f"  {line}", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
