"""Benchmark driver: one module per paper figure/table + kernels + roofline.

``python -m benchmarks.run [--quick] [--only figN,...] [--kernel-mode MODE]``
Prints per-figure CSVs, the checked claims, and the roofline summary table
(if the dry-run cache exists).  Machine output (CSVs, claim lines) goes to
stdout; narration (per-figure timings, fallback notices) goes through the
``repro`` Python logger on stderr — ``-v`` raises it to DEBUG, ``--quiet``
drops it to WARNING.  ``--profile DIR`` additionally captures a
``jax.profiler`` trace of the whole run (one ``StepTraceAnnotation`` per
figure) for TensorBoard/Perfetto.  ``--kernel-mode`` selects the sweep-engine
backend (auto/reference/pallas/pallas_interpret/stackdist) for the figures
that run trace sweeps (fig4/5/8/9/10/11); ``stackdist`` is the exact
sort-based stack-distance engine, which ``auto`` already prefers for the
pure-LRU TLB sweeps (fig4/fig5/fig8) — see EXPERIMENTS.md.  fig9/fig10 run
the joint 3-structure system sweep (``repro.core.sweep.sweep_system``,
batched scan or the ``repro.kernels.system_sim`` Pallas kernel) and fig11
additionally the batched cycle-approximate timeline engine
(``repro.core.timeline.sweep_timeline``); both engines reject sweep-only
modes such as ``stackdist`` with a ValueError naming their valid backends
(no silent coercion) — run those figures with ``auto`` or ``--only`` the
pure-TLB sweep figures.  fig5 is a hybrid: its miss-ratio grid threads the
mode through (``stackdist`` applies), and its system-sweep/timeline half
falls back to ``auto`` for sweep-only modes with a warning logged through
the ``repro.bench.fig5`` logger on stderr (never stdout — piped CSV output
stays machine-clean).  ``auto`` itself resolves through the calibrated
dispatch layer (``repro.core.dispatch``; tables under
``_cache/calibration/``, fed by the kernel benches and every orchestrated
run) — ``--explain-dispatch`` prints the decision tables without running
any sweep."""
from __future__ import annotations

import argparse
import contextlib
import inspect
import logging
import sys
import time

from repro.core.orchestrator import Preempted
from repro.kernels.common import SWEEP_MODES
from repro.runtime import telemetry

_LOG = logging.getLogger("repro.bench.run")


FIGS = ("fig2", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10",
        "fig11", "kernels")


def _explain_dispatch() -> None:
    """Print the dispatch decision tables for the three engines' canonical
    quick shapes — candidates, calibrated rates, predicted runtimes, the
    chosen mode and why — without running any sweep.  An empty calibration
    table is bootstrapped from whatever this checkout already measured
    (BENCH_sweep.json rows + run-log chunk spans for this device kind)."""
    from benchmarks import common
    from benchmarks.kernel_bench import BENCH_SWEEP_PATH
    from repro.core import dispatch
    from repro.core.sparta import TLBConfig
    from repro.core.sweep import TLBSweepSpec
    from repro.core.tlbsim import SystemSimConfig

    store = dispatch.CalibrationStore.for_dir(common.CACHE / "calibration")
    if not store.exists():
        n = dispatch.ingest_bench_history(store, BENCH_SWEEP_PATH)
        n += dispatch.ingest_runlogs(
            store, sorted(common.RUNLOGS.glob("*.jsonl"))
            if common.RUNLOGS.exists() else [])
        _LOG.info("bootstrapped %s from %d recorded rate(s)", store.path, n)
    print(f"# dispatch decisions ({store.describe()}, "
          f"device={store.device_kind})")

    tlb_specs = [
        TLBSweepSpec(TLBConfig(entries=e, ways=4), num_partitions=p,
                     page_shift=12)
        for p in (1, 128) for e in (64, 128, 256, 512)]
    cache = TLBConfig(entries=256, ways=4)
    mem = TLBConfig(entries=128, ways=4)
    sys_cfgs = [
        SystemSimConfig(cache=cache, accel_tlb=None, mem_tlb=mem,
                        num_partitions=p, page_shift=12)
        for p in (1, 8, 32)]
    decisions = [
        ("fig4-style TLB sweep (8 specs x 120k accesses)",
         dispatch.decide_tlb("auto", tlb_specs, n_accesses=120_000,
                             store=store)),
        ("fig9-style system sweep (3 configs x 10k accesses)",
         dispatch.decide_system("auto", sys_cfgs, n_accesses=10_000,
                                store=store)),
        ("fig11-quick timeline matrix (batch=12 x 8k accesses)",
         dispatch.decide_timeline("auto", batch=12, n_accesses=8_000,
                                  store=store)),
        ("single timeline sim (batch=1 x 8k accesses)",
         dispatch.decide_timeline("auto", batch=1, n_accesses=8_000,
                                  store=store)),
    ]
    print("engine,candidate,rate_sim_acc_per_s,predicted_s,chosen")
    for label, d in decisions:
        print(f"# {label}")
        for m, c in d.candidates.items():
            rate = c.get("rate")
            pred = c.get("predicted_s")
            print(f"{d.engine},{m},"
                  f"{rate if rate is not None else 'n/a'},"
                  f"{pred if pred is not None else 'n/a'},"
                  f"{'<-- chosen' if m == d.mode else ''}")
        print(f"#   -> {d.mode} [{d.calibration}]: {d.reason}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small traces (CI mode)")
    ap.add_argument("--only", default=None, help="comma-separated figure list")
    ap.add_argument("--kernel-mode", default="auto", choices=SWEEP_MODES,
                    help="sweep-engine backend for the trace-sweep figures")
    ap.add_argument("--resume", action="store_true",
                    help="re-enter interrupted trace sweeps from their last "
                         "committed chunk checkpoint (fig5/8/9/10/11)")
    ap.add_argument("--chunk-accesses", type=int, default=None,
                    help="checkpoint-commit granularity for the crash-safe "
                         "chunked sweeps (trace accesses per chunk)")
    ap.add_argument("--workers", type=int, default=1,
                    help="parallel sweep workers for the sharded scheduler "
                         "(fig5/8/9/10/11); 1 = unsharded passthrough")
    ap.add_argument("--shards", type=int, default=0,
                    help="shards per scheduled engine call "
                         "(0 = auto, 2x workers)")
    ap.add_argument("--deadline", type=float, default=None, metavar="S",
                    help="per-shard straggler deadline in seconds (past it, "
                         "idle workers run a duplicate; first completion wins)")
    ap.add_argument("--executor", default="auto",
                    choices=("auto", "serial", "thread", "process"),
                    help="scheduler executor (auto = thread when --workers>1)")
    ap.add_argument("--gc", action="store_true",
                    help="garbage-collect expired checkpoint blobs and stale "
                         "leases under benchmarks/_cache/ckpt plus stale "
                         "dispatch calibration tables under "
                         "benchmarks/_cache/calibration, then exit "
                         "(in-progress runs — fresh leases — are kept)")
    ap.add_argument("--explain-dispatch", action="store_true",
                    help="print the backend-dispatch decision tables "
                         "(candidates, predicted rates, chosen mode, "
                         "calibration provenance) for the three engines' "
                         "canonical quick shapes, then exit without running "
                         "any sweep")
    ap.add_argument("--gc-age-s", type=float, default=7 * 86400.0, metavar="S",
                    help="age threshold for --gc (default: 7 days)")
    ap.add_argument("-v", action="count", default=0, dest="verbose",
                    help="DEBUG narration on stderr (repeatable)")
    ap.add_argument("--quiet", action="store_true",
                    help="narration at WARNING only (stdout CSVs unaffected)")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the run into DIR "
                         "(one StepTraceAnnotation per figure)")
    args = ap.parse_args(argv)
    telemetry.setup_logging(-1 if args.quiet else args.verbose)

    if args.gc:
        from benchmarks import common
        from repro.core.dispatch import gc_calibration
        from repro.core.scheduler import gc_checkpoints

        summary = gc_checkpoints(common.CACHE / "ckpt", age_s=args.gc_age_s)
        print(f"# gc {common.CACHE / 'ckpt'}")
        for k in ("deleted", "kept_in_progress", "kept_young", "skipped_foreign"):
            for p in summary[k]:
                print(f"{k},{p}")
        print(f"# {len(summary['deleted'])} deleted, "
              f"{len(summary['kept_in_progress'])} in-progress kept, "
              f"{len(summary['kept_young'])} young kept, "
              f"{len(summary['skipped_foreign'])} foreign skipped")
        cal = gc_calibration(common.CACHE / "calibration", age_s=args.gc_age_s)
        print(f"# gc {common.CACHE / 'calibration'}")
        for k in ("deleted", "kept_young", "skipped_foreign"):
            for p in cal[k]:
                print(f"{k},{p}")
        print(f"# {len(cal['deleted'])} calibration deleted, "
              f"{len(cal['kept_young'])} young kept, "
              f"{len(cal['skipped_foreign'])} foreign skipped")
        return

    if args.explain_dispatch:
        _explain_dispatch()
        return

    from benchmarks import (
        fig2_pagewalk, fig4_tlb_sensitivity, fig5_contention, fig6_pagefault,
        fig7_miss_penalty, fig8_multiprog, fig9_accel_tlb, fig10_performance,
        fig11_tail_latency, kernel_bench,
    )
    modules = {
        "fig2": fig2_pagewalk, "fig4": fig4_tlb_sensitivity,
        "fig5": fig5_contention, "fig6": fig6_pagefault,
        "fig7": fig7_miss_penalty, "fig8": fig8_multiprog,
        "fig9": fig9_accel_tlb, "fig10": fig10_performance,
        "fig11": fig11_tail_latency, "kernels": kernel_bench,
    }
    chosen = args.only.split(",") if args.only else list(modules)

    from benchmarks import common
    sched = common.sched_config(workers=args.workers, shards=args.shards,
                                deadline=args.deadline, executor=args.executor)

    profile_cm = contextlib.nullcontext()
    if args.profile:
        import jax
        profile_cm = jax.profiler.trace(args.profile)

    claims = []
    with profile_cm:
        for name in chosen:
            t0 = time.perf_counter()
            kwargs = {"quick": args.quick}
            params = inspect.signature(modules[name].run).parameters
            if "kernel_mode" in params:
                kwargs["kernel_mode"] = args.kernel_mode
            if "resume" in params:
                kwargs["resume"] = args.resume
            if "chunk_accesses" in params and args.chunk_accesses:
                kwargs["chunk_accesses"] = args.chunk_accesses
            if "sched" in params and sched is not None:
                kwargs["sched"] = sched
            step_cm = contextlib.nullcontext()
            if args.profile:
                import jax
                step_cm = jax.profiler.StepTraceAnnotation(name)
            try:
                with step_cm:
                    claims += modules[name].run(**kwargs)
            except Preempted as exc:
                _LOG.warning("%s preempted: %s", name, exc)
                sys.exit(75)   # EX_TEMPFAIL: rerun with --resume
            _LOG.info("%s: %.1fs", name, time.perf_counter() - t0)
    if args.profile:
        _LOG.info("jax profiler trace written under %s", args.profile)

    print("\n# Claim summary")
    n_ok = sum(c.ok for c in claims)
    for c in claims:
        print(str(c))
    print(f"\n{n_ok}/{len(claims)} claims in band")

    # Roofline table (from the dry-run cache, if present).
    try:
        from benchmarks import roofline
        rows = roofline.table("16x16")
        if rows:
            print("\n# Roofline (16x16, per-device seconds/step)")
            print("arch,shape,compute,memory,collective,dominant,roofline_frac")
            for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
                print(f"{r['arch']},{r['shape']},{r['t_compute_s']:.4g},"
                      f"{r['t_memory_s']:.4g},{r['t_collective_s']:.4g},"
                      f"{r['dominant']},{r['roofline_fraction']:.3f}")
    except Exception as e:  # dry-run cache may not exist yet
        _LOG.info("roofline table skipped: %s", e)

    # C2b is a documented out-of-band cell (EXPERIMENTS.md §Paper claims);
    # fail only if reproduction quality actually regresses.
    if claims and n_ok < len(claims) - 1:
        sys.exit(1)

    # Degraded completion: a scheduled sweep quarantined at least one shard
    # (its figure carries zero placeholder rows + a manifest in
    # _crash_safety).  Distinct from both success (0) and failure (1) so CI
    # and operators can tell "finished, but incomplete" apart.
    if common.degraded_runs():
        from repro.core.scheduler import EX_DEGRADED
        _LOG.error("degraded run(s) with quarantined shards: %s",
                   ", ".join(common.degraded_runs()))
        sys.exit(EX_DEGRADED)


if __name__ == "__main__":
    main()
