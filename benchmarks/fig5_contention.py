"""Fig 5: thread contention on shared memory-side TLBs.

Miss rate vs (threads x partitions) with 128-entry 4-way TLBs per partition.
Each interleaved thread trace is streamed ONCE for all partition counts via
the batched sweep engine (``sweep.sweep_tlb``; bit-identical to the
per-config ``tlbsim.miss_ratio`` oracle it replaced).
Claims (C3): contention on a single shared TLB grows with threads, but
partitioning makes it vanish; (16 partitions, 16 threads) beats
(1 partition, 1 thread) at equal aggregate entries/thread."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Claim, W4, print_csv, save_fig
from repro.core import traces
from repro.core.sparta import TLBConfig
from repro.core.sweep import TLBSweepSpec, sweep_tlb

THREADS = (1, 2, 4, 8, 16)
PARTS = (1, 4, 16, 64)
TLB = TLBConfig(entries=128, ways=4)


def run(quick: bool = False, kernel_mode: str = "auto"):
    n_ops = 4_000 if quick else 12_000
    specs = [TLBSweepSpec(TLB, num_partitions=p, page_shift=12) for p in PARTS]
    results = {}
    for w in W4:
        grid = np.empty((len(PARTS), len(THREADS)))
        for i_t, t in enumerate(THREADS):
            streams = traces.thread_traces(w, t, n_ops=n_ops, seed=7)
            inter = traces.interleave(streams)[:1_200_000]
            grid[:, i_t] = sweep_tlb(inter, specs, kernel_mode=kernel_mode).miss_ratios
        for i_p, p in enumerate(PARTS):
            results[f"{w}/P{p}"] = [float(x) for x in grid[i_p]]
    rows = [[w, p] + results[f"{w}/P{p}"] for w in W4 for p in PARTS]

    # C3a: contention on 1 partition (16 threads vs 1 thread miss increase).
    bumps = [results[f"{w}/P1"][-1] - results[f"{w}/P1"][0] for w in W4]
    c3a = Claim("C3a", "single shared TLB: miss ratio increases with 16 threads (mean bump)",
                float(np.mean(bumps)), (0.005, 1.0), "")
    # C3b: partitioning beats contention: (16 part, 16 thr) < (1 part, 1 thr).
    wins = sum(
        1 for w in W4
        if results[f"{w}/P16"][THREADS.index(16)] < results[f"{w}/P1"][0]
    )
    c3b = Claim("C3b", "(16 partitions, 16 threads) < (1 partition, 1 thread) miss ratio (workloads won)",
                float(wins), (3, 4), "/4")
    print_csv("Fig5 miss ratio vs threads", ["workload", "partitions"] + [str(t) for t in THREADS], rows)
    print(c3a); print(c3b)
    save_fig("fig5", {"threads": THREADS, "parts": PARTS, "results": results,
                      "claims": [c3a.row(), c3b.row()]})
    return [c3a, c3b]
