"""Fig 5: thread contention on shared memory-side TLBs.

Miss rate vs (threads x partitions) with 128-entry 4-way TLBs per partition.
Each interleaved thread trace is streamed ONCE for all partition counts via
the batched sweep engine (``sweep.sweep_tlb``; bit-identical to the
per-config ``tlbsim.miss_ratio`` oracle it replaced).

A second, beyond-paper **timeline half** asks what the contention costs in
*cycles*: at max threads, the p99 translation-induced latency of a SPARTA
memory side with P partitions (bounded TLB ports + banked DRAM, fig11's
queueing config).  Every (workload x partition-count) cell reuses a slice of
the same max-thread interleaved trace the miss-ratio grid already streams
("differ only by slicing"), one ``sweep_system`` per workload feeds all
partition counts, and all cells run as ONE batched ``sweep_timeline`` pass
(bit-identical per cell to the looped ``simulate_timeline`` oracle).

Claims (C3): contention on a single shared TLB grows with threads, but
partitioning makes it vanish; (16 partitions, 16 threads) beats
(1 partition, 1 thread) at equal aggregate entries/thread."""
from __future__ import annotations

import logging

import numpy as np

from benchmarks.common import (Claim, W4, crash_safety, print_csv, run_config,
                               save_fig, telemetry_stamp, with_runlog)
from repro.core import timeline, traces
from repro.core.scheduler import (run_sweep_system, run_sweep_timeline,
                                  run_sweep_tlb)
from repro.core.sparta import SystemLatencies, TLBConfig
from repro.core.sweep import TLBSweepSpec
from repro.core.tlbsim import SystemSimConfig

THREADS = (1, 2, 4, 8, 16)
PARTS = (1, 4, 16, 64)
TLB = TLBConfig(entries=128, ways=4)
CACHE = TLBConfig(entries=256, ways=4)  # virtual cache for the timeline half
QUEUES = timeline.TimelineConfig(mshrs=8, tlb_ports=1, dram_banks=16)

_LOG = logging.getLogger("repro.bench.fig5")


@with_runlog("fig5")
def run(quick: bool = False, kernel_mode: str = "auto",
        resume: bool = False, chunk_accesses=None, sched=None):
    n_ops = 4_000 if quick else 12_000
    tl_cap = 12_000 if quick else 40_000
    t_max = THREADS[-1]
    rc = run_config("fig5", resume=resume, chunk_accesses=chunk_accesses)
    metas = {}
    specs = [TLBSweepSpec(TLB, num_partitions=p, page_shift=12) for p in PARTS]
    results = {}
    inter_max = {}  # workload -> the t_max interleaved trace (timeline reuse)
    for w in W4:
        grid = np.empty((len(PARTS), len(THREADS)))
        for i_t, t in enumerate(THREADS):
            streams = traces.thread_traces(w, t, n_ops=n_ops, seed=7)
            inter = traces.interleave(streams)[:1_200_000]
            if t == t_max:
                inter_max[w] = inter
            batched, metas[f"tlb-{w}-t{t}"] = run_sweep_tlb(
                inter, specs, kernel_mode=kernel_mode, run=rc,
                name=f"tlb-{w}-t{t}", sched=sched)
            grid[:, i_t] = batched.miss_ratios
        for i_p, p in enumerate(PARTS):
            results[f"{w}/P{p}"] = [float(x) for x in grid[i_p]]
    rows = [[w, p] + results[f"{w}/P{p}"] for w in W4 for p in PARTS]

    # C3a: contention on 1 partition (16 threads vs 1 thread miss increase).
    bumps = [results[f"{w}/P1"][-1] - results[f"{w}/P1"][0] for w in W4]
    c3a = Claim("C3a", "single shared TLB: miss ratio increases with 16 threads (mean bump)",
                float(np.mean(bumps)), (0.005, 1.0), "")
    # C3b: partitioning beats contention: (16 part, 16 thr) < (1 part, 1 thr).
    wins = sum(
        1 for w in W4
        if results[f"{w}/P16"][THREADS.index(16)] < results[f"{w}/P1"][0]
    )
    c3b = Claim("C3b", "(16 partitions, 16 threads) < (1 partition, 1 thread) miss ratio (workloads won)",
                float(wins), (3, 4), "/4")

    # --- timeline half: queueing cost of contention at max threads ----------
    # The miss-ratio grid above is what sweep-only modes ("stackdist") are
    # for; the joint system sweep and the timeline engine have their own
    # backends (both reject "stackdist" with a ValueError), so fall back to
    # "auto" for them — loudly, not silently — rather than discarding the
    # whole figure.  (fig9/fig10/fig11, pure joint-sweep/timeline figures,
    # reject such modes instead.)
    tl_mode = kernel_mode
    if kernel_mode == "stackdist":
        tl_mode = "auto"
        _LOG.warning(
            "fig5 timeline half: kernel_mode=%r is sweep_tlb-only; running "
            "the system sweep + timeline half with 'auto'", kernel_mode)
    lat = SystemLatencies(n_sockets=8)
    tl_specs = []
    for w in W4:
        sl = inter_max[w][:tl_cap]  # slice of the already-streamed trace
        evs, metas[f"system-{w}"] = run_sweep_system(sl, [
            SystemSimConfig(cache=CACHE, accel_tlb=None, mem_tlb=TLB,
                            num_partitions=p, page_shift=12)
            for p in PARTS
        ], kernel_mode=tl_mode, run=rc, name=f"system-{w}", sched=sched)
        for i_p, p in enumerate(PARTS):
            tl_specs.append(timeline.TimelineSpec(
                sl, evs[i_p], "sparta", cfg=QUEUES, num_partitions=p,
                num_accelerators=t_max))
    tl_res, metas["timeline"] = run_sweep_timeline(
        tl_specs, lat, kernel_mode=tl_mode, run=rc, name="timeline",
        sched=sched)
    tl_p99 = {}
    tl_rows = []
    for i, w in enumerate(W4):
        per_w = tl_res[i * len(PARTS):(i + 1) * len(PARTS)]
        tl_p99[w] = [r.overhead_percentile(99) for r in per_w]
        tl_rows.append([w] + tl_p99[w])

    print_csv("Fig5 miss ratio vs threads", ["workload", "partitions"] + [str(t) for t in THREADS], rows)
    print_csv(
        f"Fig5 timeline half: p99 translation latency at {t_max} threads (SPARTA, queued)",
        ["workload"] + [f"P{p}" for p in PARTS], tl_rows)
    print(c3a); print(c3b)
    save_fig("fig5", {"threads": THREADS, "parts": PARTS, "results": results,
                      "timeline_p99": tl_p99, "timeline_cap": tl_cap,
                      "claims": [c3a.row(), c3b.row()],
                      "_crash_safety": crash_safety(metas),
                      "_telemetry": telemetry_stamp(metas)})
    return [c3a, c3b]
