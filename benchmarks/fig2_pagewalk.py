"""Fig 2: page-walk (L2 TLB miss) rate vs memory footprint.

A Broadwell-class 1.5K-entry L2 TLB is probed with each workload at
footprints 4..128 GB; misses-per-kilo-instruction rise sharply with
footprint (claim C1)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Claim, GIB, W4, print_csv, save_fig, trace
from repro.core import tlbsim
from repro.core.sparta import TLBConfig

FOOTPRINTS_GB = (1, 2, 4, 8, 16, 32, 64, 128)
TLB = TLBConfig(entries=1536, ways=4)  # Broadwell-class L2 TLB


def run(quick: bool = False):
    n_ops = 10_000 if quick else 30_000
    rows, curves = [], {}
    for w in W4:
        mpki = []
        for gb in FOOTPRINTS_GB:
            # Zipf-popular keys for the hash table (memcached-style): the
            # absolute hot-set size vs TLB reach is what Fig 2 sweeps.
            from repro.core import traces as traces_mod
            tr = traces_mod.generate(w, n_ops=n_ops, footprint_bytes=gb * GIB,
                                     zipf_keys=1.4 if w == "hash_table" else 0.0,
                                     max_accesses=1_400_000)
            res = tlbsim.simulate_tlb(tr.vpns(12), TLB)
            walks_per_access = res.miss_ratio
            mpki.append(1000.0 * walks_per_access / tr.instr_per_access)
        curves[w] = mpki
        rows.append([w] + mpki)

    growth = [curves[w][-1] / max(curves[w][0], 1e-9) for w in W4]
    # Synthetic traces are conservative vs the paper's Pin traces (uniform
    # deep levels saturate even small-footprint TLBs); the claim is the
    # qualitative monotone growth, checked as mean ratio + monotonicity.
    mono = float(np.mean([
        np.mean(np.diff(curves[w]) >= -1e-6) for w in W4
    ]))
    c1 = Claim(
        "C1", f"page-walk MPKI grows with footprint (128GB/1GB mean ratio; monotone frac={mono:.2f})",
        float(np.mean(growth)), (1.15, 1e6), "x",
    )
    print_csv("Fig2 page-walk MPKI vs footprint (GB)",
              ["workload"] + [str(g) for g in FOOTPRINTS_GB], rows)
    print(c1)
    save_fig("fig2", {"footprints_gb": FOOTPRINTS_GB, "curves": curves,
                      "claims": [c1.row()]})
    return [c1]
