"""Fig 4: TLB miss ratio vs TLB size — conventional vs SPARTA-4 / SPARTA-128,
4 KB and 2 MB pages, 128 GB working sets.

Claims (C2): memory-side TLBs need ~4x fewer entries than conventional
accelerator-side TLBs for the same miss ratio; SPARTA-128 + 2 MB with a
handful of entries beats conventional 2048-entry TLBs."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Claim, W4, print_csv, save_fig, trace
from repro.core.sparta import TLBConfig
from repro.core.sweep import TLBSweepSpec, sweep_tlb

SIZES = (4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048)
CONFIGS = (  # (label, partitions, page_shift)
    ("conv-4K", 1, 12),
    ("conv-2M", 1, 21),
    ("sparta4-4K", 4, 12),
    ("sparta4-2M", 4, 21),
    ("sparta128-4K", 128, 12),
    ("sparta128-2M", 128, 21),
)


def _match_size(sizes, curve, target_miss):
    """Smallest TLB size achieving miss <= target."""
    for s, m in zip(sizes, curve):
        if m <= target_miss:
            return s
    return None


def run(quick: bool = False, kernel_mode: str = "auto"):
    n_ops = 10_000 if quick else 40_000
    sizes = SIZES[:7] if quick else SIZES
    results = {}
    rows = []
    for w in W4:
        tr = trace(w, n_ops=n_ops)
        # Every (config, size) point rides one batched sweep.  Under the
        # default kernel_mode the stack-distance backend buckets these specs
        # by (sets, partitions, page_shift) and runs one data-parallel depth
        # pass per bucket — no per-access sequential scan at all.
        specs = [
            TLBSweepSpec(TLBConfig(entries=int(s), ways=4),
                         num_partitions=parts, page_shift=shift)
            for _, parts, shift in CONFIGS
            for s in sizes
        ]
        mr = sweep_tlb(tr.lines, specs, kernel_mode=kernel_mode).miss_ratios
        mr = mr.reshape(len(CONFIGS), len(sizes))
        for (label, _, _), curve in zip(CONFIGS, mr):
            results[f"{w}/{label}"] = list(map(float, curve))
            rows.append([w, label] + list(map(float, curve)))

    # C2a: entries ratio conventional/memory-side for equal miss (4K pages).
    ratios = []
    for w in W4:
        conv = results[f"{w}/conv-4K"]
        sp = results[f"{w}/sparta4-4K"]
        for s, m in zip(sizes, conv):
            match = _match_size(sizes, sp, m)
            if match and match < s:
                ratios.append(s / match)
    c2a = Claim("C2a", "conventional needs ~4x the entries of SPARTA memory-side TLBs (mean)",
                float(np.mean(ratios)) if ratios else 0.0, (2.0, 64.0), "x")

    # C2b: SPARTA-128 2M @ 4 entries vs conventional @ 2048 entries (4K & 2M).
    wins = 0
    for w in W4:
        best_conv = min(results[f"{w}/conv-4K"][-1], results[f"{w}/conv-2M"][-1])
        if results[f"{w}/sparta128-2M"][0] <= best_conv + 1e-9:
            wins += 1
    c2b = Claim("C2b", "SPARTA-128+2MB with 4 entries beats conventional 2048 entries (workloads won)",
                float(wins), (3, 4), "/4")

    print_csv("Fig4 miss ratio vs entries", ["workload", "config"] + [str(s) for s in sizes], rows)
    print(c2a); print(c2b)
    save_fig("fig4", {"sizes": sizes, "results": results,
                      "claims": [c2a.row(), c2b.row()]})
    return [c2a, c2b]
