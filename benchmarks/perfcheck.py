"""ReFrame-style perf-regression gate over the BENCH_sweep.json history.

``BENCH_sweep.json`` is the repo's append-only perf record: every
``benchmarks/kernel_bench.py`` run appends one row per engine bench (min-of-N
blocked wall times + device metadata, via ``repro.core.benchtime``).  This
module makes those rows *load-bearing*: ``benchmarks/references.json`` holds
one expected value per (bench, backend, mode, quick|full) key and metric,
with a tolerance band in the spirit of ReFrame's per-system references —
``{"ref": seconds, "tol": [lower, upper]}`` passes iff

    ref * (1 + lower)  <=  recorded  <=  ref * (1 + upper).

Gate semantics (``check_perf_history``, run by
``python -m benchmarks.kernel_bench --check`` in CI):

* a recorded metric outside its band **fails** — both regressions (upper
  bound) and too-good-to-be-true speedups (lower bound, usually a broken
  timer or a silently skipped workload);
* a row whose (bench, backend, mode, quick) key has **no reference**, or
  whose ``device_kind`` differs from the reference's, **warns and passes**
  — so the first rows recorded on a real TPU can land before anyone has
  baselined that device;
* **legacy rows** (no ``schema_version``) were recorded with the old
  non-blocking last-of-N timers and are skipped entirely — their numbers
  are not trustworthy enough to gate on (see ``legacy_history`` in
  BENCH_sweep.json);
* a missing metric field on a schema'd row fails (schema violation);
* a corrupt / unparseable history file fails loudly instead of being
  silently ignored.

Re-baselining is deliberate: ``python -m benchmarks.kernel_bench
--update-refs`` (or ``python -m benchmarks.perfcheck --update-refs``)
rewrites each reference value from the latest matching recorded row,
preserving any hand-edited tolerance.  See EXPERIMENTS.md
"Measurement methodology".
"""
from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List, Optional, Tuple

REFS_PATH = pathlib.Path(__file__).resolve().parent / "references.json"

# Band applied when --update-refs creates a new reference entry.  Wide by
# design: heterogeneous CI runners easily spread 2-3x on wall time, and the
# lower bound mostly guards against obviously-broken timers.  Tighten
# per-entry in references.json as variance data accumulates.
DEFAULT_TOLERANCE = (-0.95, 3.0)

# Absolute seconds added to the *upper* bound by --update-refs: a 40 ms
# quick-mode reference should not fail CI over 120 ms of runner jitter,
# while seconds-scale references are barely affected.  Explicit per metric
# in references.json (`abs_slack_s`), so it is visible and hand-editable.
DEFAULT_ABS_SLACK_S = 1.0

REFS_SCHEMA_VERSION = 2


class WarnPass(str):
    """A warn-and-pass message that is still a plain string (callers and
    tests treat warnings as strings) but carries the machine-readable
    ``key`` (the row's (bench, backend, mode, quick) identity) and
    ``reason`` (``"unreferenced"`` / ``"device_mismatch"``) that the
    summary dict aggregates — a warn-pass CI log line should be countable
    without regex-scraping prose."""

    __slots__ = ("key", "reason")

    def __new__(cls, key: str, reason: str, msg: str):
        self = super().__new__(cls, msg)
        self.key = key
        self.reason = reason
        return self


def row_key(row: dict) -> str:
    """(bench, backend, mode, quick|full) identity of a recorded row."""
    return "|".join((
        row.get("bench", "sweep"),
        row.get("backend", "?"),
        row.get("mode", "-"),
        "quick" if row.get("quick") else "full",
    ))


def metric_fields(row: dict) -> List[str]:
    """The gated wall-time fields of a row (``t_*_s``)."""
    return sorted(k for k in row if k.startswith("t_") and k.endswith("_s"))


def load_history(path: pathlib.Path) -> dict:
    """Parse BENCH_sweep.json, failing loudly on corruption."""
    try:
        doc = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        raise SystemExit(
            f"{path} is corrupt ({e}); refusing to gate on an unreadable "
            f"perf history — restore it from git before re-running") from e
    if not isinstance(doc, dict) or not isinstance(doc.get("history", []), list):
        raise SystemExit(
            f"{path} is not a {{'history': [...]}} document; restore it "
            f"from git before re-running")
    return doc


def load_references(path: pathlib.Path = REFS_PATH) -> dict:
    if not path.exists():
        return {"schema_version": REFS_SCHEMA_VERSION, "references": {}}
    try:
        doc = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        raise SystemExit(f"{path} is corrupt ({e}); fix or regenerate it "
                         f"with --update-refs") from e
    return doc


def check_rows(history: List[dict], refs_doc: dict,
               ) -> Tuple[List[str], List[str], int, int]:
    """Gate every schema'd row against its reference bands.

    Returns ``(failures, warnings, n_checked, n_legacy)``; the caller decides
    whether failures are fatal.
    """
    refs: Dict[str, dict] = refs_doc.get("references", {})
    failures: List[str] = []
    warnings: List[str] = []
    n_checked = n_legacy = 0
    for i, row in enumerate(history):
        if "schema_version" not in row:
            n_legacy += 1
            continue
        key = row_key(row)
        where = f"history[{i}] ({key}, written_at={row.get('written_at')!r})"
        entry = refs.get(key)
        if entry is None:
            warnings.append(WarnPass(
                key, "unreferenced",
                f"{where}: no reference for this (bench, backend, mode, "
                f"quick) key — passing; baseline it with --update-refs"))
            continue
        ref_kind = entry.get("device_kind")
        row_kind = row.get("device_kind")
        if ref_kind is not None and row_kind != ref_kind:
            warnings.append(WarnPass(
                key, "device_mismatch",
                f"{where}: recorded on device_kind={row_kind!r} but the "
                f"reference was baselined on {ref_kind!r} — passing; "
                f"--update-refs on that device to start gating it"))
            continue
        n_checked += 1
        for metric, spec in entry.get("metrics", {}).items():
            val = row.get(metric)
            if not isinstance(val, (int, float)):
                failures.append(
                    f"{where}: metric {metric!r} missing from the recorded "
                    f"row (schema violation)")
                continue
            ref = float(spec["ref"])
            lower, upper = spec.get("tol", DEFAULT_TOLERANCE)
            lo = ref * (1.0 + lower)
            hi = ref * (1.0 + upper) + spec.get("abs_slack_s", 0.0)
            if not (lo <= val <= hi):
                direction = "slower — perf regression" if val > hi else \
                    "faster — suspiciously good, check the timer/workload"
                failures.append(
                    f"{where}: {metric}={val:.4g}s outside "
                    f"[{lo:.4g}, {hi:.4g}] (ref {ref:.4g}s, tol "
                    f"[{lower:+.0%}, {upper:+.0%}]) — {direction}")
    return failures, warnings, n_checked, n_legacy


def update_references(history: List[dict],
                      refs_path: pathlib.Path = REFS_PATH) -> dict:
    """Re-baseline: latest schema'd row per key becomes the reference.

    Existing per-metric tolerances are preserved; values are overwritten.
    """
    doc = load_references(refs_path)
    refs: Dict[str, dict] = doc.setdefault("references", {})
    doc["schema_version"] = REFS_SCHEMA_VERSION
    latest: Dict[str, dict] = {}
    for row in history:
        if "schema_version" in row:
            latest[row_key(row)] = row  # later rows win
    for key, row in latest.items():
        old_metrics = refs.get(key, {}).get("metrics", {})
        refs[key] = {
            "device_kind": row.get("device_kind"),
            "baselined_at": row.get("written_at"),
            "metrics": {
                m: {"ref": row[m],
                    "tol": list(old_metrics.get(m, {}).get(
                        "tol", DEFAULT_TOLERANCE)),
                    "abs_slack_s": old_metrics.get(m, {}).get(
                        "abs_slack_s", DEFAULT_ABS_SLACK_S)}
                for m in metric_fields(row)
            },
        }
    refs_path.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
    print(f"  references.json: baselined {len(latest)} key(s) from "
          f"{len(history)} recorded row(s)")
    return doc


def summarize(failures: List[str], warnings: List[str],
              n_checked: int, n_legacy: int) -> dict:
    """Machine-readable gate outcome: warn-passes are counted by key and
    reason instead of living only in prose — the CI log carries this as one
    parseable ``perfcheck summary:`` JSON line."""
    reasons: Dict[str, int] = {}
    for w in warnings:
        r = getattr(w, "reason", "other")
        reasons[r] = reasons.get(r, 0) + 1
    return {
        "n_checked": n_checked,
        "n_legacy": n_legacy,
        "n_failures": len(failures),
        "warn_pass": {
            "count": len(warnings),
            "keys": sorted({w.key for w in warnings if hasattr(w, "key")}),
            "reasons": reasons,
        },
    }


def check_perf_history(history_path: pathlib.Path,
                       refs_path: pathlib.Path = REFS_PATH,
                       history: Optional[List[dict]] = None) -> dict:
    """CI entry point: SystemExit on any out-of-band metric; returns the
    machine-readable :func:`summarize` dict otherwise (``{}`` with no
    history file)."""
    if history is None:
        if not history_path.exists():
            return {}
        history = load_history(history_path).get("history", [])
    refs_doc = load_references(refs_path)
    failures, warnings, n_checked, n_legacy = check_rows(history, refs_doc)
    for w in warnings:
        print(f"  [perfcheck warn] {w}")
    summary = summarize(failures, warnings, n_checked, n_legacy)
    if failures:
        lines = "\n".join(f"  {f}" for f in failures)
        raise SystemExit(
            f"perf-regression gate: {len(failures)} metric(s) outside their "
            f"reference band:\n{lines}\n"
            f"(re-baseline deliberately with "
            f"`python -m benchmarks.kernel_bench --update-refs`)")
    print(f"  perfcheck: {n_checked} row(s) within reference bands "
          f"({len(warnings)} unbaselined pass(es) with warning, "
          f"{n_legacy} legacy row(s) skipped)")
    print(f"  perfcheck summary: {json.dumps(summary, sort_keys=True)}")
    from repro.runtime import telemetry

    tr = telemetry.get_tracer()
    if tr.active:
        tr.event("perfcheck", **summary)
    return summary


def main(argv=None) -> None:
    from benchmarks.kernel_bench import BENCH_SWEEP_PATH

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--history", type=pathlib.Path, default=BENCH_SWEEP_PATH)
    ap.add_argument("--refs", type=pathlib.Path, default=REFS_PATH)
    ap.add_argument("--update-refs", action="store_true",
                    help="re-baseline references.json from the latest "
                         "recorded row per (bench, backend, mode, quick) key")
    args = ap.parse_args(argv)
    history = load_history(args.history).get("history", [])
    if args.update_refs:
        update_references(history, args.refs)
    check_perf_history(args.history, args.refs, history=history)


if __name__ == "__main__":
    main()
