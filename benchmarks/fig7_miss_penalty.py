"""Fig 7: TLB miss penalty, conventional vs SPARTA, 2- vs 8-socket machines.

Pure timeline analysis (Fig 3): the conventional page walk pays a full
network round trip before the data fetch; SPARTA's walk is one local DRAM
access because the PTE is co-located in the partition.  Claims (C5)."""
from __future__ import annotations

from benchmarks.common import Claim, print_csv, save_fig
from repro.core.sparta import SystemLatencies, conventional_timelines, sparta_timelines


def run(quick: bool = False):
    rows, payload = [], {}
    reductions = {}
    for sockets in (2, 8):
        lat = SystemLatencies(n_sockets=sockets)
        _, _, _, conv_miss = conventional_timelines(lat)
        _, _, _, sp_miss = sparta_timelines(lat)
        norm = sp_miss / conv_miss
        reductions[sockets] = conv_miss / sp_miss
        rows.append([f"{sockets}-socket", float(conv_miss), float(sp_miss), float(norm)])
        payload[f"{sockets}socket"] = {
            "conventional_cycles": float(conv_miss),
            "sparta_cycles": float(sp_miss),
            "normalized": float(norm),
        }

    c5a = Claim("C5a", "SPARTA miss penalty ~= one local DRAM access (8-socket cycles)",
                payload["8socket"]["sparta_cycles"],
                (0.0, SystemLatencies().l_dram + 2 * SystemLatencies().l_tlb + 1), "cy")
    c5b = Claim("C5b", "bigger machine => bigger reduction (8-socket/2-socket reduction ratio)",
                reductions[8] / reductions[2], (1.05, 10.0), "x")
    print_csv("Fig7 miss penalty", ["machine", "conventional_cy", "sparta_cy", "normalized"], rows)
    print(c5a); print(c5b)
    payload["claims"] = [c5a.row(), c5b.row()]
    save_fig("fig7", payload)
    return [c5a, c5b]
