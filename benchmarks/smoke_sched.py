"""CI fault-injection smoke for the shard scheduler: SIGKILL one worker
process mid-shard and fail unless the run survives it bit-identically.

Three phases, all ``--quick`` with a small ``--chunk-accesses``:

1. **Serial reference** — fig11 unsharded, start to finish; its
   ``fig11.json`` (minus the ``_``-prefixed stamps) is the ground truth.
2. **Sharded run + kill** — fig11 with ``--workers 2 --executor process``.
   ``REPRO_SCHED_HOLD_S`` holds each shard's first attempt open after its
   lease lands, giving this parent a deterministic window to read a worker
   pid out of a lease file (``_cache/ckpt/fig11/*.lease``) and SIGKILL it —
   a real worker death, not a simulated exception.  The run must still
   finish with exit 0/1 (claims), *not* 79 (nothing quarantined: the dead
   worker's shard is re-dispatched, it is not poisoned).
3. **Verification** — the sharded run's ``fig11.json`` must equal the
   serial reference byte-for-byte after stripping stamps, and the telemetry
   run logs (parent + per-worker, merged by ``obs_report.merge_logs``)
   must actually record the recovery: ``worker_dead``, ``lease_expire``
   and ``redispatch`` scheduler events.

Exit 0 on success, 1 on any miss, with a summary on stderr.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import signal
import subprocess
import sys
import time

HERE = pathlib.Path(__file__).resolve().parent
FIG = HERE / "_cache" / "figs" / "fig11.json"
CKPT = HERE / "_cache" / "ckpt" / "fig11"
RUNLOGS = HERE / "_cache" / "runlogs"
CHUNK = 4_096
CMD = [sys.executable, "-m", "benchmarks.fig11_tail_latency", "--quick",
       "--chunk-accesses", str(CHUNK)]
KILL_DEADLINE_S = 600


def _strip(payload: dict) -> dict:
    return {k: v for k, v in payload.items() if not k.startswith("_")}


def _clear():
    shutil.rmtree(CKPT, ignore_errors=True)
    if FIG.exists():
        FIG.unlink()
    for p in RUNLOGS.glob("fig11-w*.jsonl"):
        p.unlink()


def _kill_one_worker(parent: subprocess.Popen) -> int | None:
    """Wait for the first shard lease, then SIGKILL the worker that holds
    it.  Returns the killed pid (None if the run finished first)."""
    deadline = time.monotonic() + KILL_DEADLINE_S
    while time.monotonic() < deadline:
        if parent.poll() is not None:
            return None
        for lp in sorted(CKPT.glob("*.lease")) if CKPT.exists() else []:
            try:
                lease = json.loads(lp.read_text())
            except (OSError, json.JSONDecodeError):
                continue
            pid = lease.get("pid")
            # Never kill the parent driver: only spawned workers hold
            # leases with a pid different from the driver's.
            if pid and pid != parent.pid:
                try:
                    os.kill(pid, signal.SIGKILL)
                except ProcessLookupError:
                    continue
                return pid
        time.sleep(0.05)
    return None


def main() -> int:
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")

    print("[smoke_sched] phase 1: serial reference run")
    _clear()
    p = subprocess.run(CMD, env=env, cwd=HERE.parent)
    if p.returncode not in (0, 1):   # 1 = a claim out of band, still a figure
        print(f"[smoke_sched] reference run failed (exit {p.returncode})",
              file=sys.stderr)
        return 1
    reference = _strip(json.loads(FIG.read_text()))

    print("[smoke_sched] phase 2: --workers 2 (process executor), "
          "SIGKILL one worker mid-shard")
    _clear()
    env_kill = dict(env)
    # Hold each shard's first attempt open so the kill lands mid-shard, and
    # shrink the lease TTL so recovery fits a smoke-test budget.
    env_kill["REPRO_SCHED_HOLD_S"] = "2.0"
    env_kill["REPRO_SCHED_LEASE_TTL_S"] = "1.5"
    env_kill["REPRO_SCHED_HEARTBEAT_S"] = "0.3"
    child = subprocess.Popen(
        CMD + ["--workers", "2", "--shards", "2", "--executor", "process"],
        env=env_kill, cwd=HERE.parent)
    pid = _kill_one_worker(child)
    rc = child.wait(timeout=KILL_DEADLINE_S)
    if pid is None:
        print("[smoke_sched] FAIL: run finished before a worker lease "
              "appeared — nothing was killed", file=sys.stderr)
        return 1
    print(f"[smoke_sched] killed worker pid {pid}; run exited {rc}")
    if rc not in (0, 1):
        print(f"[smoke_sched] sharded run exited {rc} "
              f"(79 would mean quarantined shards)", file=sys.stderr)
        return 1

    print("[smoke_sched] phase 3: verify recovery + bit-identity")
    sharded = _strip(json.loads(FIG.read_text()))
    stamps = json.loads(FIG.read_text())
    if stamps.get("_crash_safety", {}).get("quarantined_shards"):
        print("[smoke_sched] FAIL: shards were quarantined — a killed "
              "worker must be survived by re-dispatch, not quarantine",
              file=sys.stderr)
        return 1

    from benchmarks import obs_report
    logs = [RUNLOGS / "fig11.jsonl"] + sorted(RUNLOGS.glob("fig11-w*.jsonl"))
    merged = obs_report.merge_logs([obs_report.load_log(p) for p in logs
                                    if p.exists()])
    counts = obs_report.event_counts(merged)
    missing = [e for e in ("worker_dead", "lease_expire", "redispatch")
               if not counts.get(e)]
    if missing:
        print(f"[smoke_sched] FAIL: merged run logs ({len(logs)} files) "
              f"missing recovery events: {missing}; saw {counts}",
              file=sys.stderr)
        return 1
    print(f"[smoke_sched] recovery recorded: "
          + ", ".join(f"{e} x{counts[e]}"
                      for e in ("worker_dead", "lease_expire", "redispatch")))

    if sharded != reference:
        ref_s = json.dumps(reference, sort_keys=True, indent=1).splitlines()
        sh_s = json.dumps(sharded, sort_keys=True, indent=1).splitlines()
        diff = [f"-{a}\n+{b}" for a, b in zip(ref_s, sh_s) if a != b]
        print("[smoke_sched] FAIL: sharded figure differs from the serial "
              "reference:", file=sys.stderr)
        print("\n".join(diff[:40]), file=sys.stderr)
        return 1
    print("[smoke_sched] PASS: killed a worker mid-shard; fig11.json is "
          "bit-identical to the serial run")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
