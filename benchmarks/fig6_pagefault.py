"""Fig 6: page-fault rate vs available memory — 1 node vs 32 partitions.

RocksDB (16 GB footprint) under exact-LRU demand paging.  Claims (C4): the
kernel handles out-of-memory demand paging under partitioning, and the
32-node curve tracks the 1-node curve with a ~1.5-2 GB offset (the Linux
NUMA-node overhead artifact, modelled as per-node reserve + capacity
jitter)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Claim, GIB, print_csv, save_fig, trace
from repro.core import pagetable

MEM_FRACS = (0.75, 0.81, 0.88, 0.94, 0.97, 1.0, 1.03, 1.06, 1.12)  # x working set
PAGE = 4096
NODE_OVERHEAD_FRAC = 0.003     # per-node reserve as a fraction of the dataset
                               # (Linux zone overhead, ~47MB/node at 16GB scale)
JITTER = 0.04


def run(quick: bool = False):
    n_ops = 30_000 if quick else 120_000
    tr = trace("rocksdb", n_ops=n_ops, footprint_bytes=16 * GIB, max_accesses=2_000_000)
    vpns = tr.vpns(12)
    # Dedupe consecutive repeats (page-level stream).
    keep = np.concatenate([[True], vpns[1:] != vpns[:-1]])
    vpns = vpns[keep]

    # The synthetic trace touches a working set smaller than the nominal
    # 16 GB footprint; sweep memory around the OBSERVED working set and
    # report the offset scaled to the paper's 16 GB axis.
    unique = int(np.unique(vpns).size)
    frames = [max(32, int(fr * unique)) for fr in MEM_FRACS]
    overhead = max(1, int(NODE_OVERHEAD_FRAC * unique))
    c1 = pagetable.page_fault_curve(vpns, frames)
    c32 = pagetable.page_fault_curve(
        vpns, frames, num_partitions=32,
        node_overhead_frames=overhead, node_capacity_jitter=JITTER,
    )

    # Offset: extra memory the 32-node setup needs for the 1-node fault rate
    # at 0.94x working set, in 16GB-footprint-equivalent GB.
    ref_idx = MEM_FRACS.index(0.94)
    target = c1[ref_idx]
    need = None
    for fr, f in zip(MEM_FRACS, c32):
        if f <= target:
            need = fr
            break
    offset = (need - MEM_FRACS[ref_idx]) * 16.0 if need else float("nan")
    MEM_GB = [fr * 16.0 for fr in MEM_FRACS]
    c4a = Claim("C4a", "demand paging works when partitioned (32-node faults finite & decreasing)",
                float(c32[0] - c32[-1]), (0.0, 1.0), "")
    c4b = Claim("C4b", "32-node needs ~1.5-2GB extra memory for equal fault rate",
                float(offset), (0.25, 3.0), "GB")
    rows = [["1-node"] + list(map(float, c1)), ["32-node"] + list(map(float, c32))]
    print_csv("Fig6 fault rate vs memory (GB)", ["config"] + [str(g) for g in MEM_GB], rows)
    print(c4a); print(c4b)
    save_fig("fig6", {"mem_gb": MEM_GB, "curve_1": list(map(float, c1)),
                      "curve_32": list(map(float, c32)),
                      "claims": [c4a.row(), c4b.row()]})
    return [c4a, c4b]
