"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh) cell, all in seconds/step/device:

  compute    = FLOPs_per_device / 197e12        (TPU v5e bf16 peak)
  memory     = HBM_bytes_per_device / 819e9
  collective = collective_bytes_per_device / 50e9 (per-link ICI)

Methodology note (documented in EXPERIMENTS.md): XLA's ``cost_analysis()``
counts while-loop (scan) bodies ONCE, so raw HLO numbers undercount a
40-layer scanned model by ~40x.  We therefore:

* parse the archived optimized HLO with a **while-aware walker** that
  multiplies collective bytes by loop trip counts (exact per-device
  collective traffic, straight from the compiled program);
* compute FLOPs and HBM bytes from **closed-form analytic models** of each
  architecture (functions below), cross-checked against the raw
  cost_analysis numbers (raw ~= analytic/L x small factor).
"""
from __future__ import annotations

import dataclasses
import gzip
import json
import pathlib
import re
from typing import Dict, List, Optional, Tuple

from repro.configs import registry
from repro.configs.base import SHAPES_BY_NAME, ModelConfig, ShapeConfig

CACHE = pathlib.Path(__file__).resolve().parent / "_cache" / "dryrun"

PEAK_FLOPS = 197e12   # bf16 / chip
HBM_BW = 819e9        # B/s / chip
ICI_BW = 50e9         # B/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "u4": 1, "s4": 1,
}
_SHAPE_RE = re.compile(r"\b(pred|bf16|f16|f32|f64|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9_\[\],{}: ]+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)


# ---------------------------------------------------------------------------
# While-aware HLO collective accounting.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Computation:
    name: str
    collectives: List[Tuple[str, int]]          # (kind, bytes)
    whiles: List[Tuple[str, str]]               # (body, cond)
    calls: List[str]                            # called computations (x1)
    max_const: int = 1                          # largest int constant (trip heuristic)


def _shape_bytes(text: str) -> int:
    n_bytes = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        n_bytes += n * _DTYPE_BYTES[dt]
    return n_bytes


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for line in text.splitlines():
        if not line.startswith(" ") and ("{" in line) and ("->" in line or line.startswith("ENTRY")):
            m = re.match(r"(ENTRY\s+)?%?([\w.\-]+)", line)
            if m:
                cur = Computation(m.group(2), [], [], [])
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
            continue
        if cur is None:
            continue
        s = line.strip()
        mc = _COLL_RE.search(s)
        if mc and not mc.group(3) == "-done":  # count start (or plain) once
            kind = mc.group(2)
            cur.collectives.append((kind, _shape_bytes(s[: mc.end(1)])))
        mw = re.search(r"while\(", s)
        if mw:
            body = re.search(r"body=%?([\w.\-]+)", s)
            cond = re.search(r"condition=%?([\w.\-]+)", s)
            if body and cond:
                cur.whiles.append((body.group(1), cond.group(1)))
        for mcall in re.finditer(r"(?:to_apply|calls)=%?([\w.\-]+)", s):
            cur.calls.append(mcall.group(1))
        for mconst in re.finditer(r"constant\((\d+)\)", s):
            cur.max_const = max(cur.max_const, int(mconst.group(1)))
    comps["__entry__"] = comps.get(entry, Computation("none", [], [], []))
    return comps


def trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Scan loops compare the induction var against a constant upper bound;
    take the cond computation's largest integer constant."""
    c = comps.get(cond_name)
    return max(1, c.max_const) if c else 1


def loop_scaled_collectives(text: str) -> Tuple[Dict[str, float], List[dict]]:
    """Per-kind collective bytes with while-loop trip multipliers, plus the
    top individual contributors (for hillclimb analysis)."""
    comps = parse_hlo(text)
    totals: Dict[str, float] = {}
    contributors: List[dict] = []
    seen: set = set()

    def walk(name: str, mult: float, depth: int = 0):
        if depth > 16 or name not in comps:
            return
        comp = comps[name]
        for kind, nbytes in comp.collectives:
            totals[kind] = totals.get(kind, 0.0) + mult * nbytes
            contributors.append({"kind": kind, "bytes": nbytes, "mult": mult,
                                 "total": mult * nbytes, "comp": name})
        for body, cond in comp.whiles:
            walk(body, mult * trip_count(comps, cond), depth + 1)
        for callee in comp.calls:
            if (name, callee) not in seen:
                seen.add((name, callee))
                walk(callee, mult, depth + 1)

    walk(comps["__entry__"].name, 1.0)
    contributors.sort(key=lambda c: -c["total"])
    return totals, contributors[:12]


# ---------------------------------------------------------------------------
# Analytic FLOPs / bytes models.
# ---------------------------------------------------------------------------

def _attn_layer_flops_per_tok(cfg: ModelConfig, ctx: float) -> float:
    proj = 2 * cfg.d_model * (2 * cfg.q_dim + 2 * cfg.kv_dim)
    attn = 4 * ctx * cfg.head_dim * cfg.num_heads
    return proj + attn


def _ffn_flops_per_tok(cfg: ModelConfig) -> float:
    mult = 3 if cfg.activation.endswith("_glu") else 2
    if cfg.moe is not None:
        return (2 * cfg.d_model * cfg.moe.num_experts
                + 2 * cfg.d_model * cfg.moe.d_ff_expert * 3 * cfg.moe.top_k)
    return 2 * cfg.d_model * cfg.d_ff * mult


def _mamba_flops_per_tok(cfg: ModelConfig) -> float:
    from repro.models.mamba2 import dims
    d_inner, H, Pd, N = dims(cfg)
    conv_dim = d_inner + 2 * N
    return (2 * cfg.d_model * (2 * d_inner + 2 * N + H)
            + 2 * conv_dim * cfg.ssm_conv_width
            + 6 * N * d_inner
            + 2 * d_inner * cfg.d_model)


def _rwkv_flops_per_tok(cfg: ModelConfig) -> float:
    D, F, N = cfg.d_model, cfg.d_ff, cfg.ssm_headdim
    tm = 2 * D * D * 5 + 2 * D * 64 * 2 + 5 * N * D
    cm = 2 * D * F * 2 + 2 * D * D
    return tm + cm


def forward_flops_per_token(cfg: ModelConfig, ctx: float) -> float:
    """Per-token forward FLOPs at average attention context ``ctx``."""
    head = 2 * cfg.d_model * cfg.vocab
    if cfg.family in ("dense", "moe", "vlm"):
        return cfg.num_layers * (_attn_layer_flops_per_tok(cfg, ctx) + _ffn_flops_per_tok(cfg)) + head
    if cfg.family == "ssm":
        return cfg.num_layers * _rwkv_flops_per_tok(cfg) + head
    if cfg.family == "hybrid":
        from repro.models.zamba2 import group_dims
        G, per = group_dims(cfg)
        shared = G * (_attn_layer_flops_per_tok(cfg, ctx) + 2 * cfg.d_model * cfg.d_ff * 3)
        return cfg.num_layers * _mamba_flops_per_tok(cfg) + shared + head
    if cfg.family == "encdec":
        # Per decoder token; the encoder is accounted separately by callers.
        self_a = _attn_layer_flops_per_tok(cfg, ctx)
        cross = 2 * cfg.d_model * 2 * cfg.q_dim  # q + o proj; scores added by caller
        return cfg.num_layers * (self_a + cross + _ffn_flops_per_tok(cfg)) + head
    raise ValueError(cfg.family)


def cell_flops(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, float]:
    """Returns {model (3x fwd, no remat), compiled (4x fwd with remat),
    fwd} total FLOPs per step (global)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            s_enc = s_dec = S // 2
            enc_tok = _attn_layer_flops_per_tok(cfg, s_enc) + _ffn_flops_per_tok(cfg)
            fwd = B * s_enc * cfg.encoder_layers * enc_tok
            fwd += B * s_dec * forward_flops_per_token(cfg, s_dec / 2)
            fwd += B * s_dec * cfg.num_layers * 4 * s_enc * cfg.head_dim * cfg.num_heads
            fwd += B * s_enc * cfg.num_layers * 2 * cfg.d_model * 2 * cfg.kv_dim  # cross KV
        elif cfg.family == "vlm":
            fwd = B * S * forward_flops_per_token(cfg, S / 2)
        else:
            fwd = B * S * forward_flops_per_token(cfg, S / 2)
        mult = {"train": (3.0, 4.0), "prefill": (1.0, 1.0)}[shape.kind]
        return {"fwd": fwd, "model": mult[0] * fwd, "compiled": mult[1] * fwd}
    # decode: one token per sequence, full context attention reads.
    if cfg.family == "encdec":
        f = B * forward_flops_per_token(cfg, S)
        f += B * cfg.num_layers * 4 * 1500 * cfg.head_dim * cfg.num_heads
    else:
        f = B * forward_flops_per_token(cfg, S)
    return {"fwd": f, "model": f, "compiled": f}


def cell_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                   param_count: int) -> float:
    """Per-device HBM bytes per step (analytic model, documented)."""
    B, S = shape.global_batch, shape.seq_len
    p_bytes = param_count * 2  # bf16 compute copies
    if shape.kind == "train":
        # FSDP: full weights stream through each device 3x (fwd, remat, bwd)
        # + grads (2B) + fp32 m/v/param opt update sharded 1/chips.
        w = 3 * p_bytes + 2 * param_count
        opt = 16 * param_count / chips
        act = cfg.num_layers * (B * S // max(chips // 16, 1)) * cfg.d_model * 2 * 8 / 16
        return w + opt + act
    if shape.kind == "prefill":
        w = p_bytes
        act = cfg.num_layers * (B * S / max(chips, 1)) * cfg.d_model * 2 * 8
        return w + act
    # decode: TP-sharded weights read once + KV pool sweep.
    w = p_bytes / 16
    page = cfg.kv_page_size
    pages = -(-S // page)
    if cfg.family == "ssm":
        from repro.models.rwkv6 import _heads
        H, N = _heads(cfg)
        state = cfg.num_layers * B * H * N * N * 4
        return w + 2 * state / chips
    n_att_layers = cfg.num_layers if cfg.family != "hybrid" else cfg.num_layers // max(cfg.hybrid_period, 1)
    pool = n_att_layers * B * pages * page * cfg.kv_dim * 2 * 2
    return w + pool / chips


def load_cells() -> List[dict]:
    out = []
    for p in sorted(CACHE.glob("*.json")):
        out.append(json.loads(p.read_text()))
    return out


def analyse_cell(rec: dict, *, top_contributors: bool = False) -> Optional[dict]:
    if not rec.get("ok"):
        return None
    cfg = registry.get_config(rec["arch"])
    shape = SHAPES_BY_NAME[rec["shape"]]
    chips = rec["chips"]
    fl = cell_flops(cfg, shape)
    hlo_gz = CACHE / f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.hlo.gz"
    coll_total = float(sum(rec.get("collective_bytes", {}).values()))
    contributors = []
    if hlo_gz.exists():
        with gzip.open(hlo_gz, "rt") as f:
            totals, contributors = loop_scaled_collectives(f.read())
        coll_total = float(sum(totals.values()))
    hbm = cell_hbm_bytes(cfg, shape, chips, rec.get("param_count", cfg.param_count()))

    t_compute = fl["compiled"] / chips / PEAK_FLOPS
    t_memory = hbm / HBM_BW
    t_coll = coll_total / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    out = {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory, "t_collective_s": t_coll,
        "dominant": dominant,
        "roofline_fraction": t_compute / bound if bound > 0 else 0.0,
        "model_flops": fl["model"], "compiled_flops_est": fl["compiled"],
        "useful_ratio": fl["model"] / fl["compiled"],
        "hlo_flops_raw_per_dev": rec.get("cost", {}).get("flops", 0.0),
        "collective_bytes_per_dev": coll_total,
        "hbm_bytes_per_dev": hbm,
    }
    if top_contributors:
        out["top_collectives"] = contributors
    return out


def table(mesh: str = "16x16") -> List[dict]:
    rows = []
    for rec in load_cells():
        if rec.get("mesh") != mesh:
            continue
        r = analyse_cell(rec)
        if r:
            rows.append(r)
    return rows


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--cell", default=None, help="arch:shape for detailed contributors")
    args = ap.parse_args()
    if args.cell:
        arch, shape = args.cell.split(":")
        rec = json.loads((CACHE / f"{arch}__{shape}__{args.mesh}.json").read_text())
        r = analyse_cell(rec, top_contributors=True)
        print(json.dumps(r, indent=1, default=float))
        return
    rows = table(args.mesh)
    hdr = ["arch", "shape", "t_compute_s", "t_memory_s", "t_collective_s", "dominant", "roofline_fraction"]
    print(",".join(hdr))
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        print(",".join(str(round(r[k], 6)) if isinstance(r[k], float) else str(r[k]) for k in hdr))


if __name__ == "__main__":
    main()
