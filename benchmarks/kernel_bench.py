"""Kernel micro-benchmarks: reference-vs-interpret allclose + XLA-path timing.

On this CPU container the timing column measures the *reference* (XLA) path
(the Pallas kernels execute via the interpreter, which is not representative
of TPU performance); the allclose column is the correctness deliverable.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_csv, save_fig


def _timeit(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6  # us


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    rows = []

    # flash attention
    from repro.kernels.flash_attention import flash_attention
    B, Hq, Hkv, T, D = 2, 8, 2, 256, 64
    q = jnp.asarray(rng.standard_normal((B, Hq, T, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, T, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, T, D)), jnp.float32)
    ref = flash_attention(q, k, v, kernel_mode="reference")
    pal = flash_attention(q, k, v, block_q=64, block_k=64, kernel_mode="pallas_interpret")
    err = float(jnp.abs(ref - pal).max())
    us = _timeit(lambda a, b, c: flash_attention(a, b, c, kernel_mode="reference"), q, k, v)
    rows.append(["flash_attention", us, err])

    # paged attention
    from repro.kernels.paged_attention import paged_attention
    slots, page, pages = 64, 32, 8
    q1 = jnp.asarray(rng.standard_normal((B, Hq, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((slots, page, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((slots, page, Hkv, D)), jnp.float32)
    tbl = jnp.asarray(rng.choice(slots, (B, pages), replace=False).astype(np.int32))
    ctx = jnp.asarray(rng.integers(1, pages * page, B).astype(np.int32))
    ref = paged_attention(q1, kp, vp, tbl, ctx, kernel_mode="reference")
    pal = paged_attention(q1, kp, vp, tbl, ctx, kernel_mode="pallas_interpret")
    err = float(jnp.abs(ref - pal).max())
    us = _timeit(lambda *a: paged_attention(*a, kernel_mode="reference"), q1, kp, vp, tbl, ctx)
    rows.append(["paged_attention", us, err])

    # rwkv6 scan
    from repro.kernels.rwkv6_scan import rwkv6_scan
    Bh, H, Ts, N = 2, 4, 128, 32
    r = jnp.asarray(rng.standard_normal((Bh, H, Ts, N)) * 0.5, jnp.float32)
    kk = jnp.asarray(rng.standard_normal((Bh, H, Ts, N)) * 0.5, jnp.float32)
    vv = jnp.asarray(rng.standard_normal((Bh, H, Ts, N)) * 0.5, jnp.float32)
    w = jnp.asarray(rng.uniform(0.8, 0.999, (Bh, H, Ts, N)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, N)) * 0.5, jnp.float32)
    oref, sref = rwkv6_scan(r, kk, vv, w, u, kernel_mode="reference")
    opal, spal = rwkv6_scan(r, kk, vv, w, u, chunk=32, kernel_mode="pallas_interpret")
    err = float(jnp.abs(oref - opal).max())
    us = _timeit(lambda *a: rwkv6_scan(*a, kernel_mode="reference")[0], r, kk, vv, w, u)
    rows.append(["rwkv6_scan", us, err])

    # mamba2 scan
    from repro.kernels.mamba2_scan import mamba2_scan
    P, Nst = 32, 16
    x = jnp.asarray(rng.standard_normal((Bh, H, Ts, P)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (Bh, H, Ts)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 4.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((Bh, Ts, Nst)) * 0.5, jnp.float32)
    C = jnp.asarray(rng.standard_normal((Bh, Ts, Nst)) * 0.5, jnp.float32)
    Dp = jnp.asarray(rng.standard_normal((H,)), jnp.float32)
    yref, _ = mamba2_scan(x, dt, A, Bm, C, Dp, kernel_mode="reference")
    ypal, _ = mamba2_scan(x, dt, A, Bm, C, Dp, chunk=32, kernel_mode="pallas_interpret")
    err = float(jnp.abs(yref - ypal).max())
    us = _timeit(lambda *a: mamba2_scan(*a, kernel_mode="reference")[0], x, dt, A, Bm, C, Dp)
    rows.append(["mamba2_scan", us, err])

    # tlb_sim
    from repro.kernels.tlb_sim import tlb_sim
    s = jnp.asarray(rng.integers(0, 64, 4096), jnp.int32)
    t = jnp.asarray(rng.integers(0, 50, 4096), jnp.int32)
    ref = tlb_sim(s, t, 64, 4, kernel_mode="reference")
    pal = tlb_sim(s, t, 64, 4, block=512, kernel_mode="pallas_interpret")
    err = float((np.asarray(ref) != np.asarray(pal)).mean())
    us = _timeit(lambda a, b: tlb_sim(a, b, 64, 4, kernel_mode="reference"), s, t)
    rows.append(["tlb_sim", us, err])

    print_csv("Kernel benches", ["kernel", "us_per_call(ref/XLA)", "max_err_vs_oracle"], rows)
    save_fig("kernel_bench", {"rows": rows})
    for name, _, err in rows:
        assert err < 5e-4, (name, err)
    return []
