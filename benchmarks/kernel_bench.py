"""Kernel micro-benchmarks: reference-vs-interpret allclose + XLA-path timing.

On this CPU container the timing column measures the *reference* (XLA) path
(the Pallas kernels execute via the interpreter, which is not representative
of TPU performance); the allclose column is the correctness deliverable.

The sweep-engine section times the batched-scan reference against the exact
stack-distance backend on a fig4-style sweep; the timeline section times the
Pallas queueing kernel against its ``lax.scan`` reference on a fig11-style
contended run.  Both append their result to ``BENCH_sweep.json`` at the repo
root, so the perf trajectory is tracked PR-over-PR.

All timing goes through ``repro.core.benchtime.measure`` (blocked warm-up,
block-until-ready inside every rep's window, min-of-N with spread recorded)
and every appended row carries the ``benchtime.device_metadata()`` schema
stamp.  ``--check`` is the CI gate: bit-identity + required-bench coverage
here, then the ReFrame-style tolerance-band regression gate in
``benchmarks/perfcheck.py`` against ``benchmarks/references.json``
(``--update-refs`` re-baselines deliberately).
"""
from __future__ import annotations

import json
import os
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_csv, save_fig, with_runlog
from repro.core import benchtime
from repro.core.benchtime import measure

BENCH_SWEEP_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_sweep.json"

# Engine benches run seconds-scale calls; two blocked reps (min kept) after
# one blocked warm-up bound the cost while still rejecting one-sided noise.
ENGINE_REPS = 2


def _timeit(fn, *args, reps=5, label=None):
    return measure(fn, *args, reps=reps, label=label).best_us


@with_runlog("kernels")
def run(quick: bool = False, profile_dir=None):
    """One telemetry run (``_cache/runlogs/kernels.jsonl``): every measured
    row lands as a ``measure`` span.  ``profile_dir`` additionally captures a
    ``jax.profiler`` trace with one ``StepTraceAnnotation`` per engine bench
    (don't pass it when already inside ``benchmarks/run.py --profile`` —
    nested profiler traces error)."""
    import contextlib

    cm = (jax.profiler.trace(str(profile_dir)) if profile_dir
          else contextlib.nullcontext())
    with cm:
        return _run_benches(quick, profile=bool(profile_dir))


def _step(name: str, profile: bool):
    import contextlib

    return (jax.profiler.StepTraceAnnotation(name) if profile
            else contextlib.nullcontext())


def _run_benches(quick: bool, profile: bool = False):
    rng = np.random.default_rng(0)
    rows = []

    # flash attention
    from repro.kernels.flash_attention import flash_attention
    B, Hq, Hkv, T, D = 2, 8, 2, 256, 64
    q = jnp.asarray(rng.standard_normal((B, Hq, T, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, T, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, T, D)), jnp.float32)
    ref = flash_attention(q, k, v, kernel_mode="reference")
    pal = flash_attention(q, k, v, block_q=64, block_k=64, kernel_mode="pallas_interpret")
    err = float(jnp.abs(ref - pal).max())
    us = _timeit(lambda a, b, c: flash_attention(a, b, c, kernel_mode="reference"), q, k, v,
                 label="kernel:flash_attention")
    rows.append(["flash_attention", us, err])

    # paged attention
    from repro.kernels.paged_attention import paged_attention
    slots, page, pages = 64, 32, 8
    q1 = jnp.asarray(rng.standard_normal((B, Hq, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((slots, page, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((slots, page, Hkv, D)), jnp.float32)
    tbl = jnp.asarray(rng.choice(slots, (B, pages), replace=False).astype(np.int32))
    ctx = jnp.asarray(rng.integers(1, pages * page, B).astype(np.int32))
    ref = paged_attention(q1, kp, vp, tbl, ctx, kernel_mode="reference")
    pal = paged_attention(q1, kp, vp, tbl, ctx, kernel_mode="pallas_interpret")
    err = float(jnp.abs(ref - pal).max())
    us = _timeit(lambda *a: paged_attention(*a, kernel_mode="reference"), q1, kp, vp, tbl, ctx,
                 label="kernel:paged_attention")
    rows.append(["paged_attention", us, err])

    # rwkv6 scan
    from repro.kernels.rwkv6_scan import rwkv6_scan
    Bh, H, Ts, N = 2, 4, 128, 32
    r = jnp.asarray(rng.standard_normal((Bh, H, Ts, N)) * 0.5, jnp.float32)
    kk = jnp.asarray(rng.standard_normal((Bh, H, Ts, N)) * 0.5, jnp.float32)
    vv = jnp.asarray(rng.standard_normal((Bh, H, Ts, N)) * 0.5, jnp.float32)
    w = jnp.asarray(rng.uniform(0.8, 0.999, (Bh, H, Ts, N)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((H, N)) * 0.5, jnp.float32)
    oref, sref = rwkv6_scan(r, kk, vv, w, u, kernel_mode="reference")
    opal, spal = rwkv6_scan(r, kk, vv, w, u, chunk=32, kernel_mode="pallas_interpret")
    err = float(jnp.abs(oref - opal).max())
    us = _timeit(lambda *a: rwkv6_scan(*a, kernel_mode="reference")[0], r, kk, vv, w, u,
                 label="kernel:rwkv6_scan")
    rows.append(["rwkv6_scan", us, err])

    # mamba2 scan
    from repro.kernels.mamba2_scan import mamba2_scan
    P, Nst = 32, 16
    x = jnp.asarray(rng.standard_normal((Bh, H, Ts, P)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (Bh, H, Ts)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 4.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((Bh, Ts, Nst)) * 0.5, jnp.float32)
    C = jnp.asarray(rng.standard_normal((Bh, Ts, Nst)) * 0.5, jnp.float32)
    Dp = jnp.asarray(rng.standard_normal((H,)), jnp.float32)
    yref, _ = mamba2_scan(x, dt, A, Bm, C, Dp, kernel_mode="reference")
    ypal, _ = mamba2_scan(x, dt, A, Bm, C, Dp, chunk=32, kernel_mode="pallas_interpret")
    err = float(jnp.abs(yref - ypal).max())
    us = _timeit(lambda *a: mamba2_scan(*a, kernel_mode="reference")[0], x, dt, A, Bm, C, Dp,
                 label="kernel:mamba2_scan")
    rows.append(["mamba2_scan", us, err])

    # tlb_sim
    from repro.kernels.tlb_sim import tlb_sim
    s = jnp.asarray(rng.integers(0, 64, 4096), jnp.int32)
    t = jnp.asarray(rng.integers(0, 50, 4096), jnp.int32)
    ref = tlb_sim(s, t, 64, 4, kernel_mode="reference")
    pal = tlb_sim(s, t, 64, 4, block=512, kernel_mode="pallas_interpret")
    err = float((np.asarray(ref) != np.asarray(pal)).mean())
    us = _timeit(lambda a, b: tlb_sim(a, b, 64, 4, kernel_mode="reference"), s, t,
                 label="kernel:tlb_sim")
    rows.append(["tlb_sim", us, err])

    # stackdist segmented stack scan
    from repro.kernels.stackdist import stack_scan
    L, C, W = 8, 128, 4
    tags = jnp.asarray(rng.integers(0, 40, (L, C)), jnp.int32)
    flags = np.zeros((L, C), bool)
    flags[:, 0] = True
    flags[rng.random((L, C)) < 0.02] = True
    flags = jnp.asarray(flags)
    init = jnp.asarray(rng.integers(0, 40, (L, W)), jnp.int32)
    dref, fref = stack_scan(tags, flags, init, kernel_mode="reference")
    dpal, fpal = stack_scan(tags, flags, init, kernel_mode="pallas_interpret")
    err = float((np.asarray(dref) != np.asarray(dpal)).mean()
                + (np.asarray(fref) != np.asarray(fpal)).mean())
    us = _timeit(lambda a, b, c: stack_scan(a, b, c, kernel_mode="reference")[0],
                 tags, flags, init, label="kernel:stackdist_scan")
    rows.append(["stackdist_scan", us, err])

    # timeline queueing scan
    from repro.kernels.timeline import TimelineParams, timeline_sim
    n = 4096
    tp = TimelineParams(mem_tlb=True, num_accels=4, mshrs=4,
                        num_partitions=8, tlb_ports=2, dram_banks=8)
    tl_inputs = (
        jnp.asarray(rng.integers(0, tp.num_accels, n), jnp.int32),
        jnp.asarray(rng.integers(0, tp.num_partitions, n), jnp.int32),
        jnp.asarray(rng.integers(0, tp.dram_banks, n), jnp.int32),
        jnp.asarray(rng.integers(0, tp.dram_banks, n), jnp.int32),
        jnp.asarray(rng.random(n) < 0.5, jnp.int32),
        jnp.asarray(rng.random(n) < 0.6, jnp.int32),
        jnp.asarray(rng.random(n) < 0.7, jnp.int32),
        jnp.zeros(n, jnp.float32),
    )
    ref = timeline_sim(*tl_inputs, tp, kernel_mode="reference")
    pal = timeline_sim(*tl_inputs, tp, block=512, kernel_mode="pallas_interpret")
    err = float(sum((np.asarray(r) != np.asarray(p)).mean()
                    for r, p in zip(ref, pal)))
    us = _timeit(lambda *a: timeline_sim(*a, tp, kernel_mode="reference")[0],
                 *tl_inputs, label="kernel:timeline_sim")
    rows.append(["timeline_sim", us, err])

    print_csv("Kernel benches", ["kernel", "us_per_call(ref/XLA)", "max_err_vs_oracle"], rows)
    save_fig("kernel_bench", {"rows": rows})
    for name, _, err in rows:
        assert err < 5e-4, (name, err)

    with _step("sweep_bench", profile):
        _sweep_bench(quick)
    with _step("timeline_bench", profile):
        _timeline_bench(quick)
    with _step("timeline_batched_bench", profile):
        _timeline_batched_bench(quick)
    with _step("system_batched_bench", profile):
        _system_batched_bench(quick)
    check_bench_history()
    return []


def _append_bench_entry(entry: dict) -> None:
    """Append one record to the BENCH_sweep.json history at the repo root.

    Every entry is stamped with the ``benchtime.device_metadata()`` schema
    (device_kind / platform / device_count / jax_version / schema_version).
    A corrupt history file raises instead of being silently overwritten —
    the file is the repo's entire perf trajectory.

    The read-modify-write cycle runs under an advisory file lock and commits
    via tmp + ``os.replace``, so two concurrent bench runs (e.g. scheduler
    workers, or parallel CI jobs on one host) serialize their appends instead
    of losing one, and a reader never observes a torn file.
    """
    from repro.checkpoint.checkpoint import file_lock

    lock = BENCH_SWEEP_PATH.with_name(BENCH_SWEEP_PATH.name + ".lock")
    with file_lock(lock):
        hist = {"history": []}
        if BENCH_SWEEP_PATH.exists():
            try:
                prior = json.loads(BENCH_SWEEP_PATH.read_text())
            except json.JSONDecodeError as e:
                raise RuntimeError(
                    f"{BENCH_SWEEP_PATH} exists but is not valid JSON ({e}); "
                    f"refusing to overwrite the recorded perf history — restore "
                    f"it from git (or delete it deliberately) and re-run"
                ) from e
            if not isinstance(prior, dict):
                raise RuntimeError(
                    f"{BENCH_SWEEP_PATH} is valid JSON but not the expected "
                    f"{{'history': [...]}} document; refusing to overwrite it")
            hist = prior
        hist.setdefault("history", []).append(
            {**benchtime.device_metadata(), **entry})
        tmp = BENCH_SWEEP_PATH.with_name(
            f"{BENCH_SWEEP_PATH.name}.tmp-{os.getpid()}")
        tmp.write_text(json.dumps(hist, indent=1))
        os.replace(tmp, BENCH_SWEEP_PATH)


def _record_calibration(entry: dict) -> None:
    """Feed this bench's measured per-backend times into the dispatch
    calibration table (``_cache/calibration/``): the engine benches time
    every backend head-to-head, which is exactly the evidence
    ``kernel_mode="auto"`` needs to stop picking ``pallas_interpret`` where
    the batched scan is measured faster.  Best-effort — a calibration
    failure must not fail the bench itself."""
    from benchmarks.common import CACHE
    from repro.core import dispatch

    try:
        store = dispatch.CalibrationStore.for_dir(CACHE / "calibration")
        n = dispatch.ingest_bench_entries(
            store, [{**benchtime.device_metadata(), **entry}])
        print(f"  calibration: {n} backend rate(s) recorded -> {store.path.name}")
    except (OSError, dispatch.CalibrationCorruptError) as e:
        print(f"  calibration: NOT recorded ({e})")


def _sweep_bench(quick: bool):
    """fig4-style sweep: batched-scan reference vs the stack-distance backend
    (plus the Pallas TPU kernel where a TPU backend is available).

    Each backend runs twice and reports the second (steady-state) time so
    one-off XLA compilation doesn't pollute the PR-over-PR trajectory.
    Results append to BENCH_sweep.json at the repo root.
    """
    from repro.core import traces
    from repro.core.sparta import TLBConfig
    from repro.core.sweep import TLBSweepSpec, sweep_tlb

    n_acc = 120_000 if quick else 1_000_000
    tr = traces.generate("bst_external", n_ops=2 * n_acc // 5, max_accesses=n_acc)
    specs = [
        TLBSweepSpec(TLBConfig(entries=e, ways=4), num_partitions=p, page_shift=12)
        for p in (1, 128) for e in (64, 128, 256, 512)
    ]

    def timed(mode):
        m = measure(sweep_tlb, tr.lines, specs, kernel_mode=mode,
                    reps=ENGINE_REPS, label=f"sweep:{mode}")
        return m, m.result

    m_ref, ref = timed("reference")
    m_sd, sd = timed("stackdist")
    t_ref, t_sd = m_ref.best_s, m_sd.best_s
    bit_identical = bool(np.array_equal(ref.hits, sd.hits))
    spread = {"t_reference_s": round(m_ref.spread_frac, 3),
              "t_stackdist_s": round(m_sd.spread_frac, 3)}
    entry = {
        "written_at": time.strftime("%Y-%m-%d %H:%M:%S"),
        "bench": "sweep",
        "backend": jax.default_backend(),
        "quick": quick,
        "n_accesses": int(tr.num_accesses),
        "n_configs": len(specs),
        "t_reference_s": round(t_ref, 3),
        "t_stackdist_s": round(t_sd, 3),
        "speedup": round(t_ref / t_sd, 2),
        "bit_identical": bit_identical,
        "reps": ENGINE_REPS,
        "spread_frac": spread,
    }
    if jax.default_backend() == "tpu":
        m_pal, pal = timed("pallas")
        entry["t_pallas_s"] = round(m_pal.best_s, 3)
        spread["t_pallas_s"] = round(m_pal.spread_frac, 3)
        entry["pallas_bit_identical"] = bool(np.array_equal(ref.hits, pal.hits))

    print_csv(
        "Sweep engine (fig4-style, one trace, 8 configs)",
        ["backend", "seconds", "vs_reference"],
        [["reference(batched scan)", t_ref, 1.0],
         ["stackdist", t_sd, t_ref / t_sd]],
    )
    print(f"  stackdist bit-identical to reference: {bit_identical}")
    # Assert BEFORE recording: a diverging run must fail loudly, not poison
    # the BENCH_sweep.json history the CI gate scans.
    assert bit_identical, "stackdist sweep diverged from the batched-scan oracle"
    assert entry.get("pallas_bit_identical", True), \
        "pallas sweep diverged from the batched-scan oracle"
    _append_bench_entry(entry)
    _record_calibration(entry)


def _timeline_bench(quick: bool):
    """fig11-style timeline run: the Pallas queueing kernel vs its jnp
    ``lax.scan`` reference, appended to BENCH_sweep.json.

    On this CPU container the Pallas path runs under the interpreter (the
    ``mode`` field records which); on a TPU backend the same entry captures
    the compiled-kernel speedup.  Bit-identity is asserted either way — the
    two paths share one ``timeline_step``.
    """
    from repro.core import timeline, traces
    from repro.core.sparta import SystemLatencies, TLBConfig
    from repro.core.sweep import sweep_system
    from repro.core.tlbsim import SystemSimConfig

    n_acc = 30_000 if quick else 120_000
    streams = traces.thread_traces("bst_external", 4, n_ops=2 * n_acc // 20, seed=7)
    inter = traces.interleave(streams)[:n_acc]
    ev = sweep_system(inter, [SystemSimConfig(
        cache=TLBConfig(entries=256, ways=4), accel_tlb=None,
        mem_tlb=TLBConfig(entries=128, ways=4), num_partitions=32,
        page_shift=12)])[0]
    lat = SystemLatencies()
    kw = dict(cfg=timeline.TimelineConfig(mshrs=8, tlb_ports=1, dram_banks=16),
              num_partitions=32, num_accelerators=4)

    pallas_mode = "pallas" if jax.default_backend() == "tpu" else "pallas_interpret"

    def timed(mode):
        m = measure(timeline.simulate_timeline, inter, ev, "sparta", lat,
                    kernel_mode=mode, reps=ENGINE_REPS,
                    label=f"timeline:{mode}", **kw)
        return m, m.result

    m_ref, ref = timed("reference")
    m_pal, pal = timed(pallas_mode)
    t_ref, t_pal = m_ref.best_s, m_pal.best_s
    bit_identical = bool(
        np.array_equal(ref.latency, pal.latency)
        and np.array_equal(ref.overhead, pal.overhead)
        and np.array_equal(ref.done, pal.done))
    entry = {
        "written_at": time.strftime("%Y-%m-%d %H:%M:%S"),
        "bench": "timeline",
        "backend": jax.default_backend(),
        "mode": pallas_mode,
        "quick": quick,
        "n_accesses": int(inter.shape[0]),
        "t_reference_s": round(t_ref, 3),
        "t_pallas_s": round(t_pal, 3),
        "speedup": round(t_ref / t_pal, 2),
        "bit_identical": bit_identical,
        "reps": ENGINE_REPS,
        "spread_frac": {"t_reference_s": round(m_ref.spread_frac, 3),
                        "t_pallas_s": round(m_pal.spread_frac, 3)},
    }
    print_csv(
        "Timeline engine (fig11-style, 4 accels, SPARTA-32)",
        ["backend", "seconds", "vs_reference"],
        [["reference(lax.scan)", t_ref, 1.0],
         [pallas_mode, t_pal, t_ref / t_pal]],
    )
    print(f"  timeline kernel bit-identical to reference: {bit_identical}")
    # Assert BEFORE recording (see _sweep_bench).
    assert bit_identical, "timeline kernel diverged from the lax.scan oracle"
    _append_bench_entry(entry)
    _record_calibration(entry)


def _timeline_batched_bench(quick: bool):
    """fig11-scale batched timeline sweep: the looped per-sim reference
    (one ``simulate_timeline`` scan per cell) vs ``sweep_timeline``'s single
    batched scan vs the batched Pallas kernel, appended to BENCH_sweep.json.

    The non-quick matrix is the full fig11 cell grid (4 workloads x 5 accel
    counts x 2 designs = 40 sims); the batched engine must stay bit-identical
    per sim and is the fix for the recorded 0.87x single-sim kernel entry —
    the sim axis gives the kernel (and the scan) something to amortize.
    """
    from repro.core import timeline, traces
    from repro.core.sparta import SystemLatencies, TLBConfig
    from repro.core.sweep import sweep_system
    from repro.core.tlbsim import SystemSimConfig

    workloads = ("bst_external", "hash_table") if quick else \
        ("bst_external", "bst_internal", "hash_table", "skip_list")
    accel_counts = (1, 4, 16) if quick else (1, 2, 4, 8, 16)
    n_acc = 8_000 if quick else 60_000
    lat = SystemLatencies(n_sockets=8)
    queues = timeline.TimelineConfig(mshrs=8, tlb_ports=1, dram_banks=16)
    cache = TLBConfig(entries=256, ways=4)
    mem = TLBConfig(entries=128, ways=4)
    accel_tlb = TLBConfig(entries=128, ways=4)

    specs = []
    for w in workloads:
        streams = traces.thread_traces(w, max(accel_counts), n_ops=2 * n_acc // 20, seed=7)
        inter = traces.interleave(streams)[:n_acc]
        evs = sweep_system(inter, [
            SystemSimConfig(cache=cache, accel_tlb=accel_tlb, mem_tlb=mem,
                            num_partitions=1, page_shift=12),
            SystemSimConfig(cache=cache, accel_tlb=None, mem_tlb=mem,
                            num_partitions=32, page_shift=12)])
        for A in accel_counts:
            ids = timeline.round_robin_accel_ids(inter.shape[0], A)
            specs.append(timeline.TimelineSpec(
                inter, evs[0], "conventional", cfg=queues,
                num_accelerators=A, accel_ids=ids))
            specs.append(timeline.TimelineSpec(
                inter, evs[1], "sparta", cfg=queues, num_partitions=32,
                num_accelerators=A, accel_ids=ids))

    def timed(fn, label):
        m = measure(fn, reps=ENGINE_REPS, label=label)
        return m, m.result

    def looped():
        return [timeline.simulate_timeline(
            sp.lines, sp.events, sp.design, lat, cfg=sp.cfg,
            num_partitions=sp.num_partitions,
            num_accelerators=sp.num_accelerators, accel_ids=sp.accel_ids,
            kernel_mode="reference") for sp in specs]

    pallas_mode = "pallas" if jax.default_backend() == "tpu" else "pallas_interpret"
    m_loop, ref = timed(looped, "timeline_batched:looped")
    m_bat, bat = timed(
        lambda: timeline.sweep_timeline(specs, lat, kernel_mode="reference"),
        "timeline_batched:reference")
    m_pal, pal = timed(
        lambda: timeline.sweep_timeline(specs, lat, kernel_mode=pallas_mode),
        f"timeline_batched:{pallas_mode}")
    t_loop, t_bat, t_pal = m_loop.best_s, m_bat.best_s, m_pal.best_s

    def identical(xs):
        return bool(all(
            np.array_equal(getattr(x, k), getattr(r, k))
            for x, r in zip(xs, ref) for k in ("latency", "overhead", "done")))

    bit_identical = identical(bat)
    pallas_identical = identical(pal)
    entry = {
        "written_at": time.strftime("%Y-%m-%d %H:%M:%S"),
        "bench": "timeline_batched",
        "backend": jax.default_backend(),
        "mode": pallas_mode,
        "quick": quick,
        "n_sims": len(specs),
        "n_accesses": int(n_acc),
        "t_looped_s": round(t_loop, 3),
        "t_batched_s": round(t_bat, 3),
        "t_pallas_s": round(t_pal, 3),
        "speedup": round(t_loop / t_bat, 2),
        "bit_identical": bit_identical and pallas_identical,
        "reps": ENGINE_REPS,
        "spread_frac": {"t_looped_s": round(m_loop.spread_frac, 3),
                        "t_batched_s": round(m_bat.spread_frac, 3),
                        "t_pallas_s": round(m_pal.spread_frac, 3)},
    }
    print_csv(
        f"Batched timeline engine ({len(specs)} sims x {n_acc} accesses)",
        ["backend", "seconds", "vs_looped"],
        [["looped reference (per-sim scans)", t_loop, 1.0],
         ["sweep_timeline (batched scan)", t_bat, t_loop / t_bat],
         [f"sweep_timeline ({pallas_mode})", t_pal, t_loop / t_pal]],
    )
    print(f"  batched scan bit-identical to looped oracle: {bit_identical}")
    print(f"  batched {pallas_mode} bit-identical to looped oracle: {pallas_identical}")
    # Assert BEFORE recording (see _sweep_bench).
    assert bit_identical, "sweep_timeline diverged from the per-sim oracle"
    assert pallas_identical, "batched timeline kernel diverged from the per-sim oracle"
    _append_bench_entry(entry)
    _record_calibration(entry)


def _system_batched_bench(quick: bool):
    """fig10-scale joint system sweep: the looped per-config reference (one
    ``simulate_system`` scan per design point) vs ``sweep_system``'s single
    batched scan vs the batched 3-structure Pallas kernel
    (``repro.kernels.system_sim``), appended to BENCH_sweep.json.

    The config matrix is the fig10 design grid (4K/2M pages x partition
    counts x cache/accel-TLB presence — a heterogeneous 9-point batch, every
    envelope-padding axis exercised).  On this CPU container the Pallas path
    runs under the interpreter (the ``mode`` field records which); all three
    paths must stay bit-identical per config.
    """
    from repro.core import traces
    from repro.core.sparta import TLBConfig
    from repro.core.sweep import sweep_system
    from repro.core.tlbsim import SystemSimConfig, simulate_system

    n_acc = 10_000 if quick else 60_000
    tr = traces.generate("bst_external", n_ops=2 * n_acc // 5, max_accesses=n_acc)
    cache = TLBConfig(entries=256, ways=4)
    accel = TLBConfig(entries=128, ways=4)
    mem = TLBConfig(entries=128, ways=4)
    cfgs = [
        SystemSimConfig(cache=cache, accel_tlb=accel, mem_tlb=mem,
                        num_partitions=1, page_shift=12),
        SystemSimConfig(cache=cache, accel_tlb=accel, mem_tlb=mem,
                        num_partitions=1, page_shift=21),
        SystemSimConfig(cache=cache, accel_tlb=None, mem_tlb=mem,
                        num_partitions=8, page_shift=12),
        SystemSimConfig(cache=cache, accel_tlb=None, mem_tlb=mem,
                        num_partitions=8, page_shift=21),
        SystemSimConfig(cache=cache, accel_tlb=None, mem_tlb=mem,
                        num_partitions=32, page_shift=12),
        SystemSimConfig(cache=cache, accel_tlb=None, mem_tlb=mem,
                        num_partitions=32, page_shift=21),
        SystemSimConfig(cache=cache, accel_tlb=None, mem_tlb=mem,
                        num_partitions=128, page_shift=21),
        SystemSimConfig(cache=None, accel_tlb=None, mem_tlb=mem,
                        num_partitions=32, page_shift=12),
        SystemSimConfig(cache=cache, accel_tlb=TLBConfig(entries=8, ways=4),
                        mem_tlb=mem, num_partitions=8, page_shift=12,
                        accel_probe_on_miss_only=False),
    ]

    def timed(fn, label):
        m = measure(fn, reps=ENGINE_REPS, label=label)
        return m, m.result

    pallas_mode = "pallas" if jax.default_backend() == "tpu" else "pallas_interpret"
    m_loop, ref = timed(lambda: [simulate_system(tr.lines, c) for c in cfgs],
                        "system_batched:looped")
    m_bat, bat = timed(lambda: sweep_system(tr.lines, cfgs, kernel_mode="reference"),
                       "system_batched:reference")
    m_pal, pal = timed(lambda: sweep_system(tr.lines, cfgs, kernel_mode=pallas_mode),
                       f"system_batched:{pallas_mode}")
    t_loop, t_bat, t_pal = m_loop.best_s, m_bat.best_s, m_pal.best_s

    def identical(bev):
        return bool(all(
            np.array_equal(getattr(bev, k)[i], getattr(ev, k))
            for i, ev in enumerate(ref)
            for k in ("cache_hit", "accel_tlb_hit", "mem_tlb_hit")))

    bit_identical = identical(bat)
    pallas_identical = identical(pal)
    entry = {
        "written_at": time.strftime("%Y-%m-%d %H:%M:%S"),
        "bench": "system_batched",
        "backend": jax.default_backend(),
        "mode": pallas_mode,
        "quick": quick,
        "n_configs": len(cfgs),
        "n_accesses": int(tr.num_accesses),
        "t_looped_s": round(t_loop, 3),
        "t_batched_s": round(t_bat, 3),
        "t_pallas_s": round(t_pal, 3),
        "speedup": round(t_loop / t_bat, 2),
        "bit_identical": bit_identical and pallas_identical,
        "reps": ENGINE_REPS,
        "spread_frac": {"t_looped_s": round(m_loop.spread_frac, 3),
                        "t_batched_s": round(m_bat.spread_frac, 3),
                        "t_pallas_s": round(m_pal.spread_frac, 3)},
    }
    print_csv(
        f"Batched system sweep ({len(cfgs)} configs x {tr.num_accesses} accesses)",
        ["backend", "seconds", "vs_looped"],
        [["looped reference (per-config scans)", t_loop, 1.0],
         ["sweep_system (batched scan)", t_bat, t_loop / t_bat],
         [f"sweep_system ({pallas_mode})", t_pal, t_loop / t_pal]],
    )
    print(f"  batched scan bit-identical to looped oracle: {bit_identical}")
    print(f"  batched {pallas_mode} bit-identical to looped oracle: {pallas_identical}")
    # Assert BEFORE recording (see _sweep_bench).
    assert bit_identical, "sweep_system diverged from the per-config oracle"
    assert pallas_identical, "batched system kernel diverged from the per-config oracle"
    _append_bench_entry(entry)
    _record_calibration(entry)


# Every engine the bench suite gates: ``--check`` fails when a bench has no
# recorded row at all, so a silently-skipped engine (e.g. the system_batched
# row added with the 3-structure kernel) cannot pass CI unverified.
REQUIRED_BENCHES = ("sweep", "timeline", "timeline_batched", "system_batched")


def check_bench_history(path: pathlib.Path = BENCH_SWEEP_PATH,
                        refs_path: pathlib.Path = None) -> dict:
    """The CI perf gate over the recorded BENCH_sweep.json history.

    Fails on (1) a corrupt/unparseable history file, (2) any recorded row
    reporting a bit-identity violation — a perf number from a diverging
    backend is not a result — (3) a required bench with no recorded row,
    and (4) any recorded wall time outside its references.json tolerance
    band (the ReFrame-style regression gate, ``benchmarks/perfcheck.py``).
    Returns the perfcheck machine-readable summary (``{}`` when no history
    file exists yet).
    """
    from benchmarks import perfcheck

    if not path.exists():
        return {}
    hist = perfcheck.load_history(path).get("history", [])
    bad = [
        (i, e) for i, e in enumerate(hist)
        if any(k.endswith("bit_identical") and e[k] is False for k in e)
    ]
    if bad:
        lines = "\n".join(
            f"  history[{i}]: bench={e.get('bench', 'sweep')!r} "
            f"written_at={e.get('written_at')!r}" for i, e in bad)
        raise SystemExit(
            f"BENCH_sweep.json records {len(bad)} non-bit-identical row(s):\n{lines}")
    seen = {e.get("bench", "sweep") for e in hist}
    missing = [b for b in REQUIRED_BENCHES if b not in seen]
    if missing:
        raise SystemExit(
            f"BENCH_sweep.json has no recorded row for bench(es) {missing}; "
            f"run `python -m benchmarks.kernel_bench` so every engine's "
            f"bit_identical field is on record")
    print(f"  BENCH_sweep.json: all {len(hist)} recorded rows bit-identical "
          f"({', '.join(REQUIRED_BENCHES)} covered)")
    return perfcheck.check_perf_history(
        path, refs_path or perfcheck.REFS_PATH, history=hist)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the engine benches "
                         "into DIR (one StepTraceAnnotation per bench)")
    ap.add_argument("--check", action="store_true",
                    help="verify BENCH_sweep.json: bit-identity, required-"
                         "bench coverage, and the references.json "
                         "tolerance-band perf gate")
    ap.add_argument("--update-refs", action="store_true",
                    help="re-baseline benchmarks/references.json from the "
                         "latest recorded row per (bench, backend, mode, "
                         "quick) key, then run the gate")
    args = ap.parse_args()
    if args.update_refs:
        from benchmarks import perfcheck

        hist = perfcheck.load_history(BENCH_SWEEP_PATH).get("history", [])
        perfcheck.update_references(hist)
        check_bench_history()
    elif args.check:
        check_bench_history()
    else:
        run(quick=args.quick, profile_dir=args.profile)
