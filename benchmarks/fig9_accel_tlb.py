"""Fig 9: accelerator-side TLB capacity under SPARTA with physical caches.

SPARTA-8, 16 KB 4-way physical cache per accelerator, accel-side TLB swept
1..128 entries; the rightmost point is SPARTA with a virtual cache and NO
accelerator-side translation hardware.  Baseline: conventional translation
with a 128-entry accel TLB and perfect MMU caches (virtual cache).

Claims (C7): ~8 accel-TLB entries suffice to beat the 128-entry baseline;
capacity beyond that gives diminishing returns."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (Claim, W4, crash_safety, print_csv, run_config,
                               save_fig, telemetry_stamp, trace, with_runlog)
from repro.core import cpi
from repro.core.scheduler import run_sweep_system
from repro.core.sparta import SystemLatencies, TLBConfig
from repro.core.tlbsim import SystemSimConfig

ENTRIES = (1, 2, 4, 8, 16, 32, 64, 128)
P = 8
MEM_TLB = TLBConfig(entries=128, ways=4)
CACHE = TLBConfig(entries=256, ways=4)  # 16KB / 64B lines


@with_runlog("fig9")
def run(quick: bool = False, kernel_mode: str = "auto",
        resume: bool = False, chunk_accesses=None, sched=None):
    n_ops = 8_000 if quick else 25_000
    lat = SystemLatencies()
    rc = run_config("fig9", resume=resume, chunk_accesses=chunk_accesses)
    metas = {}
    results, rows = {}, []
    for w in W4:
        tr = trace(w, n_ops=n_ops)
        ipa = tr.instr_per_access
        # Baseline (conventional, virtual cache + 128-entry accel TLB), the
        # accel-TLB capacity sweep, and the virtual-cache/no-TLB point all
        # ride ONE batched pass over the trace.
        cfgs = [SystemSimConfig(
            cache=CACHE, accel_tlb=TLBConfig(entries=128, ways=4),
            mem_tlb=MEM_TLB, num_partitions=1, accel_probe_on_miss_only=True)]
        cfgs += [SystemSimConfig(
            cache=CACHE, accel_tlb=TLBConfig(entries=e, ways=4),
            mem_tlb=MEM_TLB, num_partitions=P, accel_probe_on_miss_only=False)
            for e in ENTRIES]
        cfgs.append(SystemSimConfig(
            cache=CACHE, accel_tlb=None, mem_tlb=MEM_TLB, num_partitions=P))
        evs, metas[f"system-{w}"] = run_sweep_system(
            tr.lines, cfgs, kernel_mode=kernel_mode, run=rc, name=f"system-{w}",
            sched=sched)

        base = cpi.evaluate_design("conventional", evs[0], lat, instr_per_access=ipa)
        line = []
        for i_e, _ in enumerate(ENTRIES):
            sp = cpi.evaluate_design("sparta", evs[1 + i_e], lat, instr_per_access=ipa,
                                     physical_cache=True)
            line.append(float(sp.speedup_over(base)))
        # Virtual cache, no accel TLB.
        sp_v = cpi.evaluate_design("sparta", evs[len(cfgs) - 1], lat, instr_per_access=ipa)
        line.append(float(sp_v.speedup_over(base)))
        results[w] = line
        rows.append([w] + line)

    idx8 = ENTRIES.index(8)
    wins8 = sum(1 for w in W4 if results[w][idx8] >= 1.0)
    c7a = Claim("C7a", "SPARTA with 8 accel-TLB entries beats 128-entry baseline (workloads won)",
                float(wins8), (3, 4), "/4")
    gains = [results[w][-2] - results[w][idx8] for w in W4]  # 128 vs 8 entries
    c7b = Claim("C7b", "beyond 8 entries: diminishing returns (mean extra speedup 8->128)",
                float(np.mean(gains)), (-0.2, 0.25), "x")
    print_csv("Fig9 speedup vs accel TLB entries",
              ["workload"] + [str(e) for e in ENTRIES] + ["virt$ no TLB"], rows)
    print(c7a); print(c7b)
    save_fig("fig9", {"entries": ENTRIES, "results": results,
                      "claims": [c7a.row(), c7b.row()],
                      "_crash_safety": crash_safety(metas),
                      "_telemetry": telemetry_stamp(metas)})
    return [c7a, c7b]
